"""Repo-wide pytest configuration.

``--regen-goldens`` rewrites the backend golden files under
``tests/goldens/`` from the current emitted output instead of comparing
against them (used by ``tests/core/test_backends.py``).
"""

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--regen-goldens", action="store_true", default=False,
        help="regenerate tests/goldens/* from current backend output "
             "instead of comparing",
    )


@pytest.fixture
def regen_goldens(request):
    return request.config.getoption("--regen-goldens")
