"""Multi-device numerics: expert-parallel MoE vs the single-shard reference,
int8-compressed cross-pod gradient all-reduce, and sequence-parallel rules —
each on 8 in-process host devices (subprocess: jax locks the device count at
first init, so these cases cannot share the main pytest process)."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# Partial-manual shard_map over a sub-mesh (manual "pod", auto data/model)
# needs the modern jax.shard_map + XLA: the legacy SPMD partitioner crashes
# on manual subgroups (IsManualSubgroup check) / lacks PartitionId support.
requires_partial_manual = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map needs modern jax.shard_map/XLA",
)


def _run(code: str) -> str:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


MOE_EP_TEMPLATE = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh as _compat_mesh
from repro.configs.registry import get_smoke_config
from repro.models import mlp
from repro.parallel.api import use_rules
from repro.parallel.rules import rules_for

cfg = get_smoke_config({arch!r})
{cfg_patch}
mesh = _compat_mesh((2, 2, 2), ("pod", "data", "model"))
rules = rules_for(cfg, mesh, "train", batch=8, moe_ep=True)
p = mlp.init_moe(jax.random.key(0), cfg)
x = jax.random.normal(jax.random.key(1), (8, 4, cfg.d_model), jnp.float32)

y_ref, aux_ref = jax.jit(lambda p, x: mlp.moe_forward_local(p, x, cfg))(p, x)
with use_rules(rules, mesh), mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: mlp.moe_forward(p, x, cfg))(p, x)
    assert rules.rules.get("_moe_ep"), "ep flag not set"

np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                           np.asarray(y_ref, np.float32), rtol=2e-4, atol=2e-4)
# aux under EP is the mean of per-dp-shard load-balance stats (GShard-style);
# it is a different (equally valid) estimator of the global statistic —
# assert same scale, not equality
assert 0.5 * float(aux_ref) < float(aux_ep) < 2.0 * float(aux_ref), (aux_ep, aux_ref)

# gradients agree too
def loss_ref(p, x):
    y, aux = mlp.moe_forward_local(p, x, cfg)
    return (y.astype(jnp.float32) ** 2).mean() + aux

def loss_ep(p, x):
    y, aux = mlp.moe_forward(p, x, cfg)
    return (y.astype(jnp.float32) ** 2).mean() + aux

g_ref = jax.jit(jax.grad(loss_ref))(p, x)
with use_rules(rules, mesh), mesh:
    g_ep = jax.jit(jax.grad(loss_ep))(p, x)
for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_ep)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-3, atol=2e-4)
print("MOE_EP_OK")
"""


def test_moe_ep_expert_sharded_matches_local():
    """E=8 divides model=2: expert-partitioned path.  Drop-free capacity so
    global and per-shard dispatch keep identical token sets (capacity
    dropping differs by construction — local queue vs global queue)."""
    patch = ("from repro.configs.base import MoECfg\n"
             "cfg = cfg.scaled(moe=MoECfg(n_routed=8, n_shared=2, top_k=2, "
             "d_ff_expert=64, d_ff_shared=128, capacity_factor=8.0))")
    out = _run(MOE_EP_TEMPLATE.format(arch="deepseek-v2-lite-16b", cfg_patch=patch))
    assert "MOE_EP_OK" in out


def test_moe_ep_ff_sharded_matches_local():
    """E=3 does not divide model=2: TP-inside-expert path."""
    patch = ("from repro.configs.base import MoECfg\n"
             "cfg = cfg.scaled(moe=MoECfg(n_routed=3, n_shared=2, top_k=2, "
             "d_ff_expert=64, d_ff_shared=128, capacity_factor=8.0))")
    out = _run(MOE_EP_TEMPLATE.format(arch="qwen2-moe-a2.7b", cfg_patch=patch))
    assert "MOE_EP_OK" in out


@requires_partial_manual
def test_compressed_pod_grads_close_to_exact():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh as _compat_mesh
    from repro.parallel.compression import pod_grads_compressed, compressed_psum, quantize_int8

    mesh = _compat_mesh((2, 2, 2), ("pod", "data", "model"))
    w = jax.random.normal(jax.random.key(0), (64, 64)) * 0.1
    x = jax.random.normal(jax.random.key(1), (16, 64))

    def grad_fn(w, xb):
        def loss(w):
            return ((xb @ w) ** 2).mean()
        l, g = jax.value_and_grad(loss)(w)
        return l, {"l": l}, g

    with mesh:
        loss_c, metrics, g_c = jax.jit(
            lambda w, x: pod_grads_compressed(grad_fn, w, x, mesh))(w, x)
    # exact reference: mean of per-pod grads
    _, _, g0 = grad_fn(w, x[:8])
    _, _, g1 = grad_fn(w, x[8:])
    g_ref = (g0 + g1) / 2
    err = np.abs(np.asarray(g_c) - np.asarray(g_ref)).max()
    scale = np.abs(np.asarray(g_ref)).max()
    assert err <= scale * 2 / 127, (err, scale)  # int8 quantization bound
    print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_seq_shard_fallback_rules():
    """40 heads on a 16-way model axis cannot head-shard: the fallback rules
    must shard the sequence instead (and only then)."""
    out = _run("""
    import jax
    from repro.launch.mesh import make_mesh as _compat_mesh
    from repro.configs.registry import get_config
    from repro.parallel.rules import rules_for

    mesh = _compat_mesh((2, 2, 2), ("pod", "data", "model"))
    # qwen2.5-14b: 40 heads, model=2 divides -> no fallback even if enabled
    r = rules_for(get_config("qwen2.5-14b"), mesh, "prefill",
                  seq_shard_fallback=True)
    assert r.rules["heads"] == "model" and r.rules["seq"] is None
    # smollm: 15 heads, model=2 does not divide -> seq fallback kicks in
    r2 = rules_for(get_config("smollm-360m"), mesh, "prefill",
                   seq_shard_fallback=True)
    assert r2.rules["heads"] is None and r2.rules["seq"] == "model"
    print("RULES_OK")
    """)
    assert "RULES_OK" in out


@requires_partial_manual
def test_sharded_flash_decode_matches_reference():
    """The shard_map partial-softmax decode (kv cache sharded over model)
    must equal the single-device decode step exactly."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_mesh as _compat_mesh
    from repro.configs.registry import get_smoke_config
    from repro.models import attention, transformer
    from repro.parallel.api import use_rules
    from repro.parallel.rules import rules_for

    cfg = get_smoke_config("tinyllama-1.1b")
    mesh = _compat_mesh((2, 2, 2), ("pod", "data", "model"))
    p = attention.init_attn(jax.random.key(0), cfg)
    B, L = 4, 32
    cache = attention.init_attn_cache(B, L, cfg)
    # pre-fill the cache with random history
    ks = jax.random.split(jax.random.key(1), 3)
    cache = {"k": jax.random.normal(ks[0], cache["k"].shape, jnp.float32),
             "v": jax.random.normal(ks[1], cache["v"].shape, jnp.float32)}
    x1 = jax.random.normal(ks[2], (B, 1, cfg.d_model), jnp.float32)
    idx = jnp.array([5, 31, 0, 17], jnp.int32)

    ref, ref_cache = jax.jit(lambda p, x, c, i: attention.attn_decode_step(
        p, x, c, i, cfg))(p, x1, cache, idx)

    rules = rules_for(cfg, mesh, "decode", batch=B, flash_decode=True)
    assert rules.rules.get("_flash_decode")
    with use_rules(rules, mesh), mesh:
        got, got_cache = jax.jit(lambda p, x, c, i: attention.attn_decode_step(
            p, x, c, i, cfg))(p, x1, cache, idx)

    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_cache["k"], np.float32),
                               np.asarray(ref_cache["k"], np.float32))
    print("FLASH_DECODE_OK")
    """)
    assert "FLASH_DECODE_OK" in out
