"""Per-kernel interpret-mode validation against the pure-jnp oracles in
``repro.kernels.ref`` — shape/dtype sweeps per the assignment contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# -- matmul -------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (100, 130, 70), (8, 8, 8)])
def test_matmul(shape, dtype):
    M, K, N = shape
    k1, k2 = jax.random.split(jax.random.key(0))
    x = jax.random.normal(k1, (M, K), jnp.float32).astype(dtype)
    y = jax.random.normal(k2, (K, N), jnp.float32).astype(dtype)
    out = ops.matmul(x, y, bm=128, bn=128, bk=128)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_matmul_schedule_checker():
    from repro.kernels.matmul import check_schedule
    assert check_schedule(256, 256, 256, 128, 128, 128) == []
    errs = check_schedule(256, 256, 256, 100, 128, 130)
    assert errs and any("aligned" in e or "tile" in e for e in errs)


# -- flash attention ----------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Sq,Sk,H,KvH,D,window", [
    (128, 128, 4, 4, 64, None),
    (128, 128, 4, 2, 64, None),     # GQA
    (256, 256, 2, 1, 32, 64),       # sliding window + MQA
    (64, 64, 2, 2, 128, None),
])
def test_flash_attention(Sq, Sk, H, KvH, D, window, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, KvH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, KvH, D), jnp.float32).astype(dtype)
    out = ops.mha(q, k, v, causal=True, window=window, bq=64, bk=64)
    # oracle expects (B,H,S,D) with KV repeated to H
    rep = H // KvH
    kk = jnp.repeat(jnp.swapaxes(k, 1, 2), rep, axis=1)
    vv = jnp.repeat(jnp.swapaxes(v, 1, 2), rep, axis=1)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2), kk, vv,
                                   causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(jnp.swapaxes(want, 1, 2), np.float32),
                               **_tol(dtype))


def test_flash_vs_model_oracle():
    """Kernel agrees with the model-layer chunked flash oracle."""
    from repro.models.attention import flash_attention as model_flash
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, D = 1, 256, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = ops.mha(q, k, v, bq=64, bk=64)
    want = model_flash(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# -- decode attention ---------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,H,KvH,D,length", [
    (512, 4, 4, 64, 200),
    (1024, 4, 2, 64, 1024),
    (256, 2, 1, 128, 1),
])
def test_decode_attention(L, H, KvH, D, length, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, L, KvH, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, L, KvH, D), jnp.float32).astype(dtype)
    out = ops.decode(q, k, v, length, bk=128)
    rep = H // KvH
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    want = ref.decode_attention_ref(q, kk, vv, length)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_partial_merge_equals_full():
    """Sequence-sharded flash-decode: merging per-shard partials reproduces
    the unsharded result (the production decode collective schedule)."""
    ks = jax.random.split(jax.random.key(4), 3)
    B, L, H, D, S = 2, 512, 4, 64, 4
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, L, H, D))
    v = jax.random.normal(ks[2], (B, L, H, D))
    full = ops.decode(q, k, v, L, bk=128)
    shard = L // S
    outs, ms, ls = [], [], []
    for s in range(S):
        o, m, l = ops.decode_partial(q, k[:, s * shard:(s + 1) * shard],
                                     v[:, s * shard:(s + 1) * shard],
                                     shard, bk=128, interpret=True)
        outs.append(o)
        ms.append(m)
        ls.append(l)
    merged = ops.merge_partials(jnp.stack(outs), jnp.stack(ms), jnp.stack(ls))
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


# -- ssd ----------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("S,H,P,N,chunk", [
    (64, 2, 16, 16, 16),
    (100, 1, 32, 16, 32),     # ragged tail
    (128, 3, 8, 8, 128),      # single chunk
])
def test_ssd_scan(S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(5), 4)
    B = 2
    xdt = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dA = -jax.random.uniform(ks[1], (B, S, H), dtype, 0.01, 0.5)
    Bc = jax.random.normal(ks[2], (B, S, N), dtype)
    Cc = jax.random.normal(ks[3], (B, S, N), dtype)
    y = ops.ssd_scan(xdt, dA, Bc, Cc, chunk=chunk)
    want, _ = ref.ssd_scan_ref(xdt, dA, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_core():
    """Kernel output equals the model-layer chunked SSD (same recurrence)."""
    from repro.models.ssd import ssd_core_chunked
    ks = jax.random.split(jax.random.key(6), 4)
    B, S, H, P, N = 1, 64, 2, 16, 16
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(jax.random.key(7), (B, S, N))
    D = jnp.zeros((H,))
    want, _ = ssd_core_chunked(xh, dt, A, Bc, Cc, D, chunk=16)
    # kernel takes dt-weighted inputs and per-step dA
    y = ops.ssd_scan(xh * dt[..., None], dt * A[None, None, :], Bc, Cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# -- rglru --------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("S,D,bs,bd", [
    (64, 32, 16, 32),
    (100, 48, 32, 16),    # ragged both dims
    (128, 8, 128, 8),     # single block
])
def test_rglru_scan(S, D, bs, bd, dtype):
    ks = jax.random.split(jax.random.key(8), 2)
    B = 2
    a = jax.random.uniform(ks[0], (B, S, D), dtype, 0.5, 0.99)
    b = jax.random.normal(ks[1], (B, S, D), dtype)
    h = ops.rglru_scan(a, b, bs=bs, bd=bd)
    want, _ = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_rglru_kernel_matches_model_scan():
    from repro.models.rglru import rglru_forward  # noqa: F401 (import check)
    ks = jax.random.split(jax.random.key(9), 2)
    B, S, D = 1, 64, 16
    a = jax.random.uniform(ks[0], (B, S, D), jnp.float32, 0.8, 0.999)
    b = jax.random.normal(ks[1], (B, S, D))

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, want = jax.lax.associative_scan(comb, (a, b), axis=1)
    h = ops.rglru_scan(a, b, bs=16, bd=16)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- mha padded-KV masking (regression: padded keys used to attend) -----------


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Sq,Sk", [
    (80, 48),    # Sq != Sk, both ragged: causal admits k_pos in [48, 64)
    (48, 48),    # ragged keys only
])
def test_mha_padded_kv_is_masked(causal, Sq, Sk):
    """Keys appended by block padding must never attend.  The causal test
    alone admits padded key positions whenever q_pos >= Sk (and non-causal
    rows always would), so ``ops.mha`` must pass the true key length through
    to the kernel's position mask."""
    ks = jax.random.split(jax.random.key(11), 3)
    B, H, D = 1, 2, 32
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, H, D))
    v = jax.random.normal(ks[2], (B, Sk, H, D))
    out = ops.mha(q, k, v, causal=causal, bq=32, bk=32)
    want = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2),
                                   jnp.swapaxes(k, 1, 2),
                                   jnp.swapaxes(v, 1, 2), causal=causal)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(want, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_contract_errors_survive_optimization():
    """The shape contracts are ValueErrors, not asserts: they must hold even
    under ``python -O`` (which strips assert statements)."""
    from repro.kernels import flash_attention as fa
    q = jnp.zeros((1, 3, 64, 16))   # H=3
    kv = jnp.zeros((1, 2, 64, 16))  # KvH=2 does not divide H
    with pytest.raises(ValueError, match="multiple of KvH"):
        fa.flash_attention(q, kv, kv, interpret=True)
    q = jnp.zeros((1, 2, 48, 16))   # Sq=48 does not tile by bq=32
    kv = jnp.zeros((1, 2, 64, 16))
    with pytest.raises(ValueError, match="must tile"):
        fa.flash_attention(q, kv, kv, bq=32, bk=32, interpret=True)
    q = jnp.zeros((1, 2, 64, 16))
    with pytest.raises(ValueError, match="kv_len"):
        fa.flash_attention(q, kv, kv, kv_len=65, interpret=True)
