"""Use-def chain maintenance invariants (the core of the compiler-infra PR):
operand mutation, op erasure and RAUW must keep ``Value`` use lists exact."""

import pytest

from repro.core import ir
from repro.core.builder import Builder


def _chains_consistent(module: ir.Module) -> list[str]:
    """Recomputes uses from scratch and diffs against the maintained chains."""
    truth: dict[int, dict] = {}
    for op in module.walk():
        for v in op.operands:
            d = truth.setdefault(v.id, {})
            d[id(op)] = d.get(id(op), 0) + 1
    errors = []
    seen_vals = set()
    for op in module.walk():
        for v in list(op.operands) + list(op.results):
            if v.id in seen_vals:
                continue
            seen_vals.add(v.id)
            maintained = {id(o): c for o, c in v._use_ops.items()}
            if maintained != truth.get(v.id, {}):
                errors.append(f"%{v.name}: maintained={maintained} truth={truth.get(v.id)}")
    return errors


def test_construction_registers_uses():
    c1 = ir.constant(1)
    c2 = ir.constant(2)
    op = ir.arith("add", [c1.result, c2.result])
    assert c1.result.users() == [op]
    assert c1.result.num_uses == 1
    assert [u.op for u in c1.result.uses] == [op]
    assert [u.index for u in c1.result.uses] == [0]
    assert c2.result.uses[0].index == 1


def test_set_operand_moves_use():
    c1, c2, c3 = ir.constant(1), ir.constant(2), ir.constant(3)
    op = ir.arith("add", [c1.result, c2.result])
    op.set_operand(0, c3.result)
    assert not c1.result.has_uses()
    assert c3.result.users() == [op]
    assert op.operands[0] is c3.result


def test_duplicate_operand_multiplicity():
    c = ir.constant(7)
    op = ir.arith("add", [c.result, c.result])
    assert c.result.num_uses == 2
    assert len(c.result.uses) == 2
    op.set_operand(1, ir.constant(8).result)
    assert c.result.num_uses == 1


def test_slice_assignment_and_list_ops_update_chains():
    c1, c2, c3 = ir.constant(1), ir.constant(2), ir.constant(3)
    op = ir.arith("add", [c1.result, c2.result])
    op.operands[:] = [c3.result, c3.result]
    assert not c1.result.has_uses() and not c2.result.has_uses()
    assert c3.result.num_uses == 2
    op.operands.append(c1.result)
    assert c1.result.num_uses == 1
    op.operands.pop()
    assert not c1.result.has_uses()


def test_erase_drops_uses_recursively():
    b = Builder(ir.Module("m"))
    w = ir.MemrefType((8,), ir.i32, ir.PORT_W)
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        n = b.const(5)
        with b.for_(0, n, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            i1 = b.delay(l.iv, 1, at=l.time)
            b.write(0, O, [i1], at=l.time + 1)
        b.ret()
    func = b.module.get("f")
    loop = next(op for op in func.body.walk() if isinstance(op, ir.ForOp))
    n_val = loop.ub
    assert loop in n_val.users()
    loop.erase()
    # the loop's own use of %n and every use held by its body are gone
    assert not n_val.has_uses()
    assert loop.parent_region is None and loop.is_erased
    assert loop not in func.body.ops


def test_deprecated_region_scoped_shims_removed():
    """The deprecated region-scoped ``replace_all_uses`` / ``op_uses`` shims
    are gone; only the private legacy-sweep baseline helper remains."""
    assert not hasattr(ir, "replace_all_uses")
    assert not hasattr(ir, "op_uses")
    assert callable(ir._replace_all_uses_in_region)  # legacy-sweep baseline


def test_rauw_is_global_across_sibling_scopes():
    """Region-scoped replacement (the legacy-sweep baseline) silently loses
    uses in sibling scopes; Value.replace_all_uses_with is global."""
    b = Builder(ir.Module("m"))
    r = ir.MemrefType((8,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((8,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        with b.for_(0, 4, 1, at=f.t + 1) as l1:
            b.yield_(at=l1.time + 1)
            b.write(v, O, [l1.iv], at=l1.time + 1)
        with b.for_(0, 4, 1, at=l1.end + 1) as l2:
            b.yield_(at=l2.time + 1)
            b.write(v, O, [l2.iv], at=l2.time + 1)
        b.ret()
    func = b.module.get("f")
    loops = [op for op in func.body.ops if isinstance(op, ir.ForOp)]
    assert len(loops) == 2
    v = next(op for op in func.body.ops if op.opname == "mem_read").result
    replacement = ir.Value(v.type, "fresh")

    # scoped baseline, limited to the first loop's region: loses the sibling use
    n_old = ir._replace_all_uses_in_region(loops[0].region(0), v, replacement)
    assert n_old == 1
    assert v.has_uses(), "old helper left the sibling-scope use dangling"
    leftover = [u.op.opname for u in v.uses]
    assert "mem_write" in leftover  # the second loop still reads the old value

    # undo, then the new global API catches every use at once
    replacement.replace_all_uses_with(v)
    n_new = v.replace_all_uses_with(replacement)
    assert n_new == 2
    assert not v.has_uses()
    assert replacement.num_uses == 2


def test_chains_consistent_after_full_pipeline():
    from repro.core.gallery import GALLERY
    from repro.core.passes import PassManager, DEFAULT_PIPELINE_SPEC

    for name in ("stencil1d", "conv2d", "gemm"):
        m, _ = GALLERY[name].build()
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
        assert _chains_consistent(m) == []


def test_chains_consistent_after_codegen_pipeline():
    from repro.core.gallery import GALLERY
    from repro.core.passes import PassManager

    m, _ = GALLERY["conv2d"].build()
    PassManager.from_spec("inline,unroll", fixpoint=False).run(m)
    assert _chains_consistent(m) == []


def test_deepcopy_preserves_chains():
    from copy import deepcopy

    from repro.core.gallery import GALLERY

    m, _ = GALLERY["stencil1d"].build()
    m2 = deepcopy(m)
    assert _chains_consistent(m2) == []
    # and the copy's chains are disjoint from the original's
    op = next(iter(m2.walk()))
    for v in op.operands:
        assert all(u in set(m2.walk()) for u in v.users())
