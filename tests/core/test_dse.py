"""Tests of the design-space explorer (``core.hls.dse``): structural
fingerprints, Pareto-front computation, the bank-merging knob, the
``explore_design`` sweep (serial and pooled, with serial fallback when no
process pool is available), and adversarial per-function codegen cache-key
collision checks."""

import numpy as np
import pytest

from repro.core import ir
from repro.core.gallery import GALLERY
from repro.core.hls import (DSEConfig, design_space, erase_schedule,
                            explore_design, hls_schedule, merge_local_banks,
                            pareto_front)
from repro.core.hls.dse import (DSEPoint, dominates, fingerprint_func,
                                fingerprint_module, has_mergeable_banks)
from repro.core.lower import simulate


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------


def test_fingerprint_stable_across_builds():
    """Two separate builds allocate different global value ids; the
    positional namer must hash them identically."""
    m1, _ = GALLERY["gemm"].build()
    m2, _ = GALLERY["gemm"].build()
    assert fingerprint_module(erase_schedule(m1)) == \
        fingerprint_module(erase_schedule(m2))


def test_fingerprint_differs_on_structural_change():
    m1, _ = GALLERY["gemm"].build(8)
    m2, _ = GALLERY["gemm"].build(4)
    assert fingerprint_module(erase_schedule(m1)) != \
        fingerprint_module(erase_schedule(m2))


def test_fingerprint_extra_distinguishes_options():
    m, entry = GALLERY["transpose"].build()
    f = erase_schedule(m).get(entry)
    assert fingerprint_func(f, extra=("a",)) != fingerprint_func(f, extra=("b",))


# ---------------------------------------------------------------------------
# Pareto front
# ---------------------------------------------------------------------------


def _pt(lat, lut, ff, verified=True, error=None):
    return DSEPoint(config=DSEConfig(), latency_cycles=int(lat),
                    latency_ns=float(lat), lut=lut, ff=ff,
                    verified=verified, error=error)


def test_dominates():
    assert dominates((1.0, 10, 10), (2.0, 10, 10))
    assert not dominates((1.0, 10, 10), (1.0, 10, 10))   # equal: no
    assert not dominates((1.0, 20, 10), (2.0, 10, 10))   # tradeoff: no


def test_pareto_front_filters_and_sorts():
    pts = [
        _pt(100, 50, 50),                    # dominated by the next point
        _pt(100, 40, 40),
        _pt(200, 10, 10),                    # tradeoff: slower but smaller
        _pt(50, 90, 90),                     # tradeoff: faster but bigger
        _pt(10, 1, 1, verified=False),       # would win, but unverified
        _pt(10, 1, 1, error="boom"),         # would win, but errored
        _pt(200, 10, 10),                    # duplicate objective vector
    ]
    front = pareto_front(pts)
    assert [p.objectives() for p in front] == [
        (50.0, 90, 90, 0), (100.0, 40, 40, 0), (200.0, 10, 10, 0)]


def test_pareto_front_keeps_dsp_tradeoff():
    """Same latency/LUT/FF but fewer DSPs (a time-multiplexed candidate)
    must survive as a distinct frontier point — DSP is a real objective."""
    a = _pt(100, 40, 40)
    a.dsp = 48
    b = _pt(120, 40, 40)          # slower ...
    b.dsp = 3                     # ... but 16x fewer multipliers
    front = pareto_front([a, b])
    assert len(front) == 2
    c = _pt(100, 40, 40)
    c.dsp = 3                     # dominates a outright (equal lat, less dsp)
    assert pareto_front([a, c]) == [c]


def test_design_space_dedups_min_ii_when_sequential():
    space = design_space(pipeline=(True, False), min_ii=(1, 2, 4))
    seq = [c for c in space if not c.pipeline]
    assert len(seq) == 1 and seq[0].min_ii == 1   # min_ii collapsed
    assert len([c for c in space if c.pipeline]) == 3
    assert space == design_space(pipeline=(True, False), min_ii=(1, 2, 4))


# ---------------------------------------------------------------------------
# Bank merging
# ---------------------------------------------------------------------------


def test_merge_local_banks_retypes_and_stays_correct():
    gal = GALLERY["gemm"]
    m, entry = gal.build(4)
    um = erase_schedule(m)
    assert has_mergeable_banks(um)
    n = merge_local_banks(um)
    assert n > 0
    for f in um.funcs.values():
        for op in f.body.walk():
            if op.opname == "alloc":
                for r in op.results:
                    mt = r.type
                    if isinstance(mt, ir.MemrefType) and mt.kind in (
                            ir.KIND_LUTRAM, ir.KIND_BRAM):
                        assert not mt.distributed   # fully packed now
    # the serialized-bank design still schedules and computes correctly
    hls_schedule(um)
    from repro.core.passes import run_pipeline

    run_pipeline(um)
    ins = gal.make_inputs(4)
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], gal.oracle(*ins[:2]))


# ---------------------------------------------------------------------------
# explore_design
# ---------------------------------------------------------------------------


def _gemm_setup(n=4):
    gal = GALLERY["gemm"]
    m, entry = gal.build(n)
    ins = gal.make_inputs(n)
    return m, entry, ins, gal.oracle(*ins[:2])


def test_explore_design_serial_smoke():
    m, entry, ins, exp = _gemm_setup()
    space = design_space(clock_ns=(10.0, 5.0), merge_banks=(False, True))
    res = explore_design(m, space, entry=entry, inputs=ins, expected=exp)
    assert len(res.points) == len(space)
    assert all(p.verified for p in res.points), \
        [p.error for p in res.points if not p.verified]
    assert res.front, "empty Pareto frontier"
    assert all(p.verified for p in res.front)
    # frontier points are mutually non-dominated
    for p in res.front:
        assert not any(dominates(q.objectives(), p.objectives())
                       for q in res.front if q is not p)


def test_explore_design_pool_matches_serial():
    m, entry, ins, exp = _gemm_setup()
    space = design_space(clock_ns=(10.0, 5.0))
    r1 = explore_design(m, space, entry=entry, inputs=ins, expected=exp,
                        max_workers=1)
    r2 = explore_design(m, space, entry=entry, inputs=ins, expected=exp,
                        max_workers=2)
    assert [p.as_dict() for p in r1.points] == [p.as_dict() for p in r2.points]
    assert [p.as_dict() for p in r1.front] == [p.as_dict() for p in r2.front]


def test_explore_design_input_module_untouched():
    m, entry, ins, exp = _gemm_setup()
    from repro.core.printer import print_module

    before = print_module(m)
    explore_design(m, design_space(), entry=entry, inputs=ins, expected=exp)
    assert print_module(m) == before


def test_explore_design_scores_out_bad_candidate():
    """A candidate that cannot compile lands in the cloud with its error and
    stays off the frontier instead of killing the sweep."""
    m, entry, ins, exp = _gemm_setup()
    space = [DSEConfig(clock_ns=5.0), DSEConfig(clock_ns=-1.0)]
    res = explore_design(m, space, entry=entry, inputs=ins, expected=exp)
    good = [p for p in res.points if p.error is None]
    bad = [p for p in res.points if p.error is not None]
    assert len(good) >= 1 and len(bad) >= 1
    assert all(p.config.clock_ns > 0 for p in res.front)


# ---------------------------------------------------------------------------
# Pool fallback: a broken process pool degrades to serial, never crashes
# ---------------------------------------------------------------------------


def test_pool_map_warns_and_returns_none_when_pool_broken(monkeypatch):
    from repro.core import pool

    def boom(*a, **kw):
        raise OSError("no semaphores here")

    monkeypatch.setattr(pool, "ProcessPoolExecutor", boom)
    with pytest.warns(RuntimeWarning, match="falling back to serial"):
        assert pool.pool_map(len, ["ab", "cde"], max_workers=4) is None


def test_explore_design_pooled_falls_back_serially(monkeypatch):
    m, entry, ins, exp = _gemm_setup()
    space = design_space(clock_ns=(10.0, 5.0))
    r1 = explore_design(m, space, entry=entry, inputs=ins, expected=exp)

    from repro.core import pool

    def boom(*a, **kw):
        raise OSError("no semaphores here")

    monkeypatch.setattr(pool, "ProcessPoolExecutor", boom)
    with pytest.warns(RuntimeWarning, match="falling back to serial"):
        r2 = explore_design(m, space, entry=entry, inputs=ins, expected=exp,
                            max_workers=4)
    assert [p.as_dict() for p in r1.points] == [p.as_dict() for p in r2.points]


# ---------------------------------------------------------------------------
# Adversarial cache-key collisions: modules sharing a function fingerprint
# but differing in pipeline spec, scheduler options, clock, backend or
# hierarchy must never share a per-function codegen cache entry
# ---------------------------------------------------------------------------


@pytest.fixture()
def _fresh_func_cache():
    from repro.core.hls.dse import (COMPILE_CACHE, FUNC_CODEGEN_CACHE,
                                    SCHEDULE_CACHE)

    for c in (SCHEDULE_CACHE, COMPILE_CACHE, FUNC_CODEGEN_CACHE):
        c.clear()
    yield FUNC_CODEGEN_CACHE
    for c in (SCHEDULE_CACHE, COMPILE_CACHE, FUNC_CODEGEN_CACHE):
        c.clear()


def _compile_ctx(**kw):
    from repro.core.hls.scheduler import hls_compile

    m, entry = GALLERY["gemm"].build(4)
    return hls_compile(m, entry=entry, **kw)


@pytest.mark.parametrize("first,second", [
    (dict(hierarchy="modules"), dict(hierarchy="modules", backend="vhdl")),
    (dict(hierarchy="modules"), dict(hierarchy="inline")),
    (dict(hierarchy="modules"), dict(hierarchy="modules", pipeline="")),
    (dict(hierarchy="modules"),
     dict(hierarchy="modules", pipeline_loops=False)),
], ids=["backend", "hierarchy", "pipeline-spec", "sched-opts"])
def test_func_cache_keys_never_collide_across_context(
        first, second, _fresh_func_cache):
    _compile_ctx(**first)
    h0 = _fresh_func_cache.hits
    _compile_ctx(**second)
    assert _fresh_func_cache.hits == h0, (first, second)


def test_func_cache_keys_never_collide_across_clock(_fresh_func_cache):
    from repro.core.hls.scheduler import SchedulerOptions

    _compile_ctx(hierarchy="modules", options=SchedulerOptions(clock_ns=4.0))
    h0 = _fresh_func_cache.hits
    _compile_ctx(hierarchy="modules", options=SchedulerOptions(clock_ns=2.0))
    assert _fresh_func_cache.hits == h0
