"""Tests for PR 8's compile-throughput work: per-function incremental
recompilation (``dse.FUNC_CODEGEN_CACHE`` threaded through ``hls_compile``
-> ``generate_verilog``), pooled per-module backend emission, and the
successive-halving DSE strategy.

The load-bearing property throughout is *byte-identity*: every warm or
parallel path must emit exactly the text the cold serial path emits,
loc comments and signal names included."""

import os
import time

import pytest

from repro.core.gallery import gemm
from repro.core.hls import dse
from repro.core.hls.scheduler import hls_compile


@pytest.fixture(autouse=True)
def _fresh_caches():
    for c in (dse.SCHEDULE_CACHE, dse.COMPILE_CACHE, dse.FUNC_CODEGEN_CACHE):
        c.clear()
    yield
    for c in (dse.SCHEDULE_CACHE, dse.COMPILE_CACHE, dse.FUNC_CODEGEN_CACHE):
        c.clear()


def _edit_mac(m):
    """Structurally edit gemm's `mac` callee (add -> sub) without touching
    its interface — the single-function re-edit the incremental path is
    built for."""
    for op in m.funcs["mac"].body.ops:
        if op.opname == "add":
            op.opname = "sub"
            return m
    raise AssertionError("no add op in mac")


def _cold_compile(monkeypatch, m, entry, **kw):
    """Compile with every cache layer disabled (reference output)."""
    monkeypatch.setenv("REPRO_HLS_CACHE", "0")
    try:
        return hls_compile(m, entry=entry, **kw)
    finally:
        monkeypatch.delenv("REPRO_HLS_CACHE")


def _assert_same_netlists(got, want):
    assert set(got) == set(want)
    for k in want:
        assert got[k].text == want[k].text, k
        assert got[k].netlist == want[k].netlist, k


# ---------------------------------------------------------------------------
# Per-function incremental recompilation
# ---------------------------------------------------------------------------


def test_warm_reedit_hits_func_cache_modules(monkeypatch):
    n = 8
    m1, entry = gemm.build(n)
    hls_compile(m1, entry=entry, hierarchy="modules")
    assert len(dse.FUNC_CODEGEN_CACHE) == 2  # gemm + mac

    m2 = _edit_mac(gemm.build(n)[0])
    h0 = dse.FUNC_CODEGEN_CACHE.hits
    r2, v2 = hls_compile(m2, entry=entry, hierarchy="modules")
    assert not r2.from_cache            # whole-module layer missed...
    assert dse.FUNC_CODEGEN_CACHE.hits == h0 + 1  # ...but gemm was reused

    # byte-identical to a fully-cold compile of the same edited module
    m3 = _edit_mac(gemm.build(n)[0])
    _, v3 = _cold_compile(monkeypatch, m3, entry, hierarchy="modules")
    _assert_same_netlists(v2, v3)


def test_warm_reedit_speedup(monkeypatch):
    """Acceptance: warm single-function re-edit of gemm (one callee changed)
    at least 10x faster than a cold compile."""
    n = 8
    m1, entry = gemm.build(n)
    t0 = time.perf_counter()
    hls_compile(m1, entry=entry, hierarchy="modules")
    cold_s = time.perf_counter() - t0

    m2 = _edit_mac(gemm.build(n)[0])
    t0 = time.perf_counter()
    hls_compile(m2, entry=entry, hierarchy="modules")
    warm_s = time.perf_counter() - t0
    assert warm_s * 10 <= cold_s, (cold_s, warm_s)


def test_warm_reedit_byte_identity_inline(monkeypatch):
    """Inline mode: the edited callee invalidates the flattened entry (its
    body is part of the key closure), but the re-emitted text must still be
    byte-identical to cold — exercising the schedule-cache FuncOp splice
    (print/parse round trips would drop source locations)."""
    n = 4
    m1, entry = gemm.build(n)
    hls_compile(m1, entry=entry)
    m2 = _edit_mac(gemm.build(n)[0])
    _, v2 = hls_compile(m2, entry=entry)
    m3 = _edit_mac(gemm.build(n)[0])
    _, v3 = _cold_compile(monkeypatch, m3, entry)
    _assert_same_netlists(v2, v3)


def test_identical_recompile_still_hits_module_cache():
    m1, entry = gemm.build(4)
    hls_compile(m1, entry=entry, hierarchy="modules")
    r2, _ = hls_compile(gemm.build(4)[0], entry=entry, hierarchy="modules")
    assert r2.from_cache


# ---------------------------------------------------------------------------
# Parallel backend emission
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hierarchy", ["inline", "modules"])
@pytest.mark.parametrize("backend", ["verilog", "vhdl"])
def test_parallel_emission_matches_serial(hierarchy, backend):
    from repro.core.codegen.verilog import generate_verilog

    vs_s = generate_verilog(gemm.build(4)[0], entry="gemm",
                            hierarchy=hierarchy, backend=backend)
    vs_p = generate_verilog(gemm.build(4)[0], entry="gemm",
                            hierarchy=hierarchy, backend=backend,
                            max_workers=4)
    _assert_same_netlists(vs_p, vs_s)


def test_parallel_emission_falls_back_serially(monkeypatch):
    """With the process pool broken, max_workers>1 must warn and still
    produce the serial result rather than crash."""
    from repro.core import pool
    from repro.core.codegen.verilog import generate_verilog

    def boom(*a, **kw):
        raise OSError("no pool for you")

    monkeypatch.setattr(pool, "ProcessPoolExecutor", boom)
    vs_s = generate_verilog(gemm.build(4)[0], entry="gemm",
                            hierarchy="modules")
    with pytest.warns(RuntimeWarning, match="falling back to serial"):
        vs_p = generate_verilog(gemm.build(4)[0], entry="gemm",
                                hierarchy="modules", max_workers=4)
    _assert_same_netlists(vs_p, vs_s)


# ---------------------------------------------------------------------------
# Successive-halving DSE
# ---------------------------------------------------------------------------


def _halving_setup(n=4):
    m, entry = gemm.build(n)
    ins = gemm.make_inputs(n)
    return m, entry, ins, gemm.oracle(*ins[:2])


def test_halving_matches_exhaustive_front_with_half_the_compiles():
    m, entry, ins, exp = _halving_setup()
    space = dse.design_space(pipeline=(True, False), clock_ns=(2.0, 4.0),
                             merge_banks=(False, True), tile=(0, 2))
    r_ex = dse.explore_design(m, space, entry=entry,
                              inputs=[a.copy() for a in ins], expected=exp)
    r_h = dse.explore_design(m, space, entry=entry,
                             inputs=[a.copy() for a in ins], expected=exp,
                             strategy="halving", keep_frac=0.5)
    front = lambda r: sorted(repr(p.config.as_dict()) for p in r.front)
    assert front(r_h) == front(r_ex)
    assert r_h.stats["n_full"] <= len(space) // 2
    assert r_h.stats["evaluations_saved"] == \
        len(space) - r_h.stats["n_full"]
    # every candidate is accounted for: pruned ones carry their estimates
    assert len(r_h.points) == len(space)
    pruned = [p for p in r_h.points if p.pruned]
    assert len(pruned) == r_h.stats["evaluations_saved"]
    assert all(p.est is not None for p in pruned if p.error is None)


def test_halving_keep_frac_one_degenerates_to_exhaustive():
    m, entry, ins, exp = _halving_setup()
    space = dse.design_space(clock_ns=(2.0, 4.0), merge_banks=(False, True))
    r_h = dse.explore_design(m, space, entry=entry, inputs=ins, expected=exp,
                             strategy="halving", keep_frac=1.0)
    assert r_h.stats["n_full"] == len(space)
    assert not any(p.pruned for p in r_h.points)
