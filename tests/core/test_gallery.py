"""End-to-end tests of the HIR core on the paper's benchmark kernels:
verify -> simulate (cycle-accurate) -> functional JAX lowering -> passes ->
Verilog codegen, each checked against the NumPy oracle."""

import numpy as np
import pytest

from repro.core import verifier
from repro.core.codegen import estimate_resources, generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.lower import lower_to_jax, simulate
from repro.core.passes import run_pipeline

ORACLE_NARGS = {"transpose": 1, "array_add": 2, "histogram": 1, "stencil1d": 1,
                "gemm": 2, "conv2d": 1, "fifo": 1}


def _expected(name, ins):
    return GALLERY[name].oracle(*ins[: ORACLE_NARGS[name]])


@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_verifies_clean(name):
    m, _ = GALLERY[name].build()
    diags = verifier.verify(m)
    assert not [d for d in diags if d.severity == "error"]


@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_simulation_matches_oracle(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    ins = mod.make_inputs()
    res = simulate(m, entry, ins)
    assert res["cycles"] > 0
    np.testing.assert_array_equal(ins[-1], _expected(name, ins))


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_functional_jax_lowering_matches_oracle(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    ins = mod.make_inputs()
    fn = lower_to_jax(m, entry)
    out = fn(*[np.asarray(x, dtype=np.int32) for x in ins])
    f = m.get(entry)
    outname = [a.name for a in f.args if hasattr(a.type, "port") and a.type.port in ("w", "rw")][-1]
    np.testing.assert_array_equal(np.asarray(out[outname], np.int64), _expected(name, ins))


@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_optimized_design_still_correct(name):
    """Passes must never change semantics (paper: schedule/binding are
    orthogonal to the algorithm)."""
    mod = GALLERY[name]
    m, entry = mod.build()
    stats = run_pipeline(m)
    verifier.verify(m)
    ins = mod.make_inputs()
    simulate(m, entry, ins)
    np.testing.assert_array_equal(ins[-1], _expected(name, ins))


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
def test_verilog_codegen(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    run_pipeline(m)
    vs = generate_verilog(m, entry=entry)
    vm = vs[entry]
    assert vm.text.startswith("// generated")
    assert f"module {entry}" in vm.text
    assert "endmodule" in vm.text
    rep = estimate_resources(vm.netlist)
    assert rep.lut > 0
    # codegen transformations (inline+unroll) preserve semantics
    verifier.verify(m)
    ins = mod.make_inputs()
    simulate(m, entry, ins)
    np.testing.assert_array_equal(ins[-1], _expected(name, ins))


def test_gemm_uses_768_dsps_like_paper_table5():
    m, entry = GALLERY["gemm"].build()
    run_pipeline(m)
    vs = generate_verilog(m, entry=entry)
    assert estimate_resources(vs[entry].netlist).dsp == 768  # 256 PEs x 3


def test_histogram_uses_one_bram_and_demotes_port():
    m, entry = GALLERY["histogram"].build()
    stats = run_pipeline(m)
    assert stats.get("port_demotion", 0) >= 1  # paper §2 dual->single port
    vs = generate_verilog(m, entry=entry)
    assert estimate_resources(vs[entry].netlist).bram == 1


def test_conv2d_strength_reduction_avoids_dsps():
    m, entry = GALLERY["conv2d"].build()
    run_pipeline(m)
    vs = generate_verilog(m, entry=entry)
    assert estimate_resources(vs[entry].netlist).dsp == 0  # const weights -> shifts/adds
