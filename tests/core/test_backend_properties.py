"""Hypothesis property tests for the backend printers: random small
RTLModules must print without error on every backend, pass the matching
dialect linter, and keep identical ``netlist_of`` resource summaries
regardless of backend (printing never mutates the RTL IR)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.codegen import (BACKENDS, get_printer, lint_backend,  # noqa: E402
                                netlist_of)
from repro.core.codegen.resources import estimate_resources  # noqa: E402
from repro.core.codegen.rtl import (REG, Binop, CombAssign, Const,  # noqa: E402
                                    LoopController, MemRead, Memory, MemWrite,
                                    Mux, Ref, RegAssign, RTLModule, ShiftReg)

BACKEND_NAMES = sorted(BACKENDS)


@st.composite
def rtl_modules(draw):
    m = RTLModule("pm")
    for p in ("clk", "rst", "t_start"):
        m.add_port(p, "input")
    widths = st.sampled_from([1, 4, 8, 16, 32])
    sources = []
    for i in range(draw(st.integers(min_value=1, max_value=3))):
        w = draw(widths)
        m.add_port(f"in{i}", "input", w)
        sources.append((f"in{i}", w))
    for i in range(draw(st.integers(min_value=1, max_value=6))):
        kind = draw(st.sampled_from(["comb", "sr", "reg", "cmp", "mux"]))
        nm = f"n{i}"
        src, w = draw(st.sampled_from(sources))
        cmax = (1 << min(w, 8)) - 1
        if kind == "comb":
            op = draw(st.sampled_from(["+", "-", "&", "|", "^"]))
            m.new_net(nm, w)
            m.add(CombAssign(nm, Binop(
                op, Ref(src), Const(draw(st.integers(0, cmax)), w), width=w)))
        elif kind == "sr":
            m.new_net(nm, w)
            m.add(ShiftReg(nm, Ref(src), w,
                           draw(st.integers(min_value=1, max_value=4)),
                           reset_zero=draw(st.booleans())))
        elif kind == "reg":
            m.new_net(nm, w, REG)
            m.add(RegAssign(nm, Ref(src), en=Ref("t_start")))
        elif kind == "cmp":
            op = draw(st.sampled_from(["<", "<=", "==", "!=", ">="]))
            m.new_net(nm, 1)
            m.add(CombAssign(nm, Binop(
                op, Ref(src), Const(draw(st.integers(0, cmax)), w), width=w)))
            w = 1
        else:  # mux
            m.new_net(nm, w)
            m.add(CombAssign(nm, Mux(Ref("t_start"), Ref(src),
                                     Const(0, w), w)))
        sources.append((nm, w))
    if draw(st.booleans()):
        mw = draw(st.sampled_from([8, 16, 32]))
        depth = draw(st.sampled_from([4, 16]))
        aw = max(1, (depth - 1).bit_length())
        m.add(Memory("ram_m", 1, depth, mw,
                     draw(st.sampled_from(["bram", "lutram"]))))
        m.new_net("rd0", mw, REG)
        m.add(MemRead("rd0", "ram_m", 0,
                      Const(draw(st.integers(0, depth - 1)), aw),
                      Ref("t_start")))
        m.add(MemWrite("ram_m", 0,
                       Const(draw(st.integers(0, depth - 1)), aw),
                       Const(draw(st.integers(0, 255)), mw), Ref("t_start")))
        sources.append(("rd0", mw))
    if draw(st.booleans()):
        ivw = 4
        m.new_net("lc_iv", ivw, REG)
        m.new_net("lc_active", 1, REG)
        m.new_net("lc_iter", 1)
        m.new_net("lc_endp", 1, REG)
        ii = draw(st.sampled_from([1, 2, 3]))
        iicnt = ""
        if ii > 1:
            iicnt = m.new_net("lc_iicnt", max(1, (ii - 1).bit_length()), REG)
        m.add(LoopController(
            "lc", "lc_iv", ivw, "lc_active", "lc_iter", "lc_endp",
            start=Ref("t_start"), lb=Const(0, ivw),
            ub=Const(draw(st.integers(min_value=1, max_value=15)), ivw),
            step=Const(1, ivw), ii=ii, iicnt=iicnt))
        sources.append(("lc_iter", 1))
    nm, w = sources[-1]
    m.add_port("dout", "output", w)
    m.add(CombAssign("dout", Ref(nm)))
    return m


@given(rtl_modules())
@settings(max_examples=20, deadline=None)
def test_random_modules_conform_on_every_backend(m):
    baseline = netlist_of(m)
    summaries = []
    for backend in BACKEND_NAMES:
        text = get_printer(backend).print_module(m)
        assert text.strip(), backend
        diags = lint_backend(text, backend)
        assert diags == [], (backend, diags[:3], text)
        summaries.append(estimate_resources(netlist_of(m)).as_dict())
    assert netlist_of(m) == baseline, "printing mutated the module"
    assert all(s == summaries[0] for s in summaries), summaries


