"""Conformance suite for the JAX -> HIR frontend tracer.

The traced workloads are held to the same bar as the hand-written gallery:
the printed module round-trips through the parser, the RTL differential
harness checks them against their NumPy oracles on >= 256 stimulus vectors
in both emission hierarchies, and they flow through ``hls_compile`` /
``explore_design`` with correct cache keying.  Plus the frontend's error
contract: unsupported primitives and non-integer dtypes fail at trace time
with actionable messages, never silently mislower.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.codegen import sim as rsim
from repro.core.frontend import (
    FRONTEND_WORKLOADS,
    FrontendError,
    SUPPORTED_PRIMITIVES,
    UnsupportedPrimitiveError,
    trace,
)
from repro.core.gallery import GALLERY
from repro.core.hls import erase_schedule, hls_compile
from repro.core.hls.dse import (
    DSEConfig,
    explore_design,
    fingerprint_module,
)
from repro.core.parser import parse
from repro.core.printer import print_module

N_VECTORS = 256
HIERARCHIES = ["inline", "modules"]


# ---------------------------------------------------------------------------
# structure: build / round-trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FRONTEND_WORKLOADS))
def test_traced_module_prints_and_parses(name):
    mod, entry = FRONTEND_WORKLOADS[name].build()
    assert entry == name
    text = print_module(mod)
    again = parse(text)
    assert print_module(again) == text


@pytest.mark.parametrize("name", sorted(FRONTEND_WORKLOADS))
def test_traced_module_is_scheduled(name):
    # every op the scheduler touches carries a concrete start time
    mod, _ = FRONTEND_WORKLOADS[name].build()
    text = print_module(mod)
    assert "offset ?" not in text


def test_frontend_workloads_registered_in_gallery():
    for name in FRONTEND_WORKLOADS:
        assert name in GALLERY
        assert GALLERY[name] is FRONTEND_WORKLOADS[name]


# ---------------------------------------------------------------------------
# differential: traced hardware vs the JAX program's NumPy oracle
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("hierarchy", HIERARCHIES)
@pytest.mark.parametrize("name", sorted(FRONTEND_WORKLOADS))
def test_traced_differential(name, hierarchy):
    wl = FRONTEND_WORKLOADS[name]
    mod, entry = wl.build()
    batch = rsim.stack_stimulus(wl.make_inputs, N_VECTORS, base_seed=7)
    rep = rsim.run_differential(mod, entry, batch, kernel=name,
                                hierarchy=hierarchy, oracle=wl.oracle,
                                oracle_nargs=len(batch) - 1)
    assert rep.ok, (name, hierarchy, rep.mismatches[:5])
    assert rep.n_vectors == N_VECTORS
    assert rep.oracle_ok is True
    assert rep.passes_ok and all(rep.passes_ok.values()), rep.passes_ok


@pytest.mark.parametrize("name", sorted(FRONTEND_WORKLOADS))
def test_traced_differential_smoke(name):
    # fast-lane version of the matrix above: 16 vectors, inline hierarchy
    wl = FRONTEND_WORKLOADS[name]
    mod, entry = wl.build()
    batch = rsim.stack_stimulus(wl.make_inputs, 16, base_seed=3)
    rep = rsim.run_differential(mod, entry, batch, kernel=name,
                                oracle=wl.oracle,
                                oracle_nargs=len(batch) - 1)
    assert rep.ok and rep.oracle_ok, (name, rep.mismatches[:5])


def test_matmul_tile_knob_preserves_semantics():
    # tile divides n -> banked accumulator; tile=1 -> plain nest; same math
    wl = FRONTEND_WORKLOADS["frontend_matmul"]
    a, b, _ = wl.make_inputs(seed=42)
    want = wl.oracle(a, b)
    for tile in (1, 2, 4):
        mod, entry = wl.build(tile=tile)
        from repro.core.lower import simulate

        args = [a.copy(), b.copy(), np.zeros_like(want)]
        simulate(mod, entry, args)
        np.testing.assert_array_equal(args[-1], want), tile


# ---------------------------------------------------------------------------
# error contract
# ---------------------------------------------------------------------------


def test_unsupported_primitive_names_itself():
    import jax.numpy as jnp

    with pytest.raises(UnsupportedPrimitiveError, match="argmax"):
        trace(lambda x: jnp.argmax(x), [(8,)], name="bad")
    with pytest.raises(UnsupportedPrimitiveError, match="sort"):
        trace(lambda x: jnp.sort(x), [(8,)], name="bad")


def test_unsupported_primitive_lists_supported_set():
    import jax.numpy as jnp

    with pytest.raises(UnsupportedPrimitiveError,
                       match="supported primitives are"):
        trace(lambda x: jnp.sort(x), [(8,)], name="bad")
    assert "dot_general" in SUPPORTED_PRIMITIVES
    assert "reduce_sum" in SUPPORTED_PRIMITIVES


def test_float_program_rejected_at_trace_time():
    import jax.numpy as jnp

    with pytest.raises(FrontendError, match="integer-only"):
        trace(lambda x: x.astype(jnp.float32) * 1.5, [(8,)], name="bad")


def test_unsupported_error_is_a_not_implemented_error():
    # callers can catch the stdlib category without importing the frontend
    assert issubclass(UnsupportedPrimitiveError, FrontendError)
    assert issubclass(FrontendError, NotImplementedError)


# ---------------------------------------------------------------------------
# cache keying: fingerprints must separate what the scheduler must not share
# ---------------------------------------------------------------------------


def test_trace_fingerprint_deterministic():
    m1, _ = FRONTEND_WORKLOADS["frontend_scan"].build()
    m2, _ = FRONTEND_WORKLOADS["frontend_scan"].build()
    assert fingerprint_module(erase_schedule(m1)) == \
        fingerprint_module(erase_schedule(m2))


def test_trace_fingerprint_varies_with_shape_and_tile():
    wl = FRONTEND_WORKLOADS["frontend_matmul"]
    base = fingerprint_module(erase_schedule(wl.build()[0]))
    other_shape = fingerprint_module(erase_schedule(wl.build(m=8, k=8, n=8)[0]))
    other_tile = fingerprint_module(erase_schedule(wl.build(tile=4)[0]))
    assert base != other_shape
    assert base != other_tile


def test_gallery_fingerprints_all_distinct():
    prints = {name: fingerprint_module(erase_schedule(gal.build()[0]))
              for name, gal in GALLERY.items()}
    assert len(set(prints.values())) == len(prints), prints


def test_hls_compile_cache_hits_and_misses():
    wl = FRONTEND_WORKLOADS["frontend_scan"]
    um = erase_schedule(wl.build()[0])
    res1, _ = hls_compile(um, entry="frontend_scan")
    res2, _ = hls_compile(erase_schedule(wl.build()[0]),
                          entry="frontend_scan")
    assert res2.from_cache  # identical retrace -> whole-module cache hit
    um3 = erase_schedule(wl.build(n=16)[0])
    res3, _ = hls_compile(um3, entry="frontend_scan")
    assert not res3.from_cache  # different trace shape -> different key


# ---------------------------------------------------------------------------
# downstream integration: compile + DSE
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FRONTEND_WORKLOADS))
def test_traced_module_compiles_to_rtl(name):
    wl = FRONTEND_WORKLOADS[name]
    um = erase_schedule(wl.build()[0])
    res, netlists = hls_compile(um, entry=name, cache=False)
    assert name in netlists
    assert netlists[name].text.strip()


@pytest.mark.slow
def test_traced_module_explores_design_space():
    wl = FRONTEND_WORKLOADS["frontend_scan"]
    mod, entry = wl.build()
    ins = wl.make_inputs(seed=5)
    exp = wl.oracle(*ins[:2])
    space = [DSEConfig(clock_ns=10.0), DSEConfig(clock_ns=5.0)]
    res = explore_design(mod, space, entry=entry, inputs=ins, expected=exp)
    assert len(res.points) == len(space)
    assert all(p.verified for p in res.points), \
        [p.error for p in res.points if not p.verified]
    assert res.front
