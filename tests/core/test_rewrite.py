"""Worklist pattern-driver tests: convergence, revisit-on-change, erasure."""

import numpy as np

from repro.core import ir
from repro.core.builder import Builder
from repro.core.gallery import GALLERY
from repro.core.lower import simulate
from repro.core.passes.canonicalize import CanonicalizePattern, ConstFoldPattern
from repro.core.rewrite import (PatternRewriter, RewritePattern,
                                RewritePatternSet, apply_patterns_greedily)


def test_constant_chain_collapses_in_one_drain():
    """The driver revisits ops whose operands changed: a chain of constant
    adds folds completely in a single apply_patterns_greedily call."""
    b = Builder(ir.Module("m"))
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        acc = b.const(1)
        for _ in range(10):
            acc = b.add(acc, b.const(1))
        b.write(acc, O, [b.const(0)], at=f.t)
        b.ret()
    func = b.module.get("f")
    n = apply_patterns_greedily(func.body, RewritePatternSet([ConstFoldPattern()]))
    assert n == 10  # every add folded, cascade driven by the worklist
    adds = [op for op in func.body.walk() if op.opname == "add"]
    assert not adds
    write = next(op for op in func.body.walk() if op.opname == "mem_write")
    assert ir.const_value(write.operands[0]) == 11


def test_driver_converges_to_zero_rewrites():
    patterns = RewritePatternSet([CanonicalizePattern(), ConstFoldPattern()])
    m, _ = GALLERY["conv2d"].build()
    f = next(iter(m.funcs.values()))
    first = apply_patterns_greedily(f.body, patterns)
    second = apply_patterns_greedily(f.body, patterns)
    assert second == 0, "greedy driver must reach a fixpoint in one call"
    assert first >= 0


def test_pattern_set_anchoring_and_benefit_order():
    calls = []

    class A(RewritePattern):
        ops = ("add",)
        benefit = 1

        def match_and_rewrite(self, op, rewriter):
            calls.append("A")
            return False

    class B(RewritePattern):
        ops = ("add",)
        benefit = 5

        def match_and_rewrite(self, op, rewriter):
            calls.append("B")
            return False

    ps = RewritePatternSet([A(), B()])
    assert [type(p).__name__ for p in ps.get("add")] == ["B", "A"]
    assert ps.get("mult") == []

    c1, c2 = ir.constant(1), ir.constant(2)
    region = ir.Region()
    region.add(ir.arith("add", [c1.result, c2.result]))
    apply_patterns_greedily(region, ps)
    assert calls == ["B", "A"]  # benefit order, each tried once (no match)


def test_erased_ops_are_compacted_and_unlinked():
    class EraseDelays(RewritePattern):
        ops = ("delay",)

        def match_and_rewrite(self, op, rewriter):
            rewriter.replace_op(op, [op.operands[0]])
            return True

    b = Builder(ir.Module("m"))
    r = ir.MemrefType((4,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        d = b.delay(v, 2)
        b.write(d, O, [b.const(0)], at=f.t + 3)
        b.ret()
    func = b.module.get("f")
    n = apply_patterns_greedily(func.body, RewritePatternSet([EraseDelays()]))
    assert n == 1
    assert all(op.opname != "delay" for op in func.body.walk())
    write = next(op for op in func.body.walk() if op.opname == "mem_write")
    assert write.operands[0].defining_op.opname == "mem_read"


def test_worklist_canonicalize_matches_oracle_on_gallery_kernel():
    """Driver-based optimization preserves semantics on a real kernel."""
    mod = GALLERY["conv2d"]
    m, entry = mod.build()
    f = m.get(entry)
    patterns = RewritePatternSet([CanonicalizePattern(), ConstFoldPattern()])
    apply_patterns_greedily(f.body, patterns)
    ins = mod.make_inputs()
    simulate(m, entry, ins)
    np.testing.assert_array_equal(ins[-1], mod.oracle(ins[0]))
