"""Schedule-transform passes (pipeline-loop / retime): the paper's pitch that
retiming and pipelining are ordinary IR transformations over the explicit
schedule.  Every transformed gallery kernel must keep the cycle-accurate
simulation (lower/to_sim) and the schedule-free functional lowering
(lower/to_jax) in agreement with the NumPy oracle — schedules never change
semantics."""

from copy import deepcopy

import numpy as np
import pytest

from repro.core import ir, verifier
from repro.core.analysis import analyze_loops
from repro.core.builder import Builder
from repro.core.codegen import generate_verilog
from repro.core.gallery import GALLERY
from repro.core.hls import erase_schedule, hls_schedule
from repro.core.lower import lower_to_jax, simulate
from repro.core.passes import (PassManager, SCHEDULE_PIPELINE_SPEC,
                               pipeline_loops, retime)

ORACLE_NARGS = {"transpose": 1, "array_add": 2, "histogram": 1, "stencil1d": 1,
                "gemm": 2, "conv2d": 1, "fifo": 1}


def _sequentialized(name):
    """Erase the explicit schedule and re-schedule with the modulo-II search
    disabled: every loop runs sequentially (II = body span), the conservative
    input the schedule transforms start from."""
    m, entry = GALLERY[name].build()
    um = erase_schedule(m)
    hls_schedule(um, pipeline_loops=False)
    return um, entry


def _innermost_for_loops(func):
    return [op for op, li in analyze_loops(func).items()
            if op.opname == "for"
            and not any(isinstance(o, ir.ForOp) for o in op.region(0).ops)]


# ---------------------------------------------------------------------------
# correctness property: sim == jax == oracle on every gallery kernel
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_transforms_preserve_sim_vs_jax_agreement(name):
    mod = GALLERY[name]
    um, entry = _sequentialized(name)
    pm = PassManager.from_spec(SCHEDULE_PIPELINE_SPEC)
    pm.run(um)
    # the transformed schedule is verifier-legal
    diags = verifier.verify(um, raise_on_error=False)
    assert not [d for d in diags if d.severity == "error"]
    # cycle-accurate simulation matches the oracle
    ins = mod.make_inputs()
    expected = mod.oracle(*[np.asarray(x) for x in ins[: ORACLE_NARGS[name]]])
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], expected)
    # schedule-free functional lowering agrees too
    ins2 = mod.make_inputs()
    fn = lower_to_jax(um, entry)
    out = fn(*[np.asarray(x, dtype=np.int32) for x in ins2])
    f = um.get(entry)
    outname = [a.name for a in f.args
               if hasattr(a.type, "port") and a.type.port in ("w", "rw")][-1]
    np.testing.assert_array_equal(np.asarray(out[outname], np.int64), expected)


# ---------------------------------------------------------------------------
# acceptance: II < body span on gemm / conv2d / stencil1d
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["gemm", "conv2d", "stencil1d"])
def test_pipeline_loop_beats_body_span(name):
    um, entry = _sequentialized(name)
    f = um.get(entry)
    seq_iis = {l: li.ii for l, li in analyze_loops(f).items() if l.opname == "for"}
    n = PassManager.from_spec("pipeline-loop").run(um)["pipeline_loop"]
    assert n >= 1
    pipelined = [li for l, li in analyze_loops(f).items()
                 if l in seq_iis and li.pipelined]
    assert pipelined, "no loop reached II < body span"
    for li in pipelined:
        assert li.ii < li.body_span
        assert li.ii <= seq_iis[li.op]


def test_pipeline_loop_respects_rmw_recurrence():
    """Histogram's read-modify-write through the bin RAM bounds II >= 2: the
    transform must not out-schedule the recurrence."""
    um, entry = _sequentialized("histogram")
    PassManager.from_spec("pipeline-loop").run(um)
    f = um.get(entry)
    loops = {l.iv.name: li for l, li in analyze_loops(f).items() if l.opname == "for"}
    assert loops["i"].ii >= 2


def test_pipeline_loop_is_stable_at_fixpoint():
    """Re-running the pass on its own output is a no-op (no churn: the probe
    records its result and must not strip/re-insert balancing delays)."""
    from repro.core.printer import print_module

    um, entry = _sequentialized("gemm")
    PassManager.from_spec("pipeline-loop").run(um)
    before = print_module(um)
    again = PassManager.from_spec("pipeline-loop").run(um)
    assert again["pipeline_loop"] == 0
    assert print_module(um) == before


# ---------------------------------------------------------------------------
# acceptance: retime shrinks shift-register depth in the Netlist
# ---------------------------------------------------------------------------


def _shift_reg_totals(module, entry):
    vs = generate_verilog(module, entry=entry)
    nl = vs[entry].netlist
    return (sum(d for _, d in nl.shift_regs),
            sum(w * d for w, d in nl.shift_regs))


def test_retime_reduces_shift_register_depth():
    reduced = 0
    for name in ("conv2d", "stencil1d", "gemm"):
        um, entry = _sequentialized(name)
        # strength-reduce first: const-weight mults become 0.2 ns shifts, so
        # hoisting a delay across the adder fits the 5 ns clock budget
        PassManager.from_spec("pipeline-loop,strength-reduce,canonicalize").run(um)
        base = deepcopy(um)
        n = PassManager.from_spec("retime").run(um)["retime"]
        d0, b0 = _shift_reg_totals(base, entry)
        d1, b1 = _shift_reg_totals(um, entry)
        assert d1 <= d0 and b1 <= b0  # retime never grows the registers
        if n and d1 < d0:
            reduced += 1
    assert reduced >= 1, "retime reduced shift-register depth on no kernel"


def test_retime_hoists_balanced_delays_and_keeps_timing():
    """add(delay(a,2), delay(b,2)) at t+3 -> delay(add(a,b) at t+1, 2): one
    output chain replaces two input chains, and the consumer's operand is
    born at exactly the original cycle."""
    b = Builder(ir.Module("m"))
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [ir.i32, ir.i32, w], ["x", "y", "O"],
                arg_delays=[1, 1, 0]) as f:
        x, y, O = f.args
        dx = b.delay(x, 2, at=f.t + 1)
        dy = b.delay(y, 2, at=f.t + 1)
        s = b.add(dx, dy, at=f.t + 3)
        b.write(s, O, [0], at=f.t + 3)
        b.ret()
    m = b.module
    assert retime(m) == 1
    f = m.get("f")
    delays = [op for op in f.body.walk() if op.opname == "delay"]
    assert len(delays) == 1 and delays[0].attrs["by"] == 2
    add = next(op for op in f.body.walk() if op.opname == "add")
    assert add.start.offset == 1  # moved 2 cycles earlier
    write = next(op for op in f.body.walk() if op.opname == "mem_write")
    assert write.operands[0].birth.offset == 3  # original timing preserved
    assert not [d for d in verifier.verify(m, raise_on_error=False)
                if d.severity == "error"]


def test_retime_respects_clock_budget():
    """Folding the delays would merge the mults (4.5 ns) and the add
    (2.0 ns) into one 6.5 ns chain — over the 5 ns budget the scheduler
    enforced when it registered them apart.  Retime must not undo that."""
    b = Builder(ir.Module("m"))
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [ir.i32, ir.i32, w], ["x", "y", "O"],
                arg_delays=[1, 1, 0]) as f:
        x, y, O = f.args
        mx = b.mult(x, x, at=f.t + 1)
        my = b.mult(y, y, at=f.t + 1)
        dx = b.delay(mx, 1, at=f.t + 1)
        dy = b.delay(my, 1, at=f.t + 1)
        s = b.add(dx, dy, at=f.t + 2)
        b.write(s, O, [0], at=f.t + 2)
        b.ret()
    assert retime(b.module) == 0  # 4.5 + 2.0 > CLOCK_NS: fold rejected


def test_retime_skips_without_register_saving():
    """A single same-width delay operand saves nothing: no rewrite."""
    b = Builder(ir.Module("m"))
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [ir.i32, w], ["x", "O"], arg_delays=[1, 0]) as f:
        x, O = f.args
        dx = b.delay(x, 2, at=f.t + 1)
        s = b.add(dx, 5, at=f.t + 3)
        b.write(s, O, [0], at=f.t + 3)
        b.ret()
    assert retime(b.module) == 0
