"""Hypothesis property tests for the vectorized RTL simulator.

Three properties, all derandomized/seeded for CI reproducibility:

  * random hand-built ``RTLModule``s simulate identically before and after
    every RTL pass in ``RTL_PIPELINE_SPEC`` (per-cycle output-port traces);
  * on the same random modules the numpy and jax backends produce identical
    traces (skipped when jax is absent);
  * on gallery kernels with hypothesis-drawn seeds, the vectorized
    simulator matches the event-driven ``lower.simulate`` oracle exactly.

Per-lane stimulus comes from ``sim.fold_in_stimulus`` — jax-native
``fold_in`` counter streams keyed by the hypothesis-drawn seed — rather
than a shared sequential generator, so lane values are stable under suite
growth while staying pinned by ``@seed``.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

# seed-pinned fuzz: runs in the CI differential-fuzz step and the full job
pytestmark = pytest.mark.slow
from hypothesis import given, seed, settings, strategies as st  # noqa: E402

from repro.core import ir  # noqa: E402
from repro.core.codegen import sim as rsim  # noqa: E402
from repro.core.codegen.rtl import RTL_PIPELINE_SPEC, RTLDesign  # noqa: E402
from repro.core.gallery import array_add, stencil1d, transpose  # noqa: E402
from repro.core.lower import simulate  # noqa: E402
from repro.core.passmgr import PassManager  # noqa: E402

from test_backend_properties import rtl_modules  # noqa: E402

CYCLES = 64
LANES = 4


def _wrap(m):
    """Give a raw strategy-built RTLModule the hir.func facade the simulator
    binds against: every ``in*`` port becomes one scalar unsigned argument."""
    ins = [p for p in m.ports if p.name.startswith("in")]
    f = ir.FuncOp("pm", [ir.IntType(p.width, signed=False) for p in ins],
                  [p.name for p in ins])
    for i, p in enumerate(ins):
        m.arg_ports[i] = [(p.name, "input", "data", 0)]
    return f, ins


def _stimulus(ins, sd):
    # jax-native fold_in streams (per-input, per-lane); numpy SeedSequence
    # fallback keeps the suite runnable without jax.  Widths are capped at
    # 16 bits so multi-op datapaths stay inside the simulators' i64 domain.
    return rsim.fold_in_stimulus([min(p.width, 16) for p in ins], LANES,
                                 seed=sd)


def _signature(design, func, stim):
    s = rsim.RTLSimulator(design.copy(), func, "pm", backend="numpy")
    return s.run(stim, CYCLES, batched=True, check_conflicts=False,
                 trace=True)


@seed(20260808)
@given(rtl_modules(), st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_rtl_passes_preserve_cycle_accuracy(m, sd):
    func, ins = _wrap(m)
    design = RTLDesign(entry="pm")
    design.add(m)
    stim = _stimulus(ins, sd)
    prev = _signature(design, func, stim)
    for name in [p.strip() for p in RTL_PIPELINE_SPEC.split(",") if p.strip()]:
        PassManager.from_spec(name).run(design)
        cur = _signature(design, func, stim)
        for p, tr in prev.trace.items():
            assert p in cur.trace, (name, p)
            assert np.array_equal(tr, cur.trace[p]), (name, p)
        prev = cur


@pytest.mark.skipif(not rsim.HAVE_JAX, reason="jax unavailable")
@seed(20260808)
@given(rtl_modules(), st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_backends_agree_on_random_modules(m, sd):
    func, ins = _wrap(m)
    design = RTLDesign(entry="pm")
    design.add(m)
    stim = _stimulus(ins, sd)
    a = _signature(design, func, stim)
    s = rsim.RTLSimulator(design.copy(), func, "pm", backend="jax")
    b = s.run(stim, CYCLES, batched=True, check_conflicts=False, trace=True)
    for p, tr in a.trace.items():
        assert np.array_equal(tr, b.trace[p]), p


_GALLERY = {
    "array_add": (array_add, {"n": 8}, {"n": 8}),
    "transpose": (transpose, {"n": 4}, {"n": 4}),
    "stencil1d": (stencil1d, {"n": 8}, {"n": 8}),
}


@seed(20260808)
@given(st.sampled_from(sorted(_GALLERY)), st.integers(0, 2**16))
@settings(max_examples=12, deadline=None)
def test_vectorized_matches_event_driven(kernel, sd):
    gal, bkw, ikw = _GALLERY[kernel]
    mod, entry = gal.build(**bkw)
    args = [np.asarray(a, dtype=np.int64)
            for a in gal.make_inputs(seed=sd, **ikw)]
    sim, prepared = rsim.simulator_for(mod, entry, backend="numpy")
    cycles = rsim.probe_cycles(prepared, entry, args)
    res = sim.run(args, cycles)
    ev_args = [a.copy() for a in args]
    simulate(prepared, entry, ev_args)
    for i, a in enumerate(ev_args):
        assert np.array_equal(res.arrays[i][0], a), f"arg {i}"
