"""Tests for the DSE sim-verification additions and the persistent compile
cache: memoized jax-oracle reference outputs, ``explore_design``
auto-expected, batched Pareto-front verification on the vectorized
simulator, and the ``REPRO_HLS_CACHE_DIR`` on-disk compile cache."""

import os
from pathlib import Path

import numpy as np
import pytest

from repro.core.gallery import array_add, gemm
from repro.core.hls import dse
from repro.core.hls.scheduler import hls_compile


@pytest.fixture(autouse=True)
def _fresh_caches():
    dse.clear_oracle_cache()
    dse.COMPILE_CACHE.clear()
    dse.SCHEDULE_CACHE.clear()
    dse.FUNC_CODEGEN_CACHE.clear()
    yield
    dse.clear_oracle_cache()
    dse.COMPILE_CACHE.clear()
    dse.SCHEDULE_CACHE.clear()
    dse.FUNC_CODEGEN_CACHE.clear()


# ---------------------------------------------------------------------------
# Memoized oracle
# ---------------------------------------------------------------------------


def test_oracle_expected_matches_kernel_oracle():
    mod, entry = array_add.build(n=8)
    inputs = array_add.make_inputs(n=8, seed=4)
    got = dse.oracle_expected(mod, entry, inputs)
    want = array_add.oracle(inputs[0], inputs[1])
    assert np.array_equal(got.astype(np.int64), want.astype(np.int64))


def test_oracle_outputs_are_memoized():
    mod, entry = array_add.build(n=8)
    inputs = array_add.make_inputs(n=8, seed=4)
    dse.oracle_expected(mod, entry, inputs)
    s0 = dict(dse.ORACLE_STATS)
    out = dse.oracle_expected(mod, entry, inputs)
    assert dse.ORACLE_STATS["out_hits"] == s0["out_hits"] + 1
    # a structurally identical *rebuild* hits the fn cache (no re-trace)
    mod2, _ = array_add.build(n=8)
    inputs2 = array_add.make_inputs(n=8, seed=9)
    dse.oracle_expected(mod2, entry, inputs2)
    assert dse.ORACLE_STATS["fn_hits"] >= 1
    # cached arrays are private copies
    out[:] = -1
    fresh = dse.oracle_expected(mod, entry, inputs)
    assert not np.array_equal(out, fresh)


def test_oracle_cache_disabled_by_env(monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE", "0")
    mod, entry = array_add.build(n=8)
    inputs = array_add.make_inputs(n=8, seed=4)
    dse.oracle_expected(mod, entry, inputs)
    dse.oracle_expected(mod, entry, inputs)
    assert dse.ORACLE_STATS["out_hits"] == 0
    assert dse.ORACLE_STATS["fn_hits"] == 0


# ---------------------------------------------------------------------------
# explore_design auto-expected + batched front verification
# ---------------------------------------------------------------------------


def test_explore_design_auto_expected_verifies():
    mod, entry = array_add.build(n=8)
    inputs = array_add.make_inputs(n=8, seed=2)
    space = dse.design_space(pipeline=(True, False))
    res = dse.explore_design(mod, space, entry=entry, inputs=inputs)
    assert res.front, [p.error for p in res.points]
    assert all(p.verified for p in res.front)


def test_sim_verify_front_batched():
    from repro.core.codegen.sim import stack_stimulus

    mod, entry = array_add.build(n=8)
    inputs = array_add.make_inputs(n=8, seed=2)
    space = dse.design_space(pipeline=(True, False))
    res = dse.explore_design(mod, space, entry=entry, inputs=inputs)
    batch = stack_stimulus(array_add.make_inputs, 32, base_seed=50, n=8)
    n_ok = dse.sim_verify_front(mod, res, entry=entry, args_batch=batch)
    assert n_ok == len(res.front) > 0
    for p in res.front:
        assert p.batch_verified is True
        assert p.batch_vectors == 32
        assert p.as_dict()["batch_verified"] is True


# ---------------------------------------------------------------------------
# Persistent on-disk compile cache
# ---------------------------------------------------------------------------


def test_disk_cache_round_trips_compile(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path))
    mod, entry = gemm.build(n=4)
    r1, v1 = hls_compile(mod.clone(), entry=entry)
    assert not r1.from_cache
    assert len(dse.disk_cache()) == 1
    # fresh process simulated by clearing the in-memory layer
    dse.COMPILE_CACHE.clear()
    r2, v2 = hls_compile(mod.clone(), entry=entry)
    assert r2.from_cache
    assert v2.keys() == v1.keys()
    for k in v1:
        assert v1[k].text == v2[k].text
        assert v2[k].rtl is None  # RTL trees are never pickled
        assert v1[k].netlist == v2[k].netlist
    # resource reports survive the rtl=None reload
    from repro.core.codegen.resources import report_design
    a, b = report_design(v1, entry=entry), report_design(v2, entry=entry)
    assert (a.lut, a.ff, a.dsp, a.bram) == (b.lut, b.ff, b.dsp, b.bram)
    # the disk hit also re-populated the in-memory cache
    assert len(dse.COMPILE_CACHE) == 1


def test_disk_cache_unset_means_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_HLS_CACHE_DIR", raising=False)
    assert dse.disk_cache() is None


def test_disk_cache_tolerates_corrupt_entries(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path))
    dc = dse.disk_cache()
    (tmp_path / "deadbeef.pkl").write_bytes(b"not a pickle")
    assert dc.get("deadbeef") is None
    assert dc.misses == 1


def test_disk_cache_size_cap_evicts_oldest(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path))
    mod, entry = array_add.build(n=8)
    r, vs = hls_compile(mod.clone(), entry=entry)
    dc = dse.disk_cache()
    entry_bytes = sum(f.stat().st_size for f in tmp_path.glob("*.pkl"))
    # cap at ~2 entries, then insert 4 distinct keys
    dc.max_bytes = int(entry_bytes * 2.5)
    import time
    for i in range(4):
        dc.put(f"key{i:02d}", mod, vs, {"funcs": []})
        time.sleep(0.01)  # distinct mtimes for deterministic eviction
    files = sorted(f.name for f in tmp_path.glob("*.pkl"))
    assert len(files) <= 3  # cap enforced
    assert "key03.pkl" in files  # newest survives


def test_disk_cache_respects_global_kill_switch(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_HLS_CACHE", "0")
    mod, entry = gemm.build(n=4)
    hls_compile(mod.clone(), entry=entry)
    assert len(list(tmp_path.glob("*.pkl"))) == 0


_STRESS_WORKER = r"""
import os, sys, random

from repro.core.gallery import array_add
from repro.core.hls import dse
from repro.core.hls.scheduler import hls_compile

wid = int(sys.argv[1])
cache_dir = sys.argv[2]

mod, entry = array_add.build(n=8)
os.environ["REPRO_HLS_CACHE"] = "0"
_, vs = hls_compile(mod.clone(), entry=entry)
del os.environ["REPRO_HLS_CACHE"]

dc = dse.DiskCompileCache(cache_dir)
dc.put("probe", mod, vs, {"funcs": []})
entry_bytes = max(f.stat().st_size for f in dc.root.glob("*.pkl"))
dc.max_bytes = entry_bytes * 3  # keep eviction constantly racing

rng = random.Random(wid)
for i in range(30):
    dc.put(f"w{wid}k{i}", mod, vs, {"funcs": []})
    hit = dc.get(f"w{rng.randrange(4)}k{rng.randrange(30)}")
    if hit is not None:
        m, nets, meta = hit
        assert nets, "hit with no netlists"
print("OK", wid)
"""


def test_disk_cache_concurrent_writers_race_safely(tmp_path):
    """Several processes hammer one size-capped cache directory: racing
    puts, gets and evictions (files vanishing between listing, stat and
    unlink) must never raise, and the cap must still be roughly enforced
    once the dust settles."""
    import subprocess
    import sys

    script = tmp_path / "worker.py"
    script.write_text(_STRESS_WORKER)
    cache_dir = tmp_path / "cache"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(Path(__file__).resolve().parents[2] / "src"),
         env.get("PYTHONPATH", "")])
    procs = [subprocess.Popen(
        [sys.executable, str(script), str(w), str(cache_dir)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE)
        for w in range(4)]
    for w, p in enumerate(procs):
        out, err = p.communicate(timeout=180)
        assert p.returncode == 0, (w, err.decode()[-2000:])
        assert f"OK {w}" in out.decode()
    # cap roughly holds: each worker ran with max_bytes = 3 entries, so the
    # survivor set is a handful of entries, not 120
    files = list(cache_dir.glob("*.pkl"))
    assert 1 <= len(files) <= 8, [f.name for f in files]
    # the directory is still a working cache for a fresh process
    dc = dse.DiskCompileCache(str(cache_dir))
    key = files[0].stem
    assert dc.get(key) is not None
