"""Fatal errors must not be swallowed by containment handlers.

The compiler has a handful of places that deliberately contain failures —
const-folding declines to fold, the DSE sweep scores a candidate out, the
disk cache misses, the pool mapper falls back to serial.  Each of those
handlers is narrowed to the failures it actually expects; this suite pins
the other side of the contract: ``MemoryError`` / ``KeyboardInterrupt``
(and plain bugs, where the policy is warn-and-contain) escape.
"""

from __future__ import annotations

import pickle
import warnings

import pytest

from repro.core.hls.dse import (
    DSEConfig,
    DiskCompileCache,
    _cheap_score_candidate,
    _evaluate_candidate,
)
from repro.core.passes.canonicalize import _fold
from repro.core.pool import POOL_FALLBACK_ERRORS, pool_map


class _Fatal:
    """Operand whose arithmetic raises a chosen fatal error."""

    def __init__(self, exc):
        self.exc = exc

    def __add__(self, other):
        raise self.exc


# -- const folding ------------------------------------------------------------


def test_fold_declines_on_expected_arith_errors():
    assert _fold("div", [1, 0]) is None
    assert _fold("add", [1, object()]) is None


@pytest.mark.parametrize("exc", [MemoryError, KeyboardInterrupt])
def test_fold_does_not_swallow_fatal(exc):
    with pytest.raises(exc):
        _fold("add", [_Fatal(exc("boom")), 1])


def test_legacy_sweep_fold_matches_policy():
    from repro.core.passes.legacy_sweep import _fold as _legacy_fold

    assert _legacy_fold("div", [1, 0]) is None
    with pytest.raises(MemoryError):
        _legacy_fold("add", [_Fatal(MemoryError("boom")), 1])


# -- pool mapper --------------------------------------------------------------


def _oom_worker(x):
    raise MemoryError("worker oom")


def test_pool_fallback_errors_exclude_fatal():
    assert MemoryError not in POOL_FALLBACK_ERRORS
    assert KeyboardInterrupt not in POOL_FALLBACK_ERRORS


def test_pool_map_reraises_worker_memoryerror():
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            res = pool_map(_oom_worker, [1, 2], 2, label="policy test")
    except MemoryError:
        return  # worker's own error propagated — the contract under test
    if res is None:
        pytest.skip("no process pool available in this environment")
    pytest.fail(f"worker MemoryError was swallowed; got {res!r}")


# -- DSE candidate workers ----------------------------------------------------

_BAD_TEXT = "this is not hir"


def _payload_full():
    return (_BAD_TEXT, "main", DSEConfig(), None, None, None)


def _payload_cheap():
    return (_BAD_TEXT, "main", DSEConfig())


def test_dse_candidate_scores_out_parse_error():
    row = _evaluate_candidate(_payload_full())
    assert row["error"] and "ParseError" in row["error"]
    row = _cheap_score_candidate(_payload_cheap())
    assert row["error"] and "ParseError" in row["error"]


@pytest.mark.parametrize("exc", [MemoryError, KeyboardInterrupt])
def test_dse_candidate_reraises_fatal(monkeypatch, exc):
    import repro.core.parser as parser_mod

    def boom(text):
        raise exc("boom")

    monkeypatch.setattr(parser_mod, "parse", boom)
    with pytest.raises(exc):
        _evaluate_candidate(_payload_full())
    with pytest.raises(exc):
        _cheap_score_candidate(_payload_cheap())


def test_dse_candidate_warns_on_unexpected_error(monkeypatch):
    import repro.core.parser as parser_mod

    def boom(text):
        raise RuntimeError("compiler bug")

    monkeypatch.setattr(parser_mod, "parse", boom)
    with pytest.warns(RuntimeWarning, match="unexpected RuntimeError"):
        row = _evaluate_candidate(_payload_full())
    assert "RuntimeError" in row["error"]
    with pytest.warns(RuntimeWarning, match="unexpected RuntimeError"):
        row = _cheap_score_candidate(_payload_cheap())
    assert "RuntimeError" in row["error"]


# -- disk compile cache -------------------------------------------------------


def test_disk_cache_corrupt_entry_is_a_miss(tmp_path):
    cache = DiskCompileCache(tmp_path)
    key = "deadbeef"
    cache._path(key).write_bytes(b"not a pickle")
    assert cache.get(key) is None
    assert cache.misses == 1
    # a well-formed pickle missing the expected keys is also just a miss
    cache._path(key).write_bytes(pickle.dumps({"wrong": "shape"}))
    assert cache.get(key) is None
    assert cache.misses == 2


def test_disk_cache_does_not_swallow_fatal(tmp_path, monkeypatch):
    import repro.core.hls.dse as dse_mod

    cache = DiskCompileCache(tmp_path)
    key = "deadbeef"
    cache._path(key).write_bytes(b"whatever")

    def boom(blob):
        raise MemoryError("boom")

    monkeypatch.setattr(dse_mod.pickle, "loads", boom)
    with pytest.raises(MemoryError):
        cache.get(key)
