"""Tests of the MII-bounded modulo-schedule search: the II search must start
at max(resMII, recMII) and never probe below it, galloping + binary search
must find the same minimal II as the reference linear scan (with schedules
identical up to auto-generated value names), scheduler options must thread
through ``hls_compile``, and the fingerprint caches must serve warm repeats
with identical output."""

import os

import numpy as np
import pytest

from repro.core import ir
from repro.core.builder import Builder
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls import (SchedulerOptions, erase_schedule, hls_compile,
                            hls_schedule)
from repro.core.hls import dse
from repro.core.lower import simulate
from repro.core.parser import parse
from repro.core.printer import print_func, print_module


def _structural(m):
    """Printed module with positional names for auto-generated values, so
    schedules compare equal across runs that allocate different global ids
    (balance-inserted delays are anonymous)."""
    return "\n".join(print_func(f, 1, namer=dse._StructuralNamer())
                     for f in m.funcs.values())


# ---------------------------------------------------------------------------
# MII lower bounds
# ---------------------------------------------------------------------------


def _build_port_pressure(n_reads: int):
    """One single-bank read port accessed ``n_reads`` times per iteration:
    resMII = n_reads."""
    b = Builder(ir.Module("m"))
    rmem = ir.MemrefType((16,), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((16,), ir.i32, ir.PORT_W)
    with b.func("f", [rmem, wmem], ["Ai", "Bo"]) as f:
        Ai, Bo = f.args
        with b.for_(0, 16, 1, at=f.t, iv_name="i") as li:
            b.yield_(at=li.time + 1)
            vs = [b.read(Ai, [li.iv], at=li.time + k) for k in range(n_reads)]
            s = vs[0]
            for v in vs[1:]:
                s = b.add(s, v)
            b.write(s, Bo, [li.iv], at=li.time + n_reads)
        b.ret()
    return b.module


def test_resmii_bound_from_port_pressure():
    um = erase_schedule(_build_port_pressure(4))
    res = hls_schedule(um)
    assert res.miis["i"] == 4          # 4 accesses on one bank
    assert res.iis["i"] == 4           # bound is tight here
    assert res.ii_probes["i"] == [4]   # a from-1 scan would probe 1,2,3,4


def test_recmii_bound_from_carried_recurrence():
    """Read-modify-write through one BRAM cell: the carried cycle
    read -> add -> write -> (next-iteration) read forces II >= 2."""
    b = Builder(ir.Module("m"))
    rmem = ir.MemrefType((16,), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((16,), ir.i32, ir.PORT_W)
    with b.func("g", [rmem, wmem], ["Ai", "Bo"]) as f:
        Ai, Bo = f.args
        acc = ir.MemrefType((1,), ir.i32, kind=ir.KIND_BRAM)
        Ar, Aw = b.alloc(acc, names=["Ar", "Aw"])
        with b.for_(0, 16, 1, at=f.t, iv_name="i") as li:
            b.yield_(at=li.time + 2)
            x = b.read(Ai, [li.iv], at=li.time)
            a = b.read(Ar, [0], at=li.time)
            s = b.add(a, x)
            b.write(s, Aw, [0], at=li.time + 1)
            b.write(s, Bo, [li.iv], at=li.time + 1)
        b.ret()
    um = erase_schedule(b.module)
    res = hls_schedule(um)
    assert res.miis["i"] == 2
    assert res.iis["i"] == 2
    assert res.ii_probes["i"] == [2]


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
def test_search_never_probes_below_mii(name):
    m, _ = GALLERY[name].build()
    res = hls_schedule(erase_schedule(m))
    assert res.ii_probes, "no pipelined loops probed"
    for iv, probes in res.ii_probes.items():
        mii = res.miis[iv]
        assert probes[0] == mii, (iv, probes, mii)
        assert min(probes) >= mii, (iv, probes, mii)
        assert res.iis[iv] >= mii


def test_mii_bound_prunes_the_scan():
    """Across the gallery the bounded search probes no more often than a
    from-1 linear scan would (one probe per II value up to the answer), and
    strictly fewer on histogram (II = 2, bound = 2: one probe, not two)."""
    total_probes, total_from1 = 0, 0
    for name in PAPER_BENCHMARKS:
        m, _ = GALLERY[name].build()
        res = hls_schedule(erase_schedule(m))
        for iv, probes in res.ii_probes.items():
            total_probes += len(probes)
            total_from1 += res.iis[iv]
            assert len(probes) <= res.iis[iv]
    assert total_probes < total_from1


# ---------------------------------------------------------------------------
# Gallop + binary search vs the reference linear scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
def test_gallop_matches_linear_scan(name):
    m, _ = GALLERY[name].build()
    txt = print_module(erase_schedule(m))
    ua, ub = parse(txt), parse(txt)
    ra = hls_schedule(ua)
    rb = hls_schedule(ub, options=SchedulerOptions(linear_scan=True))
    assert ra.iis == rb.iis
    assert ra.miis == rb.miis
    assert _structural(ua) == _structural(ub)
    assert ra.search_iters <= rb.search_iters


# ---------------------------------------------------------------------------
# Unroll staggering (nested loops through MemTouches summaries)
# ---------------------------------------------------------------------------


def _build_nested_unroll(banked: bool):
    """Outer ``unroll_for`` whose body is an inner sequential loop writing a
    2-d memref: with dim 0 distributed each unrolled lane owns a bank and
    lanes run parallel; with a shared monolithic port they must stagger.
    The stagger decision sees the inner *loop's* summarized touches — the
    path the seed's dead ``isinstance(o, ForOp)`` branch never reached."""
    b = Builder(ir.Module("m"))
    packed = [1] if banked else [0, 1]
    wmem = ir.MemrefType((4, 8), ir.i32, ir.PORT_W, packed=packed,
                         kind=ir.KIND_BRAM)
    with b.func("f", [wmem], ["Bo"]) as f:
        Bo, = f.args
        with b.for_(0, 4, 1, at=f.t, unroll=True, iv_name="u") as lu:
            b.yield_(at=lu.time)
            with b.for_(0, 8, 1, at=lu.time, iv_name="i") as li:
                b.yield_(at=li.time + 1)
                b.write(li.iv, Bo, [lu.iv, li.iv], at=li.time)
        b.ret()
    return b.module


@pytest.mark.parametrize("banked,want_parallel", [(True, True), (False, False)])
def test_nested_loop_unroll_stagger(banked, want_parallel):
    um = erase_schedule(_build_nested_unroll(banked))
    hls_schedule(um)
    outer = next(op for op in um.get("f").body.ops if isinstance(op, ir.ForOp))
    y = outer.yield_op()
    assert y.start.tv is outer.time_var
    if want_parallel:
        assert y.start.offset == 0      # per-lane banks: fully parallel
    else:
        assert y.start.offset >= 8      # shared port: serialized lanes


def test_unroll_parallel_option_forces_stagger():
    um = erase_schedule(_build_nested_unroll(True))
    hls_schedule(um, options=SchedulerOptions(unroll_parallel=False))
    outer = next(op for op in um.get("f").body.ops if isinstance(op, ir.ForOp))
    assert outer.yield_op().start.offset >= 1


# ---------------------------------------------------------------------------
# Option threading through hls_compile
# ---------------------------------------------------------------------------


def test_hls_compile_threads_pipeline_loops():
    m, entry = GALLERY["stencil1d"].build()
    um = erase_schedule(m)
    res, _ = hls_compile(um, entry=entry, pipeline_loops=False, cache=False)
    assert res.miis == {}          # no modulo search ran
    assert res.ii_probes == {}
    assert all(ii >= 1 for ii in res.iis.values())


def test_hls_compile_threads_scheduler_options():
    m, entry = GALLERY["stencil1d"].build()
    um = erase_schedule(m)
    res, _ = hls_compile(um, entry=entry,
                         options=SchedulerOptions(min_ii=3), cache=False)
    assert res.ii_probes, "expected pipelined loops"
    assert all(mii >= 3 for mii in res.miis.values())
    assert all(ii >= 3 for ii in res.iis.values() if ii)
    # and the throttled design still computes the right answer
    gal = GALLERY["stencil1d"]
    ins = gal.make_inputs()
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], gal.oracle(ins[0]))


# ---------------------------------------------------------------------------
# Fingerprint caches
# ---------------------------------------------------------------------------


def test_schedule_cache_hits_and_identity():
    cache = dse.ScheduleCache()
    m, _ = GALLERY["transpose"].build()
    erased = erase_schedule(m)
    m1, m2 = erased.clone(), erased.clone()
    r1 = hls_schedule(m1, cache=cache)
    assert (r1.search_cache_hits, r1.search_cache_misses) == (0, 1)
    r2 = hls_schedule(m2, cache=cache)
    assert (r2.search_cache_hits, r2.search_cache_misses) == (1, 0)
    assert r2.iis == r1.iis and r2.miis == r1.miis
    assert _structural(m1) == _structural(m2)
    assert cache.stats_dict()["hits"] == 1


def test_compile_cache_warm_repeat_identical():
    m, entry = GALLERY["gemm"].build()
    erased = erase_schedule(m)
    dse.COMPILE_CACHE.clear()
    dse.SCHEDULE_CACHE.clear()
    dse.FUNC_CODEGEN_CACHE.clear()
    m1, m2 = erased.clone(), erased.clone()
    r1, v1 = hls_compile(m1, entry=entry)
    r2, v2 = hls_compile(m2, entry=entry)
    assert not r1.from_cache and r2.from_cache
    assert r2.search_cache_stats()["hits"] >= 1
    assert print_module(m1) == print_module(m2)     # scheduled HIR identical
    assert set(v1) == set(v2)                       # backend output identical
    for name in v1:
        assert v1[name].text == v2[name].text
    assert r2.iis == r1.iis


@pytest.mark.skipif(os.environ.get("REPRO_SKIP_PERF") == "1",
                    reason="perf asserts skipped on slow runners")
def test_compile_cache_warm_repeat_is_10x_faster():
    import time

    m, entry = GALLERY["gemm"].build()
    erased = erase_schedule(m)
    dse.COMPILE_CACHE.clear()
    dse.SCHEDULE_CACHE.clear()
    dse.FUNC_CODEGEN_CACHE.clear()
    t0 = time.perf_counter()
    hls_compile(erased.clone(), entry=entry)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    res, _ = hls_compile(erased.clone(), entry=entry)
    warm = time.perf_counter() - t0
    assert res.from_cache
    assert cold >= 10 * warm, (cold, warm)


def test_cache_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_HLS_CACHE", "0")
    m, entry = GALLERY["transpose"].build()
    erased = erase_schedule(m)
    r1, _ = hls_compile(erased.clone(), entry=entry)
    r2, _ = hls_compile(erased.clone(), entry=entry)
    assert not r1.from_cache and not r2.from_cache
    assert r2.search_cache_stats()["hits"] == 0


def test_parallel_schedule_matches_serial():
    # gemm calls mac, so result-delay reconciliation forces the serial
    # callee-first path even at max_workers=2 — both runs must agree
    m, _ = GALLERY["gemm"].build()
    erased = erase_schedule(m)
    ma, mb = erased.clone(), erased.clone()
    ra = hls_schedule(ma, max_workers=1)
    rb = hls_schedule(mb, max_workers=2)
    assert ra.iis == rb.iis and ra.miis == rb.miis
    assert _structural(ma) == _structural(mb)


def test_parallel_schedule_matches_serial_flat_module():
    # a module whose functions never call each other takes the process-pool
    # path; output must be byte-identical to the serial schedule
    src_a = print_module(erase_schedule(GALLERY["array_add"].build(n=8)[0]))
    src_b = print_module(erase_schedule(GALLERY["transpose"].build(n=4)[0]))
    merged = src_a + "\n" + src_b
    ma, mb = parse(merged), parse(merged)
    ra = hls_schedule(ma, max_workers=1)
    rb = hls_schedule(mb, max_workers=2)
    assert ra.iis == rb.iis and ra.miis == rb.miis
    assert _structural(ma) == _structural(mb)


def test_result_delay_padded_to_declaration():
    # at a 10 ns clock stencil_op's body completes one cycle before its
    # declared result delay: the call site latches exactly `delay` cycles
    # after issue, so the reschedule must hold the returned value to the
    # declared cycle with a trailing hir.delay instead of streaming early
    m, _ = GALLERY["stencil1d"].build(n=8)
    um = erase_schedule(m)
    hls_schedule(um, options=SchedulerOptions(clock_ns=10.0))
    f = um.funcs["stencil_op"]
    assert tuple(f.attrs["result_delays"]) == (1,)
    ret = next(op for op in f.body.ops if op.opname == "return")
    d = ret.operands[0].defining_op
    assert d is not None and d.opname == "delay"


def test_result_delay_bumped_and_call_sites_synced():
    # at a 5 ns clock mac's chained multiply-add needs one pipeline stage
    # more than gemm's zero-delay declaration allows; the declaration is
    # bumped and every call site refreshed before gemm itself is scheduled
    m, _ = GALLERY["gemm"].build(n=4)
    um = erase_schedule(m)
    hls_schedule(um, options=SchedulerOptions(clock_ns=5.0))
    ds = tuple(um.funcs["mac"].attrs["result_delays"])
    assert ds and ds[0] >= 1
    calls = [op for f in um.funcs.values() for op in f.body.walk()
             if op.opname == "call" and op.attrs.get("callee") == "mac"]
    assert calls
    assert all(tuple(c.attrs["result_delays"]) == ds for c in calls)
