"""Differential acceptance tests for the vectorized RTL simulator.

Every gallery kernel is run on >= 256 random stimulus vectors through the
batched cycle-accurate simulator and checked three ways (``run_differential``):
against the event-driven HIR simulator on sample lanes, against the kernel's
functional numpy oracle on *every* lane, and per-RTL-pass (pass input vs pass
output, per-cycle result-port traces) — in both inline and hierarchical
emission modes.  Plus unit tests for the simulator's value semantics and the
batch/stimulus API."""

import functools

import numpy as np
import pytest

from repro.core.codegen import sim as rsim
from repro.core.codegen.rtl import Binop, Const, Ref, Signed
from repro.core.gallery import (array_add, conv2d, fifo, gemm, gemm_shared,
                                histogram, mac, stencil1d, transpose)
from repro.core.lower import simulate_batch

N_VECTORS = 256

# kernel -> (module, build kwargs, make_inputs kwargs, oracle, oracle_nargs)
KERNELS = {
    "array_add": (array_add, {"n": 8}, {"n": 8}, array_add.oracle, 2),
    "transpose": (transpose, {"n": 4}, {"n": 4}, transpose.oracle, 1),
    "gemm": (gemm, {"n": 4}, {"n": 4}, gemm.oracle, 2),
    # column-staggered II=n schedule: its hierarchical emission exercises
    # rtl-share-instances' time-division muxes under the full matrix
    "gemm_shared": (gemm_shared, {"n": 4}, {"n": 4}, gemm_shared.oracle, 2),
    "stencil1d": (stencil1d, {"n": 8}, {"n": 8}, stencil1d.oracle, 1),
    "conv2d": (conv2d, {"h": 6, "w": 6}, {"h": 6, "w": 6}, conv2d.oracle, 1),
    "histogram": (histogram, {"n": 8, "bins": 4}, {"n": 8, "bins": 4},
                  functools.partial(histogram.oracle, bins=4), 1),
    "fifo": (fifo, {"depth": 16, "n": 8}, {"n": 8}, fifo.oracle, 1),
}

HIERARCHIES = ["inline", "modules"]


@pytest.mark.slow
@pytest.mark.parametrize("hierarchy", HIERARCHIES)
@pytest.mark.parametrize("kernel", sorted(KERNELS))
def test_gallery_differential(kernel, hierarchy):
    gal, bkw, ikw, oracle, nargs = KERNELS[kernel]
    mod, entry = gal.build(**bkw)
    batch = rsim.stack_stimulus(gal.make_inputs, N_VECTORS, base_seed=7,
                                **ikw)
    rep = rsim.run_differential(mod, entry, batch, kernel=kernel,
                                hierarchy=hierarchy, oracle=oracle,
                                oracle_nargs=nargs)
    assert rep.ok, (kernel, hierarchy, rep.mismatches[:5])
    assert rep.n_vectors == N_VECTORS
    assert rep.event_lanes_checked >= 2
    assert rep.oracle_ok is True
    assert rep.passes_ok and all(rep.passes_ok.values()), rep.passes_ok


@pytest.mark.parametrize("hierarchy", HIERARCHIES)
def test_mac_differential(hierarchy):
    # mac takes three scalar args and returns a scalar — the oracle leg
    # checks the captured result value on every lane instead of a memref
    mod, entry = mac.build()
    rng = np.random.default_rng(11)
    batch = [rng.integers(0, 1 << 15, size=N_VECTORS).astype(np.int64)
             for _ in range(3)]
    rep = rsim.run_differential(mod, entry, batch, kernel="mac",
                                hierarchy=hierarchy)
    assert rep.ok, rep.mismatches[:5]
    sim, prepared = rsim.simulator_for(mod, entry, hierarchy=hierarchy)
    cycles = rsim.probe_cycles(prepared, entry, [int(c[0]) for c in batch])
    res = sim.run(batch, cycles, batched=True)
    want = np.array([mac.oracle(int(a), int(b), int(c))
                     for a, b, c in zip(*batch)], dtype=np.int64)
    assert np.all(np.asarray(res.returns_valid[0]) == 1)
    assert np.array_equal(np.asarray(res.returns[0]), want)


@pytest.mark.skipif(not rsim.HAVE_JAX, reason="jax unavailable")
def test_numpy_and_jax_backends_agree():
    mod, entry = gemm.build(n=4)
    batch = rsim.stack_stimulus(gemm.make_inputs, 32, base_seed=3, n=4)
    results = {}
    for backend in ("numpy", "jax"):
        sim, prepared = rsim.simulator_for(mod, entry, backend=backend)
        cycles = rsim.probe_cycles(prepared, entry,
                                   [c[0] for c in batch])
        results[backend] = sim.run(batch, cycles, batched=True, trace=True)
    a, b = results["numpy"], results["jax"]
    for i in a.arrays:
        assert np.array_equal(a.arrays[i], b.arrays[i]), f"arg {i}"
    for p in a.trace:
        assert np.array_equal(a.trace[p], b.trace[p]), f"trace {p}"
    assert np.array_equal(a.conflicts, b.conflicts)
    assert not a.conflicts.any()


def test_vectorized_matches_event_batch():
    # simulate_batch (per-lane event-driven) and the batched simulator agree
    # on final memref state for every lane
    mod, entry = stencil1d.build(n=8)
    batch = rsim.stack_stimulus(stencil1d.make_inputs, 16, base_seed=5, n=8)
    sim, prepared = rsim.simulator_for(mod, entry, backend="numpy")
    cycles = rsim.probe_cycles(prepared, entry, [c[0] for c in batch])
    res = sim.run(batch, cycles, batched=True)
    _, finals = simulate_batch(prepared, entry, batch)
    for i, fin in enumerate(finals):
        if fin is not None:
            assert np.array_equal(res.arrays[i], fin), f"arg {i}"


def test_division_is_floor_and_by_zero_is_zero():
    # matches the event-driven oracle: signed floor division, x/0 == 0
    widths = {"a": 8, "b": 8}
    expr = Binop("/", Signed(Ref("a")), Signed(Ref("b")), width=8)
    fn, _ = rsim._compile_expr(expr, widths)
    ops = rsim._NumpyOps(4)
    env = {"a": np.array([0xF9, 0xF9, 7, 7], dtype=np.int64),   # -7,-7,7,7
           "b": np.array([2, 0, 2, 0xFE], dtype=np.int64)}      # 2,0,2,-2
    got = np.asarray(fn(env, ops)) & 0xFF
    assert got.tolist() == [(-4) & 0xFF, 0, 3, (-4) & 0xFF]


def test_shift_clamp_semantics():
    widths = {"a": 8, "s": 8}
    fn, _ = rsim._compile_expr(Binop("<<", Ref("a"), Ref("s"), width=8),
                               widths)
    ops = rsim._NumpyOps(3)
    env = {"a": np.array([1, 1, 0xFF], dtype=np.int64),
           "s": np.array([3, 200, 1], dtype=np.int64)}
    got = np.asarray(fn(env, ops)) & 0xFF
    assert got.tolist() == [8, 0, 0xFE]


def test_wide_nets_rejected():
    with pytest.raises(rsim.RTLSimError):
        rsim._mask_of(64)


def test_stack_stimulus_shapes_and_determinism():
    batch = rsim.stack_stimulus(array_add.make_inputs, 5, base_seed=1, n=8)
    assert [b.shape for b in batch] == [(5, 8)] * 3
    again = rsim.stack_stimulus(array_add.make_inputs, 5, base_seed=1, n=8)
    assert all(np.array_equal(a, b) for a, b in zip(batch, again))
    # lanes differ (distinct seeds)
    assert not np.array_equal(batch[0][0], batch[0][1])


def test_unbatched_run_lifts_to_single_lane():
    mod, entry = array_add.build(n=8)
    sim, prepared = rsim.simulator_for(mod, entry, backend="numpy")
    args = array_add.make_inputs(n=8, seed=9)
    cycles = rsim.probe_cycles(prepared, entry, args)
    res = sim.run(args, cycles)
    assert res.batch == 1
    want = array_add.oracle(args[0], args[1])
    assert np.array_equal(res.arrays[2][0], want)


def test_all_backend_printers_simulate_identically():
    # (c) leg of the differential harness: every backend printer emits from
    # the same RTL structure, so the cycle-accurate behavior bound to each
    # backend's source modules must be identical (the text-level conformance
    # is covered by the PR 4 golden/lint suites)
    from repro.core.codegen import BACKENDS, generate_verilog
    from repro.core.codegen.sim import RTLSimulator, design_of

    batch = rsim.stack_stimulus(array_add.make_inputs, 16, base_seed=4, n=8)
    ref = None
    for backend in sorted(BACKENDS):
        mod, entry = array_add.build(n=8)
        prepared = mod
        mods = generate_verilog(prepared, entry, backend=backend)
        assert all(vm.text.strip() for vm in mods.values()), backend
        sim = RTLSimulator(design_of(mods, entry),
                           prepared.funcs[entry], entry, backend="numpy")
        cycles = rsim.probe_cycles(prepared, entry, [c[0] for c in batch])
        res = sim.run(batch, cycles, batched=True)
        if ref is None:
            ref = res
        else:
            for i in ref.arrays:
                assert np.array_equal(ref.arrays[i], res.arrays[i]), \
                    (backend, i)


RESCHEDULE_CONFIGS = [
    # (kernel, pipeline, clock_ns) — the exact configs where a rescheduled
    # callee body used to violate its declared result-delay contract (the
    # call site latched data one cycle early) or ControllerMerge dropped a
    # merged FSM's iicnt net with readers still attached; caught by this
    # batched differential, invisible to the event-driven HIR simulator
    ("stencil1d", True, 10.0),
    ("stencil1d", True, 2.5),
    ("gemm", True, 5.0),
    ("gemm", True, 2.5),
    ("gemm", False, 10.0),  # merged ii=2 controllers: dangling iicnt refs
]


@pytest.mark.parametrize("kernel,pipe,clock_ns", RESCHEDULE_CONFIGS)
def test_rescheduled_design_matches_oracle(kernel, pipe, clock_ns):
    from repro.core.hls import SchedulerOptions, erase_schedule, hls_schedule
    from repro.core.passmgr import DEFAULT_PIPELINE_SPEC, PassManager

    gal, bkw, ikw, oracle, nargs = KERNELS[kernel]
    mod, entry = gal.build(**bkw)
    um = erase_schedule(mod)
    hls_schedule(um, options=SchedulerOptions(pipeline_loops=pipe,
                                              clock_ns=clock_ns))
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(um)
    n_vec = 16
    batch = rsim.stack_stimulus(gal.make_inputs, n_vec, base_seed=9, **ikw)
    sim, prepared = rsim.simulator_for(um, entry, backend="numpy")
    cycles = rsim.probe_cycles(prepared, entry, [c[0] for c in batch])
    res = sim.run(batch, cycles, batched=True)
    want = np.stack([np.asarray(oracle(*[col[k] for col in batch[:nargs]]))
                     for k in range(n_vec)])
    got = np.asarray(res.arrays[len(batch) - 1]).reshape(want.shape)
    assert np.array_equal(got, want), (kernel, pipe, clock_ns)


def test_const_fold_matches_event_sim_on_passes():
    # verify_rtl_passes standalone: every RTL pass preserves per-cycle
    # result-port traces and final state on a real kernel
    mod, entry = transpose.build(n=4)
    batch = rsim.stack_stimulus(transpose.make_inputs, 8, base_seed=2, n=4)
    sim, prepared = rsim.simulator_for(mod, entry, backend="numpy")
    cycles = rsim.probe_cycles(prepared, entry, [c[0] for c in batch])
    ok, mism = rsim.verify_rtl_passes(prepared, entry, batch, cycles,
                                      hierarchy="inline")
    assert ok and all(ok.values()), mism[:5]
