"""Hierarchical (non-inlined) Verilog emission: every non-trivial
``hir.func`` stays a Verilog module instantiated at its ``hir.call`` sites,
semantics are preserved (sim-vs-jax on every gallery kernel), resources are
costed with per-instance multiplicity, and the emitted RTL lints clean in
both emission modes."""

import numpy as np
import pytest

from repro.core.codegen import (generate_verilog, lint_verilog,
                                report_design)
from repro.core.gallery import GALLERY
from repro.core.lower import lower_to_jax, simulate
from repro.core.passes import run_pipeline

ORACLE_NARGS = {"transpose": 1, "array_add": 2, "histogram": 1, "stencil1d": 1,
                "gemm": 2, "conv2d": 1, "fifo": 1}


def _expected(name, ins):
    return GALLERY[name].oracle(*ins[: ORACLE_NARGS[name]])


# ---------------------------------------------------------------------------
# the gemm/mac hierarchy (the paper's §5.4 module-composition story)
# ---------------------------------------------------------------------------


def test_gemm_emits_instantiated_mac_module():
    m, entry = GALLERY["gemm"].build()
    run_pipeline(m)
    vs = generate_verilog(m, entry, hierarchy="modules")
    assert "mac" in vs and entry in vs
    top = vs[entry]
    # 16x16 PE grid -> 256 instances of the one mac module
    assert top.netlist.instances.count("mac") == 256
    assert "mac u_mac" in top.text
    assert "module mac (" in vs["mac"].text
    # the mac *module* holds one 32-bit multiply; the grid costs 256x it
    assert report_design(vs, entry).dsp == 768
    assert vs["mac"].netlist.mults == [(32, "dsp")]


def test_gemm_hierarchical_matches_oracle():
    mod = GALLERY["gemm"]
    m, entry = mod.build()
    run_pipeline(m)
    generate_verilog(m, entry, hierarchy="modules")  # mutates (unroll only)
    ins = mod.make_inputs()
    simulate(m, entry, ins)
    np.testing.assert_array_equal(ins[-1], _expected("gemm", ins))


def test_stencil_and_fifo_keep_their_callees_as_modules():
    for name, callee in (("stencil1d", "stencil_op"), ("fifo", "fifo_step")):
        m, entry = GALLERY[name].build()
        run_pipeline(m)
        vs = generate_verilog(m, entry, hierarchy="modules")
        assert callee in vs, name
        assert callee in vs[entry].netlist.instances, name
        assert f"module {callee} (" in vs[callee].text


def test_inline_mode_still_flattens():
    m, entry = GALLERY["stencil1d"].build()
    run_pipeline(m)
    vs = generate_verilog(m, entry, hierarchy="inline")
    assert vs[entry].netlist.instances == []


# ---------------------------------------------------------------------------
# semantics + lint over the whole gallery, both emission modes
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_hierarchical_emission_preserves_semantics(name):
    """generate_verilog(hierarchy="modules") mutates the module (unroll +
    trivial-inline only); the result must still simulate and JAX-lower to
    the oracle."""
    mod = GALLERY[name]
    m, entry = mod.build()
    run_pipeline(m)
    vs = generate_verilog(m, entry, hierarchy="modules")
    assert vs[entry].text.startswith("// generated")

    ins = mod.make_inputs()
    simulate(m, entry, ins)
    np.testing.assert_array_equal(ins[-1], _expected(name, ins))

    fn = lower_to_jax(m, entry)
    ins2 = mod.make_inputs()
    out = fn(*[np.asarray(x, dtype=np.int32) for x in ins2])
    f = m.get(entry)
    outname = [a.name for a in f.args
               if hasattr(a.type, "port") and a.type.port in ("w", "rw")][-1]
    np.testing.assert_array_equal(np.asarray(out[outname], np.int64),
                                  _expected(name, ins2))


@pytest.mark.parametrize("mode", ["inline", "modules"])
@pytest.mark.parametrize("name", sorted(ORACLE_NARGS))
def test_emitted_rtl_lints_clean(name, mode):
    mod = GALLERY[name]
    m, entry = mod.build()
    run_pipeline(m)
    vs = generate_verilog(m, entry, hierarchy=mode)
    text = "\n".join(vm.text for vm in vs.values())
    assert lint_verilog(text, known_modules=list(vs)) == []


# ---------------------------------------------------------------------------
# RTL pipeline reduces resources on the gallery (acceptance criterion)
# ---------------------------------------------------------------------------


def test_rtl_pipeline_reduces_resources_on_at_least_three_kernels():
    from copy import deepcopy

    reduced = 0
    for name in ("transpose", "stencil1d", "histogram", "gemm", "conv2d", "fifo"):
        m, entry = GALLERY[name].build()
        run_pipeline(m)
        pre = report_design(
            generate_verilog(deepcopy(m), entry, rtl_spec=None), entry)
        post = report_design(generate_verilog(deepcopy(m), entry), entry)
        assert post.lut <= pre.lut and post.ff <= pre.ff, name  # never grows
        assert post.dsp == pre.dsp and post.bram == pre.bram, name
        if post.lut < pre.lut or post.ff < pre.ff:
            reduced += 1
    assert reduced >= 3
