"""Tests of the HLS-style auto-scheduler baseline (the paper's Vivado HLS
comparison point): the erased (unscheduled) designs must be re-scheduled to
functionally-correct implementations, and the explicit-schedule path must be
faster to compile (Table 6's mechanism)."""

import time

import numpy as np
import pytest

from repro.core.codegen import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls import erase_schedule, hls_compile, hls_schedule
from repro.core.lower import simulate
from repro.core.passes import run_pipeline

ORACLE_NARGS = {"transpose": 1, "histogram": 1, "stencil1d": 1, "gemm": 2, "conv2d": 1, "fifo": 1}


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
def test_hls_rescheduled_design_is_correct(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    um = erase_schedule(m)
    hls_compile(um, entry=entry)
    ins = mod.make_inputs()
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], mod.oracle(*ins[: ORACLE_NARGS[name]]))


def test_eraser_strips_everything():
    m, _ = GALLERY["transpose"].build()
    um = erase_schedule(m)
    f = um.get("transpose")
    for op in f.body.walk():
        assert op.start is None
        assert op.opname != "delay"


def test_hls_finds_ii1_for_simple_pipeline():
    m, entry = GALLERY["transpose"].build()
    um = erase_schedule(m)
    res = hls_schedule(um)
    assert res.iis.get("j") == 1  # inner loop pipelines fully


def test_hls_respects_rmw_recurrence():
    """Histogram's read-modify-write through the bin RAM forces II >= 2."""
    m, entry = GALLERY["histogram"].build()
    um = erase_schedule(m)
    res = hls_schedule(um)
    assert res.iis.get("i", 0) >= 2


def test_explicit_schedule_verification_beats_schedule_search():
    """The Table 6 mechanism: with explicit schedules the compiler only
    *verifies* (linear passes); the HLS baseline must *search* (II loop,
    reservation tables, balancing).  Verification must be faster than search
    on the same kernel.  Verilog emission is shared by both paths and
    excluded."""
    from repro.core import verifier

    name = "gemm"
    mod = GALLERY[name]
    reps = 3

    t_hir = 1e9
    for _ in range(reps):
        m, entry = mod.build()
        t0 = time.perf_counter()
        verifier.verify(m)
        t_hir = min(t_hir, time.perf_counter() - t0)

    t_hls = 1e9
    for _ in range(reps):
        m2, _ = mod.build()
        um = erase_schedule(m2)
        t0 = time.perf_counter()
        hls_schedule(um)
        t_hls = min(t_hls, time.perf_counter() - t0)

    assert t_hls > t_hir, f"schedule search ({t_hls:.4f}s) should dominate verification ({t_hir:.4f}s)"
