"""Tests of the HLS-style auto-scheduler baseline (the paper's Vivado HLS
comparison point): the erased (unscheduled) designs must be re-scheduled to
functionally-correct implementations, and the explicit-schedule path must be
faster to compile (Table 6's mechanism)."""

import time

import numpy as np
import pytest

from repro.core.codegen import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls import erase_schedule, hls_compile, hls_schedule
from repro.core.lower import simulate
from repro.core.passes import run_pipeline

ORACLE_NARGS = {"transpose": 1, "histogram": 1, "stencil1d": 1, "gemm": 2, "conv2d": 1, "fifo": 1}


@pytest.mark.parametrize("name", PAPER_BENCHMARKS)
def test_hls_rescheduled_design_is_correct(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    um = erase_schedule(m)
    hls_compile(um, entry=entry)
    ins = mod.make_inputs()
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], mod.oracle(*ins[: ORACLE_NARGS[name]]))


def test_eraser_strips_everything():
    m, _ = GALLERY["transpose"].build()
    um = erase_schedule(m)
    f = um.get("transpose")
    for op in f.body.walk():
        assert op.start is None
        assert op.opname != "delay"


def test_hls_finds_ii1_for_simple_pipeline():
    m, entry = GALLERY["transpose"].build()
    um = erase_schedule(m)
    res = hls_schedule(um)
    assert res.iis.get("j") == 1  # inner loop pipelines fully


def test_hls_respects_rmw_recurrence():
    """Histogram's read-modify-write through the bin RAM forces II >= 2."""
    m, entry = GALLERY["histogram"].build()
    um = erase_schedule(m)
    res = hls_schedule(um)
    assert res.iis.get("i", 0) >= 2


def test_unroll_iv_banked_writes_run_parallel():
    """Regression for the dead `and False` clause in the old touch analysis:
    an unroll IV indexing a *distributed* dim selects a distinct bank per
    iteration, so iterations are legal in parallel (stagger 0) even for
    writes.  The bug pessimized them to staggered execution."""
    from repro.core import ir
    from repro.core.builder import Builder

    b = Builder(ir.Module("m"))
    regs = ir.MemrefType((8,), ir.i32, packed=[], kind=ir.KIND_REG)
    with b.func("f", [], []) as f:
        Rr, Rw = b.alloc(regs, names=["Rr", "Rw"])
        with b.for_(0, 8, 1, at=f.t + 1, unroll=True, iv_name="u") as lu:
            b.yield_(at=lu.time)
            b.write(7, Rw, [lu.iv], at=lu.time)
        b.ret()
    um = erase_schedule(b.module)
    hls_schedule(um)
    loop = next(op for op in um.get("f").body.walk() if isinstance(op, ir.ForOp))
    y = loop.yield_op()
    assert y.start.tv is loop.time_var and y.start.offset == 0  # fully parallel


def test_gemm_accumulator_unrolls_are_parallel_banked():
    """Gallery-level regression: in the HLS-rescheduled GEMM the
    accumulator-zeroing and PE-compute unroll loops write a fully distributed
    register bank indexed by their unroll IVs — distinct banks, stagger 0 —
    while the single-ported drain loop stays staggered."""
    from repro.core import ir

    m, entry = GALLERY["gemm"].build()
    um = erase_schedule(m)
    hls_schedule(um)
    f = um.get(entry)
    staggers = {}
    for op in f.body.walk():
        if isinstance(op, ir.ForOp) and op.opname == "unroll_for":
            y = op.yield_op()
            staggers[op.iv.name] = y.start.offset if y.start.tv is op.time_var else None
    assert staggers["zi"] == 0 and staggers["zj"] == 0  # banked writes: parallel
    assert staggers["pi"] == 0 and staggers["pj"] == 0  # PE grid: parallel
    assert staggers["di"] > 0  # drain shares one output port: staggered
    # and the re-scheduled design still computes the right answer
    ins = GALLERY["gemm"].make_inputs()
    simulate(um, entry, ins)
    np.testing.assert_array_equal(ins[-1], GALLERY["gemm"].oracle(*ins[:2]))


def test_explicit_schedule_verification_beats_schedule_search():
    """The Table 6 mechanism: with explicit schedules the compiler only
    *verifies* (linear passes); the HLS baseline must *search* (II loop,
    reservation tables, balancing).  Verification must be faster than search
    on the same kernel.  Verilog emission is shared by both paths and
    excluded."""
    from repro.core import verifier

    name = "gemm"
    mod = GALLERY[name]
    reps = 3

    t_hir = 1e9
    for _ in range(reps):
        m, entry = mod.build()
        t0 = time.perf_counter()
        verifier.verify(m)
        t_hir = min(t_hir, time.perf_counter() - t0)

    t_hls = 1e9
    for _ in range(reps):
        m2, _ = mod.build()
        um = erase_schedule(m2)
        t0 = time.perf_counter()
        hls_schedule(um)
        t_hls = min(t_hls, time.perf_counter() - t0)

    assert t_hls > t_hir, f"schedule search ({t_hls:.4f}s) should dominate verification ({t_hir:.4f}s)"
