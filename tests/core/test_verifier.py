"""Schedule-verification tests reproducing the paper's Figures 1 and 2, plus
port-conflict and structural diagnostics."""

import pytest

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.gallery import array_add, mac


def _errors(m):
    return [d for d in verifier.verify(m, raise_on_error=False) if d.severity == "error"]


def test_fig1_stale_induction_variable():
    m, _ = array_add.build_broken()
    errs = _errors(m)
    assert len(errs) == 1
    assert "mismatched delay (0 vs 1) in address 0" in errs[0].message
    assert errs[0].notes and "Prior definition" in errs[0].notes[0][1]


def test_fig1_fixed_design_is_clean():
    m, _ = array_add.build()
    assert not _errors(m)


def test_fig2_pipeline_imbalance():
    m, _ = mac.build_broken()
    errs = _errors(m)
    assert len(errs) == 1
    assert "mismatched delay (2 vs 3) in right operand" in errs[0].message


def test_fig2_balanced_design_is_clean():
    m, _ = mac.build()
    assert not _errors(m)


def test_port_conflict_same_cycle_different_address():
    b = Builder(ir.Module("pc"))
    r = ir.MemrefType((8,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((8,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v0 = b.read(A, [b.const(0)], at=f.t)
        v1 = b.read(A, [b.const(1)], at=f.t)  # same port, same cycle, diff addr
        b.write(v0, O, [b.const(0)], at=f.t + 1)
        b.write(v1, O, [b.const(1)], at=f.t + 2)
        b.ret()
    errs = _errors(b.module)
    assert any("same cycle with different addresses" in e.message for e in errs)


def test_same_address_parallel_reads_are_legal():
    b = Builder(ir.Module("pc2"))
    r = ir.MemrefType((8,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((8,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v0 = b.read(A, [b.const(3)], at=f.t)
        v1 = b.read(A, [b.const(3)], at=f.t)  # broadcast: same address
        b.write(v0, O, [b.const(0)], at=f.t + 1)
        v1d = b.delay(v1, 1)  # v1 valid at t+1; hold one cycle for the t+2 write
        b.write(v1d, O, [b.const(1)], at=f.t + 2)
        b.ret()
    assert not _errors(b.module)


def test_pipelined_congruence_conflict():
    """Two accesses at offsets 0 and II inside an II-pipelined loop collide
    (same congruence class) even though their offsets differ."""
    b = Builder(ir.Module("pc3"))
    r = ir.MemrefType((64,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((64,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        with b.for_(0, 32, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 2)  # II = 2
            v0 = b.read(A, [l.iv], at=l.time)
            i2 = b.delay(l.iv, 2, at=l.time)
            v1 = b.read(A, [i2], at=l.time + 2)  # offset 2 ≡ 0 (mod 2)
            b.write(v0, O, [b.delay(l.iv, 1, at=l.time)], at=l.time + 1)
            b.write(v1, O, [b.delay(i2, 1)], at=l.time + 3)
        b.ret()
    errs = _errors(b.module)
    assert any("same cycle with different addresses" in e.message for e in errs)


def test_distributed_dim_needs_constant_index():
    b = Builder(ir.Module("bank"))
    w = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        bank = ir.MemrefType((4,), ir.i32, packed=[], kind=ir.KIND_REG)
        Br, Bw = b.alloc(bank, names=["Br", "Bw"])
        with b.for_(0, 4, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            b.write(0, Bw, [l.iv], at=l.time)  # dynamic bank index: error
        b.ret()
    errs = _errors(b.module)
    assert any("compile-time constant" in e.message for e in errs)


def test_time_variable_scoping():
    """Ops inside a loop may only schedule on the iteration time variable
    (paper §4.2)."""
    b = Builder(ir.Module("scope"))
    r = ir.MemrefType((8,), ir.i32, ir.PORT_R)
    with b.func("f", [r], ["A"]) as f:
        (A,) = f.args
        with b.for_(0, 4, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            # schedule on the FUNCTION time var from inside the loop: error
            b.read(A, [b.const(0)], at=f.t + 5)
        b.ret()
    errs = _errors(b.module)
    assert any("not\nvisible" in e.message.replace("is not ", "not\n") or "not" in e.message.lower()
               for e in errs)
    assert errs


def test_unscheduled_op_rejected_in_strict_mode():
    b = Builder(ir.Module("strict"))
    r = ir.MemrefType((8,), ir.i32, ir.PORT_R)
    with b.func("f", [r], ["A"]) as f:
        (A,) = f.args
        op = ir.mem_read(A, [b.const(0)], ir.Time(f.op.time_var, 0))
        op.start = None
        b.insert(op)
        b.ret()
    errs = _errors(b.module)
    assert any("unscheduled" in e.message for e in errs)


def test_alloc_inside_loop_rejected():
    b = Builder(ir.Module("allocscope"))
    with b.func("f", [], []) as f:
        with b.for_(0, 4, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            b.alloc(ir.MemrefType((4,), ir.i32), names=["Xr", "Xw"])
        b.ret()
    errs = _errors(b.module)
    assert any("function scope" in e.message for e in errs)


def test_diagnostics_render_with_locations():
    m, _ = array_add.build_broken()
    errs = _errors(m)
    rendered = errs[0].render()
    assert "array_add.py" in rendered
    assert "note: Prior definition here." in rendered


def test_sequential_iv_allowed_in_bounded_nested_scope():
    """HLS-style sequential loop (yield on its own tv, II >= body span) with
    a statically-bounded inner loop: the inner scope's use of the outer IV is
    legal — iterations never overlap and the inner loop completes within the
    iteration window."""
    b = Builder(ir.Module("seqiv"))
    w = ir.MemrefType((8, 8), ir.i32, ir.PORT_W)
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        with b.for_(0, 4, 1, at=f.t + 1, iv_name="r", tv_name="tr") as lr:
            b.yield_(at=lr.time + 10)  # II = 10 >= span (HLS sequential form)
            with b.for_(0, 4, 1, at=lr.time + 1, iv_name="c", tv_name="tc") as lc:
                b.yield_(at=lc.time + 1)
                i1 = b.delay(lc.iv, 1, at=lc.time)
                b.write(0, O, [lr.iv, i1], at=lc.time + 1)  # outer IV, inner scope
        b.ret()
    assert _errors(b.module) == []


def test_sequential_iv_rejected_when_nested_scope_unbounded():
    """Same shape, but the inner loop's trip count is dynamic: its latency is
    not statically derivable, so it is absent from the outer body span and
    may outlive the IV's validity window — the use must still be flagged."""
    b = Builder(ir.Module("seqiv_dyn"))
    r = ir.MemrefType((1,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((8, 8), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["N", "O"]) as f:
        N, O = f.args
        n = b.read(N, [0], at=f.t)  # dynamic bound -> trip count unknown
        with b.for_(0, 4, 1, at=f.t + 1, iv_name="r", tv_name="tr") as lr:
            b.yield_(at=lr.time + 10)
            with b.for_(0, n, 1, at=lr.time + 1, iv_name="c", tv_name="tc") as lc:
                b.yield_(at=lc.time + 1)
                i1 = b.delay(lc.iv, 1, at=lc.time)
                b.write(0, O, [lr.iv, i1], at=lc.time + 1)
        b.ret()
    errs = _errors(b.module)
    assert any("%tr" in e.message and "%tc" in e.message for e in errs)
