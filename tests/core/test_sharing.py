"""Cross-instance time-multiplexing (`rtl-share-instances`) and II-aware
arbitration (`rtl-arbitrate`): the `activation-intervals` pulse analysis,
the merge itself (gemm_shared's staggered II=n schedule shares, plain gemm's
coincident schedule must refuse), resource accounting (`sharing_summary`),
the `PortConflictAssert` conflict lanes under the vectorized simulator on
both backends, the DSE `share_instances` knob, and the `rtl-dce`
dangling-net audit (`REPRO_RTL_AUDIT=1`)."""

import numpy as np
import pytest

from repro.core import ir
from repro.core.analysis import (PULSES_TOP, ActivationIntervals,
                                 ActivationIntervalsAnalysis)
from repro.core.builder import Builder
from repro.core.codegen import sim as rsim
from repro.core.codegen import (generate_verilog, report_design,
                                sharing_summary)
from repro.core.codegen.rtl import Instance
from repro.core.codegen.sim import RTLSimError
from repro.core.gallery import GALLERY, gemm, gemm_shared
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager


def _emit(name, hierarchy="modules", rtl_spec="default", **bkw):
    gal = GALLERY[name]
    m, entry = gal.build(**bkw)
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
    kw = {} if rtl_spec == "default" else {"rtl_spec": rtl_spec}
    mods = generate_verilog(m, entry=entry, hierarchy=hierarchy, **kw)
    return mods, entry


# ---------------------------------------------------------------------------
# activation-intervals analysis
# ---------------------------------------------------------------------------


def test_pulses_of_staggered_instances_are_finite_and_disjoint():
    """On gemm_shared's hand schedule, every mac instance in one PE row has
    a finite t_start pulse set, and the sets within a row are pairwise
    disjoint — exactly the precondition rtl-share-instances merges on."""
    mods, entry = _emit("gemm_shared", rtl_spec=None, n=4)
    m = mods[entry].rtl
    ai = ActivationIntervalsAnalysis.run(m, None)
    assert isinstance(ai, ActivationIntervals)
    pulses = []
    for it in m.items:
        if isinstance(it, Instance):
            ts = dict((p, e) for p, e, _o in it.conns)["t_start"]
            pulses.append(ai.of_expr(ts))
    assert len(pulses) == 16
    assert all(p is not PULSES_TOP and len(p) == 4 for p in pulses)
    # 4 groups of 4 mutually-disjoint schedules (one group per PE row)
    rows = [pulses[i:i + 4] for i in range(0, 16, 4)]
    for row in rows:
        union = frozenset().union(*row)
        assert len(union) == sum(len(p) for p in row)   # pairwise disjoint


def test_pulses_of_coincident_instances_overlap():
    """Plain gemm fires all PEs of a wavefront in the same cycle: the sets
    must overlap (or be TOP), so sharing is correctly refused."""
    mods, entry = _emit("gemm", rtl_spec=None, n=4)
    m = mods[entry].rtl
    ai = ActivationIntervalsAnalysis.run(m, None)
    pulses = [ai.of_expr(dict((p, e) for p, e, _o in it.conns)["t_start"])
              for it in m.items if isinstance(it, Instance)]
    assert len(pulses) == 16
    finite = [p for p in pulses if p is not PULSES_TOP]
    assert any(a & b for i, a in enumerate(finite)
               for b in finite[i + 1:]), "expected coinciding pulses"


def test_tstart_is_cycle_zero():
    mods, entry = _emit("mac", rtl_spec=None, hierarchy="inline")
    ai = ActivationIntervalsAnalysis.run(mods[entry].rtl, None)
    assert ai.of_net("t_start") == frozenset({0})


# ---------------------------------------------------------------------------
# rtl-share-instances / rtl-arbitrate
# ---------------------------------------------------------------------------


def test_gemm_shared_merges_and_gemm_refuses():
    shared, entry = _emit("gemm_shared", n=4)
    sh = sharing_summary(shared, entry=entry)
    assert sh["per_module"]["mac"] == {
        "physical": 4, "logical": 16, "max_degree": 4}
    plain, gentry = _emit("gemm", n=4)
    ph = sharing_summary(plain, entry=gentry)
    assert ph["absorbed"] == 0
    assert ph["per_module"]["mac"]["physical"] == 16
    # the shared emission needs 4x fewer multipliers
    assert report_design(shared, entry=entry).dsp * 4 == \
        report_design(plain, entry=gentry).dsp


@pytest.mark.slow
def test_gemm_shared_16x_reduction_at_full_size():
    """Acceptance: hierarchical gemm at n=16 cuts physical macs >= 4x on the
    analysis-proven schedule (it achieves exactly 16x)."""
    mods, entry = _emit("gemm_shared", n=16)
    sh = sharing_summary(mods, entry=entry)
    assert sh["per_module"]["mac"]["logical"] == 256
    assert sh["per_module"]["mac"]["physical"] == 16
    assert sh["per_module"]["mac"]["logical"] >= \
        4 * sh["per_module"]["mac"]["physical"]
    assert report_design(mods, entry=entry).dsp == 48


def test_shared_netlist_records_degree_and_printers_annotate():
    mods, entry = _emit("gemm_shared", n=4)
    nl = mods[entry].netlist
    assert sorted(nl.shared) == [("mac", 4)] * 4
    for backend, mark in (("verilog", "//"), ("systemverilog", "//"),
                          ("vhdl", "--"), ("circt", "//")):
        bm, be = _emit("gemm_shared", n=4) if backend != "verilog" else \
            (mods, entry)
        if backend != "verilog":
            gal = GALLERY["gemm_shared"]
            m, be = gal.build(4)
            PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
            bm = generate_verilog(m, entry=be, hierarchy="modules",
                                  backend=backend)
        text = bm[be].text
        assert f"{mark} time-shared x4" in text, backend


def test_shared_design_simulates_bit_for_bit():
    gal = GALLERY["gemm_shared"]
    mod, entry = gal.build(4)
    batch = rsim.stack_stimulus(gal.make_inputs, 32, base_seed=11, n=4)
    rep = rsim.run_differential(mod, entry, batch, kernel="gemm_shared",
                                hierarchy="modules", oracle=gal.oracle,
                                oracle_nargs=2)
    assert rep.ok, rep.mismatches[:5]
    assert rep.oracle_ok is True
    assert rep.passes_ok.get("rtl-share-instances") is True
    assert rep.passes_ok.get("rtl-arbitrate") is True


def test_proven_asserts_are_discharged():
    """rtl-arbitrate deletes PortConflictAsserts whose enables have finite
    pairwise-disjoint pulse sets (stencil1d's shift-register writes)."""
    from repro.core.codegen.rtl import PortConflictAssert
    before, entry = _emit("stencil1d",
                          rtl_spec="rtl-merge-ctrl,rtl-share-comb,"
                                   "rtl-share-mem,rtl-merge-srl,rtl-dce",
                          n=8)
    after, _ = _emit("stencil1d", n=8)
    n_before = sum(isinstance(it, PortConflictAssert)
                   for it in before[entry].rtl.items)
    n_after = sum(isinstance(it, PortConflictAssert)
                  for it in after[entry].rtl.items)
    assert n_before > 0 and n_after < n_before


# ---------------------------------------------------------------------------
# PortConflictAssert under the vectorized simulator
# ---------------------------------------------------------------------------


def _colliding_build():
    """Two writers hit the same output bank in the same cycle — the §4.5
    condition the static analysis cannot discharge away (same literal
    schedule), so the emitted PortConflictAssert must fire every lane."""
    b = Builder(ir.Module("collide"))
    wmem = ir.MemrefType((4,), ir.i32, ir.PORT_W)
    with b.func("collide", [ir.i32, wmem], ["x", "Out"]) as f:
        x, out = f.args
        b.write(x, out, [0], at=f.t + 1)
        b.write(x, out, [1], at=f.t + 1)
        b.ret()
    return b.module, "collide"


@pytest.mark.parametrize("backend", [
    "numpy",
    pytest.param("jax", marks=pytest.mark.skipif(
        not rsim.HAVE_JAX, reason="jax unavailable")),
])
def test_colliding_schedule_surfaces_conflict_lanes(backend):
    mod, entry = _colliding_build()
    sim, _prepared = rsim.simulator_for(mod, entry, backend=backend)
    batch = [np.arange(8, dtype=np.int64), np.zeros((8, 4), dtype=np.int64)]
    res = sim.run(batch, 6, batched=True, check_conflicts=False)
    assert res.backend == backend
    assert res.conflicts.shape == (8,)
    assert (res.conflicts >= 1).all(), res.conflicts   # every lane collides
    assert res.conflict_buses
    with pytest.raises(RTLSimError, match="port conflict"):
        sim.run(batch, 6, batched=True)


@pytest.mark.parametrize("backend", [
    "numpy",
    pytest.param("jax", marks=pytest.mark.skipif(
        not rsim.HAVE_JAX, reason="jax unavailable")),
])
def test_clean_schedule_has_no_conflicts(backend):
    gal = GALLERY["gemm_shared"]
    mod, entry = gal.build(4)
    sim, prepared = rsim.simulator_for(mod, entry, hierarchy="modules",
                                       backend=backend)
    lane = gal.make_inputs(4, seed=3)
    cycles = rsim.probe_cycles(prepared, entry, lane)
    batch = [np.asarray(a)[None].astype(np.int64) for a in lane]
    res = sim.run(batch, cycles, batched=True)
    assert not res.conflicts.any()


# ---------------------------------------------------------------------------
# DSE knob
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_dse_share_instances_knob_yields_dsp_tradeoff():
    from repro.core.hls import design_space, explore_design

    gal = GALLERY["gemm"]
    m, entry = gal.build(4)
    ins = gal.make_inputs(4)
    space = design_space(pipeline=(True,), unroll_parallel=(True, False),
                         share_instances=(False, True))
    res = explore_design(m, space, entry=entry,
                         inputs=[a.copy() for a in ins],
                         expected=gal.oracle(*ins[:2]))
    assert all(p.verified for p in res.points), \
        [p.error for p in res.points if not p.verified]
    shared = [p for p in res.points
              if p.config.share_instances and p.shared_absorbed > 0]
    assert shared, "no candidate actually time-multiplexed"
    spatial = min(p.dsp for p in res.points if p.shared_absorbed == 0)
    assert all(p.dsp < spatial for p in shared)
    # the tradeoff survives Pareto filtering (slower, but fewer DSPs)
    assert any(p.shared_absorbed > 0 for p in res.front)


# ---------------------------------------------------------------------------
# rtl-dce dangling-net audit
# ---------------------------------------------------------------------------


def test_audit_passes_on_clean_designs(monkeypatch):
    monkeypatch.setenv("REPRO_RTL_AUDIT", "1")
    for name, kw in (("gemm_shared", {"n": 4}), ("stencil1d", {"n": 8})):
        for hierarchy in ("inline", "modules"):
            _emit(name, hierarchy=hierarchy, **kw)


def test_audit_flags_dangling_net(monkeypatch):
    from repro.core.codegen.rtl import (CombAssign, DeadNetElim, Ref,
                                        RTLModule)

    monkeypatch.setenv("REPRO_RTL_AUDIT", "1")
    m = RTLModule("dangle")
    m.add_port("t_start", "input", 1)
    m.add_port("result_0", "output", 8)
    m.new_net("ghost", 8)   # read below but never driven
    m.add(CombAssign("result_0", Ref("ghost")))
    with pytest.raises(AssertionError, match="ghost"):
        DeadNetElim().run_module(m)
    monkeypatch.setenv("REPRO_RTL_AUDIT", "0")
    DeadNetElim().run_module(m)   # audit off: legacy behavior preserved
