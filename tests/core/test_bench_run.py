"""benchmarks/run.py orchestration contract: ``--only``/``--skip``
filtering, flag passthrough, and nonzero exit when a benchmark fails (the
CI perf-smoke step gates on the exit status)."""

import pytest

bench_run = pytest.importorskip("benchmarks.run")

ALL = ("codegen_speed,codegen_scaling,dse,incremental,resource_usage,"
       "precision_opt,roofline,sim_throughput,sharing")


def test_split_opt_consumes_both_forms():
    argv = ["--only", "a,b", "x", "--skip=c"]
    only = bench_run._split_opt(argv, "--only")
    skip = bench_run._split_opt(argv, "--skip")
    assert only == {"a", "b"}
    assert skip == {"c"}
    assert argv == ["x"]


def test_unknown_benchmark_name_is_an_error():
    assert bench_run.main(["definitely_not_a_benchmark"]) == 2
    assert bench_run.main(["--only", "definitely_not_a_benchmark"]) == 2


def test_skip_everything_runs_nothing():
    assert bench_run.main(["--skip", ALL]) == 0


def test_failing_benchmark_turns_exit_nonzero(monkeypatch):
    import benchmarks.roofline as roofline

    def boom():
        raise RuntimeError("kaput")

    monkeypatch.setattr(roofline, "main", boom)
    assert bench_run.main(["--only", "roofline"]) == 1


def test_only_filter_selects_single_suite(monkeypatch):
    import benchmarks.roofline as roofline

    calls = []
    monkeypatch.setattr(roofline, "main", lambda: calls.append(1) or 0)
    assert bench_run.main(["--only", "roofline"]) == 0
    assert calls == [1]
