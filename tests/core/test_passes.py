"""Unit tests for the optimization passes (paper §6.2–6.4)."""

import numpy as np

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.lower import simulate
from repro.core.passes import (
    canonicalize,
    constprop,
    cse,
    dce,
    delay_elim,
    precision_opt,
    strength_reduce,
)


def _simple_func():
    b = Builder(ir.Module("m"))
    r = ir.MemrefType((16,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((16,), ir.i32, ir.PORT_W)
    return b, r, w


def test_constprop_folds_constant_chain():
    b, r, w = _simple_func()
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        c = b.add(b.const(3), b.const(4))
        d = b.mult(c, b.const(2))
        b.write(d, O, [b.const(0)], at=f.t)
        b.ret()
    n = constprop(b.module)
    assert n >= 2
    out = np.zeros((16,), np.int64)
    simulate(b.module, "f", [out])
    assert out[0] == 14


def test_cse_merges_duplicate_expressions():
    b, r, w = _simple_func()
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        x1 = b.add(v, 5)
        x2 = b.add(v, 5)  # duplicate
        b.write(x1, O, [b.const(0)], at=f.t + 1)
        b.write(x2, O, [b.const(1)], at=f.t + 2)
        b.ret()
    assert cse(b.module) >= 1
    adds = [op for op in b.module.get("f").body.walk() if op.opname == "add"]
    live = dce(b.module)
    adds_after = [op for op in b.module.get("f").body.walk() if op.opname == "add"]
    assert len(adds_after) == 1


def test_strength_reduce_pow2_mult_to_shift():
    b, r, w = _simple_func()
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        x = b.mult(v, 8)
        b.write(x, O, [b.const(0)], at=f.t + 1)
        b.ret()
    assert strength_reduce(b.module) == 1
    ops = [op.opname for op in b.module.get("f").body.walk()]
    assert "shl" in ops and "mult" not in ops
    a = np.full((16,), 5, np.int64)
    out = np.zeros((16,), np.int64)
    simulate(b.module, "f", [a, out])
    assert out[0] == 40


def test_strength_reduce_iv_mult_to_counter():
    b, r, w = _simple_func()
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        with b.for_(0, 5, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            x = b.mult(l.iv, 3)  # IV * const -> scaled counter
            i1 = b.delay(l.iv, 1, at=l.time)
            xd = b.delay(x, 1, at=l.time)
            b.write(xd, O, [i1], at=l.time + 1)
        b.ret()
    assert strength_reduce(b.module) == 1
    mults = [op for op in b.module.get("f").body.walk() if op.opname == "mult"]
    assert mults and mults[0].attrs.get("impl") == "counter"


def test_precision_opt_narrows_loop_counter():
    """Paper Table 4: constant loop bounds bound the IV width."""
    b, r, w = _simple_func()
    with b.func("f", [w], ["O"]) as f:
        (O,) = f.args
        with b.for_(0, 16, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            i1 = b.delay(l.iv, 1, at=l.time)
            b.write(0, O, [i1], at=l.time + 1)
        b.ret()
        iv = l.iv
    assert precision_opt(b.module) >= 1
    assert isinstance(iv.type, ir.IntType) and iv.type.width <= 5  # [0,15] -> 4 bits


def test_delay_elim_shares_shift_register_chains():
    b, r, w = _simple_func()
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        d2 = b.delay(v, 2)
        d5 = b.delay(v, 5)  # should re-tap d2's chain: depth 3 instead of 5
        b.write(d2, O, [b.const(0)], at=f.t + 3)
        b.write(d5, O, [b.const(1)], at=f.t + 6)
        b.ret()
    assert delay_elim(b.module) >= 1
    delays = [op for op in b.module.get("f").body.walk() if op.opname == "delay"]
    total_regs = sum(op.attrs["by"] for op in delays)
    assert total_regs == 5  # 2 + 3 shared, not 2 + 5
    verifier.verify(b.module)
    a = np.full((16,), 7, np.int64)
    out = np.zeros((16,), np.int64)
    simulate(b.module, "f", [a, out])
    assert out[0] == 7 and out[1] == 7


def test_canonicalize_identity_folds():
    b, r, w = _simple_func()
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        v = b.read(A, [b.const(0)], at=f.t)
        x = b.add(v, 0)       # x + 0 -> x
        y = b.mult(x, 1)      # x * 1 -> x
        b.write(y, O, [b.const(0)], at=f.t + 1)
        b.ret()
    assert canonicalize(b.module) >= 2
    dce(b.module)
    ops = [op.opname for op in b.module.get("f").body.walk()]
    assert "add" not in ops and "mult" not in ops
