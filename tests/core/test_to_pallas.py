"""HIR -> Pallas lowering: every loop-nest gallery kernel must match its
NumPy oracle (interpret mode), and the functional JAX lowering, proving the
schedule -> grid / state -> scratch mapping preserves the algorithm."""

import numpy as np
import pytest

from repro.core.gallery import array_add, conv2d, histogram, stencil1d, transpose
from repro.core.lower import lower_to_jax
from repro.core.lower.to_pallas import lower_to_pallas


def _run(build, make_inputs, oracle_args=None):
    module, name = build()
    fn = lower_to_pallas(module, name)
    inputs = make_inputs()
    n_in = sum(1 for a in module.get(name).args
               if a.type.port == "r")
    outs = fn(*inputs[:n_in])
    return module, name, inputs, outs


def test_array_add():
    module, name, inputs, outs = _run(array_add.build, array_add.make_inputs)
    want = array_add.oracle(inputs[0], inputs[1])
    np.testing.assert_array_equal(np.asarray(outs["C"], np.int64), want)


def test_transpose():
    module, name, inputs, outs = _run(transpose.build, transpose.make_inputs)
    want = transpose.oracle(inputs[0])
    np.testing.assert_array_equal(np.asarray(outs["Co"], np.int64), want)


def test_stencil1d():
    module, name, inputs, outs = _run(stencil1d.build, stencil1d.make_inputs)
    want = stencil1d.oracle(inputs[0])
    np.testing.assert_array_equal(np.asarray(outs["Bw"], np.int64), want)


def test_histogram():
    module, name, inputs, outs = _run(histogram.build, histogram.make_inputs)
    want = histogram.oracle(inputs[0])
    np.testing.assert_array_equal(np.asarray(outs["Out"], np.int64), want)


def test_conv2d():
    module, name, inputs, outs = _run(conv2d.build, conv2d.make_inputs)
    want = conv2d.oracle(inputs[0])
    np.testing.assert_array_equal(np.asarray(outs["Out"], np.int64), want)


@pytest.mark.parametrize("gal", [array_add, transpose, stencil1d, histogram, conv2d])
def test_pallas_agrees_with_functional_lowering(gal):
    """Same HIR, two lowerings (functional JAX vs Pallas grid): identical."""
    module, name = gal.build()
    inputs = gal.make_inputs()
    n_in = sum(1 for a in module.get(name).args if a.type.port == "r")
    jfn = lower_to_jax(module, name)
    pfn = lower_to_pallas(module, name)
    jout = jfn(*inputs)
    pout = pfn(*inputs[:n_in])
    for k, v in pout.items():
        np.testing.assert_array_equal(np.asarray(v, np.int64),
                                      np.asarray(jout[k], np.int64))


def test_gemm_binds_to_mxu_kernel():
    """The systolic GEMM's TPU binding is the MXU matmul kernel (DESIGN §3):
    same math, hardware systolic array instead of PE emulation."""
    import jax.numpy as jnp

    from repro.core.gallery import gemm
    from repro.kernels import ops

    module, name = gemm.build()
    a, b, _ = gemm.make_inputs()
    want = gemm.oracle(a, b)
    got = ops.matmul(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                     bm=16, bn=16, bk=16)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# -- dtype coercion policy ----------------------------------------------------


def _build_float_add(elem):
    """8-wide elementwise add over float memrefs (array_add shape)."""
    from repro.core import ir
    from repro.core.builder import Builder

    b = Builder(ir.Module("fadd"))
    r = ir.MemrefType((8,), elem, ir.PORT_R)
    w = ir.MemrefType((8,), elem, ir.PORT_W)
    with b.func("fadd", [r, r, w], ["A", "B", "C"]) as f:
        A, B, C = f.args
        with b.for_(0, 8, 1, at=f.t + 1, iv_name="i", tv_name="ti") as li:
            b.yield_(at=li.time + 1)
            a = b.read(A, [li.iv], at=li.time)
            v = b.read(B, [li.iv], at=li.time)
            c = b.add(a, v)
            i1 = b.delay(li.iv, 1, at=li.time)
            b.write(c, C, [i1], at=li.time + 1)
        b.ret()
    return b.module, "fadd"


def test_pallas_f64_raises_by_default():
    """The old behavior silently truncated f64 -> f32; now it is an error
    unless the caller opts into the downcast explicitly."""
    from repro.core import ir

    module, name = _build_float_add(ir.FloatType(64))
    with pytest.raises(TypeError, match="allow_downcast"):
        lower_to_pallas(module, name)


def test_pallas_f64_downcast_is_explicit_and_warned():
    import warnings

    from repro.core import ir
    from repro.core.lower.common import PrecisionWarning

    module, name = _build_float_add(ir.FloatType(64))
    with pytest.warns(PrecisionWarning, match="f64 -> f32"):
        fn = lower_to_pallas(module, name, allow_downcast=True)
    a = np.arange(8.0)
    b = 2.0 * np.arange(8.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PrecisionWarning)
        out = fn(a, b)
    np.testing.assert_allclose(np.asarray(out["C"], np.float64), a + b)


def test_pallas_f16_maps_to_bf16_with_warning():
    from repro.core import ir
    from repro.core.lower.common import PrecisionWarning

    module, name = _build_float_add(ir.FloatType(16))
    with pytest.warns(PrecisionWarning, match="bfloat16"):
        fn = lower_to_pallas(module, name)
