"""Backend conformance suite for the multi-backend netlist printers.

Covers, per backend (verilog / systemverilog / vhdl / circt):

  * golden-file snapshots of every gallery kernel in both hierarchy modes
    (``tests/goldens/``; regenerate with ``pytest --regen-goldens``; outputs
    above a size threshold are stored as digest + preview so the repo stays
    reviewable);
  * dialect lint cleanliness of every kernel x mode;
  * reserved-identifier legalization (nets/ports/modules named after
    backend keywords must be renamed, consistently across instances);
  * backend-invariance of the resource summaries (``netlist_of`` /
    ``report_design`` are derived from the RTL structure, never the text,
    and printing must not mutate the structure);
  * hypothesis property tests: random small RTLModules print without error
    on every backend, lint clean, and keep identical resource summaries;
  * opportunistic elaboration through ``iverilog -g2012`` / ``ghdl`` when
    those tools exist (skipped gracefully otherwise).
"""

import hashlib
import re
import shutil
import subprocess
from pathlib import Path

import pytest

from repro.core.codegen import (BACKENDS, generate_verilog, get_printer,
                                lint_backend, netlist_of, report_design)
from repro.core.codegen.rtl import (REG, Binop, CombAssign, Const, Instance,
                                    LoopController, MemRead, Memory, MemWrite,
                                    Mux, Ref, RegAssign, RTLDesign, RTLModule,
                                    ShiftReg, Unop)
from repro.core.codegen.resources import estimate_resources
from repro.core.gallery import GALLERY
from repro.core.passes import run_pipeline

KERNELS = sorted(GALLERY)
MODES = ("inline", "modules")
BACKEND_NAMES = sorted(BACKENDS)
EXT = {"verilog": "v", "systemverilog": "sv", "vhdl": "vhd", "circt": "mlir"}
GOLDEN_DIR = Path(__file__).resolve().parent.parent / "goldens"
BIG = 64 * 1024  # outputs above this are stored as digest + preview

_design_cache: dict = {}


def _design(kernel, mode):
    """One optimized emission per (kernel, mode); all four backends print
    from the same RTLModules."""
    key = (kernel, mode)
    if key not in _design_cache:
        m, entry = GALLERY[kernel].build()
        run_pipeline(m)
        _design_cache[key] = generate_verilog(m, entry, hierarchy=mode)
    return _design_cache[key]


def _emit(kernel, mode, backend):
    """({module: text}, [module names]) for one kernel/mode/backend."""
    mods = _design(kernel, mode)
    if backend == "verilog":
        return {n: vm.text for n, vm in mods.items()}, list(mods)
    design = RTLDesign({n: vm.rtl for n, vm in mods.items()})
    return get_printer(backend).print_modules(design), list(mods)


def _normalize(text):
    lines = [l.rstrip() for l in text.splitlines()]
    return "\n".join(lines).rstrip() + "\n"


# ---------------------------------------------------------------------------
# golden-file snapshots
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKEND_NAMES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("kernel", KERNELS)
def test_golden(kernel, mode, backend, regen_goldens):
    texts, _names = _emit(kernel, mode, backend)
    text = _normalize("\n".join(texts.values()))
    digest = hashlib.sha256(text.encode()).hexdigest()
    path = GOLDEN_DIR / f"{kernel}.{mode}.{EXT[backend]}"
    if len(text) > BIG:
        content = (
            f"# golden digest=sha256:{digest} bytes={len(text)}\n"
            f"# output too large to store verbatim; first 40 lines follow\n"
            + "\n".join(text.splitlines()[:40]) + "\n")
    else:
        content = text
    if regen_goldens:
        GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
        path.write_text(content)
        return
    assert path.exists(), (
        f"golden file missing: {path}; run `pytest --regen-goldens` once")
    stored = path.read_text()
    if stored.startswith("# golden digest="):
        m = re.match(r"# golden digest=sha256:([0-9a-f]{64})", stored)
        assert m is not None, f"{path}: malformed digest golden"
        assert m.group(1) == digest, (
            f"{path}: {backend} output changed (digest mismatch); rerun "
            f"with --regen-goldens if the change is intended")
    else:
        assert stored == content, (
            f"{path}: {backend} output changed; rerun with --regen-goldens "
            f"if the change is intended")


# ---------------------------------------------------------------------------
# dialect lint over every kernel x mode x backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKEND_NAMES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("kernel", KERNELS)
def test_backend_lints_clean(kernel, mode, backend):
    texts, names = _emit(kernel, mode, backend)
    diags = lint_backend("\n".join(texts.values()), backend,
                         known_modules=names)
    assert diags == [], f"{kernel}/{mode}/{backend}: {diags[:5]}"


# ---------------------------------------------------------------------------
# resource summaries are backend-invariant (and printing is pure)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("kernel", KERNELS)
def test_resource_summaries_backend_invariant(kernel, mode):
    mods = _design(kernel, mode)
    before = {n: netlist_of(vm.rtl) for n, vm in mods.items()}
    r0 = report_design(mods).as_dict()
    for backend in BACKEND_NAMES:
        _emit(kernel, mode, backend)  # printing must not mutate the RTL IR
        after = {n: netlist_of(vm.rtl) for n, vm in mods.items()}
        assert after == before, f"{backend} printing mutated the netlist"
        assert report_design(mods).as_dict() == r0
    # full end-to-end: a fresh compile per backend yields byte-identical
    # report_design numbers (the summary never looks at the text)
    if kernel in ("mac", "stencil1d", "histogram"):
        reports = []
        for backend in BACKEND_NAMES:
            m, entry = GALLERY[kernel].build()
            run_pipeline(m)
            vs = generate_verilog(m, entry, hierarchy=mode, backend=backend)
            assert all(vm.backend == backend for vm in vs.values())
            reports.append(report_design(vs, entry).as_dict())
        assert all(r == reports[0] for r in reports), reports


# ---------------------------------------------------------------------------
# reserved-identifier legalization
# ---------------------------------------------------------------------------


def _keyword_module(name="kwmod"):
    """Nets/ports deliberately named after backend keywords: ``reg``
    (Verilog), ``logic`` (SystemVerilog), ``signal``/``out``/``process``
    (VHDL)."""
    m = RTLModule(name)
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("t_start", "input")
    m.add_port("signal", "input", 8)
    m.add_port("out", "output", 8)
    m.new_net("reg", 8)
    m.new_net("logic", 8)
    m.new_net("process", 8)
    m.add(CombAssign("reg", Binop("+", Ref("signal"), Const(1, 8), width=8)))
    m.add(CombAssign("logic", Binop("&", Ref("reg"), Const(255, 8), width=8)))
    m.add(CombAssign("process", Unop("~", Ref("logic"), 8)))
    m.add(CombAssign("out", Ref("process")))
    return m


@pytest.mark.parametrize("backend", BACKEND_NAMES)
def test_reserved_identifiers_escaped(backend):
    text = get_printer(backend).print_module(_keyword_module())
    assert lint_backend(text, backend) == [], text
    if backend == "verilog":
        # `reg` must be renamed; `logic` is a fine Verilog-2001 identifier
        assert "assign reg =" not in text
        assert re.search(r"\bwire \[7:0\] logic;", text)
    if backend == "systemverilog":
        assert "assign reg =" not in text
        assert "assign logic =" not in text
    if backend == "vhdl":
        # no port/signal declaration may use the bare keyword
        assert re.search(r"^\s*signal\s*:", text, re.M) is None
        assert re.search(r"^\s*out\s*:", text, re.M) is None
        assert re.search(r"^\s*signal\s+process\s*:", text, re.M) is None


def test_reserved_module_name_renamed_consistently():
    child = RTLModule("reg")  # a module named after a Verilog keyword
    for p in ("clk", "rst", "t_start"):
        child.add_port(p, "input")
    child.add_port("a", "input", 8)
    child.add_port("y", "output", 8)
    child.add(CombAssign("y", Binop("+", Ref("a"), Const(1, 8), width=8)))
    top = RTLModule("top")
    for p in ("clk", "rst", "t_start"):
        top.add_port(p, "input")
    top.add_port("din", "input", 8)
    top.add_port("dout", "output", 8)
    top.new_net("res", 8)
    top.add(Instance("reg", "u0", [
        ("clk", Ref("clk"), False), ("rst", Ref("rst"), False),
        ("t_start", Ref("t_start"), False), ("a", Ref("din"), False),
        ("y", Ref("res"), True)]))
    top.add(CombAssign("dout", Ref("res")))
    design = RTLDesign({"reg": child, "top": top}, entry="top")
    for backend in BACKEND_NAMES:
        pr = get_printer(backend)
        texts = pr.print_modules(design)
        joined = "\n".join(texts.values())
        assert lint_backend(joined, backend, known_modules=[]) == [], (
            backend, joined)
        renamed = pr.module_name_map(design.modules).get("reg", "reg")
        if backend in ("verilog", "systemverilog"):
            assert renamed != "reg"
            assert "module reg (" not in joined
            assert f"module {renamed} (" in joined
            assert f"{renamed} u0 (" in joined
        # consistency: the definition spelling appears wherever instantiated
        assert joined.count(renamed) >= 2


def test_case_insensitive_collision_vhdl():
    m = RTLModule("cc")
    m.add_port("clk", "input")
    m.add_port("Data", "input", 8)
    m.add_port("dout", "output", 8)
    m.new_net("data", 8)  # collides with Data under VHDL case folding
    m.add(CombAssign("data", Binop("+", Ref("Data"), Const(1, 8), width=8)))
    m.add(CombAssign("dout", Ref("data")))
    text = get_printer("vhdl").print_module(m)
    assert lint_backend(text, "vhdl") == [], text


# ---------------------------------------------------------------------------
# opportunistic elaboration (real tools, graceful skip)
# ---------------------------------------------------------------------------

IVERILOG = shutil.which("iverilog")
GHDL = shutil.which("ghdl")


@pytest.mark.skipif(IVERILOG is None, reason="iverilog not installed")
@pytest.mark.parametrize("backend", ["verilog", "systemverilog"])
def test_elaborates_with_iverilog(backend, tmp_path):
    texts, _ = _emit("stencil1d", "inline", backend)
    src = tmp_path / f"stencil1d.{EXT[backend]}"
    src.write_text("\n".join(texts.values()))
    subprocess.run(
        [IVERILOG, "-g2012", "-o", str(tmp_path / "a.out"), str(src)],
        check=True, capture_output=True)


@pytest.mark.skipif(GHDL is None, reason="ghdl not installed")
def test_elaborates_with_ghdl(tmp_path):
    texts, _ = _emit("stencil1d", "inline", "vhdl")
    src = tmp_path / "stencil1d.vhd"
    src.write_text("\n".join(texts.values()))
    subprocess.run(
        [GHDL, "-a", "--std=08", f"--workdir={tmp_path}", str(src)],
        check=True, capture_output=True)
