"""Tests for the compile-time performance infrastructure: hash-consed /
interned ``Expr.key()`` (equivalence with the seed recursive computation, no
aliasing of distinct structures, no per-item recomputation inside the RTL
passes) and the fast ``Module.clone()`` (printed-IR round-trip, disjoint
object graphs, intact use-def chains, codegen equivalence).

Perf-assert tests are skippable on slow/contended runners via
``REPRO_SKIP_PERF=1``."""

import os
import time

import pytest

from repro.core.codegen import rtl
from repro.core.codegen.rtl import (RTL_PIPELINE_SPEC, Binop, CombAssign,
                                    CombShare, Const, Mux, Ref, Repeat,
                                    RTLDesign, RTLModule, Signed, Unop,
                                    walk_expr)
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import GALLERY
from repro.core.passmgr import (DEFAULT_PIPELINE_SPEC, AnalysisManager,
                                PassManager)
from repro.core.printer import print_module
from repro.core import verifier

SKIP_PERF = pytest.mark.skipif(
    os.environ.get("REPRO_SKIP_PERF") == "1",
    reason="perf asserts disabled on slow runners (REPRO_SKIP_PERF=1)")


# ---------------------------------------------------------------------------
# hash-consed Expr.key() — property tests (hypothesis optional, like
# test_roundtrip/test_backend_properties; the deterministic tests below run
# regardless)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _names = st.sampled_from(["a", "b", "c", "d"])
    _leaf = st.one_of(
        st.builds(Ref, _names),
        st.builds(Const, st.integers(0, 7), st.sampled_from([None, 4, 8]),
                  st.booleans()),
    )
    _exprs = st.recursive(
        _leaf,
        lambda ch: st.one_of(
            st.builds(Signed, ch),
            st.builds(lambda a: Unop("~", a), ch),
            st.builds(lambda op, a, b: Binop(op, a, b),
                      st.sampled_from(["+", "-", "&", "|", "=="]), ch, ch),
            st.builds(lambda c, a, b: Mux(c, a, b), ch, ch, ch),
            st.builds(lambda n, a: Repeat(n, a), st.integers(1, 4), ch),
        ),
        max_leaves=16,
    )

    @given(_exprs, _exprs)
    @settings(max_examples=200, deadline=None)
    def test_interned_key_matches_seed_recursive_computation(e1, e2):
        """key() equality must coincide exactly with the seed-path recursive
        structural key: equal structures share one interned key, and
        interning never aliases structurally distinct nodes."""
        same_structural = e1.structural_key() == e2.structural_key()
        same_interned = e1.key() == e2.key()
        assert same_interned == same_structural

    @given(_exprs)
    @settings(max_examples=100, deadline=None)
    def test_key_is_cached_and_deterministic(e):
        k1 = e.key()
        assert e.key() == k1  # cached value stable

    @given(_exprs, st.sampled_from(["a", "b"]), st.sampled_from(["x", "y"]))
    @settings(max_examples=100, deadline=None)
    def test_map_refs_copy_on_write_keeps_keys_consistent(e, old, new):
        """Renaming through ``map_refs`` builds new nodes; the original
        node's cached key must be unchanged, and the renamed tree's key must
        discriminate exactly like its structural key."""
        k_before = e.key()
        renamed = e.map_refs({old: new})
        assert e.key() == k_before
        assert (renamed.key() == e.key()) == (
            renamed.structural_key() == e.structural_key())


def test_interned_key_equivalence_deterministic():
    """No-hypothesis fallback of the equivalence property on hand-built
    trees: equal structures share a key, distinct structures never alias."""
    mk = lambda nm, c: Binop("+", Signed(Ref(nm)), Mux(Ref("p"), Const(c, 8),
                                                       Repeat(2, Ref(nm))))
    a1, a2 = mk("a", 3), mk("a", 3)
    b1, b2 = mk("b", 3), mk("a", 4)
    assert a1.key() == a2.key()
    assert a1.structural_key() == a2.structural_key()
    for other in (b1, b2):
        assert a1.key() != other.key()
        assert a1.structural_key() != other.structural_key()


def _count_nodes(m: RTLModule) -> int:
    return sum(1 for it in m.items for e in it.exprs() for _ in walk_expr(e))


def test_comb_share_computes_each_key_at_most_once():
    """The counting test for the acceptance criterion: one CombShare run
    over a module derives the seed-path structural key at most once per
    expression node that ever existed (pre-existing nodes + the Refs the
    pass itself creates)."""
    m = RTLModule("t")
    for p in ("clk", "rst", "t_start"):
        m.add_port(p, "input")
    m.add_port("o", "output", 8)
    for i in range(20):
        m.new_net(f"n{i}", 8)
        # ten duplicated pairs: n0/n1 share, n2/n3 share, ...
        expr = Binop("+", Ref("o"), Const(i // 2, 8), width=8)
        m.add(CombAssign(f"n{i}", expr))
    nodes_before = _count_nodes(m)
    rtl.reset_key_stats()
    rewrites = CombShare().run_module(m)
    assert rewrites > 0
    assert rtl.KEY_STATS["computed"] <= nodes_before + rewrites, (
        "sharing pass recomputed structural keys per item")


def test_clear_key_intern_is_sound():
    """Clearing the intern table (the per-compilation memory bound) may
    miss sharing across the boundary but must never alias: ids are
    monotonic, so a stale cached key never equals a fresh one."""
    e1 = Binop("+", Ref("a"), Const(1, 8))
    k1 = e1.key()
    released = rtl.clear_key_intern()
    assert released >= 1
    twin = Binop("+", Ref("a"), Const(1, 8))
    other = Binop("-", Ref("a"), Const(1, 8))
    assert twin.key() != k1        # cross-boundary sharing missed, not wrong
    assert other.key() != k1
    assert other.key() != twin.key()
    assert e1.key() == k1          # cached key survives the clear


def test_rtl_pipeline_at_fixpoint_recomputes_no_keys():
    """After one full RTL pipeline run the netlist is at a fixpoint; a
    second run must be 100% key-cache hits — no pass re-derives structural
    identity node by node."""
    m, entry = GALLERY["gemm"].build(n=4)
    mods = generate_verilog(m, entry)
    design = RTLDesign({n: vm.rtl for n, vm in mods.items()})
    rtl.reset_key_stats()
    PassManager.from_spec(RTL_PIPELINE_SPEC).run(design)
    assert rtl.KEY_STATS["computed"] == 0
    assert rtl.KEY_STATS["hits"] > 0


# ---------------------------------------------------------------------------
# Module.clone()
# ---------------------------------------------------------------------------


def _all_values(module):
    out = []
    for f in module.funcs.values():
        stack = [f]
        while stack:
            op = stack.pop()
            out.extend(op.results)
            for r in op.regions:
                out.extend(r.args)
                stack.extend(r.ops)
    return out


@pytest.mark.parametrize("name", sorted(GALLERY))
def test_clone_round_trips_printed_ir(name):
    m, _entry = GALLERY[name].build()
    c = m.clone()
    assert print_module(c) == print_module(m)


@pytest.mark.parametrize("name", ["gemm", "conv2d", "stencil1d"])
def test_clone_graph_is_disjoint(name):
    m, _entry = GALLERY[name].build()
    c = m.clone()
    orig_ops = {id(op) for op in m.walk()}
    clone_ops = {id(op) for op in c.walk()}
    assert not orig_ops & clone_ops
    orig_vals = {id(v) for v in _all_values(m)}
    clone_vals = {id(v) for v in _all_values(c)}
    assert not orig_vals & clone_vals
    # every operand of the clone resolves inside the clone's own value set
    for op in c.walk():
        for o in op.operands:
            assert id(o) in clone_vals


@pytest.mark.parametrize("name", ["gemm", "stencil1d"])
def test_clone_use_def_chains_intact(name):
    m, _entry = GALLERY[name].build()
    c = m.clone()
    for op in c.walk():
        for v in op.operands:
            assert op in v._use_ops
        for r in op.results:
            for user, count in r._use_ops.items():
                slots = sum(1 for o in user.operands if o is r)
                assert slots == count


def test_clone_isolates_mutation():
    m, entry = GALLERY["stencil1d"].build()
    c = m.clone()
    before = print_module(c)
    # mutate the original aggressively: run the whole optimization pipeline
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
    assert print_module(c) == before


def test_clone_codegen_equivalent():
    m, entry = GALLERY["stencil1d"].build()
    texts_orig = {n: vm.text
                  for n, vm in generate_verilog(m.clone(), entry).items()}
    texts_clone = {n: vm.text
                   for n, vm in generate_verilog(m.clone(), entry).items()}
    assert texts_orig == texts_clone


def test_clone_preserves_schedules_and_verifies():
    m, _entry = GALLERY["gemm"].build(n=4)
    c = m.clone()
    verifier.verify(c)  # strict: schedules, births and windows all intact


# ---------------------------------------------------------------------------
# perf smoke (skippable)
# ---------------------------------------------------------------------------


@SKIP_PERF
def test_full_pipeline_smoke_budget():
    """Generous end-to-end wall budget on a mid-size config (measured ~0.04s
    after the hash-consing overhaul; budget leaves 100x headroom)."""
    m, entry = GALLERY["gemm"].build(n=8)
    am = AnalysisManager()
    t0 = time.perf_counter()
    verifier.verify(m, am=am)
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC, analysis_manager=am).run(m)
    generate_verilog(m, entry, am=am)
    assert time.perf_counter() - t0 < 5.0


@SKIP_PERF
def test_clone_is_not_slower_than_deepcopy():
    from copy import deepcopy

    m, _entry = GALLERY["gemm"].build(n=8)
    t0 = time.perf_counter()
    deepcopy(m)
    t_deep = time.perf_counter() - t0
    t0 = time.perf_counter()
    m.clone()
    t_clone = time.perf_counter() - t0
    assert t_clone <= t_deep * 2  # in practice ~20x faster; 2x guards noise
