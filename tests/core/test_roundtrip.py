"""Round-trip (print -> parse -> print) and property-based IR tests."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.gallery import GALLERY
from repro.core.lower import simulate
from repro.core.parser import parse
from repro.core.printer import print_module


@pytest.mark.parametrize("name", sorted(GALLERY))
def test_roundtrip_fixpoint(name):
    m, _ = GALLERY[name].build()
    t1 = print_module(m)
    m2 = parse(t1)
    t2 = print_module(m2)
    assert t1 == t2


@pytest.mark.parametrize("name", ["transpose", "histogram", "gemm"])
def test_parsed_module_simulates_identically(name):
    mod = GALLERY[name]
    m, entry = mod.build()
    m2 = parse(print_module(m))
    ins1, ins2 = mod.make_inputs(), mod.make_inputs()
    simulate(m, entry, ins1)
    simulate(m2, entry, ins2)
    np.testing.assert_array_equal(ins1[-1], ins2[-1])


# ---------------------------------------------------------------------------
# property-based: random pipelined array pipelines round-trip and verify
# ---------------------------------------------------------------------------

@st.composite
def pipeline_design(draw):
    """A random single-loop pipeline: out[i] = f(a[i]) with random unary op
    chain and a schedule with a random (valid) write offset."""
    n = draw(st.integers(min_value=4, max_value=32))
    n_ops = draw(st.integers(min_value=1, max_value=4))
    kinds = draw(st.lists(st.sampled_from(["add", "sub", "mult", "xor"]), min_size=n_ops, max_size=n_ops))
    consts = draw(st.lists(st.integers(min_value=1, max_value=7), min_size=n_ops, max_size=n_ops))
    ii = draw(st.integers(min_value=1, max_value=3))
    return n, kinds, consts, ii


def _build_pipeline(n, kinds, consts, ii):
    b = Builder(ir.Module("prop"))
    r = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        with b.for_(0, n, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + ii)
            v = b.read(A, [l.iv], at=l.time)
            for k, c in zip(kinds, consts):
                v = getattr(b, {"add": "add", "sub": "sub", "mult": "mult", "xor": "xor_"}[k])(v, c)
            i1 = b.delay(l.iv, 1, at=l.time)
            b.write(v, O, [i1], at=l.time + 1)
        b.ret()
    return b.module


def _apply_ops(a, kinds, consts):
    v = a.astype(np.int64)
    for k, c in zip(kinds, consts):
        if k == "add":
            v = v + c
        elif k == "sub":
            v = v - c
        elif k == "mult":
            v = v * c
        elif k == "xor":
            v = v ^ c
    return v


@given(pipeline_design())
@settings(max_examples=40, deadline=None)
def test_random_pipeline_roundtrips_verifies_simulates(design):
    n, kinds, consts, ii = design
    m = _build_pipeline(n, kinds, consts, ii)
    # 1. verifies clean
    assert not [d for d in verifier.verify(m, raise_on_error=False) if d.severity == "error"]
    # 2. round-trips
    t1 = print_module(m)
    assert print_module(parse(t1)) == t1
    # 3. simulates to the oracle
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2**16, size=(n,), dtype=np.int64)
    out = np.zeros((n,), dtype=np.int64)
    simulate(m, "f", [a, out])
    np.testing.assert_array_equal(out, _apply_ops(a, kinds, consts))


@given(pipeline_design(), st.integers(min_value=2, max_value=5))
@settings(max_examples=25, deadline=None)
def test_verifier_catches_injected_schedule_bug(design, extra):
    """Mutating a correct schedule (late write without re-delaying the IV)
    must be caught — the generalized Fig. 1 property."""
    n, kinds, consts, ii = design
    b = Builder(ir.Module("prop2"))
    r = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        with b.for_(0, n, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + ii)
            v = b.read(A, [l.iv], at=l.time)
            # BUG: index used at an offset beyond the IV validity window
            b.write(v, O, [l.iv], at=l.time + ii + extra)
        b.ret()
    errs = [d for d in verifier.verify(b.module, raise_on_error=False) if d.severity == "error"]
    assert errs, "verifier must reject stale-IV schedules"
    assert any("mismatched delay" in e.message for e in errs)
