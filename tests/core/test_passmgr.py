"""PassManager tests: pipeline-spec parsing, statistics, fixpoint behavior,
parity with the seed sweep on the gallery kernels."""

import numpy as np
import pytest

from repro.core import verifier
from repro.core.gallery import GALLERY
from repro.core.lower import simulate
from repro.core.passes import (DEFAULT_PIPELINE_SPEC, PassManager, dce,
                               run_pipeline)
from repro.core.passmgr import (Pass, create_pass, parse_pipeline_spec)


def test_spec_parses_registered_passes():
    passes = parse_pipeline_spec("canonicalize,cse,strength-reduce,dce")
    assert [p.name for p in passes] == ["canonicalize", "cse", "strength-reduce", "dce"]
    # underscores accepted as aliases
    assert parse_pipeline_spec("strength_reduce")[0].name == "strength-reduce"
    assert "delay-elim" in PassManager.from_spec(DEFAULT_PIPELINE_SPEC).spec


def test_spec_rejects_unknown_pass_names():
    with pytest.raises(ValueError, match="unknown pass 'frobnicate'"):
        parse_pipeline_spec("canonicalize,frobnicate")
    with pytest.raises(ValueError):
        parse_pipeline_spec("")
    with pytest.raises(ValueError):
        parse_pipeline_spec("cse,,dce")
    with pytest.raises(ValueError):
        create_pass("not-a-pass")


def test_statistics_record_rewrites_timing_and_invocations():
    m, _ = GALLERY["conv2d"].build()
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC)
    stats = pm.run(m)
    assert sum(stats.values()) > 0
    by_name = pm.stats_dict()
    assert set(by_name) == set(DEFAULT_PIPELINE_SPEC.split(","))
    for st in pm.statistics:
        assert st.invocations >= 1
        assert st.wall_s >= 0.0
    assert by_name["strength-reduce"]["rewrites"] >= 1  # conv2d const weights
    # legacy-compat dict keys are underscored
    assert "strength_reduce" in stats
    table = pm.render_stats()
    assert "rewrites" in table and "canonicalize" in table


def test_run_pipeline_shim_matches_passmanager():
    m1, _ = GALLERY["stencil1d"].build()
    m2, _ = GALLERY["stencil1d"].build()
    s1 = run_pipeline(m1)
    s2 = PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m2)
    assert s1 == s2
    # legacy list-of-callables form still accepted
    m3, _ = GALLERY["stencil1d"].build()
    s3 = run_pipeline(m3, passes=[dce])
    assert set(s3) == {"dce"}


def test_verify_each_runs_clean_on_gallery():
    m, _ = GALLERY["stencil1d"].build()
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC, verify_each=True)
    pm.run(m)  # raises if any pass breaks the IR


def test_custom_pass_objects_and_callables():
    events = []

    class Marker(Pass):
        name = "marker"

        def run(self, module):
            events.append("marker")
            return 0

    def fn_pass(module):
        events.append("fn")
        return 0

    pm = PassManager([Marker(), fn_pass])
    m, _ = GALLERY["transpose"].build()
    stats = pm.run(m)
    assert events == ["marker", "fn"]  # converged after one iteration
    assert stats == {"marker": 0, "fn_pass": 0}


def test_clean_pass_skipping_preserves_fixpoint():
    """Passes reporting 0 rewrites are skipped until the module changes;
    the final module must equal a run without skipping."""
    from copy import deepcopy

    from repro.core.printer import print_module

    m0, _ = GALLERY["conv2d"].build()
    m1, m2 = deepcopy(m0), deepcopy(m0)
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m1)
    # no-skip reference: force max_iterations=1 repeatedly (no skip state kept)
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC, fixpoint=False)
    for _ in range(3):
        pm.run(m2)
    assert print_module(m1) == print_module(m2)


@pytest.mark.parametrize("name", ["transpose", "stencil1d", "histogram", "gemm", "conv2d"])
def test_pipeline_results_match_legacy_sweep(name):
    """Acceptance: unchanged optimization results on the gallery kernels —
    the worklist pipeline and the seed sweep produce equivalent optimized
    designs (same simulation results, same resource estimates)."""
    from repro.core.codegen import estimate_resources, generate_verilog
    from repro.core.passes.legacy_sweep import run_legacy_sweep

    mod = GALLERY[name]
    m_new, entry = mod.build()
    m_old, _ = mod.build()
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m_new)
    run_legacy_sweep(m_old)

    verifier.verify(m_new)
    ins = mod.make_inputs()
    simulate(m_new, entry, ins)
    np.testing.assert_array_equal(
        ins[-1], mod.oracle(*ins[: {"gemm": 2, "transpose": 1, "stencil1d": 1,
                                    "histogram": 1, "conv2d": 1}[name]]))

    r_new = estimate_resources(generate_verilog(m_new, entry)[entry].netlist)
    r_old = estimate_resources(generate_verilog(m_old, entry)[entry].netlist)
    assert (r_new.lut, r_new.ff, r_new.dsp, r_new.bram) == \
        (r_old.lut, r_old.ff, r_old.dsp, r_old.bram)
