"""AnalysisManager semantics: construct-on-demand caching, hit statistics,
and preserve/invalidate behavior driven by the PassManager — a pass that
preserves an analysis must not trigger recomputation; one that doesn't must;
a pass reporting 0 rewrites preserves everything implicitly."""

import pytest

from repro.core import ir, verifier
from repro.core.analysis import (DependenceAnalysis, LoopAnalysis,
                                 MemTouchAnalysis, PortAccessAnalysis)
from repro.core.gallery import GALLERY
from repro.core.passmgr import AnalysisManager, Pass, PassManager


def _func(name="stencil1d"):
    m, entry = GALLERY[name].build()
    return m, m.get(entry)


def test_get_computes_once_then_hits():
    m, f = _func()
    am = AnalysisManager()
    r1 = am.get(LoopAnalysis, f)
    r2 = am.get(LoopAnalysis, f)
    assert r1 is r2
    st = am.stats["loop-info"]
    assert st.computed == 1 and st.hits == 1
    assert am.stats_dict()["hits"] == 1


def test_get_by_name_and_unknown_name():
    m, f = _func()
    am = AnalysisManager()
    assert am.get("loop-info", f) is am.get(LoopAnalysis, f)
    with pytest.raises(ValueError, match="unknown analysis"):
        am.get("frobnicate", f)


def test_dependent_analyses_share_the_cache():
    """port-accesses / dependence pull loop-info & mem-touch through the
    manager, so a later direct query is a hit, not a recomputation."""
    m, f = _func()
    am = AnalysisManager()
    am.get(PortAccessAnalysis, f)
    am.get(DependenceAnalysis, f)
    assert am.stats["loop-info"].computed == 1
    assert am.stats["loop-info"].hits >= 1
    am.get(MemTouchAnalysis, f)
    assert am.stats["mem-touch"].computed == 1
    assert am.stats["mem-touch"].hits == 1


def test_invalidate_respects_preserve_sets():
    m, f = _func()
    am = AnalysisManager()
    am.get(LoopAnalysis, f)
    am.get(MemTouchAnalysis, f)
    am.invalidate(preserve=("loop-info",))
    assert am.cached(LoopAnalysis, f) is not None
    assert am.cached(MemTouchAnalysis, f) is None
    assert am.invalidate(preserve_all=True) == 0  # no-op
    am.invalidate()
    assert am.cached(LoopAnalysis, f) is None


def test_invalidate_scoped_to_one_func():
    m1, f1 = _func("stencil1d")
    m2, f2 = _func("conv2d")
    am = AnalysisManager()
    am.get(LoopAnalysis, f1)
    am.get(LoopAnalysis, f2)
    am.invalidate(func=f1)
    assert am.cached(LoopAnalysis, f1) is None
    assert am.cached(LoopAnalysis, f2) is not None


class _RewritingPass(Pass):
    """Claims one rewrite per run without touching the IR (cache probe)."""

    name = "probe-rewrite"

    def run(self, module):
        return 1


class _PreservingPass(_RewritingPass):
    name = "probe-preserving"
    preserves = ("loop-info",)


class _CleanPass(Pass):
    name = "probe-clean"

    def run(self, module):
        return 0


def _pm_with_warm_cache(passes, func):
    am = AnalysisManager()
    am.get(LoopAnalysis, func)
    am.get(MemTouchAnalysis, func)
    return PassManager(passes, fixpoint=False, analysis_manager=am), am


def test_pass_that_preserves_does_not_trigger_recomputation():
    m, f = _func()
    pm, am = _pm_with_warm_cache([_PreservingPass()], f)
    pm.run(m)
    assert am.cached(LoopAnalysis, f) is not None  # preserved across the rewrite
    assert am.cached(MemTouchAnalysis, f) is None  # not in the preserve set
    before = am.stats["loop-info"].computed
    am.get(LoopAnalysis, f)
    assert am.stats["loop-info"].computed == before  # cache hit, no recompute


def test_pass_that_does_not_preserve_invalidates():
    m, f = _func()
    pm, am = _pm_with_warm_cache([_RewritingPass()], f)
    pm.run(m)
    assert am.cached(LoopAnalysis, f) is None
    before = am.stats["loop-info"].computed
    am.get(LoopAnalysis, f)
    assert am.stats["loop-info"].computed == before + 1  # recomputed


def test_clean_pass_preserves_everything_implicitly():
    m, f = _func()
    pm, am = _pm_with_warm_cache([_CleanPass()], f)
    pm.run(m)
    assert am.cached(LoopAnalysis, f) is not None
    assert am.cached(MemTouchAnalysis, f) is not None


def test_verifier_and_pipeline_share_one_cache():
    """The codegen_speed flow: verify computes loop-info/port-accesses, the
    default pipeline's port-demotion re-uses them through the shared
    AnalysisManager (>= 1 hit across the pipeline)."""
    from repro.core.passes import DEFAULT_PIPELINE_SPEC

    m, entry = GALLERY["histogram"].build()
    am = AnalysisManager()
    verifier.verify(m, am=am)
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC, analysis_manager=am)
    stats = pm.run(m)
    assert stats.get("port_demotion", 0) >= 1
    assert am.stats_dict()["hits"] >= 1
    assert am.stats["port-accesses"].hits >= 1
