"""Unit tests for the structured RTL netlist IR (core.codegen.rtl): module
construction, the printer, the net-fanout analysis and each RTL pass on
hand-built netlists, plus pipeline idempotence on a real kernel."""

import pytest

from repro.core.codegen import lint_verilog
from repro.core.codegen.rtl import (RTL_PIPELINE_SPEC, Binop, CombAssign,
                                    CombShare, Const, ControllerMerge,
                                    DeadNetElim, Instance, LoopController,
                                    MemRead, Memory, MemReadShare, MemWrite,
                                    Mux, NetFanoutAnalysis, Ref, RegAssign,
                                    RTLDesign, RTLModule, ShiftReg,
                                    ShiftRegMerge, print_rtl)
from repro.core.codegen.verilog import netlist_of
from repro.core.passmgr import AnalysisManager, PassManager


def _module() -> RTLModule:
    """in -> +1 -> delay(3) -> out, plus a dead chain."""
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("t_start", "input")
    m.add_port("din", "input", 8)
    m.add_port("dout", "output", 8)
    m.new_net("inc", 8)
    m.add(CombAssign("inc", Binop("+", Ref("din"), Const(1, 8), width=8)))
    m.new_net("d3", 8)
    m.add(ShiftReg("d3", Ref("inc"), 8, 3))
    m.add(CombAssign("dout", Ref("d3")))
    # dead: a comb net and a shift reg nobody reads
    m.new_net("dead_c", 8)
    m.add(CombAssign("dead_c", Binop("-", Ref("din"), Const(1, 8), width=8)))
    m.new_net("dead_sr", 8)
    m.add(ShiftReg("dead_sr", Ref("dead_c"), 8, 5))
    return m


# ---------------------------------------------------------------------------
# construction / printing / netlist derivation
# ---------------------------------------------------------------------------


def test_module_construction_and_print():
    m = _module()
    assert set(m.nets) == {"inc", "d3", "dead_c", "dead_sr"}
    text = print_rtl(m)
    assert text.startswith("// generated")
    assert "module t (" in text and text.rstrip().endswith("endmodule")
    assert lint_verilog(text) == []


def test_duplicate_net_rejected():
    m = RTLModule("t")
    m.new_net("x", 1)
    with pytest.raises(AssertionError):
        m.new_net("x", 2)


def test_netlist_derivation_counts():
    m = _module()
    nl = netlist_of(m)
    assert sorted(nl.adders) == [8, 8]          # the +1 and the dead -1
    assert sorted(nl.shift_regs) == [(8, 3), (8, 5)]
    assert nl.registers == [] and nl.rams == []


def test_net_fanout_analysis():
    m = _module()
    fo = AnalysisManager().get(NetFanoutAnalysis, m)
    assert fo.fanout("inc") == 1          # read by the shift reg
    assert fo.fanout("dead_c") == 1       # read by the dead shift reg
    assert fo.fanout("dead_sr") == 0
    assert fo.writers["d3"] != []


# ---------------------------------------------------------------------------
# rtl-dce
# ---------------------------------------------------------------------------


def test_dce_removes_dead_chain_and_keeps_live_path():
    m = _module()
    n = DeadNetElim().run_module(m)
    assert n > 0
    assert "dead_c" not in m.nets and "dead_sr" not in m.nets
    assert {"inc", "d3"} <= set(m.nets)
    assert len(m.items) == 3
    # idempotent: a second run is a no-op
    assert DeadNetElim().run_module(m) == 0


def test_dce_keeps_memory_with_live_reader_drops_dead_memory():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("q", "output", 8)
    m.add(Memory("live", 1, 16, 8, "lutram"))
    m.add(MemWrite("live", 0, Const(0, 4), Const(7, 8), Const(1, 1)))
    m.new_net("rd", 8, "reg")
    m.add(MemRead("rd", "live", 0, Const(0, 4), Const(1, 1)))
    m.add(CombAssign("q", Ref("rd")))
    m.add(Memory("dead", 1, 16, 8, "lutram"))
    m.add(MemWrite("dead", 0, Const(0, 4), Const(9, 8), Const(1, 1)))
    DeadNetElim().run_module(m)
    kinds = [type(it).__name__ for it in m.items]
    assert kinds.count("Memory") == 1 and kinds.count("MemWrite") == 1
    assert netlist_of(m).rams == [(1, 16, 8, 2, "lutram")]


def test_dce_prunes_unread_controller_end_pulse():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("t_start", "input")
    m.add_port("iv_out", "output", 8)
    m.new_net("iv", 8, "reg")
    m.new_net("act", 1, "reg")
    m.new_net("itr", 1)
    m.new_net("endp", 1, "reg")
    m.add(LoopController("l", "iv", 8, "act", "itr", "endp",
                         start=Ref("t_start"), lb=Const(0, 8), ub=Const(4, 8),
                         step=Const(1, 8), ii=1))
    m.add(CombAssign("iv_out", Ref("iv")))
    n = DeadNetElim().run_module(m)
    assert n >= 1
    ctrl = next(it for it in m.items if isinstance(it, LoopController))
    assert ctrl.endp == "" and "endp" not in m.nets
    assert lint_verilog(print_rtl(m)) == []
    reg_count = sum(netlist_of(m).registers)
    assert reg_count == 1  # only the active flag remains


# ---------------------------------------------------------------------------
# rtl-merge-srl
# ---------------------------------------------------------------------------


def _sr_module():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("x", "input", 8)
    m.add_port("o1", "output", 8)
    m.add_port("o2", "output", 8)
    m.add_port("o3", "output", 8)
    for nm, depth in (("a", 2), ("b", 2), ("c", 5)):
        m.new_net(nm, 8)
        m.add(ShiftReg(nm, Ref("x"), 8, depth))
    m.add(CombAssign("o1", Ref("a")))
    m.add(CombAssign("o2", Ref("b")))
    m.add(CombAssign("o3", Ref("c")))
    return m


def test_srl_merge_shares_equal_and_retaps_deeper():
    m = _sr_module()
    n = ShiftRegMerge().run_module(m)
    assert n == 2  # b merged into a; c re-tapped from a
    srs = [it for it in m.items if isinstance(it, ShiftReg)]
    assert len(srs) == 2
    deep = next(s for s in srs if s.dest == "c")
    assert isinstance(deep.src, Ref) and deep.src.name == "a"
    assert deep.depth == 3  # 5 total = 2 shared + 3 private
    # total delayed stages dropped from 9 to 5
    assert sum(d for _w, d in netlist_of(m).shift_regs) == 5
    # o2 now reads the shared chain
    o2 = next(it for it in m.items
              if isinstance(it, CombAssign) and it.dest == "o2")
    assert o2.expr.key() == Ref("a").key()
    assert lint_verilog(print_rtl(m)) == []
    # idempotent
    assert ShiftRegMerge().run_module(m) == 0


@pytest.mark.parametrize("depths,expected_totals", [
    ((2, 5, 5), {2: 2, 5: 5}),   # equal deeper chains merge onto one tail
    ((2, 5, 7), {2: 2, 5: 5, 7: 7}),  # each re-tap keeps the cumulative delay
    ((3, 3, 3), {3: 3}),
])
def test_srl_merge_preserves_cumulative_delays(depths, expected_totals):
    """Regression: re-tapping must track the cumulative delay from the
    *source*, not the residual depth of the previous chain — depths (2,5,5)
    once produced a 7-cycle third chain."""
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("x", "input", 8)
    for i, d in enumerate(depths):
        m.add_port(f"o{i}", "output", 8)
        m.new_net(f"n{i}", 8)
        m.add(ShiftReg(f"n{i}", Ref("x"), 8, d))
        m.add(CombAssign(f"o{i}", Ref(f"n{i}")))
    ShiftRegMerge().run_module(m)
    # recover each surviving chain's total delay back to the source
    srs = {it.dest: it for it in m.items if isinstance(it, ShiftReg)}

    def total(sr):
        t = sr.depth
        while isinstance(sr.src, Ref) and sr.src.name in srs:
            sr = srs[sr.src.name]
            t += sr.depth
        return t

    got = sorted(total(sr) for sr in srs.values())
    assert got == sorted(expected_totals.values())
    # every output port still sees exactly its original delay
    for i, d in enumerate(depths):
        o = next(it for it in m.items
                 if isinstance(it, CombAssign) and it.dest == f"o{i}")
        assert total(srs[o.expr.name]) == d, (i, d)
    assert ShiftRegMerge().run_module(m) == 0  # idempotent


def test_srl_merge_respects_reset_and_width():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("x", "input", 8)
    m.add_port("o1", "output", 8)
    m.add_port("o2", "output", 8)
    m.new_net("a", 8)
    m.add(ShiftReg("a", Ref("x"), 8, 2, reset_zero=True))
    m.new_net("b", 8)
    m.add(ShiftReg("b", Ref("x"), 8, 2, reset_zero=False))
    m.add(CombAssign("o1", Ref("a")))
    m.add(CombAssign("o2", Ref("b")))
    assert ShiftRegMerge().run_module(m) == 0  # different reset: no merge


# ---------------------------------------------------------------------------
# rtl-share-comb / rtl-share-mem / rtl-merge-ctrl
# ---------------------------------------------------------------------------


def test_comb_share_merges_duplicates_and_keeps_port_driven():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("x", "input", 8)
    m.add_port("out", "output", 8)
    e = lambda: Binop("+", Ref("x"), Const(3, 8), width=8)
    m.new_net("u", 8)
    m.add(CombAssign("u", e()))
    m.new_net("v", 8)
    m.add(CombAssign("v", e()))
    m.add(CombAssign("out", e()))  # an output port with the same expr
    m.new_net("w", 8)
    m.add(CombAssign("w", Mux(Ref("clk"), Ref("u"), Ref("v"), 8)))
    n = CombShare().run_module(m)
    assert n >= 2
    assert "v" not in m.nets                      # merged into u
    out = next(it for it in m.items
               if isinstance(it, CombAssign) and it.dest == "out")
    assert out.expr.key() == Ref("u").key()       # port re-pointed, not dropped
    # the mux collapsed to identical branches referencing u
    assert sum(isinstance(it, CombAssign) for it in m.items) == 3
    assert netlist_of(m).adders == [8]
    assert CombShare().run_module(m) == 0         # idempotent


def test_mem_read_share_dedups_broadcast_reads():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("en", "input")
    m.add_port("a", "output", 8)
    m.add_port("b", "output", 8)
    m.add(Memory("buf", 1, 16, 8, "lutram"))
    m.add(MemWrite("buf", 0, Const(1, 4), Const(5, 8), Ref("en")))
    for nm in ("r1", "r2"):
        m.new_net(nm, 8, "reg")
        m.add(MemRead(nm, "buf", 0, Const(1, 4), Ref("en")))
    m.add(CombAssign("a", Ref("r1")))
    m.add(CombAssign("b", Ref("r2")))
    assert MemReadShare().run_module(m) == 1
    assert "r2" not in m.nets
    assert sum(isinstance(it, MemRead) for it in m.items) == 1
    bb = next(it for it in m.items
              if isinstance(it, CombAssign) and it.dest == "b")
    assert bb.expr.key() == Ref("r1").key()
    assert MemReadShare().run_module(m) == 0


def test_controller_merge_unifies_identical_fsms():
    m = RTLModule("t")
    m.add_port("clk", "input")
    m.add_port("rst", "input")
    m.add_port("t_start", "input")
    m.add_port("o1", "output", 8)
    m.add_port("o2", "output", 8)
    for i in (1, 2):
        m.new_net(f"iv{i}", 8, "reg")
        m.new_net(f"act{i}", 1, "reg")
        m.new_net(f"itr{i}", 1)
        m.new_net(f"endp{i}", 1, "reg")
        m.add(LoopController(f"l{i}", f"iv{i}", 8, f"act{i}", f"itr{i}",
                             f"endp{i}", start=Ref("t_start"), lb=Const(0, 8),
                             ub=Const(16, 8), step=Const(1, 8), ii=1))
    m.add(CombAssign("o1", Ref("iv1")))
    m.add(CombAssign("o2", Ref("iv2")))
    assert ControllerMerge().run_module(m) == 1
    assert sum(isinstance(it, LoopController) for it in m.items) == 1
    o2 = next(it for it in m.items
              if isinstance(it, CombAssign) and it.dest == "o2")
    assert o2.expr.key() == Ref("iv1").key()
    assert lint_verilog(print_rtl(m)) == []
    assert ControllerMerge().run_module(m) == 0


# ---------------------------------------------------------------------------
# pipeline-level behaviour
# ---------------------------------------------------------------------------


def test_rtl_pipeline_runs_via_passmanager_spec():
    design = RTLDesign({"t": _module()}, entry="t")
    pm = PassManager.from_spec(RTL_PIPELINE_SPEC)
    stats = pm.run(design)
    assert stats["rtl_dce"] > 0
    # fixpoint reached: a fresh pipeline reports zero rewrites
    again = PassManager.from_spec(RTL_PIPELINE_SPEC).run(design)
    assert sum(again.values()) == 0


def test_rtl_pipeline_idempotent_on_gallery_kernel():
    from repro.core.codegen import generate_verilog
    from repro.core.gallery import GALLERY
    from repro.core.passes import run_pipeline

    m, entry = GALLERY["conv2d"].build()
    run_pipeline(m)
    vs = generate_verilog(m, entry=entry)  # default pipeline already applied
    design = RTLDesign({entry: vs[entry].rtl}, entry=entry)
    again = PassManager.from_spec(RTL_PIPELINE_SPEC).run(design)
    assert sum(again.values()) == 0


def test_instances_kept_alive_by_dce():
    m = RTLModule("top")
    m.add_port("clk", "input")
    m.new_net("sub_out", 8)
    m.add(Instance("child", "u_child", [
        ("clk", Ref("clk"), False), ("q", Ref("sub_out"), True)]))
    DeadNetElim().run_module(m)
    assert any(isinstance(it, Instance) for it in m.items)
    assert netlist_of(m).instances == ["child"]
