"""Launch-layer integration: the dry-run machinery (specs, shardings,
lower+compile, loop-aware HLO analysis) exercised end-to-end on a small
8-device mesh with smoke configs — the 512-device production run uses the
identical code path (subprocess: device count locks at first jax init)."""

import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _run(code: str) -> str:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout[-3000:]}\nstderr:\n{out.stderr[-3000:]}"
    return out.stdout


MINI = """
import jax, json
import jax.numpy as jnp
from repro.configs.base import ShapeCfg
from repro.configs.registry import get_smoke_config
from repro.launch import hlo_analysis
from repro.launch.specs import cell_abstract_inputs
from repro.optim.adamw import OptCfg
from repro.parallel.api import use_rules
from repro.parallel.rules import rules_for
from repro.train.steps import make_serve_step, make_train_step

cfg = get_smoke_config({arch!r})
shape = ShapeCfg("mini", seq_len=16, global_batch=8, kind={kind!r})
# version-compatible mesh helper (AxisType only exists on jax >= 0.5)
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = rules_for(cfg, mesh, {mode!r}, batch=8)
with use_rules(rules, mesh):
    args, in_sh, out_sh = cell_abstract_inputs(cfg, shape, rules, mesh)
    step = (make_train_step(cfg, OptCfg(), mesh=mesh) if {kind!r} == "train"
            else make_serve_step(cfg))
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()

st = hlo_analysis.analyze(hlo)
assert st.flops > 0, "dot FLOPs must be attributed"
assert st.mem_bytes > 0
# the layer scan must be trip-count-multiplied (no unknown whiles)
assert st.unknown_trip_whiles == 0, st.unknown_trip_whiles
terms = hlo_analysis.roofline_terms(st.flops, st.mem_bytes, st.coll_bytes)
assert terms["bottleneck"] in ("compute", "memory", "collective")
print("MINI_OK", json.dumps({{"flops": st.flops, "coll": st.coll_bytes}}))
"""


def test_mini_dryrun_train_dense():
    out = _run(MINI.format(arch="tinyllama-1.1b", kind="train", mode="train"))
    assert "MINI_OK" in out
    stats = json.loads(out.split("MINI_OK", 1)[1])
    assert stats["coll"] > 0  # FSDP/TP training must communicate


def test_mini_dryrun_train_moe():
    out = _run(MINI.format(arch="qwen2-moe-a2.7b", kind="train", mode="train"))
    assert "MINI_OK" in out


def test_mini_dryrun_decode():
    out = _run(MINI.format(arch="tinyllama-1.1b", kind="decode", mode="decode"))
    assert "MINI_OK" in out
