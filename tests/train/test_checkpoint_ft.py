"""Checkpoint + fault-tolerance tests: atomic save/restore, async writer,
elastic re-mesh, exact-resume equivalence, injected-failure restart."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore, save
from repro.configs.base import ShapeCfg
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import make_batch
from repro.ft.runtime import (InjectedFailure, RunReport, StepMonitor,
                              inject_failures, run_with_restarts)
from repro.optim.adamw import OptCfg
from repro.train.steps import init_train_state, make_train_step

SHAPE = ShapeCfg("t", seq_len=16, global_batch=4, kind="train")


def _cfg():
    return get_smoke_config("smollm-360m")


def _batch(cfg, step):
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE, step=step).items()}


def test_save_restore_roundtrip(tmp_path):
    cfg = _cfg()
    state = init_train_state(jax.random.key(0), cfg)
    save(tmp_path, 7, state)
    assert latest_step(tmp_path) == 7
    restored, step = restore(tmp_path, jax.eval_shape(lambda: init_train_state(
        jax.random.key(0), cfg)))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_keeps_previous_on_partial_write(tmp_path):
    cfg = _cfg()
    state = init_train_state(jax.random.key(0), cfg)
    save(tmp_path, 1, state)
    # simulate a torn write: stale tmp dir + LATEST pointing at missing dir
    (tmp_path / ".tmp-00000002").mkdir()
    (tmp_path / "LATEST").write_text("step_00000002")
    assert latest_step(tmp_path) == 1  # falls back to newest complete
    restored, step = restore(tmp_path, state)
    assert step == 1


def test_async_checkpointer(tmp_path):
    cfg = _cfg()
    state = init_train_state(jax.random.key(0), cfg)
    ck = AsyncCheckpointer(tmp_path, keep=2)
    for s in (0, 1, 2, 3):
        ck.save(s, state)
    ck.wait()
    assert latest_step(tmp_path) == 3
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2  # gc keeps the newest 2


def test_exact_resume_matches_uninterrupted_run(tmp_path):
    """Train 6 steps straight vs 3 steps + checkpoint + restore + 3 steps:
    identical final parameters (seekable data + full state in ckpt)."""
    cfg = _cfg()
    step_fn = jax.jit(make_train_step(cfg, OptCfg(lr=1e-3, warmup_steps=2,
                                                  decay_steps=10)))

    s_a = init_train_state(jax.random.key(0), cfg)
    for i in range(6):
        s_a, _ = step_fn(s_a, _batch(cfg, i))

    s_b = init_train_state(jax.random.key(0), cfg)
    for i in range(3):
        s_b, _ = step_fn(s_b, _batch(cfg, i))
    save(tmp_path, 2, s_b)
    s_c, _ = restore(tmp_path, jax.eval_shape(lambda: init_train_state(
        jax.random.key(0), cfg)))
    for i in range(3, 6):
        s_c, _ = step_fn(s_c, _batch(cfg, i))

    for a, c in zip(jax.tree.leaves(s_a["params"]), jax.tree.leaves(s_c["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=1e-6, atol=1e-6)


def test_run_with_restarts_survives_injected_failures(tmp_path):
    cfg = _cfg()
    base_step = jax.jit(make_train_step(cfg, OptCfg(lr=1e-3, warmup_steps=2,
                                                    decay_steps=10)))
    step_fn = inject_failures(base_step, fail_at={5, 12})
    report = run_with_restarts(
        init_state=lambda: init_train_state(jax.random.key(0), cfg),
        step_fn=step_fn,
        batch_at=lambda i: _batch(cfg, i),
        num_steps=15,
        ckpt_dir=str(tmp_path),
        ckpt_every=4,
        max_restarts=3,
    )
    assert report.steps_completed == 15
    assert report.restarts == 2
    # optimizer step count equals the step the run finished at
    like = jax.eval_shape(lambda: init_train_state(jax.random.key(0), cfg))
    final, step = restore(tmp_path, like)
    assert step == 14


def test_restart_budget_exhausted_raises(tmp_path):
    cfg = _cfg()
    base_step = jax.jit(make_train_step(cfg, OptCfg()))
    step_fn = inject_failures(base_step, fail_at={1, 2, 3, 4, 5})
    with pytest.raises(InjectedFailure):
        run_with_restarts(
            init_state=lambda: init_train_state(jax.random.key(0), cfg),
            step_fn=step_fn,
            batch_at=lambda i: _batch(cfg, i),
            num_steps=10,
            ckpt_dir=str(tmp_path),
            ckpt_every=2,
            max_restarts=2,
        )


def test_straggler_detection():
    import time

    mon = StepMonitor(threshold=2.0)
    for i in range(8):
        mon.start()
        time.sleep(0.01)
        mon.stop(i)
    mon.start()
    time.sleep(0.08)
    mon.stop(99)
    assert any(s == 99 for s, _ in mon.stragglers)


def test_elastic_remesh_restore(tmp_path):
    """A checkpoint saved from one mesh restores onto a different mesh
    (arrays are stored unsharded; restore re-shards)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    cfg = _cfg()
    state = init_train_state(jax.random.key(0), cfg)
    save(tmp_path, 0, state)
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = restore(tmp_path, state, shardings=sh)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == NamedSharding(mesh, P())
