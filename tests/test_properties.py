"""Hypothesis property tests on the framework's system invariants
(deliverable c): pass-pipeline semantic preservation, optimizer math,
compression error bounds, pipeline determinism, checkpoint round-trips,
kernel/oracle agreement over drawn shapes, and the HLO analyzer on
synthetic modules with known ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.lower import lower_to_jax, simulate
from repro.core.passes import run_pipeline


# ---------------------------------------------------------------------------
# 1. optimization passes never change semantics (random affine pipelines)
# ---------------------------------------------------------------------------


@st.composite
def affine_pipeline(draw):
    n = draw(st.integers(min_value=4, max_value=24))
    muls = draw(st.lists(st.integers(min_value=0, max_value=9), min_size=1, max_size=3))
    adds = draw(st.lists(st.integers(min_value=-5, max_value=5), min_size=1, max_size=3))
    return n, muls, adds


def _build(n, muls, adds):
    b = Builder(ir.Module("p"))
    r = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("f", [r, w], ["A", "O"]) as f:
        A, O = f.args
        with b.for_(0, n, 1, at=f.t + 1) as l:
            b.yield_(at=l.time + 1)
            v = b.read(A, [l.iv], at=l.time)
            for m in muls:
                v = b.mult(v, m)
            for a in adds:
                v = b.add(v, a)
            i1 = b.delay(l.iv, 1, at=l.time)
            b.write(v, O, [i1], at=l.time + 1)
        b.ret()
    return b.module


@given(affine_pipeline())
@settings(max_examples=25, deadline=None)
def test_pass_pipeline_preserves_semantics(design):
    n, muls, adds = design
    m1 = _build(n, muls, adds)
    m2 = _build(n, muls, adds)
    run_pipeline(m2)   # constprop/cse/strength-reduce/precision/delay-elim
    assert not [d for d in verifier.verify(m2, raise_on_error=False)
                if d.severity == "error"]
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2**10, size=(n,), dtype=np.int64)
    o1, o2 = np.zeros_like(a), np.zeros_like(a)
    simulate(m1, "f", [a.copy(), o1])
    simulate(m2, "f", [a.copy(), o2])
    np.testing.assert_array_equal(o1, o2)
    # the functional JAX lowering agrees with the optimized design too
    j = lower_to_jax(m2, "f")(a, np.zeros_like(a))["O"]
    np.testing.assert_array_equal(np.asarray(j, np.int64), o1)


# ---------------------------------------------------------------------------
# 2. optimizer invariants
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=64), st.floats(0.1, 10.0),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_clip_by_global_norm_bounds(dim, max_norm, seed):
    from repro.optim.adamw import clip_by_global_norm, global_norm

    g = {"w": jax.random.normal(jax.random.key(seed), (dim,)) * 10}
    clipped, norm = clip_by_global_norm(g, max_norm)
    assert float(global_norm(clipped)) <= max_norm * 1.001
    # direction preserved
    ratio = np.asarray(clipped["w"]) / np.maximum(np.abs(np.asarray(g["w"])), 1e-9)
    assert (np.sign(np.asarray(clipped["w"])) == np.sign(np.asarray(g["w"]))).all()


@given(st.integers(min_value=1, max_value=32))
@settings(max_examples=10, deadline=None)
def test_adamw_zero_grad_no_decay_is_identity(dim):
    from repro.optim.adamw import OptCfg, adamw_update, init_opt_state

    p = {"w": jnp.ones((dim,)), "b": jnp.zeros((dim,))}  # ndim<2: never decayed
    opt = init_opt_state(p)
    g = jax.tree.map(jnp.zeros_like, p)
    newp, newopt, _ = adamw_update(g, opt, p, OptCfg(weight_decay=0.0))
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(newp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    assert int(newopt["step"]) == 1


# ---------------------------------------------------------------------------
# 3. int8 compression error bound
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=256), st.floats(1e-3, 1e3),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale, seed):
    from repro.parallel.compression import dequantize, quantize_int8

    x = jax.random.normal(jax.random.key(seed), (n,)) * scale
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize(q, s)) - np.asarray(x)).max()
    amax = np.abs(np.asarray(x)).max()
    assert err <= amax / 127.0 + 1e-12


# ---------------------------------------------------------------------------
# 4. data pipeline: determinism, seekability, host disjointness
# ---------------------------------------------------------------------------


@given(st.integers(min_value=0, max_value=10_000), st.integers(min_value=0, max_value=99))
@settings(max_examples=20, deadline=None)
def test_pipeline_batch_is_pure_function_of_step(step, seed):
    from repro.configs.base import ShapeCfg
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import make_batch

    cfg = get_smoke_config("tinyllama-1.1b")
    shape = ShapeCfg("t", seq_len=8, global_batch=2, kind="train")
    b1 = make_batch(cfg, shape, step=step, seed=seed)
    b2 = make_batch(cfg, shape, step=step, seed=seed)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # adjacent steps differ (with overwhelming probability)
    b3 = make_batch(cfg, shape, step=step + 1, seed=seed)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are the next-token shift of the same stream
    assert b1["labels"].shape == b1["tokens"].shape


@given(st.integers(min_value=0, max_value=500))
@settings(max_examples=10, deadline=None)
def test_pipeline_host_shards_are_distinct(step):
    from repro.configs.base import ShapeCfg
    from repro.configs.registry import get_smoke_config
    from repro.data.pipeline import make_batch

    cfg = get_smoke_config("tinyllama-1.1b")
    shape = ShapeCfg("t", seq_len=16, global_batch=4, kind="train")
    h0 = make_batch(cfg, shape, step=step, host_id=0, n_hosts=2)
    h1 = make_batch(cfg, shape, step=step, host_id=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 2
    assert not np.array_equal(h0["tokens"], h1["tokens"])


# ---------------------------------------------------------------------------
# 5. checkpoint round-trip on random pytrees
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(min_value=1, max_value=16), min_size=1, max_size=4),
       st.sampled_from(["float32", "bfloat16", "int32"]))
@settings(max_examples=15, deadline=None)
def test_checkpoint_roundtrip_random_tree(dims, dtype):
    import tempfile

    from repro.checkpoint.store import restore, save

    tree = {f"leaf{i}": (jnp.arange(d * 2, dtype=dtype).reshape(d, 2) + i)
            for i, d in enumerate(dims)}
    with tempfile.TemporaryDirectory() as td:
        save(td, 3, tree)
        back, step = restore(td, tree)
        assert step == 3
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 6. HLO analyzer ground truth on synthetic modules
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=40))
@settings(max_examples=25, deadline=None)
def test_hlo_analyzer_dot_flops_and_trip_counts(m, n, k, trip):
    from repro.launch.hlo_analysis import HloModule

    hlo = f"""HloModule synth

%body (p: (s32[], f32[{m},{k}], f32[{k},{n}])) -> (s32[], f32[{m},{k}], f32[{k},{n}]) {{
  %p = (s32[], f32[{m},{k}], f32[{k},{n}]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %a = f32[{m},{k}]{{1,0}} get-tuple-element(%p), index=1
  %b = f32[{k},{n}]{{1,0}} get-tuple-element(%p), index=2
  %d = f32[{m},{n}]{{1,0}} dot(%a, %b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  %ar = f32[{m},{n}]{{1,0}} all-reduce(%d), replica_groups={{}}, to_apply=%add
  ROOT %t = (s32[], f32[{m},{k}], f32[{k},{n}]) tuple(%i, %a, %b)
}}

%cond (p: (s32[], f32[{m},{k}], f32[{k},{n}])) -> pred[] {{
  %p = (s32[], f32[{m},{k}], f32[{k},{n}]) parameter(0)
  ROOT %lt = pred[] constant(true)
}}

ENTRY %main (x: f32[{m},{k}], y: f32[{k},{n}]) -> f32[] {{
  %x = f32[{m},{k}]{{1,0}} parameter(0)
  %y = f32[{k},{n}]{{1,0}} parameter(1)
  %init = (s32[], f32[{m},{k}], f32[{k},{n}]) tuple(%x, %x, %y)
  %w = (s32[], f32[{m},{k}], f32[{k},{n}]) while(%init), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trip}"}}}}
  ROOT %r = f32[] constant(0)
}}
"""
    st_ = HloModule(hlo).stats()
    assert st_.flops == 2.0 * m * n * k * trip
    assert st_.coll_bytes == 4.0 * m * n * trip
    assert st_.coll_by_kind == {"all-reduce": 4.0 * m * n * trip}


# ---------------------------------------------------------------------------
# 7. kernels vs oracles over drawn shapes (interpret mode, kept small)
# ---------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=40), st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=40))
@settings(max_examples=10, deadline=None)
def test_matmul_kernel_any_shape(m, k, n):
    from repro.kernels import ops, ref

    k1, k2 = jax.random.split(jax.random.key(m * 1000 + k * 100 + n))
    x = jax.random.normal(k1, (m, k), jnp.float32)
    y = jax.random.normal(k2, (k, n), jnp.float32)
    out = ops.matmul(x, y, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul_ref(x, y)),
                               rtol=3e-5, atol=3e-5)


@given(st.integers(min_value=1, max_value=48), st.integers(min_value=1, max_value=24))
@settings(max_examples=10, deadline=None)
def test_rglru_kernel_any_shape(S, D):
    from repro.kernels import ops, ref

    k1, k2 = jax.random.split(jax.random.key(S * 100 + D))
    a = jax.random.uniform(k1, (2, S, D), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(k2, (2, S, D), jnp.float32)
    h = ops.rglru_scan(a, b, bs=16, bd=16)
    want, _ = ref.rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(want), rtol=2e-4, atol=2e-4)
