"""Prefill/decode consistency: teacher-forced step-by-step decode must
reproduce the forward pass's logits at every position — the strongest
end-to-end invariant of the cache machinery (KV, latent, ring-buffer and
recurrent states all participate).  Plus the MLA cache-size claim."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import make_batch
from repro.models import transformer

SHAPE = ShapeCfg("t", seq_len=12, global_batch=2, kind="train")

# cross-attn archs need the memory plumbing exercised too
ARCHS = ["tinyllama-1.1b", "qwen2-7b", "deepseek-v2-lite-16b",
         "mamba2-780m", "recurrentgemma-9b", "seamless-m4t-medium"]


@pytest.mark.parametrize("arch", ARCHS)
def test_teacher_forced_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity-based routing drops tokens as a function of the *queue*
        # (whole sequence in forward, one token in decode) — equality only
        # holds drop-free, so give the test unbounded capacity
        import dataclasses
        cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    tokens = batch["tokens"]
    B, S = tokens.shape

    params = transformer.init_lm(jax.random.key(0), cfg)
    fwd_logits, _ = jax.jit(
        lambda p, b: transformer.lm_forward(p, b, cfg))(params, batch)

    cache = transformer.init_lm_cache(cfg, B, S, memory_tokens=cfg.frontend_tokens)
    if cfg.frontend is not None:
        cache = transformer.lm_prepare_decode_cache(params, cache, batch, cfg)

    step = jax.jit(lambda p, c, t, i: transformer.lm_decode_step(p, c, t, i, cfg))
    dec = []
    for t in range(S):
        logits1, cache = step(params, cache, tokens[:, t:t + 1],
                              jnp.asarray(t, jnp.int32))
        dec.append(logits1[:, 0])
    dec_logits = jnp.stack(dec, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(fwd_logits, np.float32),
        rtol=2e-3, atol=2e-3,
        err_msg=f"{arch}: decode diverges from forward")


def test_mla_cache_is_an_order_of_magnitude_smaller():
    """DeepSeek-V2's headline: the latent cache stores (kv_lora + rope_dim)
    per token instead of 2 * KvH * Dh — 93% smaller at paper scale."""
    from repro.configs.registry import get_config

    cfg = get_config("deepseek-v2-lite-16b")
    a = cfg.attn
    mla_per_tok = a.kv_lora_rank + a.rope_head_dim
    mha_per_tok = 2 * a.n_kv_heads * (a.nope_head_dim + a.rope_head_dim)
    assert mla_per_tok * 10 < mha_per_tok * 2  # >5x smaller
    # and the actual cache tensors agree with the formula
    c = jax.eval_shape(lambda: transformer.init_lm_cache(cfg, 1, 128))
    import jax as _j
    total = sum(x.size for x in _j.tree.leaves(c))
    assert total == cfg.n_layers * 128 * mla_per_tok
