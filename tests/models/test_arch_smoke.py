"""Per-assigned-architecture smoke tests: a reduced same-family config runs
one forward + one train step + a short decode on CPU; output shapes and
finiteness are asserted (assignment requirement f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import get_smoke_config, list_archs
from repro.data.pipeline import make_batch
from repro.models import transformer
from repro.optim.adamw import OptCfg
from repro.train.steps import init_train_state, make_serve_step, make_train_step

ARCHS = list_archs()
SMOKE_SHAPE = ShapeCfg("smoke", seq_len=16, global_batch=2, kind="train")


def _batch(cfg):
    return {k: jnp.asarray(v) for k, v in make_batch(cfg, SMOKE_SHAPE).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    params = transformer.init_lm(jax.random.key(0), cfg)
    logits, aux = jax.jit(lambda p, b: transformer.lm_forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    state = init_train_state(jax.random.key(0), cfg)
    step = make_train_step(cfg, OptCfg(lr=1e-3, warmup_steps=2, decay_steps=10),
                           num_microbatches=2)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(d0, np.float32), np.asarray(d1, np.float32))
    assert int(new_state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    batch = _batch(cfg)
    params = transformer.init_lm(jax.random.key(0), cfg)
    cache = transformer.init_lm_cache(cfg, batch=2, seq_len=32,
                                      memory_tokens=cfg.frontend_tokens)
    if cfg.frontend is not None:
        cache = transformer.lm_prepare_decode_cache(params, cache, batch, cfg)
    serve = make_serve_step(cfg)
    tok = batch["tokens"][:, :1]
    jit_serve = jax.jit(serve)
    for i in range(3):
        tok, cache = jit_serve(params, cache, tok, jnp.asarray(i, jnp.int32))
    assert tok.shape == (2, 1)
    assert int(tok.min()) >= 0 and int(tok.max()) < cfg.padded_vocab


def test_train_loss_decreases_tinyllama():
    """End-to-end sanity: 30 steps on the structured synthetic stream
    decrease loss on the smallest dense config."""
    cfg = get_smoke_config("tinyllama-1.1b")
    shape = ShapeCfg("smoke", seq_len=32, global_batch=8, kind="train")
    state = init_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg, OptCfg(lr=1e-2, warmup_steps=5, decay_steps=100)))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(cfg, shape, step=i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses
