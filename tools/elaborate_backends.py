"""Opportunistically elaborate the generated netlists with real HDL tools.

For every gallery kernel x hierarchy mode, emits each backend's text to a
temp dir and runs

  * ``iverilog -g2012``  over the verilog and systemverilog outputs,
  * ``ghdl -a --std=08`` over the vhdl outputs,

when the tool is on PATH — exiting 0 with a notice otherwise, so the CI
step degrades gracefully on runners without HDL tools.  CIRCT output is
text-checked by the dialect linter only (no circt-opt assumed anywhere).

Run:  PYTHONPATH=src python tools/elaborate_backends.py [--kernels a,b]
"""

from __future__ import annotations

import argparse
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

from repro.core.codegen import generate_verilog
from repro.core.gallery import GALLERY
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager

EXT = {"verilog": "v", "systemverilog": "sv", "vhdl": "vhd"}


def _emit(kernel: str, mode: str, backend: str) -> str:
    m, entry = GALLERY[kernel].build()
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
    mods = generate_verilog(m, entry, hierarchy=mode, backend=backend)
    return "\n".join(vm.text for vm in mods.values())


def main(kernels=None) -> int:
    iverilog = shutil.which("iverilog")
    ghdl = shutil.which("ghdl")
    if not iverilog and not ghdl:
        print("elaborate: neither iverilog nor ghdl on PATH; skipping "
              "(lint-only coverage)")
        return 0
    names = kernels or sorted(GALLERY)
    failures = 0
    with tempfile.TemporaryDirectory() as td:
        tdir = Path(td)
        for kernel in names:
            for mode in ("inline", "modules"):
                jobs = []
                if iverilog:
                    jobs += [("verilog", [iverilog, "-g2012"]),
                             ("systemverilog", [iverilog, "-g2012"])]
                if ghdl:
                    jobs += [("vhdl", [ghdl, "-a", "--std=08",
                                       f"--workdir={td}"])]
                for backend, cmd in jobs:
                    src = tdir / f"{kernel}.{mode}.{EXT[backend]}"
                    src.write_text(_emit(kernel, mode, backend))
                    extra = (["-o", str(tdir / "a.out")]
                             if cmd[0] == iverilog else [])
                    r = subprocess.run(cmd + extra + [str(src)],
                                       capture_output=True, text=True)
                    status = "ok" if r.returncode == 0 else "FAIL"
                    print(f"elaborate[{backend:13s}] {kernel:12s} "
                          f"[{mode:7s}] {status}")
                    if r.returncode != 0:
                        failures += 1
                        print((r.stderr or r.stdout).strip()[:2000])
    return 1 if failures else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel subset")
    args = ap.parse_args()
    ks = [s.strip() for s in args.kernels.split(",")] if args.kernels else None
    sys.exit(main(ks))
