"""CI perf-regression gate: compare a fresh benchmark artifact against its
committed baseline with a generous tolerance.

Usage::

    python tools/perf_gate.py --fresh artifacts/bench/BENCH_codegen_scaling.json \
        --baseline benchmarks/baselines/BENCH_codegen_scaling.json [--tolerance 8]

The schema is detected from the payload:

  * ``BENCH_codegen_scaling.json`` (``{"rows": [...]}``) — every
    (kernel, size) row present in BOTH files must have
    ``fresh total_s <= tolerance * baseline total_s``.
  * ``BENCH_incremental.json`` (``{"reedit": [...]}``) — per matching gemm
    size, ``warm_reedit_s`` within tolerance, plus the machine-independent
    correctness flags: ``byte_identical`` and ``emit_equal`` must hold and
    ``reedit_speedup`` must stay above ``--speedup-floor``.
  * ``BENCH_sharing.json`` (``{"sharing": [...]}``) — machine-independent
    only: every differentially-verified row must be green and lint clean on
    all four backends, shared designs may never cost more DSPs than their
    unshared emission, at least one row must reach a time-division degree of
    ``--share-floor``, the DSE sweep must keep at least one time-multiplexed
    Pareto point, and per matching (kernel, hierarchy, size) row the
    absorbed-instance count must not drop below the baseline's.

Only rows present in both files are gated (CI runs smaller sweeps than the
committed full-run baselines), and the tolerance is deliberately loose —
shared CI runners are noisy; the gate exists to catch order-of-magnitude
regressions (a quadratic sneaking back in, a cache layer silently dead),
not single-digit percent drift.  Exits nonzero with a per-row report on any
violation."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _gate_scaling(fresh: dict, base: dict, tol: float) -> list[str]:
    fr = {(r["kernel"], r["size"]): r for r in fresh["rows"]}
    br = {(r["kernel"], r["size"]): r for r in base["rows"]}
    bad, n = [], 0
    for key in sorted(fr.keys() & br.keys()):
        f_s, b_s = fr[key]["total_s"], br[key]["total_s"]
        n += 1
        verdict = "ok" if f_s <= tol * max(b_s, 1e-4) else "REGRESSION"
        print(f"  {key[0]:10s} size={key[1]:<4d} total_s {f_s:.3f} "
              f"(baseline {b_s:.3f}, x{tol:g} allowed): {verdict}")
        if verdict != "ok":
            bad.append(f"{key}: {f_s:.3f}s > {tol:g} * {b_s:.3f}s")
    if n == 0:
        bad.append("no (kernel, size) rows in common — gate checked nothing")
    return bad


def _gate_incremental(fresh: dict, base: dict, tol: float,
                      speedup_floor: float) -> list[str]:
    fr = {r["n"]: r for r in fresh["reedit"]}
    br = {r["n"]: r for r in base["reedit"]}
    bad, n = [], 0
    for size in sorted(fr.keys() & br.keys()):
        f, b = fr[size], br[size]
        n += 1
        ok_t = f["warm_reedit_s"] <= tol * max(b["warm_reedit_s"], 1e-4)
        ok_s = f["reedit_speedup"] >= speedup_floor
        ok_b = f["byte_identical"]
        print(f"  gemm n={size}: warm_reedit {f['warm_reedit_s']:.4f}s "
              f"(baseline {b['warm_reedit_s']:.4f}s), speedup "
              f"{f['reedit_speedup']}x (floor {speedup_floor:g}), "
              f"byte_identical={ok_b}: "
              f"{'ok' if ok_t and ok_s and ok_b else 'REGRESSION'}")
        if not ok_t:
            bad.append(f"n={size}: warm_reedit_s {f['warm_reedit_s']:.4f} > "
                       f"{tol:g} * {b['warm_reedit_s']:.4f}")
        if not ok_s:
            bad.append(f"n={size}: reedit_speedup {f['reedit_speedup']} < "
                       f"{speedup_floor:g}")
        if not ok_b:
            bad.append(f"n={size}: warm output not byte-identical to cold")
    for e in fresh.get("parallel_emit", []):
        if not e["emit_equal"]:
            bad.append(f"parallel emit n={e['n']}: output differs from serial")
    if n == 0:
        bad.append("no gemm sizes in common — gate checked nothing")
    return bad


def _gate_sharing(fresh: dict, base: dict, share_floor: int) -> list[str]:
    bad = []
    br = {(r["kernel"], r["hierarchy"], repr(sorted(r["size"].items()))): r
          for r in base["sharing"]}
    best_degree = 0
    for r in fresh["sharing"]:
        key = (r["kernel"], r["hierarchy"], repr(sorted(r["size"].items())))
        tag = f"{r['kernel']}[{r['hierarchy']}] {r['size']}"
        ok = True
        if r["verified"] is False:   # None = resources-only row, not swept
            bad.append(f"{tag}: differential verification failed")
            ok = False
        unlinted = [be for be, lint_ok in r["lint_ok"].items() if not lint_ok]
        if unlinted:
            bad.append(f"{tag}: lint failures on {', '.join(unlinted)}")
            ok = False
        if r["after"]["DSP"] > r["before"]["DSP"]:
            bad.append(f"{tag}: sharing RAISED DSPs "
                       f"({r['before']['DSP']} -> {r['after']['DSP']})")
            ok = False
        b = br.get(key)
        if b is not None and r["absorbed"] < b["absorbed"]:
            bad.append(f"{tag}: absorbed {r['absorbed']} < baseline "
                       f"{b['absorbed']} — sharing regressed")
            ok = False
        best_degree = max(best_degree, r["max_degree"])
        print(f"  {tag}: dsp {r['before']['DSP']}->{r['after']['DSP']}, "
              f"absorbed {r['absorbed']} (x{r['max_degree']}), "
              f"verified={r['verified']}: "
              f"{'ok' if ok else 'REGRESSION'}")
    if best_degree < share_floor:
        bad.append(f"best time-division degree {best_degree} < "
                   f"floor {share_floor} — the analysis stopped proving "
                   f"disjointness")
    if not fresh.get("dse", {}).get("sharing_points"):
        bad.append("DSE frontier lost its time-multiplexed Pareto point")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True, help="freshly produced artifact")
    ap.add_argument("--baseline", required=True, help="committed baseline")
    ap.add_argument("--tolerance", type=float, default=5.0,
                    help="allowed slowdown factor vs baseline (default 5)")
    ap.add_argument("--speedup-floor", type=float, default=5.0,
                    help="minimum warm-reedit speedup (incremental schema)")
    ap.add_argument("--share-floor", type=int, default=4,
                    help="minimum best time-division degree (sharing schema)")
    args = ap.parse_args(argv)

    fresh = json.loads(Path(args.fresh).read_text())
    base = json.loads(Path(args.baseline).read_text())
    print(f"perf gate: {args.fresh} vs {args.baseline} "
          f"(tolerance x{args.tolerance:g})")
    if "rows" in fresh and "rows" in base:
        bad = _gate_scaling(fresh, base, args.tolerance)
    elif "reedit" in fresh and "reedit" in base:
        bad = _gate_incremental(fresh, base, args.tolerance,
                                args.speedup_floor)
    elif "sharing" in fresh and "sharing" in base:
        bad = _gate_sharing(fresh, base, args.share_floor)
    else:
        print("unrecognized or mismatched artifact schemas")
        return 2
    if bad:
        print("\nperf gate FAILED:")
        for b in bad:
            print(f"  - {b}")
        return 1
    print("perf gate ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
