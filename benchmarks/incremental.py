"""Incremental-compilation benchmark (PR 8): per-function warm
recompilation and pooled backend emission.

Three measurements per swept gemm size, all in hierarchical
(``hierarchy="modules"``) emission:

  * **cold** — every cache layer empty, full schedule + codegen;
  * **warm module hit** — recompiling a structurally identical build is
    served whole from the compile cache;
  * **warm re-edit** — one callee (``mac``) is structurally edited: the
    whole-module layer misses, but every untouched function's scheduled HIR
    and lowered RTL is spliced from ``dse.FUNC_CODEGEN_CACHE``, so only the
    edited function recompiles.  The emitted netlists are checked
    byte-identical against a caches-off compile of the same edited module.

Plus a serial-vs-pooled ``generate_verilog(max_workers=N)`` emission timing
on the same design (identical output by construction; wall-clock only wins
once per-module emission outweighs process-pool startup, so small designs
honestly report a slowdown).

``main()`` writes ``artifacts/bench/BENCH_incremental.json``.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import gemm
from repro.core.hls import dse
from repro.core.hls.scheduler import hls_compile

ARTIFACT = (Path(__file__).resolve().parents[1] / "artifacts" / "bench"
            / "BENCH_incremental.json")


def _clear_caches() -> None:
    dse.SCHEDULE_CACHE.clear()
    dse.COMPILE_CACHE.clear()
    dse.FUNC_CODEGEN_CACHE.clear()


def _edit_mac(m):
    for op in m.funcs["mac"].body.ops:
        if op.opname == "add":
            op.opname = "sub"
            return m
    raise AssertionError("no add op in mac")


def _netlists_equal(a, b) -> bool:
    return set(a) == set(b) and all(
        a[k].text == b[k].text and a[k].netlist == b[k].netlist for k in a)


def bench_reedit(n: int) -> dict:
    """Cold vs warm-module-hit vs warm-single-function-re-edit at gemm
    ``n`` (an n x n systolic array calling one shared ``mac``)."""
    _clear_caches()
    entry = "gemm"
    t0 = time.perf_counter()
    hls_compile(gemm.build(n)[0], entry=entry, hierarchy="modules")
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    r2, _ = hls_compile(gemm.build(n)[0], entry=entry, hierarchy="modules")
    warm_hit_s = time.perf_counter() - t0
    assert r2.from_cache

    h0 = dse.FUNC_CODEGEN_CACHE.hits
    t0 = time.perf_counter()
    _, vs = hls_compile(_edit_mac(gemm.build(n)[0]), entry=entry,
                        hierarchy="modules")
    reedit_s = time.perf_counter() - t0
    func_hits = dse.FUNC_CODEGEN_CACHE.hits - h0

    os.environ["REPRO_HLS_CACHE"] = "0"
    try:
        _, vs_cold = hls_compile(_edit_mac(gemm.build(n)[0]), entry=entry,
                                 hierarchy="modules")
    finally:
        del os.environ["REPRO_HLS_CACHE"]

    return {
        "kernel": "gemm", "n": n,
        "cold_s": round(cold_s, 4),
        "warm_module_hit_s": round(warm_hit_s, 4),
        "warm_reedit_s": round(reedit_s, 4),
        "reedit_speedup": round(cold_s / reedit_s, 1) if reedit_s else None,
        "func_cache_hits": func_hits,
        "byte_identical": _netlists_equal(vs, vs_cold),
    }


def bench_parallel_emit(n: int, workers: int) -> dict:
    t0 = time.perf_counter()
    vs_s = generate_verilog(gemm.build(n)[0], entry="gemm",
                            hierarchy="modules")
    serial_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    vs_p = generate_verilog(gemm.build(n)[0], entry="gemm",
                            hierarchy="modules", max_workers=workers)
    parallel_s = time.perf_counter() - t0
    return {
        "kernel": "gemm", "n": n, "workers": workers,
        "n_modules": len(vs_s),
        "emit_serial_s": round(serial_s, 4),
        "emit_parallel_s": round(parallel_s, 4),
        "emit_equal": _netlists_equal(vs_s, vs_p),
    }


def main(json_out: bool = False, sizes=None, workers: int = 0,
         smoke: bool = False, artifact: bool = True) -> dict:
    sizes = tuple(sizes) if sizes else ((4,) if smoke else (8, 16))
    workers = workers or min(4, os.cpu_count() or 1)
    reedit = [bench_reedit(n) for n in sizes]
    emit = [bench_parallel_emit(max(sizes), workers)]
    payload = {"reedit": reedit, "parallel_emit": emit}
    if artifact:
        ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
        ARTIFACT.write_text(json.dumps(payload, indent=2))
    if json_out:
        print(json.dumps(payload, indent=2))
        return payload
    for r in reedit:
        print(f"gemm n={r['n']:3d}: cold {r['cold_s']:.3f}s, "
              f"module-hit {r['warm_module_hit_s']:.3f}s, "
              f"re-edit {r['warm_reedit_s']:.3f}s "
              f"({r['reedit_speedup']}x, {r['func_cache_hits']} func hits, "
              f"byte_identical={r['byte_identical']})")
    for r in emit:
        print(f"emit gemm n={r['n']} ({r['n_modules']} modules): serial "
              f"{r['emit_serial_s']:.3f}s, x{r['workers']} pool "
              f"{r['emit_parallel_s']:.3f}s, equal={r['emit_equal']}")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit payload as JSON")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated gemm sizes (default 8,16)")
    ap.add_argument("--workers", type=int, default=0,
                    help="emission pool width (default min(4, cpus))")
    ap.add_argument("--smoke", action="store_true", help="small CI config")
    ap.add_argument("--no-artifact", action="store_true",
                    help="skip writing artifacts/bench/BENCH_incremental.json")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",")] if args.sizes else None
    main(json_out=args.json, sizes=sizes, workers=args.workers,
         smoke=args.smoke, artifact=not args.no_artifact)
