"""Benchmark orchestrator — one module per paper table plus the roofline
report mandated by the assignment:

  codegen_speed    paper Table 6 (HIR vs HLS codegen time)
  dse              Pareto-front design-space exploration (gemm, conv2d)
  incremental      per-function warm recompilation + pooled emission
  resource_usage   paper Table 5 (LUT/FF/DSP/BRAM per kernel)
  precision_opt    paper Table 4 (precision-opt ablation)
  roofline         EXPERIMENTS §Roofline source (reads dry-run artifacts)
  sim_throughput   vectorized vs event-driven simulation throughput
  sharing          cross-instance time-multiplexing resources + verification

``python -m benchmarks.run [name ...]`` runs all (or the named) benchmarks
and writes artifacts/bench/BENCH_<name>.json (the same naming every
self-writing suite uses, so the artifacts directory holds exactly one file
per benchmark).  ``--only a,b`` / ``--skip x,y``
filter the suite list (combinable with positional names); a failing
benchmark is reported and turns the final exit status nonzero instead of
silently passing, so CI perf-smoke steps can gate on it.  ``--profile``
reruns the suites that support it (codegen_speed) under cProfile, printing
the top cumulative hotspots instead of benchmarking — the starting point
for perf PRs.
"""

from __future__ import annotations

import inspect
import json
import sys
import time
import traceback
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def _split_opt(argv: list, flag: str) -> set:
    """Pop ``--flag a,b`` / ``--flag=a,b`` occurrences; returns the names."""
    names: set = set()
    out = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == flag and i + 1 < len(argv):
            names.update(x for x in argv[i + 1].split(",") if x)
            i += 2
            continue
        if a.startswith(flag + "="):
            names.update(x for x in a[len(flag) + 1:].split(",") if x)
            i += 1
            continue
        out.append(a)
        i += 1
    argv[:] = out
    return names


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    profile = "--profile" in argv
    if profile:
        argv = [a for a in argv if a != "--profile"]
    only = _split_opt(argv, "--only")
    skip = _split_opt(argv, "--skip")
    from . import (codegen_scaling, codegen_speed, dse, incremental,
                   precision_opt, resource_usage, roofline, sharing,
                   sim_throughput)

    suites = {
        "codegen_speed": codegen_speed,
        "codegen_scaling": codegen_scaling,
        "dse": dse,
        "incremental": incremental,
        "resource_usage": resource_usage,
        "precision_opt": precision_opt,
        "roofline": roofline,
        "sim_throughput": sim_throughput,
        "sharing": sharing,
    }
    passthrough = [a for a in argv if a.startswith("--")]
    argv = [a for a in argv if not a.startswith("--")]
    names = argv or list(suites)
    unknown = [n for n in set(names) | only | skip if n not in suites]
    if unknown:
        print(f"unknown benchmark(s): {', '.join(sorted(unknown))}; "
              f"available: {', '.join(suites)}")
        return 2
    if only:
        names = [n for n in names if n in only]
    names = [n for n in names if n not in skip]
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    failed: list[str] = []
    for name in names:
        mod = suites[name]
        print(f"\n=== {name} ===")
        t0 = time.time()
        try:
            params = inspect.signature(mod.main).parameters
            kw = {}
            if "argv" in params:
                # suites parse sys.argv when argv is None; hand them exactly
                # the flags not consumed here (e.g. --quick) instead
                kw["argv"] = list(passthrough)
            if profile:
                if "profile" not in params:
                    print(f"({name}: no --profile support, skipped)")
                    continue
                rows = mod.main(profile=True, **kw)
            else:
                rows = mod.main(**kw)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            print(f"({name}: FAILED after {time.time() - t0:.1f}s)")
            continue
        dt = time.time() - t0
        print(f"({name}: {dt:.1f}s)")
        if rows and not isinstance(rows, int):
            (ARTIFACTS / f"BENCH_{name}.json").write_text(
                json.dumps(rows, indent=2, default=str))
    if failed:
        print(f"\nFAILED benchmarks: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
