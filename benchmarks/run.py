"""Benchmark orchestrator — one module per paper table plus the roofline
report mandated by the assignment:

  codegen_speed    paper Table 6 (HIR vs HLS codegen time)
  dse              Pareto-front design-space exploration (gemm, conv2d)
  resource_usage   paper Table 5 (LUT/FF/DSP/BRAM per kernel)
  precision_opt    paper Table 4 (precision-opt ablation)
  roofline         EXPERIMENTS §Roofline source (reads dry-run artifacts)

``python -m benchmarks.run [name ...]`` runs all (or the named) benchmarks
and writes artifacts/bench/<name>.json.  ``--profile`` reruns the suites
that support it (codegen_speed) under cProfile, printing the top cumulative
hotspots instead of benchmarking — the starting point for perf PRs.
"""

from __future__ import annotations

import inspect
import json
import sys
import time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    profile = "--profile" in argv
    if profile:
        argv = [a for a in argv if a != "--profile"]
    from . import (codegen_scaling, codegen_speed, dse, precision_opt,
                   resource_usage, roofline)

    suites = {
        "codegen_speed": codegen_speed,
        "codegen_scaling": codegen_scaling,
        "dse": dse,
        "resource_usage": resource_usage,
        "precision_opt": precision_opt,
        "roofline": roofline,
    }
    names = argv or list(suites)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    for name in names:
        mod = suites[name]
        print(f"\n=== {name} ===")
        t0 = time.time()
        if profile:
            if "profile" not in inspect.signature(mod.main).parameters:
                print(f"({name}: no --profile support, skipped)")
                continue
            rows = mod.main(profile=True)
        else:
            rows = mod.main()
        dt = time.time() - t0
        print(f"({name}: {dt:.1f}s)")
        if rows and not isinstance(rows, int):
            (ARTIFACTS / f"{name}.json").write_text(
                json.dumps(rows, indent=2, default=str))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
