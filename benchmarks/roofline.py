"""Roofline report: aggregates artifacts/dryrun/*.json into the
EXPERIMENTS.md tables (per-cell three-term roofline, bottleneck, useful-FLOP
ratio; baseline vs optimized vs kernel-substituted) and ranks hillclimb
candidates."""

from __future__ import annotations

import glob
import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "single", tag: str | None = None) -> list[dict]:
    suffix = f"__{tag}" if tag else ""
    rows = []
    for f in sorted(glob.glob(str(ARTIFACTS / f"*__{mesh}{suffix}.json"))):
        rows.append(json.loads(Path(f).read_text()))
    return rows


def table(rows: list[dict], opt_rows: list[dict] | None = None) -> str:
    by_cell = {}
    for r in opt_rows or []:
        by_cell[(r["arch"], r["shape"])] = r
    out = ["| arch | shape | bottleneck | compute s | memory s | collective s "
           "| bound s | frac | useful | opt bound s | opt+kernels bound s | opt frac |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']}: {reason} "
                       f"| | | | | | | | | |")
            continue
        t = r["roofline"]
        uf = r.get("useful_flops_frac") or 0
        o = by_cell.get((r["arch"], r["shape"]))
        if o is not None and o["status"] == "ok":
            ob = f"{o['roofline']['bound_step_time_s']:.3f}"
            ks = o.get("roofline_kernel_substituted", {})
            ok = f"{ks.get('bound_step_time_s', 0):.3f}"
            of = f"{ks.get('roofline_fraction', 0):.3f}"
        else:
            ob = ok = of = ""
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['bottleneck']} "
            f"| {t['compute_s']:.3f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.3f} | {t['bound_step_time_s']:.3f} "
            f"| {t['roofline_fraction']:.3f} | {uf:.2f} | {ob} | {ok} | {of} |")
    return "\n".join(out)


def candidates(rows: list[dict]) -> dict:
    ok = [r for r in rows if r["status"] == "ok" and r["kind"] in ("train", "prefill")]
    worst_frac = min(ok, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = [r for r in ok if r["roofline"]["bottleneck"] == "collective"]
    most_coll = max(coll or ok, key=lambda r: r["roofline"]["collective_s"])
    return {"worst_fraction": (worst_frac["arch"], worst_frac["shape"]),
            "most_collective": (most_coll["arch"], most_coll["shape"])}


def main():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        rows = [r for r in rows if not r.get("opts")]  # baselines only
        opt_rows = load(mesh, tag="opt")
        if not rows:
            print(f"(no dry-run artifacts for mesh={mesh}; run repro.launch.dryrun)")
            continue
        print(f"== mesh: {mesh} ({len(rows)} baseline cells, "
              f"{len(opt_rows)} optimized) ==")
        print(table(rows, opt_rows))
        if mesh == "single":
            print("hillclimb candidates:", candidates(rows))
    return 0


if __name__ == "__main__":
    main()
