"""Table 6 mechanism, strengthened: codegen time vs design size.

The paper's 1112x gap comes from HLS *searching* a schedule where HIR only
*verifies* one.  Search cost grows with the design (II candidates x
reservation-table passes x SDC relaxations), verification stays near-linear
in op count — so the explicit-schedule advantage widens with scale.  We
sweep the GEMM systolic array size (n x n PEs: op count grows as n^2)
and report both pipelines' times and the trend.
"""

from __future__ import annotations

import time
from copy import deepcopy

from repro.core import verifier
from repro.core.gallery import gemm
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_schedule
from repro.core.passes import unroll_loops


def _time(fn, reps: int = 2) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(sizes=(2, 4, 8, 12)) -> list[dict]:
    rows = []
    for n in sizes:
        base, entry = gemm.build(n=n)
        unroll_loops(base)     # expand the PE array: op count grows as n^2
        n_ops = sum(1 for _ in base.get(entry).body.walk())

        t_hir = _time(lambda: verifier.verify(deepcopy(base)))
        t_hls = _time(lambda: hls_schedule(erase_schedule(deepcopy(base))))
        rows.append({"n": n, "ops": n_ops,
                     "hir_verify_s": round(t_hir, 4),
                     "hls_search_s": round(t_hls, 4),
                     "speedup": round(t_hls / t_hir, 1)})
    return rows


def main():
    rows = run()
    print(f"{'PEs':>6s} {'ops':>7s} {'verify(s)':>10s} {'search(s)':>10s} {'speedup':>8s}")
    for r in rows:
        print(f"{r['n']:4d}^2 {r['ops']:7d} {r['hir_verify_s']:10.4f} "
              f"{r['hls_search_s']:10.4f} {r['speedup']:7.1f}x")
    if len(rows) >= 2:
        g_hir = rows[-1]["hir_verify_s"] / max(rows[0]["hir_verify_s"], 1e-9)
        g_hls = rows[-1]["hls_search_s"] / max(rows[0]["hls_search_s"], 1e-9)
        print(f"growth {rows[0]['n']}->{rows[-1]['n']}: "
              f"verify {g_hir:.1f}x, search {g_hls:.1f}x "
              f"(gap widens {g_hls / g_hir:.1f}x)")
    return rows


if __name__ == "__main__":
    main()
