"""Compile-time scaling harness: per-phase codegen time vs design size.

Two questions, one sweep:

  1. The paper's Table 6 mechanism — HLS *searches* a schedule where HIR only
     *verifies* one, so the explicit-schedule advantage widens with scale
     (``hir_verify_s`` vs ``hls_search_s``).
  2. The generator's own scaling — the end-to-end ``verify -> optimize ->
     lower -> RTL passes -> emit`` pipeline must stay near-linear in design
     size for the Table 6 advantage to survive large designs.  Each phase is
     timed through one uniform stats schema (``generate_verilog(timings=)``,
     the PassManager shape) and a least-squares scaling exponent is fitted
     per phase over the sweep (t ~ ops^e).

Sweeps: the gemm systolic array (n x n PEs, ops ~ n^2; default n up to 32 =
1024 PEs), conv2d image-size unrolls and stencil1d unrolls.  Module cloning
uses ``Module.clone()`` and always happens *outside* the timed sections (the
seed benchmark deep-copied inside the timed lambdas, so large-n rows timed
Python cloning instead of verification).

``main()`` writes ``artifacts/bench/BENCH_codegen_scaling.json`` so future
PRs can track the trajectory; ``--budget-s`` turns the run into a perf smoke
check (non-zero exit when the largest swept config exceeds the budget).
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import gemm
from repro.core.gallery.conv2d import WGT
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_schedule
from repro.core.passmgr import (DEFAULT_PIPELINE_SPEC, AnalysisManager,
                                PassManager)

ARTIFACT = (Path(__file__).resolve().parents[1] / "artifacts" / "bench"
            / "BENCH_codegen_scaling.json")

#: Phases reported per row (uniform schema: {invocations, rewrites, wall_s}).
PIPELINE_PHASES = ("verify", "optimize", "unroll", "lower", "rtl", "emit")


# ---------------------------------------------------------------------------
# Unroll-sweep builders: lane-replicated streaming kernels.  The gallery
# conv2d/stencil1d are single-stream designs whose netlist size is fixed, so
# the unroll sweep replicates the stream ``lanes``-fold via an ``unroll_for``
# over a lane-distributed (banked) memref dim — post-unroll op count grows
# linearly with ``lanes``, exercising the RTL sharing passes exactly like
# the paper's large-unroll designs.
# ---------------------------------------------------------------------------


def build_stencil1d_lanes(n: int = 32, lanes: int = 4):
    """``lanes`` parallel 3-tap 1-d stencil pipelines over a lane-banked
    input (dim 0 distributed), one output stream per lane."""
    b = Builder(ir.Module(f"stencil1d_x{lanes}"))
    rmem = ir.MemrefType((lanes, n), ir.i32, ir.PORT_R, packed=[1])
    wmem = ir.MemrefType((lanes, n - 2), ir.i32, ir.PORT_W, packed=[1])
    with b.func("stencil1d_lanes", [rmem, wmem], ["Ai", "Bw"]) as f:
        Ai, Bw = f.args
        win = ir.MemrefType((lanes, 2), ir.i32, ir.PORT_RW, packed=[],
                            kind=ir.KIND_REG)
        Wr, Ww = b.alloc(win, names=["Wr", "Ww"])
        with b.for_(0, lanes, 1, at=f.t, unroll=True, iv_name="ln",
                    tv_name="tl") as ll:
            b.yield_(at=ll.time)
            L = ll.iv
            vA = b.read(Ai, [L, 0], at=ll.time)
            vA1 = b.delay(vA, 1, at=ll.time + 1)
            vB = b.read(Ai, [L, 1], at=ll.time + 1)
            b.write(vA1, Ww, [L, 0], at=ll.time + 2)
            b.write(vB, Ww, [L, 1], at=ll.time + 2)
            with b.for_(1, n - 1, 1, at=ll.time + 3, iv_name="i",
                        tv_name="ti") as li:
                b.yield_(at=li.time + 1)
                v0 = b.read(Wr, [L, 0], at=li.time + 1)
                v1 = b.read(Wr, [L, 1], at=li.time + 1)
                ip1 = b.add(li.iv, 1)
                v = b.read(Ai, [L, ip1], at=li.time)
                b.write(v1, Ww, [L, 0], at=li.time + 1)
                b.write(v, Ww, [L, 1], at=li.time + 1)
                s = b.add(b.add(b.mult(v0, 1), b.mult(v1, 2)), b.mult(v, 1))
                r = b.delay(s, 1, at=li.time + 1)
                i2 = b.delay(li.iv, 2, at=li.time)
                im1 = b.sub(i2, 1)
                b.write(r, Bw, [L, im1], at=li.time + 2)
        b.ret()
    return b.module, "stencil1d_lanes"


def build_conv2d_lanes(h: int = 8, w: int = 8, lanes: int = 2):
    """``lanes`` parallel 3x3 convolution pipelines (line buffers + window
    registers per lane) over a lane-banked image — the "large-unroll conv2d"
    configuration: post-unroll size grows with ``lanes``."""
    b = Builder(ir.Module(f"conv2d_x{lanes}"))
    rmem = ir.MemrefType((lanes, h, w), ir.i32, ir.PORT_R, packed=[1, 2])
    wmem = ir.MemrefType((lanes, h - 2, w - 2), ir.i32, ir.PORT_W,
                         packed=[1, 2])
    with b.func("conv2d_lanes", [rmem, wmem], ["Img", "Out"]) as f:
        Img, Out = f.args
        lb_t = ir.MemrefType((lanes, w), ir.i32, packed=[1],
                             kind=ir.KIND_LUTRAM)
        L0r, L0w = b.alloc(lb_t, names=["L0r", "L0w"])
        L1r, L1w = b.alloc(lb_t, names=["L1r", "L1w"])
        p_t = ir.MemrefType((lanes, 3, 2), ir.i32, packed=[],
                            kind=ir.KIND_REG)
        Pr, Pw = b.alloc(p_t, names=["Pr", "Pw"])

        with b.for_(0, lanes, 1, at=f.t, unroll=True, iv_name="ln",
                    tv_name="tl") as ll:
            b.yield_(at=ll.time)
            L = ll.iv

            def tap_row(col_vals, wcol):
                acc = None
                for v, wt in zip(col_vals, wcol):
                    m = b.mult(v, wt)
                    acc = m if acc is None else b.add(acc, m)
                return acc

            def shift_and_fill(c_loop, with_output, row_iv):
                tc, c = c_loop.time, c_loop.iv
                v = b.read(Img, [L, row_iv, c], at=tc)
                a = b.read(L1r, [L, c], at=tc)
                bm = b.read(L0r, [L, c], at=tc)
                c1 = b.delay(c, 1, at=tc)
                b.write(bm, L1w, [L, c1], at=tc + 1)
                b.write(v, L0w, [L, c1], at=tc + 1)
                col1 = [b.read(Pr, [L, r, 1], at=tc + 1) for r in range(3)]
                for r in range(3):
                    b.write(col1[r], Pw, [L, r, 0], at=tc + 1)
                for r, val in enumerate([a, bm, v]):
                    b.write(val, Pw, [L, r, 1], at=tc + 1)
                if with_output:
                    col0 = [b.read(Pr, [L, r, 0], at=tc + 1) for r in range(3)]
                    s0 = tap_row(col0, [WGT[r][0] for r in range(3)])
                    s1 = tap_row(col1, [WGT[r][1] for r in range(3)])
                    s2 = tap_row([a, bm, v], [WGT[r][2] for r in range(3)])
                    s = b.add(b.add(s0, s1), s2)
                    sreg = b.delay(s, 1, at=tc + 1)
                    c2 = b.delay(c, 2, at=tc)
                    cm2 = b.sub(c2, 2)
                    rm2 = b.sub(row_iv, 2)
                    b.write(sreg, Out, [L, rm2, cm2], at=tc + 2)

            with b.for_(0, 2, 1, at=ll.time + 1, iv_name="r0",
                        tv_name="tr0") as lr0:
                with b.for_(0, w, 1, at=lr0.time + 1, iv_name="c0",
                            tv_name="tc0") as lc0:
                    b.yield_(at=lc0.time + 1)
                    v = b.read(Img, [L, lr0.iv, lc0.iv], at=lc0.time)
                    bm = b.read(L0r, [L, lc0.iv], at=lc0.time)
                    c1 = b.delay(lc0.iv, 1, at=lc0.time)
                    b.write(bm, L1w, [L, c1], at=lc0.time + 1)
                    b.write(v, L0w, [L, c1], at=lc0.time + 1)
                b.yield_(at=lc0.end + 1)
            with b.for_(2, h, 1, at=lr0.end + 1, iv_name="r",
                        tv_name="tr") as lr:
                with b.for_(0, 2, 1, at=lr.time + 1, iv_name="cp",
                            tv_name="tcp") as lcp:
                    b.yield_(at=lcp.time + 1)
                    shift_and_fill(lcp, False, lr.iv)
                with b.for_(2, w, 1, at=lcp.end + 2, iv_name="c",
                            tv_name="tcs") as lcs:
                    b.yield_(at=lcs.time + 1)
                    shift_and_fill(lcs, True, lr.iv)
                b.yield_(at=lcs.end + 2)
        b.ret()
    return b.module, "conv2d_lanes"


def _time(fn, reps: int = 1) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def fit_exponent(sizes: list[int], times: list[float]) -> float | None:
    """Least-squares slope of log(time) vs log(size) — the scaling exponent
    of t ~ size^e.  Points below the timer floor are dropped; returns None
    with fewer than two usable points."""
    pts = [(math.log(s), math.log(t)) for s, t in zip(sizes, times)
           if s > 0 and t > 1e-5]
    if len(pts) < 2:
        return None
    n = len(pts)
    mx = sum(x for x, _ in pts) / n
    my = sum(y for _, y in pts) / n
    den = sum((x - mx) ** 2 for x, _ in pts)
    if den == 0:
        return None
    return sum((x - mx) * (y - my) for x, y, in pts) / den


#: Largest unrolled op count at which the HLS schedule *search* is timed.
#: ``None`` = uncapped (the default): the MII-bounded gallop/binary search
#: with incremental relaxation made the search near-linear in design size,
#: so even the 32x32-PE gemm completes in seconds where the seed's linear
#: scan took ~70 s at n=16.  ``--search-cap N`` restores a cap for very
#: constrained environments.
SEARCH_CAP_OPS = None


def bench_config(build, reps: int = 1, emit_backend: str = "verilog",
                 search_cap_ops: int | None = SEARCH_CAP_OPS) -> dict:
    """One sweep point: build, then time verification, the HLS schedule
    search, and every phase of the end-to-end compile pipeline.  All clones
    happen outside the timed sections; the GC is collected and frozen first
    so a generational collection of earlier sweep points' garbage cannot
    land inside (and be misattributed to) a timed phase."""
    import gc

    base, entry = build()
    gc.collect()
    gc.freeze()
    try:
        return _bench_config_inner(base, entry, reps, emit_backend,
                                   search_cap_ops)
    finally:
        gc.unfreeze()


def _bench_config_inner(base, entry, reps: int, emit_backend: str,
                        search_cap_ops: int | None) -> dict:
    # Table 6 mechanism on the *unrolled* design, as in the seed benchmark
    # (op count grows with the sweep, so the verify-vs-search gap widening
    # with scale is actually observable): verify an explicit schedule vs
    # search for one.  Unroll + all cloning stay outside the timers.
    unrolled = base.clone()
    PassManager.from_spec("unroll", fixpoint=False).run(unrolled)
    unrolled_count = sum(1 for _ in unrolled.get(entry).body.walk())
    clones = [unrolled.clone() for _ in range(reps)]
    t_verify = min(_time(lambda m=m: verifier.verify(m)) for m in clones)
    if search_cap_ops is None or unrolled_count <= search_cap_ops:
        erased = [erase_schedule(unrolled.clone()) for _ in range(reps)]
        t_search = min(_time(lambda m=m: hls_schedule(m)) for m in erased)
    else:
        t_search = None

    # End-to-end pipeline, phase-accounted through the uniform stats schema.
    m = base.clone()
    am = AnalysisManager()
    t0 = time.perf_counter()
    verifier.verify(m, am=am)
    verify_s = time.perf_counter() - t0
    opt_pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC, analysis_manager=am)
    t0 = time.perf_counter()
    opt_pm.run(m)
    optimize_s = time.perf_counter() - t0
    timings: dict = {}
    t0 = time.perf_counter()
    generate_verilog(m, entry, am=am, backend=emit_backend, timings=timings)
    codegen_s = time.perf_counter() - t0

    unroll_s = sum(st["wall_s"] for nm, st in timings.items()
                   if nm in ("unroll", "inline"))
    lower_s = timings.get("lower", {}).get("wall_s", 0.0)
    rtl_s = sum(st["wall_s"] for nm, st in timings.items()
                if nm.startswith("rtl-"))
    emit_s = timings.get(f"emit:{emit_backend}", {}).get("wall_s", 0.0)
    ops = sum(1 for _ in base.get(entry).body.walk())
    unrolled_ops = sum(1 for _ in m.get(entry).body.walk())
    return {
        "ops": ops,
        "unrolled_ops": unrolled_ops,
        "hir_verify_s": round(t_verify, 5),
        "hls_search_s": round(t_search, 5) if t_search is not None else None,
        "search_capped": t_search is None,
        "search_vs_verify": (round(t_search / t_verify, 1)
                             if t_search is not None and t_verify > 1e-9
                             else None),
        "phase_s": {
            "verify": round(verify_s, 5),
            "optimize": round(optimize_s, 5),
            "unroll": round(unroll_s, 5),
            "lower": round(lower_s, 5),
            "rtl": round(rtl_s, 5),
            "emit": round(emit_s, 5),
        },
        "total_s": round(verify_s + optimize_s + codegen_s, 5),
        "per_pass": timings,
    }


def run(gemm_sizes=(2, 4, 8, 16, 24, 32),
        conv2d_lanes=(1, 2, 4, 8),
        stencil_lanes=(1, 4, 16, 32),
        reps: int = 1,
        search_cap_ops: int | None = SEARCH_CAP_OPS) -> list[dict]:
    sweeps = [("gemm", n, lambda n=n: gemm.build(n=n)) for n in gemm_sizes]
    sweeps += [("conv2d", u, lambda u=u: build_conv2d_lanes(lanes=u))
               for u in conv2d_lanes]
    sweeps += [("stencil1d", u, lambda u=u: build_stencil1d_lanes(lanes=u))
               for u in stencil_lanes]
    rows = []
    for kernel, size, build in sweeps:
        row = {"kernel": kernel, "size": size,
               **bench_config(build, reps=reps,
                              search_cap_ops=search_cap_ops)}
        rows.append(row)
    return rows


def fit_rows(rows: list[dict]) -> dict:
    """Per-kernel, per-phase scaling exponents of wall time vs unrolled op
    count (the size measure every post-unroll phase actually sees)."""
    fits: dict = {}
    for kernel in sorted({r["kernel"] for r in rows}):
        kr = [r for r in rows if r["kernel"] == kernel]
        sizes = [r["unrolled_ops"] for r in kr]
        kf = {}
        for ph in PIPELINE_PHASES:
            e = fit_exponent(sizes, [r["phase_s"][ph] for r in kr])
            kf[ph] = round(e, 2) if e is not None else None
        e = fit_exponent(sizes, [r["total_s"] for r in kr])
        kf["total"] = round(e, 2) if e is not None else None
        rtl_emit = fit_exponent(
            sizes, [r["phase_s"]["rtl"] + r["phase_s"]["emit"] for r in kr])
        kf["rtl+emit"] = round(rtl_emit, 2) if rtl_emit is not None else None
        # the Table 6 pair on the unrolled design (search only below the cap)
        e = fit_exponent(sizes, [r["hir_verify_s"] for r in kr])
        kf["hir_verify"] = round(e, 2) if e is not None else None
        pts = [(s, r["hls_search_s"]) for s, r in zip(sizes, kr)
               if r["hls_search_s"] is not None]
        e = fit_exponent([s for s, _ in pts], [t for _, t in pts])
        kf["hls_search"] = round(e, 2) if e is not None else None
        kf["search"] = kf["hls_search"]
        fits[kernel] = kf
    return fits


def main(json_out: bool = False, gemm_sizes=None, reps: int = 1,
         budget_s: float | None = None, artifact: bool = True,
         search_cap_ops: int | None = SEARCH_CAP_OPS):
    rows = run(gemm_sizes=tuple(gemm_sizes) if gemm_sizes else (2, 4, 8, 16, 24, 32),
               reps=reps, search_cap_ops=search_cap_ops)
    fits = fit_rows(rows)
    payload = {"rows": rows, "fits": fits,
               "phases": list(PIPELINE_PHASES)}
    if artifact:
        ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
        ARTIFACT.write_text(json.dumps(payload, indent=2))
    if json_out:
        print(json.dumps(payload, indent=2))
    else:
        hdr = (f"{'kernel':10s} {'size':>5s} {'ops':>7s} {'verify':>8s} "
               f"{'search':>8s} {'opt':>8s} {'lower':>8s} {'rtl':>8s} "
               f"{'emit':>8s} {'total':>8s}")
        print(hdr)
        for r in rows:
            p = r["phase_s"]
            search = (f"{r['hls_search_s']:8.4f}"
                      if r["hls_search_s"] is not None else f"{'capped':>8s}")
            print(f"{r['kernel']:10s} {r['size']:5d} {r['unrolled_ops']:7d} "
                  f"{r['hir_verify_s']:8.4f} {search} "
                  f"{p['optimize']:8.4f} {p['lower']:8.4f} {p['rtl']:8.4f} "
                  f"{p['emit']:8.4f} {r['total_s']:8.4f}")
        print("\nfitted scaling exponents (t ~ unrolled_ops^e):")
        for kernel, kf in fits.items():
            print(f"  {kernel:10s} " + ", ".join(
                f"{ph}: {e if e is not None else '-'}"
                for ph, e in kf.items()))
    if budget_s is not None:
        import sys

        worst = max(r["total_s"] for r in rows)
        if worst > budget_s:
            raise SystemExit(
                f"perf smoke FAILED: slowest config took {worst:.2f}s "
                f"(budget {budget_s:.2f}s)")
        # stderr: keep stdout valid JSON under --json
        print(f"perf smoke OK: slowest config {worst:.2f}s "
              f"<= budget {budget_s:.2f}s", file=sys.stderr)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit payload as JSON")
    ap.add_argument("--gemm-sizes", default=None,
                    help="comma-separated gemm PE-array sizes (default 2..32)")
    ap.add_argument("--reps", type=int, default=1, help="timing repetitions")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the slowest swept config exceeds this "
                         "wall-clock budget (CI perf smoke)")
    ap.add_argument("--no-artifact", action="store_true",
                    help="skip writing artifacts/bench/BENCH_codegen_scaling.json")
    ap.add_argument("--search-cap", type=int, default=None,
                    help="skip timing the HLS schedule search above this "
                         "unrolled op count (default: uncapped)")
    args = ap.parse_args()
    sizes = ([int(s) for s in args.gemm_sizes.split(",")]
             if args.gemm_sizes else None)
    main(json_out=args.json, gemm_sizes=sizes, reps=args.reps,
         budget_s=args.budget_s, artifact=not args.no_artifact,
         search_cap_ops=args.search_cap)
