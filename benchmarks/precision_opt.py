"""Table 4 analogue: matrix-transpose resource usage with and without the
automatic precision optimization (+ the passes it enables)."""

from __future__ import annotations


from repro.core.codegen.resources import report_module
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import transpose
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager

PAPER = {
    "Vivado HLS": (41, 92),
    "Vivado HLS (manual opt)": (7, 51),
    "HIR (no opt)": (32, 72),
    "HIR (auto opt)": (8, 18),
}


def _resources(module, entry) -> dict:
    mods = generate_verilog(module, entry)
    tot = None
    for vm in mods.values():
        r = report_module(vm)
        tot = r if tot is None else tot + r
    return tot.as_dict()


def run() -> list[dict]:
    rows = []
    m0, entry = transpose.build()
    rows.append({"flow": "HIR (no opt)", **_resources(m0.clone(), entry),
                 "paper": PAPER["HIR (no opt)"]})

    m1, _ = transpose.build()
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m1)  # includes precision-opt
    rows.append({"flow": "HIR (auto opt)", **_resources(m1, entry),
                 "paper": PAPER["HIR (auto opt)"]})

    m2, _ = transpose.build()
    # everything except precision opt — isolates Table 4's effect
    PassManager.from_spec(
        "canonicalize,constprop,cse,strength-reduce,delay-elim,dce").run(m2)
    rows.append({"flow": "HIR (opt, no precision)", **_resources(m2, entry),
                 "paper": None})
    return rows


def main():
    rows = run()
    print(f"{'flow':26s} {'LUT':>6s} {'FF':>6s}   paper(LUT,FF)")
    for r in rows:
        print(f"{r['flow']:26s} {r['LUT']:6d} {r['FF']:6d}   {r['paper']}")
    return rows


if __name__ == "__main__":
    main()
