"""Design-space exploration benchmark: the II / clock / unroll / banking
autotuner over the paper's gemm and conv2d kernels (ScaleHLS-style DSE on
top of the HLS baseline).

For each kernel the harness sweeps a :func:`repro.core.hls.design_space` —
pipelining on/off, minimum II, clock budget, unroll staggering, local-bank
merging — through ``explore_design``: every candidate is scheduled under its
knobs, optimized, emitted, resource-scored with ``report_design`` and
simulated for its cycle count, then *verified* against the kernel's NumPy
oracle.  The result is the full scored point cloud plus the Pareto frontier
over (latency_ns, LUT, FF); non-verifying or erroring candidates are kept in
the cloud (with their error) but never reach the frontier.

Each kernel is swept twice: exhaustively, and with the adaptive
``strategy="halving"`` explorer (cheap schedule-only scoring of the full
pool, full compile+verify of the surviving half) — the artifact records
whether both reach the same verified Pareto front and how many full
evaluations halving saved.

Candidates run on a process pool with ``--workers N`` (serial at 1, the
default — results are identical either way).  ``--smoke`` shrinks the space
to a handful of candidates for CI.  ``main()`` writes
``artifacts/bench/BENCH_dse.json``::

    {"kernels": {gemm: {"points": [...], "pareto_front": [...],
                        "n_verified": int, "wall_s": float,
                        "halving": {"stats": {...}, "front_equal": bool,
                                    "wall_s": float}}, conv2d: ...},
     "space_axes": {...}, "workers": N}
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.gallery import GALLERY
from repro.core.hls import design_space, explore_design

ARTIFACT = (Path(__file__).resolve().parents[1] / "artifacts" / "bench"
            / "BENCH_dse.json")

#: kernel -> (build kwargs, number of oracle input args)
KERNELS = {
    "gemm": ({"n": 8}, 2),
    "conv2d": ({"h": 8, "w": 8}, 1),
    # traced through core/frontend: the autotuner sees the jnp.matmul
    # program exactly like a hand-written kernel
    "frontend_matmul": ({"m": 8, "k": 8, "n": 8}, 2),
}

#: Swept axes.  Three clock budgets trade cycle count against chaining
#: registers (faster clocks pipeline deeper -> more FF), which is what puts
#: genuine area-vs-latency tradeoffs on the frontier; ``merge_banks`` trades
#: RAM count against access serialization on kernels with distributed local
#: banks (gemm); ``min_ii`` relaxes the initiation interval.
SPACE_AXES = {
    "pipeline": (True, False),
    "min_ii": (1, 2),
    "clock_ns": (10.0, 5.0, 2.5),
    "unroll_parallel": (True, False),
    "merge_banks": (False, True),
    "tile": (0, 2),
}

SMOKE_AXES = {
    "pipeline": (True,),
    "min_ii": (1,),
    "clock_ns": (10.0, 5.0, 2.5),
    "unroll_parallel": (True,),
    "merge_banks": (False, True),
    "tile": (0, 2),
}


def run(kernels=None, axes=None, workers: int = 1) -> dict:
    axes = dict(axes or SPACE_AXES)
    out: dict = {}
    for name in (kernels or list(KERNELS)):
        build_kwargs, nargs = KERNELS[name]
        gal = GALLERY[name]
        module, entry = gal.build(**build_kwargs)
        inputs = gal.make_inputs(**build_kwargs)
        expected = gal.oracle(*inputs[:nargs])
        space = design_space(**axes)
        t0 = time.perf_counter()
        res = explore_design(module, space, entry=entry,
                             inputs=[a.copy() for a in inputs],
                             expected=expected, max_workers=workers)
        wall = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_h = explore_design(module, space, entry=entry,
                               inputs=[a.copy() for a in inputs],
                               expected=expected, max_workers=workers,
                               strategy="halving")
        wall_h = time.perf_counter() - t0
        front = lambda r: sorted(repr(p.config.as_dict()) for p in r.front)
        out[name] = {
            **res.as_dict(),
            "n_points": len(res.points),
            "n_verified": sum(p.verified for p in res.points),
            "n_front": len(res.front),
            "wall_s": round(wall, 2),
            "halving": {
                "stats": res_h.stats,
                "front_equal": front(res_h) == front(res),
                "n_front": len(res_h.front),
                "wall_s": round(wall_h, 2),
            },
        }
    return out


def main(json_out: bool = False, kernels=None, workers: int = 1,
         smoke: bool = False, artifact: bool = True) -> dict:
    axes = SMOKE_AXES if smoke else SPACE_AXES
    kernel_rows = run(kernels=kernels, axes=axes, workers=workers)
    payload = {"kernels": kernel_rows,
               "space_axes": {k: list(v) for k, v in axes.items()},
               "workers": workers}
    if artifact:
        ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
        ARTIFACT.write_text(json.dumps(payload, indent=2))
    if json_out:
        print(json.dumps(payload, indent=2))
        return payload
    for name, row in kernel_rows.items():
        print(f"{name}: {row['n_points']} candidates, "
              f"{row['n_verified']} verified, "
              f"{row['n_front']} on the Pareto frontier "
              f"({row['wall_s']}s, workers={workers})")
        print(f"  {'latency_ns':>10s} {'lut':>6s} {'ff':>6s}  config")
        for p in row["pareto_front"]:
            cfg = p["config"]
            knobs = (f"pipeline={cfg['pipeline']} min_ii={cfg['min_ii']} "
                     f"clock={cfg['clock_ns']}ns "
                     f"stagger={cfg['unroll_parallel']} "
                     f"merge_banks={cfg['merge_banks']} "
                     f"tile={cfg.get('tile', 0)}")
            print(f"  {p['latency_ns']:10.1f} {p['lut']:6d} {p['ff']:6d}  "
                  f"{knobs}")
        errs = [p for p in row["points"] if p["error"]]
        if errs:
            print(f"  ({len(errs)} candidates errored out)")
        h = row["halving"]
        print(f"  halving: {h['stats']['n_full']}/{h['stats']['n_candidates']}"
              f" full evaluations ({h['stats']['evaluations_saved']} saved), "
              f"front_equal={h['front_equal']}, {h['wall_s']}s")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit payload as JSON")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel subset (default: gemm,conv2d)")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width (1 = serial)")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI space (6 candidates per kernel)")
    ap.add_argument("--no-artifact", action="store_true",
                    help="skip writing artifacts/bench/BENCH_dse.json")
    args = ap.parse_args()
    names = args.kernels.split(",") if args.kernels else None
    main(json_out=args.json, kernels=names, workers=args.workers,
         smoke=args.smoke, artifact=not args.no_artifact)
