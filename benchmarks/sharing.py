"""Cross-instance time-multiplexing benchmark: resources before/after the
``rtl-share-instances`` / ``rtl-arbitrate`` passes (paper §4.4/§4.5 applied
at module granularity).

For each kernel x hierarchy the harness emits the design twice — once with
the sharing passes stripped from the RTL pipeline, once with the full
pipeline — and reports the LUT/FF/DSP deltas plus the sharing summary
(physical vs logical instances, max time-division degree).  Shared designs
are then differentially verified: ``run_differential`` runs the vectorized
cycle-accurate simulator over a stimulus batch against the NumPy oracle
*and* replays the RTL pipeline pass-by-pass (so both new passes are checked
for per-cycle equivalence), and all four backend printers must lint clean
on the shared netlist.

``gemm`` (coincident pulses — the analysis proves nothing, sharing must
refuse) and ``gemm_shared`` (column-staggered II=n schedule — n-way
provable sharing) bracket the analysis; ``conv2d`` has no callee instances
at all and pins the no-op path.

A small DSE sweep (``share_instances`` x ``unroll_parallel``) records the
latency-vs-DSP Pareto frontier: the time-multiplexed candidate must survive
as a genuine tradeoff point next to its fully-spatial sibling.

``--smoke`` shrinks sizes and vector counts for CI.  ``main()`` writes
``artifacts/bench/BENCH_sharing.json``::

    {"sharing": [{kernel, hierarchy, size, before, after, saved,
                  physical, logical, absorbed, max_degree,
                  verified, vectors, lint_ok}, ...],
     "dse": {"kernel": ..., "pareto_front": [...], "sharing_points": [...]},
     "smoke": bool}
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.codegen import (BACKENDS, generate_verilog, lint_backend,
                                report_design, sharing_summary)
from repro.core.codegen import sim as rsim
from repro.core.codegen.rtl import RTL_PIPELINE_SPEC
from repro.core.gallery import GALLERY
from repro.core.hls import design_space, explore_design
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager

ARTIFACT = (Path(__file__).resolve().parents[1] / "artifacts" / "bench"
            / "BENCH_sharing.json")

SHARE_PASSES = ("rtl-share-instances", "rtl-arbitrate")
#: the RTL pipeline with only the sharing passes removed — the "before"
#: emission, so deltas isolate exactly what sharing buys.
NOSHARE_SPEC = ",".join(p for p in RTL_PIPELINE_SPEC.split(",")
                        if p not in SHARE_PASSES)

#: kernel -> (build kwargs, oracle nargs, differentially verify?)
FULL_KERNELS = [
    ("gemm", {"n": 8}, 2, True),
    ("gemm_shared", {"n": 8}, 2, True),
    ("gemm_shared", {"n": 16}, 2, False),   # resources only: 16x reduction
    ("conv2d", {"h": 8, "w": 8}, 1, True),
]
SMOKE_KERNELS = [
    ("gemm", {"n": 4}, 2, True),
    ("gemm_shared", {"n": 4}, 2, True),
    ("conv2d", {"h": 4, "w": 4}, 1, True),
]


def _resources(module, entry, hierarchy, rtl_spec):
    mods = generate_verilog(module.clone(), entry=entry, hierarchy=hierarchy,
                            rtl_spec=rtl_spec)
    return mods, report_design(mods, entry=entry).as_dict()


def _lint_all(module, entry, hierarchy) -> dict:
    """All four backend printers must emit a shared design that lints."""
    out = {}
    for be in BACKENDS:
        mods = generate_verilog(module.clone(), entry=entry,
                                hierarchy=hierarchy, backend=be)
        text = "\n".join(vm.text for vm in mods.values())
        out[be] = not lint_backend(text, be, known_modules=list(mods))
    return out


def bench_kernel(name: str, build_kwargs: dict, nargs: int, verify: bool,
                 hierarchy: str, n_vectors: int) -> dict:
    gal = GALLERY[name]
    module, entry = gal.build(**build_kwargs)
    PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(module)

    _, before = _resources(module, entry, hierarchy, NOSHARE_SPEC)
    mods, after = _resources(module, entry, hierarchy, RTL_PIPELINE_SPEC)
    sh = sharing_summary(mods, entry=entry)
    row = {
        "kernel": name, "hierarchy": hierarchy, "size": dict(build_kwargs),
        "before": before, "after": after,
        "saved": {k: before[k] - after[k] for k in before},
        "physical": sh["physical_instances"],
        "logical": sh["logical_instances"],
        "absorbed": sh["absorbed"],
        "max_degree": max((d["max_degree"]
                           for d in sh["per_module"].values()), default=0),
        "verified": None, "vectors": 0,
        "lint_ok": _lint_all(module, entry, hierarchy),
    }
    if verify:
        fresh, _ = gal.build(**build_kwargs)
        batch = rsim.stack_stimulus(gal.make_inputs, n_vectors, base_seed=7,
                                    **build_kwargs)
        rep = rsim.run_differential(fresh, entry, batch, kernel=name,
                                    hierarchy=hierarchy, oracle=gal.oracle,
                                    oracle_nargs=nargs)
        row["verified"] = bool(rep.ok and rep.oracle_ok
                               and all(rep.passes_ok.values()))
        row["vectors"] = n_vectors
    return row


def bench_dse(n: int, workers: int = 1) -> dict:
    """Sweep gemm with the sharing knob: `unroll_parallel=False` staggers
    the unrolled PE copies, which is what makes the pulses provably
    disjoint under the autotuner's own schedules."""
    gal = GALLERY["gemm"]
    module, entry = gal.build(n)
    inputs = gal.make_inputs(n)
    expected = gal.oracle(*inputs[:2])
    space = design_space(pipeline=(True,), unroll_parallel=(True, False),
                         share_instances=(False, True))
    res = explore_design(module, space, entry=entry,
                         inputs=[a.copy() for a in inputs],
                         expected=expected, max_workers=workers)
    front = [p.as_dict() for p in res.front]
    return {"kernel": "gemm", "size": {"n": n},
            "n_points": len(res.points),
            "n_verified": sum(p.verified for p in res.points),
            "pareto_front": front,
            "sharing_points": [p for p in front
                               if p["config"]["share_instances"]
                               and p["shared_absorbed"] > 0]}


def run(smoke: bool = False, workers: int = 1) -> dict:
    kernels = SMOKE_KERNELS if smoke else FULL_KERNELS
    n_vectors = 32 if smoke else 256
    rows = []
    for name, kw, nargs, verify in kernels:
        for hierarchy in ("inline", "modules"):
            t0 = time.perf_counter()
            row = bench_kernel(name, kw, nargs, verify, hierarchy, n_vectors)
            row["wall_s"] = round(time.perf_counter() - t0, 2)
            rows.append(row)
            print(f"{name}{kw} {hierarchy}: dsp {row['before']['DSP']} -> "
                  f"{row['after']['DSP']}, lut {row['before']['LUT']} -> "
                  f"{row['after']['LUT']}, absorbed {row['absorbed']} "
                  f"(x{row['max_degree']}), verified={row['verified']} "
                  f"({row['wall_s']}s)")
    dse = bench_dse(4, workers=workers)
    print(f"dse gemm n=4: {len(dse['pareto_front'])} frontier points, "
          f"{len(dse['sharing_points'])} time-multiplexed")
    return {"sharing": rows, "dse": dse, "smoke": smoke}


def main(json_out: bool = False, smoke: bool = False, workers: int = 1,
         artifact: bool = True) -> dict:
    payload = run(smoke=smoke, workers=workers)
    if artifact:
        ARTIFACT.parent.mkdir(parents=True, exist_ok=True)
        ARTIFACT.write_text(json.dumps(payload, indent=2))
    if json_out:
        print(json.dumps(payload, indent=2))
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit payload as JSON")
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + 32 vectors for CI")
    ap.add_argument("--workers", type=int, default=1,
                    help="process-pool width for the DSE sweep")
    ap.add_argument("--no-artifact", action="store_true",
                    help="skip writing artifacts/bench/BENCH_sharing.json")
    args = ap.parse_args()
    main(json_out=args.json, smoke=args.smoke, workers=args.workers,
         artifact=not args.no_artifact)
