"""Table 5 analogue: FPGA resource usage of the generated designs,
HIR-scheduled vs HLS-auto-scheduled, under the documented cost model
(``core.codegen.resources``).  The paper's Vivado numbers are printed
alongside for reference (absolute values differ — different synthesis
stack — the claim reproduced is comparable-or-better resources under one
consistent flow)."""

from __future__ import annotations

from copy import deepcopy

from repro.core.codegen.resources import report_module
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_schedule
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager

PAPER = {  # (vivado LUT, FF, DSP, BRAM), (hir LUT, FF, DSP, BRAM)
    "transpose": ((7, 51, 0, 0), (8, 18, 0, 0)),
    "stencil1d": ((152, 237, 6, 0), (114, 147, 6, 0)),
    "histogram": ((130, 107, 0, 1), (101, 146, 0, 1)),
    "gemm": ((14495, 24538, 768, 0), (12645, 29062, 768, 0)),
    "conv2d": ((1517, 2490, 0, 0), (289, 661, 0, 0)),
    "fifo": ((34, 36, 0, 1), (43, 140, 0, 1)),
}


def _total(mods) -> dict:
    tot = None
    for vm in mods.values():
        r = report_module(vm)
        tot = r if tot is None else tot + r
    return tot.as_dict()


def run(bench_names=None) -> list[dict]:
    rows = []
    for name in bench_names or PAPER_BENCHMARKS:
        gal = GALLERY[name]
        module, entry = gal.build()

        hir_m = deepcopy(module)
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(hir_m)
        hir_res = _total(generate_verilog(hir_m, entry))

        row = {"kernel": name, "hir": hir_res,
               "paper_vivado": dict(zip(("LUT", "FF", "DSP", "BRAM"), PAPER[name][0])),
               "paper_hir": dict(zip(("LUT", "FF", "DSP", "BRAM"), PAPER[name][1]))}
        if name != "fifo":  # paper compares FIFO against hand Verilog, not HLS
            hls_m = erase_schedule(deepcopy(module))
            hls_schedule(hls_m)
            PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(hls_m)
            row["hls"] = _total(generate_verilog(hls_m, entry))
        rows.append(row)
    return rows


def main():
    rows = run()
    print(f"{'kernel':12s} {'flow':6s} {'LUT':>8s} {'FF':>8s} {'DSP':>6s} {'BRAM':>6s}")
    for r in rows:
        for flow in ("hir", "hls"):
            if flow in r:
                d = r[flow]
                print(f"{r['kernel']:12s} {flow:6s} {d['LUT']:8d} {d['FF']:8d} "
                      f"{d['DSP']:6d} {d['BRAM']:6d}")
        pv, ph = r["paper_vivado"], r["paper_hir"]
        print(f"{'':12s} paper  vivado {pv}  hir {ph}")
    return rows


if __name__ == "__main__":
    main()
