"""Table 5 analogue: FPGA resource usage of the generated designs,
HIR-scheduled vs HLS-auto-scheduled, under the documented cost model
(``core.codegen.resources``).  The paper's Vivado numbers are printed
alongside for reference (absolute values differ — different synthesis
stack — the claim reproduced is comparable-or-better resources under one
consistent flow).

Each row also reports the **RTL pass pipeline's effect** per kernel:
``hir_pre_rtl`` is the direct (raw-lowering) emission, ``hir`` the
post-pipeline emission, ``rtl_delta`` the difference (negative = saved), and
``rtl_per_pass`` the per-pass rewrite counts.  ``hier`` is the hierarchical
(non-inlined) emission total, costed with per-instance multiplicity, and
``sharing`` its cross-instance time-multiplexing delta: how many callee
instances ``rtl-share-instances``/``rtl-arbitrate`` folded onto shared
physical hardware and the LUT/FF/DSP that saved relative to the same
hierarchical emission without the sharing passes.  The row keys are stable
for trend tracking; ``--json`` emits them as JSON.
"""

from __future__ import annotations

import argparse
import json

from repro.core.codegen.resources import report_design, sharing_summary
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_schedule
from repro.core.passes import (DEFAULT_PIPELINE_SPEC, RTL_PIPELINE_SPEC,
                               PassManager)

PAPER = {  # (vivado LUT, FF, DSP, BRAM), (hir LUT, FF, DSP, BRAM)
    "transpose": ((7, 51, 0, 0), (8, 18, 0, 0)),
    "stencil1d": ((152, 237, 6, 0), (114, 147, 6, 0)),
    "histogram": ((130, 107, 0, 1), (101, 146, 0, 1)),
    "gemm": ((14495, 24538, 768, 0), (12645, 29062, 768, 0)),
    "conv2d": ((1517, 2490, 0, 0), (289, 661, 0, 0)),
    "fifo": ((34, 36, 0, 1), (43, 140, 0, 1)),
}


def _total(mods, entry) -> dict:
    return report_design(mods, entry).as_dict()


def run(bench_names=None) -> list[dict]:
    rows = []
    # gemm_shared rides along: same matmul, but its staggered II=n schedule
    # is the one the sharing passes can actually prove disjoint, so its row
    # shows a nonzero sharing delta next to gemm's refused (coincident) one.
    for name in bench_names or PAPER_BENCHMARKS + ["gemm_shared"]:
        gal = GALLERY[name]
        module, entry = gal.build()

        hir_m = module.clone()
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(hir_m)

        # direct emission (no RTL pipeline) vs the optimized RTL netlist
        pre = _total(generate_verilog(hir_m.clone(), entry, rtl_spec=None), entry)
        rtl_pm = PassManager.from_spec(RTL_PIPELINE_SPEC)
        hir_res = _total(generate_verilog(hir_m.clone(), entry,
                                          rtl_pass_manager=rtl_pm), entry)
        delta = {k: hir_res[k] - pre[k] for k in pre}
        # hierarchical (non-inlined) emission of the same design, with and
        # without the instance-sharing passes: the delta is what
        # cross-instance time-multiplexing saves on this kernel's schedule
        noshare = ",".join(p for p in RTL_PIPELINE_SPEC.split(",")
                           if p not in ("rtl-share-instances",
                                        "rtl-arbitrate"))
        hier_pre = _total(generate_verilog(hir_m.clone(), entry,
                                           hierarchy="modules",
                                           rtl_spec=noshare), entry)
        hier_mods = generate_verilog(hir_m.clone(), entry,
                                     hierarchy="modules")
        hier = _total(hier_mods, entry)
        sh = sharing_summary(hier_mods, entry=entry)

        row = {"kernel": name, "hir": hir_res,
               "hir_pre_rtl": pre, "rtl_delta": delta, "hier": hier,
               "sharing": {"physical": sh["physical_instances"],
                           "logical": sh["logical_instances"],
                           "absorbed": sh["absorbed"],
                           "saved": {k: hier_pre[k] - hier[k]
                                     for k in hier}},
               "rtl_per_pass": {k: v["rewrites"]
                                for k, v in rtl_pm.stats_dict().items()}}
        if name in PAPER:  # ride-along kernels have no paper row
            row["paper_vivado"] = dict(
                zip(("LUT", "FF", "DSP", "BRAM"), PAPER[name][0]))
            row["paper_hir"] = dict(
                zip(("LUT", "FF", "DSP", "BRAM"), PAPER[name][1]))
        if name != "fifo":  # paper compares FIFO against hand Verilog, not HLS
            hls_m = erase_schedule(module.clone())
            hls_schedule(hls_m)
            PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(hls_m)
            row["hls"] = _total(generate_verilog(hls_m, entry), entry)
        rows.append(row)
    return rows


def main(json_out: bool = False, bench_names=None):
    rows = run(bench_names)
    if json_out:
        print(json.dumps(rows, indent=2))
        return rows
    print(f"{'kernel':12s} {'flow':8s} {'LUT':>8s} {'FF':>8s} {'DSP':>6s} {'BRAM':>6s}")
    for r in rows:
        for flow in ("hir_pre_rtl", "hir", "hier", "hls"):
            if flow in r:
                d = r[flow]
                print(f"{r['kernel']:12s} {flow:8s} {d['LUT']:8d} {d['FF']:8d} "
                      f"{d['DSP']:6d} {d['BRAM']:6d}")
        dd = r["rtl_delta"]
        busy = {k: v for k, v in r["rtl_per_pass"].items() if v}
        print(f"{'':12s} rtl-pipeline delta LUT {dd['LUT']:+d} FF {dd['FF']:+d} "
              f"({', '.join(f'{k}:{v}' for k, v in busy.items()) or 'no rewrites'})")
        sh = r["sharing"]
        if sh["absorbed"]:
            sv = sh["saved"]
            print(f"{'':12s} sharing: {sh['logical']} -> {sh['physical']} "
                  f"instances ({sh['absorbed']} absorbed), saved "
                  f"LUT {sv['LUT']:+d} FF {sv['FF']:+d} DSP {sv['DSP']:+d}")
        if "paper_vivado" in r:
            pv, ph = r["paper_vivado"], r["paper_hir"]
            print(f"{'':12s} paper  vivado {pv}  hir {ph}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit rows as JSON")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel names (default: paper benchmarks)")
    args = ap.parse_args()
    names = [s.strip() for s in args.kernels.split(",")] if args.kernels else None
    main(json_out=args.json, bench_names=names)
