"""Table 6 analogue: code-generation time, explicit-schedule HIR vs the
in-repo HLS auto-scheduler.

HIR pipeline  = verify(explicit schedule) -> optimize -> Verilog
HLS pipeline  = erase schedule -> dependence analysis + chaining + modulo-II
                search + SDC refinement + rebalancing -> verify -> Verilog

The measured gap is the *scheduling search* the paper's insight removes; the
paper measured 333-2166x against Vivado HLS (which also parses C++ and runs
many more passes — absolute numbers differ, the mechanism is the same).
"""

from __future__ import annotations

import time
from copy import deepcopy

from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_schedule
from repro.core.passes import run_pipeline
from repro.core import verifier

PAPER_SECONDS = {  # (HIR, Vivado HLS) from paper Table 6
    "transpose": (0.006, 13), "stencil1d": (0.007, 8), "histogram": (0.007, 13),
    "gemm": (0.099, 33), "conv2d": (0.013, 14),
}


def _time(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(bench_names=None) -> list[dict]:
    rows = []
    names = [n for n in (bench_names or PAPER_BENCHMARKS) if n != "fifo"]
    for name in names:
        gal = GALLERY[name]
        base_module, entry = gal.build()

        def hir_pipeline():
            m = deepcopy(base_module)
            verifier.verify(m)
            run_pipeline(m)
            generate_verilog(m, entry)

        def hls_pipeline():
            m = erase_schedule(deepcopy(base_module))
            res = hls_schedule(m)
            # HLS trusts its own scheduler: non-strict sanity verify only
            verifier.verify(m, strict_schedule=False, raise_on_error=False)
            run_pipeline(m)
            generate_verilog(m, entry)

        t_hir = _time(hir_pipeline)
        t_hls = _time(hls_pipeline)
        paper = PAPER_SECONDS.get(name, (None, None))
        rows.append({
            "kernel": name,
            "hir_s": round(t_hir, 4),
            "hls_s": round(t_hls, 4),
            "speedup": round(t_hls / t_hir, 1),
            "paper_hir_s": paper[0],
            "paper_vivado_s": paper[1],
            "paper_speedup": (round(paper[1] / paper[0]) if paper[0] else None),
        })
    return rows


def main():
    rows = run()
    hdr = f"{'kernel':12s} {'HIR(s)':>8s} {'HLS(s)':>8s} {'speedup':>8s} {'paper':>8s}"
    print(hdr)
    for r in rows:
        print(f"{r['kernel']:12s} {r['hir_s']:8.4f} {r['hls_s']:8.4f} "
              f"{r['speedup']:7.1f}x {str(r['paper_speedup'] or '-'):>7s}x")
    return rows


if __name__ == "__main__":
    main()
