"""Table 6 analogue: code-generation time, explicit-schedule HIR vs the
in-repo HLS auto-scheduler — plus the optimizer-infrastructure benchmark:
the seed's O(region²) fixpoint sweep vs the worklist pattern driver with
maintained use-def chains.

HIR pipeline  = verify(explicit schedule) -> optimize (PassManager) -> Verilog
HLS pipeline  = erase schedule -> dependence analysis + chaining + modulo-II
                search + SDC refinement + rebalancing -> verify -> Verilog

The measured HIR-vs-HLS gap is the *scheduling search* the paper's insight
removes; the paper measured 333-2166x against Vivado HLS (which also parses
C++ and runs many more passes — absolute numbers differ, the mechanism is
the same).  The legacy-vs-worklist columns measure this PR's infrastructure
claim: same pipeline, same results, asymptotically cheaper rewriting.

Each row also carries ``per_pass`` (the PassManager's per-pass wall time and
rewrite counts for the HIR optimization pipeline) and ``analysis_cache`` (the
shared AnalysisManager's hit/computed/invalidated counters for the
verify+optimize flow — ``hits`` > 0 shows analyses being reused across the
default pipeline instead of re-derived per consumer).  ``backend_emit_s``
times each netlist printer (verilog / systemverilog / vhdl / circt) over the
same optimized RTL design — pure printing cost, since every backend is a
printer over the shared structure.  ``search_cache`` reports the HLS
schedule-search memoization layer next to ``analysis_cache``: cold vs warm
``hls_compile`` wall time through the fingerprint-keyed compile cache plus
its hit/miss counters.  ``--json`` (or ``main(json_out=True)``)
emits the rows as JSON; ``--kernels a,b`` and ``--reps N`` bound the run
(the CI smoke step uses a single small kernel).
"""

from __future__ import annotations

import argparse
import cProfile
import io
import json
import pstats
import sys
import time

from repro.core.codegen import BACKENDS, get_printer
from repro.core.codegen.rtl import RTLDesign
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import GALLERY, PAPER_BENCHMARKS
from repro.core.hls import dse as hls_dse
from repro.core.hls.eraser import erase_schedule
from repro.core.hls.scheduler import hls_compile, hls_schedule
from repro.core.passes import (AnalysisManager, DEFAULT_PIPELINE_SPEC,
                               RTL_PIPELINE_SPEC, PassManager)
from repro.core.passes.legacy_sweep import run_legacy_sweep
from repro.core import verifier

PAPER_SECONDS = {  # (HIR, Vivado HLS) from paper Table 6
    "transpose": (0.006, 13), "stencil1d": (0.007, 8), "histogram": (0.007, 13),
    "gemm": (0.099, 33), "conv2d": (0.013, 14),
}


def _time(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(bench_names=None, reps: int = 3) -> list[dict]:
    rows = []
    names = [n for n in (bench_names or PAPER_BENCHMARKS) if n != "fifo"]
    for name in names:
        gal = GALLERY[name]
        base_module, entry = gal.build()

        # per-pass + analysis-cache statistics come from one representative
        # verify->optimize run sharing a single AnalysisManager: the verifier
        # computes loop-info/port-accesses, the pipeline's schedule-preserving
        # passes keep them cached, port-demotion re-uses them (cache hits).
        stats_am = AnalysisManager()
        stats_m = base_module.clone()
        verifier.verify(stats_m, am=stats_am)
        stats_pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC,
                                         analysis_manager=stats_am)
        stats_pm.run(stats_m)
        # RTL-pipeline statistics from the same representative flow: the
        # post-lowering netlist passes report rewrites/wall time exactly like
        # the HIR-level passes above
        rtl_pm = PassManager.from_spec(RTL_PIPELINE_SPEC)
        phase_stats: dict = {}
        stats_mods = generate_verilog(stats_m, entry, am=stats_am,
                                      rtl_pass_manager=rtl_pm,
                                      timings=phase_stats)

        # per-backend emission timing: every printer reads the *same*
        # optimized RTLModules, so this isolates pure printing cost
        rtl_design = RTLDesign({n: vm.rtl for n, vm in stats_mods.items()})
        backend_emit = {}
        for bname in sorted(BACKENDS):
            printer = get_printer(bname)
            backend_emit[bname] = round(
                _time(lambda p=printer: p.print_design(rtl_design), reps), 5)

        def hir_pipeline():
            m = base_module.clone()
            am = AnalysisManager()
            verifier.verify(m, am=am)
            PassManager.from_spec(DEFAULT_PIPELINE_SPEC, analysis_manager=am).run(m)
            generate_verilog(m, entry, am=am)

        def hls_pipeline():
            m = erase_schedule(base_module.clone())
            res = hls_schedule(m)
            # HLS trusts its own scheduler: non-strict sanity verify only
            verifier.verify(m, strict_schedule=False, raise_on_error=False)
            PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m)
            generate_verilog(m, entry)

        # optimizer-only: the seed's blind fixpoint sweep vs the worklist
        # driver on identical input (Module.clone excluded from the timing).
        # Measured twice: on the kernel as built (small IR — driver overhead
        # must not regress) and on the inlined+unrolled IR codegen actually
        # optimizes (real region sizes — where O(region²) vs O(uses) shows).
        def _opt_times(mod, n_reps):
            tl = min(_time(lambda m=m: run_legacy_sweep(m), reps=1)
                     for m in [mod.clone() for _ in range(n_reps)])
            tw = min(
                _time(lambda m=m: PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(m),
                      reps=1)
                for m in [mod.clone() for _ in range(n_reps)])
            return tl, tw

        t_opt_legacy, t_opt_worklist = _opt_times(base_module, max(reps, 5))
        unrolled = base_module.clone()
        PassManager.from_spec("inline,unroll", fixpoint=False).run(unrolled)
        unrolled_ops = sum(1 for _ in unrolled.walk())
        t_opt_ul, t_opt_uw = _opt_times(unrolled, reps)

        t_hir = _time(hir_pipeline, reps)
        t_hls = _time(hls_pipeline, reps)

        # search-cache columns: cold vs warm ``hls_compile`` through the
        # fingerprint-keyed compile cache (warm repeat of a structurally
        # identical module is a cache hit), reported next to the analysis
        # cache so both memoization layers are visible per kernel.
        erased = erase_schedule(base_module.clone())
        hls_dse.COMPILE_CACHE.clear()
        hls_dse.SCHEDULE_CACHE.clear()
        mc = erased.clone()
        t0 = time.perf_counter()
        hls_compile(mc, entry=entry)
        t_cold = time.perf_counter() - t0
        mw = erased.clone()
        t0 = time.perf_counter()
        r_warm, _ = hls_compile(mw, entry=entry)
        t_warm = time.perf_counter() - t0
        search_cache = {
            "cold_s": round(t_cold, 5),
            "warm_s": round(t_warm, 5),
            "warm_speedup": round(t_cold / t_warm, 1) if t_warm > 0 else None,
            **r_warm.search_cache_stats(),
            "schedule_cache": hls_dse.SCHEDULE_CACHE.stats_dict(),
            "compile_cache": hls_dse.COMPILE_CACHE.stats_dict(),
        }
        paper = PAPER_SECONDS.get(name, (None, None))
        rows.append({
            "kernel": name,
            "hir_s": round(t_hir, 4),
            "hls_s": round(t_hls, 4),
            "speedup": round(t_hls / t_hir, 1),
            "paper_hir_s": paper[0],
            "paper_vivado_s": paper[1],
            "paper_speedup": (round(paper[1] / paper[0]) if paper[0] else None),
            # optimizer infrastructure comparison (this PR's claim)
            "opt_legacy_s": round(t_opt_legacy, 5),
            "opt_worklist_s": round(t_opt_worklist, 5),
            "opt_speedup": round(t_opt_legacy / t_opt_worklist, 2)
            if t_opt_worklist > 0 else None,
            "unrolled_ops": unrolled_ops,
            "opt_unrolled_legacy_s": round(t_opt_ul, 5),
            "opt_unrolled_worklist_s": round(t_opt_uw, 5),
            "opt_unrolled_speedup": round(t_opt_ul / t_opt_uw, 2)
            if t_opt_uw > 0 else None,
            # per-pass PassManager statistics (wall seconds + rewrites)
            "per_pass": stats_pm.stats_dict(),
            # RTL netlist pipeline statistics (same shape as per_pass)
            "rtl_per_pass": rtl_pm.stats_dict(),
            # uniform whole-pipeline phase accounting (same schema again):
            # pre-codegen passes + lower + RTL passes + emit, as filled by
            # generate_verilog(timings=)
            "phase_stats": phase_stats,
            # pure printing wall time per backend over the same RTL design
            "backend_emit_s": backend_emit,
            # shared-analysis cache counters for the verify+optimize flow
            "analysis_cache": stats_am.stats_dict(),
            # schedule-search memoization counters + cold/warm compile times
            "search_cache": search_cache,
        })
    return rows


def profile_pipeline(bench_names=None, top: int = 20) -> None:
    """--profile: run the full HIR pipeline (verify -> optimize -> codegen)
    for each kernel under cProfile and print the top cumulative hotspots —
    so perf work starts from data, not guesses."""
    names = [n for n in (bench_names or PAPER_BENCHMARKS) if n != "fifo"]
    for name in names:
        gal = GALLERY[name]
        base_module, entry = gal.build()
        m = base_module.clone()
        pr = cProfile.Profile()
        pr.enable()
        am = AnalysisManager()
        verifier.verify(m, am=am)
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC, analysis_manager=am).run(m)
        generate_verilog(m, entry, am=am)
        pr.disable()
        buf = io.StringIO()
        pstats.Stats(pr, stream=buf).sort_stats("cumulative").print_stats(top)
        print(f"\n=== {name}: top-{top} cumulative hotspots ===")
        print(buf.getvalue())


def main(json_out: bool = False, bench_names=None, reps: int = 3,
         profile: bool = False):
    if profile:
        profile_pipeline(bench_names)
        return []
    rows = run(bench_names, reps=reps)
    if json_out:
        print(json.dumps(rows, indent=2))
        return rows
    hdr = (f"{'kernel':12s} {'HIR(s)':>8s} {'HLS(s)':>8s} {'speedup':>8s} {'paper':>8s}"
           f" {'opt-old(s)':>11s} {'opt-new(s)':>11s} {'opt-spdup':>10s}"
           f" {'unrolled':>9s} {'u-spdup':>8s}")
    print(hdr)
    def _x(v, width):  # speedup column; None when a timer floor was hit
        return f"{v:{width}.2f}x" if v is not None else f"{'-':>{width}s} "

    for r in rows:
        print(f"{r['kernel']:12s} {r['hir_s']:8.4f} {r['hls_s']:8.4f} "
              f"{r['speedup']:7.1f}x {str(r['paper_speedup'] or '-'):>7s}x"
              f" {r['opt_legacy_s']:11.5f} {r['opt_worklist_s']:11.5f}"
              f" {_x(r['opt_speedup'], 9)}"
              f" {r['unrolled_ops']:8d}o {_x(r['opt_unrolled_speedup'], 7)}")
    print("\nper-pass statistics (worklist PassManager, one run per kernel):")
    for r in rows:
        busy = {k: v for k, v in r["per_pass"].items() if v["rewrites"]}
        print(f"  {r['kernel']:12s} " + ", ".join(
            f"{k}: {v['rewrites']}rw/{v['wall_s'] * 1e3:.1f}ms" for k, v in busy.items()))
    print("\nRTL-pipeline statistics (post-lowering netlist passes):")
    for r in rows:
        busy = {k: v for k, v in r["rtl_per_pass"].items() if v["rewrites"]}
        print(f"  {r['kernel']:12s} " + (", ".join(
            f"{k}: {v['rewrites']}rw/{v['wall_s'] * 1e3:.1f}ms"
            for k, v in busy.items()) or "no rewrites"))
    print("\nper-backend emission time (pure printing over the same RTL design):")
    for r in rows:
        print(f"  {r['kernel']:12s} " + ", ".join(
            f"{b}: {s * 1e3:.1f}ms" for b, s in r["backend_emit_s"].items()))
    print("\nanalysis cache (shared verify+optimize AnalysisManager):")
    for r in rows:
        ac = r["analysis_cache"]
        per = ", ".join(f"{k}: {v['computed']}c/{v['hits']}h"
                        for k, v in ac["per_analysis"].items())
        print(f"  {r['kernel']:12s} computed={ac['computed']} hits={ac['hits']} "
              f"invalidated={ac['invalidated']}  [{per}]")
    print("\nsearch cache (fingerprint-keyed hls_compile memoization):")
    for r in rows:
        sc = r["search_cache"]
        spd = f"{sc['warm_speedup']:.1f}x" if sc["warm_speedup"] else "-"
        print(f"  {r['kernel']:12s} cold={sc['cold_s'] * 1e3:.1f}ms "
              f"warm={sc['warm_s'] * 1e3:.1f}ms ({spd})  "
              f"hits={sc['hits']} misses={sc['misses']} "
              f"compile_cache={sc['compile_cache']['hits']}h/"
              f"{sc['compile_cache']['misses']}m")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit rows as JSON")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated kernel names (default: paper benchmarks)")
    ap.add_argument("--reps", type=int, default=3, help="timing repetitions")
    ap.add_argument("--profile", action="store_true",
                    help="run the pipeline under cProfile and print the "
                         "top-20 cumulative hotspots instead of benchmarking")
    args = ap.parse_args()
    names = [s.strip() for s in args.kernels.split(",")] if args.kernels else None
    main(json_out=args.json, bench_names=names, reps=args.reps,
         profile=args.profile)
