"""Vectorized RTL-simulator throughput vs the per-vector event-driven
oracle (acceptance gate: >= 100x test-vectors/sec on gemm).

For each kernel the same netlist is executed two ways over a random
stimulus batch:

  * ``lower.simulate_batch`` — the event-driven HIR interpreter, one full
    simulation per stimulus vector (the verification path before this
    benchmark's subject existed);
  * ``codegen.sim.RTLSimulator`` — the batched cycle-accurate interpreter
    (jax scan+vmap when available, vectorized numpy otherwise), timed after
    a warm-up run so jit compilation is amortized the way a fuzzing loop
    amortizes it.

Writes ``artifacts/bench/BENCH_sim_throughput.json`` and exits nonzero (via
``RuntimeError`` -> ``benchmarks/run.py``) when the speed-up floor is
missed.  ``--quick`` shrinks the batch for CI smoke runs.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"
OUT = ARTIFACTS / "BENCH_sim_throughput.json"

#: kernels measured: (build kwargs, make_inputs kwargs); gemm carries the
#: acceptance floor, the others are informational
KERNELS = {
    "gemm": ({"n": 16}, {"n": 16}),
    "stencil1d": ({"n": 16}, {"n": 16}),
    "array_add": ({"n": 16}, {"n": 16}),
}
FLOOR_KERNEL = "gemm"
#: full runs must clear 100x (the paper-repro acceptance gate); ``--quick``
#: smoke runs use a small batch that cannot amortize the per-cycle dispatch
#: cost, so they gate at a sandbagged floor that still catches order-of-
#: magnitude regressions
SPEEDUP_FLOOR = 100.0
QUICK_FLOOR = 20.0


def _bench_kernel(name: str, batch_size: int, event_lanes: int) -> dict:
    from repro.core.codegen.sim import (probe_cycles, simulator_for,
                                        stack_stimulus)
    from repro.core.gallery import GALLERY
    from repro.core.lower import simulate_batch

    gal = GALLERY[name]
    bkw, ikw = KERNELS[name]
    mod, entry = gal.build(**bkw)
    batch = stack_stimulus(gal.make_inputs, batch_size, base_seed=1, **ikw)

    sim, prepared = simulator_for(mod, entry)
    cycles = probe_cycles(prepared, entry, [c[0] for c in batch])

    # warm-up compiles the jit scan (a fuzzing loop pays this once)
    res = sim.run(batch, cycles, batched=True)
    t0 = time.perf_counter()
    res = sim.run(batch, cycles, batched=True)
    vec_s = time.perf_counter() - t0
    vec_rate = batch_size / vec_s

    lanes = min(event_lanes, batch_size)
    ev_batch = [c[:lanes] for c in batch]
    t0 = time.perf_counter()
    _, finals = simulate_batch(prepared, entry, ev_batch)
    ev_s = time.perf_counter() - t0
    ev_rate = lanes / ev_s

    # the comparison is only meaningful if both paths computed the same thing
    ridx = len(batch) - 1
    if finals[ridx] is not None and not np.array_equal(
            np.asarray(res.arrays[ridx][:lanes]), finals[ridx]):
        raise RuntimeError(f"{name}: vectorized != event-driven result")

    return {"kernel": name, "backend": sim.backend, "cycles": cycles,
            "batch": batch_size, "event_lanes": lanes,
            "vectorized_s": round(vec_s, 6),
            "event_driven_s": round(ev_s, 6),
            "vectorized_vectors_per_s": round(vec_rate, 1),
            "event_vectors_per_s": round(ev_rate, 1),
            "speedup": round(vec_rate / ev_rate, 1)}


def main(argv=None, profile: bool = False) -> dict:
    argv = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in argv
    batch_size = 1024 if not quick else 256
    event_lanes = 4 if not quick else 2
    floor = SPEEDUP_FLOOR if not quick else QUICK_FLOOR
    rows = []
    for name in KERNELS:
        r = _bench_kernel(name, batch_size, event_lanes)
        print(f"  {r['kernel']:<10} backend={r['backend']} "
              f"batch={r['batch']} cycles={r['cycles']} "
              f"vec={r['vectorized_vectors_per_s']:.0f}/s "
              f"event={r['event_vectors_per_s']:.0f}/s "
              f"speedup={r['speedup']:.0f}x")
        rows.append(r)
    out = {"floor_kernel": FLOOR_KERNEL, "speedup_floor": floor,
           "quick": quick, "rows": rows}
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(out, indent=2))
    print(f"  wrote {OUT}")
    floor_row = next(r for r in rows if r["kernel"] == FLOOR_KERNEL)
    if floor_row["speedup"] < floor:
        raise RuntimeError(
            f"sim throughput regression: {FLOOR_KERNEL} speedup "
            f"{floor_row['speedup']:.1f}x < floor {floor:.0f}x")
    return out


if __name__ == "__main__":
    main()
