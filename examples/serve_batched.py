"""Batched serving example: continuous batching over decode slots.

Serves synthetic requests against a smoke-scale model using the production
serving engine (per-lane cache positions; wave refill for recurrent archs).

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-780m
"""

import sys

from repro.launch.serve import main as serve_main


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    defaults = ["--smoke", "--requests", "10", "--slots", "4",
                "--max-new", "12", "--prompt-len", "6", "--max-len", "96"]
    if not any(a.startswith("--arch") for a in argv):
        defaults = ["--arch", "tinyllama-1.1b"] + defaults
    return serve_main(defaults + argv)


if __name__ == "__main__":
    raise SystemExit(main())
