"""The paper -> TPU bridge on the 1-d stencil (paper Listing 2).

Shows the three design components (algorithm / schedule / binding) moving
from the paper's FPGA world to TPU:

  * the HIR source is identical (explicit II=1 pipelined schedule,
    register-window banking);
  * the FPGA binding emits Verilog (shift registers, FSMs) + a resource
    estimate under the Table-5 cost model;
  * the TPU binding emits a ``pl.pallas_call`` whose grid realises the
    pipelined schedule and whose VMEM scratch realises the register window —
    then a retiming error is introduced and the schedule verifier rejects it
    BEFORE any lowering (paper Fig. 2's class of bug).

    PYTHONPATH=src python examples/hir_to_pallas.py
"""

import numpy as np

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.codegen.resources import report_module
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import stencil1d
from repro.core.lower.to_pallas import lower_to_pallas
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager


def main():
    module, entry = stencil1d.build(n=64)
    verifier.verify(module)
    print("== schedule verified (II=1 pipelined stencil) ==")

    # FPGA binding: Verilog + resources
    m2, _ = stencil1d.build(n=64)
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC)
    pm.run(m2)
    vmods = generate_verilog(m2, entry)
    res = None
    for vm in vmods.values():
        r = report_module(vm)
        res = r if res is None else res + r
    print(f"FPGA binding:  {sum(len(v.text.splitlines()) for v in vmods.values())} "
          f"lines of Verilog, resources {res.as_dict()}")

    # TPU binding: Pallas kernel (grid = the pipelined loop, scratch = the
    # register window), validated against the oracle
    inputs = stencil1d.make_inputs(n=64)
    fn = lower_to_pallas(module, entry)
    out = fn(inputs[0])["Bw"]
    want = stencil1d.oracle(inputs[0])
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)
    print("TPU binding:   pallas_call(grid=(62,), scratch=VMEM(2)) matches oracle")

    # now break the schedule the way a retiming would (paper Fig. 2) and
    # watch the verifier refuse it statically
    b = Builder(ir.Module("broken"))
    with b.func("mac", [ir.i32, ir.i32, ir.i32], ["a", "b", "c"],
                result_types=[ir.i32], result_delays=[3]) as f:
        m = b.mult(f.args[0], f.args[1], at=f.t, stages=3)   # 3-stage multiplier
        c2 = b.delay(f.args[2], 2, at=f.t)                   # ...2-stage delay
        s = b.add(m, c2)                                     # imbalance!
        b.ret([s])
    diags = verifier.verify(b.module, raise_on_error=False)
    print("\n== retimed design rejected by the schedule verifier ==")
    for d in diags:
        print(d.render())
    assert any("mismatched delay" in d.message for d in diags)
    print("\nhir_to_pallas OK")


if __name__ == "__main__":
    main()
