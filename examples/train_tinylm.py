"""End-to-end driver: train a ~100M-parameter LM on the synthetic stream.

Exercises the full production path on local devices: config -> sharded
train step (FSDP x TP rules on the local mesh) -> AdamW + cosine -> async
checkpointing -> fault-tolerant restart -> seekable data pipeline.

Quick demo (a few minutes on CPU):
    PYTHONPATH=src python examples/train_tinylm.py --steps 40

The assignment's "few hundred steps" run:
    PYTHONPATH=src python examples/train_tinylm.py --steps 300 --seq 256 --batch 8
"""

import argparse
import math

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionCfg, ModelCfg, Segment, ShapeCfg
from repro.data.pipeline import make_batch
from repro.ft.runtime import StepMonitor, run_with_restarts
from repro.launch.mesh import host_device_mesh
from repro.models.transformer import param_count
from repro.optim.adamw import OptCfg
from repro.parallel.api import use_rules
from repro.parallel.rules import rules_for
from repro.train.steps import init_train_state, make_train_step

# ~100M params: 10 layers, d=640, ff=2560, tied 32k vocab
TINYLM = ModelCfg(
    name="tinylm-100m",
    family="dense",
    d_model=640,
    vocab=32000,
    d_ff=2560,
    segments=(Segment(pattern=("attn",), repeats=10, ffn="mlp"),),
    attn=AttentionCfg(n_heads=10, n_kv_heads=5, d_head=64),
    tie_embeddings=True,
    dtype="float32",
    remat="none",
)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/tinylm_ckpt")
    args = ap.parse_args(argv)

    cfg = TINYLM
    shape = ShapeCfg("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    mesh = host_device_mesh()
    rules = rules_for(cfg, mesh, "train", batch=args.batch)
    monitor = StepMonitor()

    with use_rules(rules, mesh), mesh:
        state0 = init_train_state(jax.random.key(0), cfg)
        n = param_count(state0["params"])
        print(f"model: {cfg.name}  params={n / 1e6:.1f}M  devices={mesh.size}")
        step_fn = jax.jit(make_train_step(
            cfg, OptCfg(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                        decay_steps=args.steps)))

        losses = []

        def on_metrics(i, m):
            losses.append(float(m["loss"]))
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"acc {float(m['accuracy']):.3f}  "
                      f"step_t {monitor.median:.2f}s")

        report = run_with_restarts(
            init_state=lambda: init_train_state(jax.random.key(0), cfg),
            step_fn=lambda s, b, _step=None: step_fn(s, b),
            batch_at=lambda i: {k: jnp.asarray(v) for k, v in
                                make_batch(cfg, shape, step=i).items()},
            num_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=max(10, args.steps // 5),
            monitor=monitor,
            on_metrics=on_metrics,
        )

    print(f"\ncompleted {report.steps_completed} steps; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ppl {math.exp(min(20, losses[0])):.0f} -> {math.exp(min(20, losses[-1])):.0f})")
    assert losses[-1] < losses[0], "loss must decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
