"""Quickstart: the paper's workflow end-to-end in two minutes.

1. Write a kernel in HIR (explicit schedule).
2. The schedule verifier catches a pipelining bug (paper Fig. 1).
3. Optimize (precision opt etc.) and generate Verilog (paper's target).
4. Lower the SAME IR to a Pallas TPU kernel (this repo's hardware
   adaptation) and execute it against the NumPy oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import ir, verifier
from repro.core.builder import Builder
from repro.core.codegen.verilog import generate_verilog
from repro.core.gallery import array_add
from repro.core.lower import lower_to_jax, simulate
from repro.core.lower.to_pallas import lower_to_pallas
from repro.core.passes import DEFAULT_PIPELINE_SPEC, PassManager
from repro.core.printer import print_module


def main():
    # -- 1. a fresh HIR kernel: out[i] = a[i] + b[i], pipelined at II=1 ----
    module, entry = array_add.build(n=64)
    print("== HIR (explicit schedule) ==")
    print_module(module)

    # -- 2. the paper's Fig. 1 bug is caught statically ---------------------
    broken, _ = array_add.build_broken(n=64)
    diags = verifier.verify(broken, raise_on_error=False)
    print("\n== schedule verifier on the Fig. 1 design ==")
    for d in diags:
        print(d.render())

    # -- 3. optimize + Verilog ---------------------------------------------
    # pipelines are declarative specs; the PassManager reports per-pass
    # rewrite counts and wall time
    pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC)
    stats = pm.run(module)
    print(f"\n== optimization pipeline ({pm.spec}) ==")
    print(pm.render_stats())
    vmods = generate_verilog(module, entry)
    v = vmods[entry].text
    print(f"== Verilog: {len(v.splitlines())} lines, module {entry} ==")
    print("\n".join(v.splitlines()[:12]), "\n...")

    # -- 3b. same netlist, other backends ----------------------------------
    # every backend is a printer over the same optimized RTLModule; the
    # resource summary is derived from the structure, so it never changes
    from repro.core.codegen import get_printer
    from repro.core.codegen.rtl import RTLDesign

    design = RTLDesign({n: vm.rtl for n, vm in vmods.items()})
    for backend in ("systemverilog", "vhdl", "circt"):
        text = get_printer(backend).print_design(design)
        first = next(l for l in text.splitlines() if l and not l.startswith(("//", "--")))
        print(f"== {backend}: {len(text.splitlines())} lines | {first[:60]}")

    # -- 4. same IR -> Pallas TPU kernel (interpret mode on CPU) ------------
    inputs = array_add.make_inputs(n=64)
    fn = lower_to_pallas(module, entry)
    out = fn(inputs[0], inputs[1])["C"]
    want = array_add.oracle(inputs[0], inputs[1])
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)
    print("\n== Pallas lowering matches the NumPy oracle ==")

    # cross-check: cycle-accurate simulation and functional JAX lowering
    sim_inputs = array_add.make_inputs(n=64)
    simulate(module, entry, sim_inputs)
    np.testing.assert_array_equal(sim_inputs[-1], want)
    jout = lower_to_jax(module, entry)(*array_add.make_inputs(n=64))
    np.testing.assert_array_equal(np.asarray(jout["C"], np.int64), want)
    print("== cycle-accurate sim and functional lowering agree ==")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
