"""Logical-axis sharding API (the thin layer every model touches).

Models annotate activations with *logical* axes (``batch``, ``seq``,
``embed``, ``heads``, ``ff`` ...).  A :class:`ShardingRules` context resolves
logical axes to mesh axes; outside any context annotations are no-ops so the
same model code runs in single-device tests and in the 512-chip dry-run.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

MeshAxes = Union[None, str, tuple[str, ...]]


class ShardingRules:
    """Mapping logical axis -> mesh axis (or tuple, or None)."""

    def __init__(self, **rules: MeshAxes):
        self.rules: dict[str, MeshAxes] = dict(rules)

    def resolve(self, logical: Sequence[Optional[str]]) -> P:
        out = []
        for ax in logical:
            out.append(None if ax is None else self.rules.get(ax))
        return P(*out)

    def replace(self, **kw: MeshAxes) -> "ShardingRules":
        r = dict(self.rules)
        r.update(kw)
        return ShardingRules(**r)


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def use_rules(rules: ShardingRules, mesh: Optional[Mesh] = None):
    old_r = getattr(_state, "rules", None)
    old_m = getattr(_state, "mesh", None)
    _state.rules = rules
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = old_r
        _state.mesh = old_m


def logical_spec(logical: Sequence[Optional[str]]) -> P:
    r = current_rules()
    if r is None:
        return P()
    return r.resolve(logical)


def shard(x, *logical: Optional[str]):
    """Annotate ``x`` with logical axes (no-op without rules/mesh)."""
    r = current_rules()
    if r is None:
        return x
    spec = r.resolve(logical)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, logical: Sequence[Optional[str]], rules: ShardingRules) -> NamedSharding:
    return NamedSharding(mesh, rules.resolve(logical))


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma: bool = False):
    """Version-compatible ``jax.shard_map``: newer jax exposes it top-level
    with ``axis_names`` (manual axes) and ``check_vma``; older releases only
    have ``jax.experimental.shard_map.shard_map`` with the complementary
    ``auto`` set and ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    manual = frozenset(axis_names) if axis_names is not None else frozenset(mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
