"""Divisibility-aware logical-axis -> mesh-axis rule assignment.

The production mesh is ``("data","model")=(16,16)`` per pod and
``("pod","data","model")=(2,16,16)`` across pods.  Policies:

  train    batch over (pod,data);  weights FSDP over data (embed dim) x TP
           over model (ff/heads/experts dims); optimizer state inherits
           (ZeRO-1).
  prefill  same activation sharding, no optimizer.
  decode   batch over (pod,data); KV caches sequence-sharded over model
           (flash-decode); small recurrent states batch-sharded.

Every mapping is validated against the actual dimension sizes of the config:
a logical axis whose dims do not divide the mesh-axis product falls back one
step (e.g. heads -> None for 28-head models on a 16-way model axis) instead
of failing at lowering time.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

from jax.sharding import Mesh

from ..configs.base import ModelCfg, ShapeCfg
from .api import ShardingRules


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def _mesh_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return _prod(mesh.shape[a] for a in axes)


def _fit(mesh: Mesh, axes, *dims: int):
    """axes if every dim divides the mesh-axis product, else None."""
    n = _mesh_size(mesh, axes)
    if all(d % n == 0 and d >= n for d in dims):
        return axes
    return None


def _head_dims(cfg: ModelCfg) -> list[int]:
    dims = []
    kinds = {k for s in cfg.segments for k in s.pattern} | {
        k for s in cfg.encoder_segments for k in s.pattern}
    if kinds & {"attn", "local_attn", "enc_attn", "cross_attn", "mla"}:
        dims.append(cfg.attn.n_heads)
    if "ssd" in kinds:
        dims.append(cfg.ssd.expand * cfg.d_model // cfg.ssd.headdim)
    if "rglru" in kinds and cfg.rglru.n_heads:
        dims.append(cfg.rglru.n_heads)
    return dims or [1]


def _ff_dims(cfg: ModelCfg) -> list[int]:
    dims = []
    if cfg.d_ff:
        dims.append(cfg.d_ff)
    if cfg.moe is not None and cfg.moe.n_shared:
        dims.append(cfg.moe.d_ff_shared or cfg.moe.n_shared * cfg.moe.d_ff_expert)
    kinds = {k for s in cfg.segments for k in s.pattern}
    if "rglru" in kinds:
        dr = cfg.rglru.d_rnn or cfg.d_model
        dims.append(dr)
    if "ssd" in kinds:
        d_inner = cfg.ssd.expand * cfg.d_model
        H = d_inner // cfg.ssd.headdim
        dims += [d_inner, d_inner + 2 * cfg.ssd.d_state,
                 2 * d_inner + 2 * cfg.ssd.d_state + H]
    return dims or [1]


def padded_vocab(cfg: ModelCfg) -> int:
    return cfg.padded_vocab


def rules_for(
    cfg: ModelCfg,
    mesh: Mesh,
    mode: str,                     # train | prefill | decode
    *,
    batch: Optional[int] = None,   # per-step batch (post-microbatching)
    pod_in_batch: bool = True,     # False under manual-pod shard_map
    moe_ep: bool = False,          # expert-parallel shard_map MoE dispatch
    seq_shard_fallback: bool = False,  # context-parallel attention when the
                                       # head count cannot shard over model
    embed_fsdp: bool = True,       # FSDP-shard the embed table's d dim
    flash_decode: bool = False,    # shard_map partial-softmax decode over
                                   # the sequence-sharded KV cache
) -> ShardingRules:
    names = mesh.axis_names
    dp: tuple[str, ...] = tuple(a for a in ("pod", "data") if a in names)
    if not pod_in_batch:
        dp = tuple(a for a in dp if a != "pod")
    mdl = "model" if "model" in names else None

    batch_ax = dp if (batch is None or _fit(mesh, dp, batch)) else (
        _fit(mesh, ("data",), batch) if "data" in names else None)

    heads_ax = _fit(mesh, mdl, *_head_dims(cfg)) if mdl else None
    kv_ax = _fit(mesh, mdl, cfg.attn.n_kv_heads) if mdl else None
    ff_ax = _fit(mesh, mdl, *_ff_dims(cfg)) if mdl else None
    vocab_ax = _fit(mesh, mdl, padded_vocab(cfg)) if mdl else None
    embed_ax = None
    if "data" in names:
        embed_ax = _fit(mesh, ("data",), cfg.d_model)   # FSDP shard of weights

    experts_ax = None
    ff_exp_ax = None
    if cfg.moe is not None and mdl:
        experts_ax = _fit(mesh, mdl, cfg.moe.n_routed)
        if experts_ax is None:
            ff_exp_ax = _fit(mesh, mdl, cfg.moe.d_ff_expert)  # TP inside experts

    # context parallelism: when attention heads cannot shard over the model
    # axis (e.g. 40 heads on a 16-way axis), shard the *sequence* dim of the
    # attention activations instead — otherwise attention replicates its
    # compute across the whole model axis.
    seq_ax = None
    if seq_shard_fallback and mdl and heads_ax is None and mode in ("train", "prefill"):
        seq_ax = mdl
        vocab_ax = None   # logits shard over seq instead (one axis per spec)

    rules = dict(
        batch=batch_ax,
        seq=seq_ax,
        act_embed=None,
        act_ff=None if seq_ax is not None else ff_ax,
        embed_tp=embed_ax,
        embed_gather=embed_ax if embed_fsdp else None,
        vocab=vocab_ax,
        heads=heads_ax,
        kv_heads=kv_ax,
        ff=ff_ax,
        ff2=None,
        ff_expert=ff_exp_ax,
        experts=experts_ax,
        layers=None,
        kv_seq=None,
        kv_heads_decode=None,
    )

    if mode == "decode":
        # sequence-sharded KV cache (flash-decode); the model axis holds the
        # long context, heads replicated for the (B,1,·) matmuls.
        rules.update(kv_seq=mdl, kv_heads_decode=None)

    if moe_ep and cfg.moe is not None:
        rules["_moe_ep"] = True
    if flash_decode and mode == "decode":
        rules["_flash_decode"] = True

    return ShardingRules(**rules)


def describe(rules: ShardingRules) -> str:
    return ", ".join(f"{k}->{v}" for k, v in sorted(rules.rules.items()) if v is not None)
