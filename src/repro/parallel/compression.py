"""Int8-compressed cross-pod gradient all-reduce.

Cross-pod ICI/DCN links are the scarcest bandwidth in a multi-pod job; the
within-pod reduction happens at full precision (GSPMD, over the auto axes)
while the pod-to-pod exchange ships int8 + one fp32 scale per tensor — a 4x
(vs fp32) / 2x (vs bf16) wire-byte reduction.

Implementation: ``jax.shard_map`` manual over the ``pod`` axis only
(``axis_names={"pod"}``); ``data``/``model`` stay automatic so the inner
fwd+bwd keeps its GSPMD sharding.  The all-reduce is an all-gather of the
int8 payload + per-pod scales followed by a local fused dequant-sum
(sum_i scale_i * q_i), which is how compressed collectives are actually
realised (you cannot sum int8 payloads with different scales on the wire).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .api import shard_map_compat


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """All-reduce ``x`` over ``axis_name`` shipping int8 on the wire."""
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)          # int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)      # (npods,) fp32 scales
    return jnp.einsum("p,p...->...", ss, qs.astype(jnp.float32))


def pod_grads_compressed(grad_fn, params, batch, mesh):
    """Run ``grad_fn(params, batch) -> (loss, metrics, grads)`` per pod and
    combine gradients with the compressed cross-pod all-reduce."""
    npods = mesh.shape["pod"]

    def body(params, batch):
        loss, metrics, grads = grad_fn(params, batch)
        grads = jax.tree.map(lambda g: compressed_psum(g, "pod") / npods, grads)
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        return loss, metrics, grads

    fm = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(), P("pod")),
        out_specs=(P(), P(), P()),
        axis_names={"pod"},
        check_vma=False,
    )
    return fm(params, batch)
