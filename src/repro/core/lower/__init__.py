from .to_sim import SimulationError, simulate, simulate_batch  # noqa: F401
from .to_jax import lower_to_jax  # noqa: F401
