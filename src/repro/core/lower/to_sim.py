"""Cycle-accurate simulator for scheduled HIR (the semantic oracle).

The simulator realises exactly the hardware semantics of §4.6 / Table 3:

  * every op fires at its scheduled absolute cycle,
  * RAM reads sample the address in cycle ``c`` and deliver data valid at
    ``c + latency`` (1 for LUTRAM/BRAM, 0 for registers),
  * writes commit at the *end* of their cycle (visible from ``c+1``),
  * pipelined loop iterations genuinely overlap in time,
  * memref port conflicts (two same-cycle accesses at different addresses on
    one port) raise ``SimulationError`` — these are the runtime assertions the
    Verilog backend emits for the paper's §4.5 UB rules.

Pure (combinational) scalar ops are evaluated lazily by SSA identity, which is
sound because the schedule verifier has already proven every value is consumed
within its validity window.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Union

import numpy as np

from .. import ir
from ..ir import ForOp, FuncOp, MemrefType, Module, Operation, Region, Time, Value


class SimulationError(Exception):
    pass


def _mask(val: int, t: ir.Type) -> Union[int, float]:
    if isinstance(t, ir.FloatType):
        return float(val)
    if isinstance(t, ir.ConstType):
        return val
    assert isinstance(t, ir.IntType)
    w = t.width
    v = int(val) & ((1 << w) - 1)
    if t.signed and v >= (1 << (w - 1)):
        v -= 1 << w
    return v


_ARITH_EVAL: dict[str, Callable[..., Any]] = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mult": lambda a, b: a * b,
    "div": lambda a, b: (a // b if isinstance(a, int) and isinstance(b, int) else a / b),
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
    "xor": lambda a, b: a ^ b,
    "not": lambda a: ~a,
    "shl": lambda a, b: a << b,
    "shr": lambda a, b: a >> b,
    "cmp_lt": lambda a, b: int(a < b),
    "cmp_le": lambda a, b: int(a <= b),
    "cmp_eq": lambda a, b: int(a == b),
    "cmp_ne": lambda a, b: int(a != b),
    "cmp_gt": lambda a, b: int(a > b),
    "cmp_ge": lambda a, b: int(a >= b),
    "select": lambda c, a, b: a if c else b,
    "trunc": lambda a: a,
    "zext": lambda a: a,
    "sext": lambda a: a,
}


@dataclass
class _Storage:
    """Backing store for one allocated tensor (all banks)."""

    array: np.ndarray
    memref: MemrefType


class _Ctx:
    """One dynamic scope instance: binds SSA values to concrete values/thunks
    and time variables to absolute cycles."""

    __slots__ = ("vals", "times", "parent", "id")
    _ids = itertools.count()

    def __init__(self, parent: Optional["_Ctx"] = None):
        self.vals: dict[Value, Any] = {}
        self.times: dict[Value, int] = {}
        self.parent = parent
        self.id = next(self._ids)

    def lookup(self, v: Value) -> Any:
        c: Optional[_Ctx] = self
        while c is not None:
            if v in c.vals:
                return c.vals[v]
            c = c.parent
        raise SimulationError(f"unbound value %{v.name}")

    def lookup_time(self, tv: Value) -> int:
        c: Optional[_Ctx] = self
        while c is not None:
            if tv in c.times:
                return c.times[tv]
            c = c.parent
        raise SimulationError(f"unbound time variable %{tv.name}")


class Simulator:
    READ_PHASE = 0
    WRITE_PHASE = 1

    def __init__(self, module: Module, externals: Optional[dict[str, Callable]] = None,
                 check_conflicts: bool = True, max_cycles: int = 10_000_000):
        self.module = module
        self.externals = externals or {}
        self.check_conflicts = check_conflicts
        self.max_cycles = max_cycles
        self._heap: list[tuple[int, int, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._port_access: dict[tuple, dict] = {}  # (storage, port, cycle) -> {bank: packed_addr}
        self.final_cycle = 0
        self.events_executed = 0

    # -- event queue -------------------------------------------------------
    def _at(self, cycle: int, phase: int, fn: Callable[[], None]) -> None:
        if cycle > self.max_cycles:
            raise SimulationError(f"simulation exceeded {self.max_cycles} cycles")
        heapq.heappush(self._heap, (cycle, phase, next(self._seq), fn))

    def _abs_time(self, ctx: _Ctx, t: Time) -> int:
        return ctx.lookup_time(t.tv) + t.offset

    # -- value evaluation ----------------------------------------------------
    def _eval(self, ctx: _Ctx, v: Value) -> Any:
        x = ctx.lookup(v)
        if callable(x) and not isinstance(x, (_Storage,)):
            x = x()
        return x

    # -- main entry -----------------------------------------------------------
    def run(self, func_name: str, args: Sequence[Any], start_cycle: int = 0) -> dict[str, Any]:
        func = self.module.get(func_name)
        ctx = _Ctx()
        self._bind_call(func, args, ctx, start_cycle)
        self._schedule_region(func.body, ctx)
        while self._heap:
            cycle, phase, _, fn = heapq.heappop(self._heap)
            self.final_cycle = max(self.final_cycle, cycle)
            self.events_executed += 1
            fn()
        rets = {}
        for op in func.body.ops:
            if op.opname == "return" and op.operands:
                rets = {f"ret{i}": self._eval(ctx, v) for i, v in enumerate(op.operands)}
        return {"cycles": self.final_cycle - start_cycle, "returns": rets, "events": self.events_executed}

    # -- binding ---------------------------------------------------------------
    def _bind_call(self, func: FuncOp, args: Sequence[Any], ctx: _Ctx, cycle: int) -> None:
        assert len(args) == len(func.args), (func.name, len(args), len(func.args))
        ctx.times[func.time_var] = cycle
        for formal, actual in zip(func.args, args):
            if isinstance(formal.type, MemrefType):
                if isinstance(actual, _Storage):
                    ctx.vals[formal] = actual
                else:
                    arr = np.asarray(actual)
                    assert arr.shape == formal.type.shape, (arr.shape, formal.type.shape)
                    ctx.vals[formal] = _Storage(arr, formal.type)
            else:
                ctx.vals[formal] = actual

    # -- region scheduling --------------------------------------------------------
    def _schedule_region(self, region: Region, ctx: _Ctx) -> None:
        for op in region.ops:
            self._schedule_op(op, ctx)

    def _schedule_op(self, op: Operation, ctx: _Ctx) -> None:
        o = op.opname

        if o == "constant":
            ctx.vals[op.result] = op.attrs["value"]
            return

        if o == "alloc":
            base: MemrefType = op.attrs["base"]
            init = np.full(base.shape, 0, dtype=np.int64 if isinstance(base.elem, ir.IntType) else np.float64)
            st = _Storage(init, base)
            for r in op.results:
                ctx.vals[r] = st
            return

        if o == "time":
            base = ctx.lookup_time(op.operands[0]) + op.attrs.get("offset", 0)
            ctx.times[op.result] = base
            return

        if o in ir.ARITH_OPS:
            def thunk(op=op, ctx=ctx):
                vals = [self._eval(ctx, v) for v in op.operands]
                raw = _ARITH_EVAL[op.opname](*vals)
                return _mask(raw, op.result.type) if isinstance(raw, int) else raw

            ctx.vals[op.result] = thunk
            return

        if o == "delay":
            ctx.vals[op.result] = lambda op=op, ctx=ctx: self._eval(ctx, op.operands[0])
            return

        if o == "mem_read":
            cycle = self._abs_time(ctx, op.start)
            cell: dict[str, Any] = {}

            def do_read(op=op, ctx=ctx, cycle=cycle, cell=cell):
                st: _Storage = self._eval(ctx, op.operands[0])
                idx = tuple(int(self._eval(ctx, v)) for v in op.operands[1:])
                self._check_bounds(op, st, idx)
                self._check_port(op, ctx, cycle, idx)
                cell["v"] = st.array[idx].item()

            self._at(cycle, self.READ_PHASE, do_read)

            def result(cell=cell, op=op):
                if "v" not in cell:
                    raise SimulationError(f"{op.loc}: read value consumed before it was sampled")
                return cell["v"]

            ctx.vals[op.result] = result
            return

        if o == "mem_write":
            cycle = self._abs_time(ctx, op.start)

            def do_write(op=op, ctx=ctx, cycle=cycle):
                value_v, mem_v, idx_vs, pred_v = ir.mem_write_parts(op)
                if pred_v is not None and not int(self._eval(ctx, pred_v)):
                    return  # write-enable low: no port activity
                st: _Storage = self._eval(ctx, mem_v)
                idx = tuple(int(self._eval(ctx, v)) for v in idx_vs)
                self._check_bounds(op, st, idx)
                self._check_port(op, ctx, cycle, idx, is_write=True)
                val = self._eval(ctx, value_v)
                st.array[idx] = _mask(val, st.memref.elem) if isinstance(val, int) else val

            self._at(cycle, self.WRITE_PHASE, do_write)
            return

        if o == "yield" or o == "return":
            return  # handled by the loop/func drivers

        if o == "call":
            cycle = self._abs_time(ctx, op.start)
            callee_name = op.attrs["callee"]
            callee = self.module.funcs.get(callee_name)
            if callee is None or callee.attrs.get("external"):
                fn = self.externals.get(callee_name)
                if fn is None:
                    raise SimulationError(f"no model registered for external @{callee_name}")
                cell: dict[str, Any] = {}

                def do_call(op=op, ctx=ctx, cell=cell, fn=fn):
                    vals = [self._eval(ctx, v) for v in op.operands]
                    out = fn(*vals)
                    cell["v"] = out if isinstance(out, tuple) else (out,)

                self._at(cycle, self.READ_PHASE, do_call)
                for i, r in enumerate(op.results):
                    ctx.vals[r] = (lambda cell=cell, i=i: cell["v"][i])
            else:
                sub = _Ctx(None)
                self._bind_args_lazy(callee, op, ctx, sub, cycle)
                self._schedule_region(callee.body, sub)
                for bop in callee.body.ops:
                    if bop.opname == "return" and bop.operands:
                        for r, v in zip(op.results, bop.operands):
                            ctx.vals[r] = (lambda v=v, sub=sub: self._eval(sub, v))
            return

        if isinstance(op, ForOp):
            self._schedule_loop(op, ctx)
            return

        raise SimulationError(f"simulator: unknown op hir.{o}")

    def _bind_args_lazy(self, callee: FuncOp, call_op: Operation, caller_ctx: _Ctx, sub: _Ctx, cycle: int) -> None:
        sub.times[callee.time_var] = cycle
        for formal, actual in zip(callee.args, call_op.operands):
            if isinstance(formal.type, MemrefType):
                sub.vals[formal] = caller_ctx.lookup(actual)
            else:
                sub.vals[formal] = (lambda a=actual, c=caller_ctx: self._eval(c, a))

    # -- loops -----------------------------------------------------------------
    def _schedule_loop(self, op: ForOp, ctx: _Ctx) -> None:
        lb = int(self._eval(ctx, op.lb))
        ub = int(self._eval(ctx, op.ub))
        step = int(self._eval(ctx, op.step))
        if step <= 0:
            raise SimulationError(f"{op.loc}: non-positive loop step {step}")
        start_cycle = self._abs_time(ctx, op.start) + op.attrs.get("iter_arg_offset", 0)
        y = op.yield_op()
        if y is None:
            raise SimulationError(f"{op.loc}: loop without yield")

        if op.opname == "unroll_for":
            # spatial replication: iteration m starts at start + m*stagger
            stagger = y.start.offset if (y.start is not None and y.start.tv is op.time_var) else 0
            cyc = start_cycle
            last_end = start_cycle
            for ivv in range(lb, ub, step):
                it = _Ctx(ctx)
                it.vals[op.iv] = ivv
                it.times[op.time_var] = cyc
                self._schedule_region_loop_body(op, it)
                last_end = cyc + stagger
                cyc += stagger
            ctx.times[op.end_time] = last_end
            return

        # hir.for: iterations may overlap (pipelining).  The next iteration's
        # start is the yield's absolute time in the current iteration context.
        # Nested loops schedule recursively and resolve their end-times during
        # scheduling, so data-dependent (sequential) IIs are resolvable here.
        cyc = start_cycle
        ivv = lb
        while ivv < ub:
            it = _Ctx(ctx)
            it.vals[op.iv] = ivv
            it.times[op.time_var] = cyc
            self._schedule_region_loop_body(op, it)
            if y.start.tv is op.time_var:
                nxt = cyc + y.start.offset
                if nxt <= cyc:
                    raise SimulationError(f"{op.loc}: loop II must be >= 1")
            else:
                nxt = self._abs_time(it, y.start)
            cyc = nxt
            ivv += step
        ctx.times[op.end_time] = cyc

    def _schedule_region_loop_body(self, op: ForOp, it: _Ctx) -> None:
        for inner in op.region(0).ops:
            if inner.opname in ("yield",):
                continue
            self._schedule_op(inner, it)

    # -- checks -------------------------------------------------------------------
    def _check_bounds(self, op: Operation, st: _Storage, idx: tuple[int, ...]) -> None:
        for d, (i, n) in enumerate(zip(idx, st.array.shape)):
            if not (0 <= i < n):
                raise SimulationError(f"{op.loc}: out-of-bounds access dim {d}: {i} not in [0,{n}) (UB §4.5)")

    def _check_port(self, op: Operation, ctx: _Ctx, cycle: int, idx: tuple[int, ...], is_write: bool = False) -> None:
        if not self.check_conflicts:
            return
        port_v = op.operands[1] if is_write else op.operands[0]
        # identify the *physical* port: (storage id, port value id) so two
        # memrefs on one tensor are distinct ports (paper §4.4)
        key = (id(ctx.lookup(port_v)), port_v.id, cycle)
        mt: MemrefType = port_v.type  # type: ignore[assignment]
        # bank-select part of the address: accesses to different banks never
        # conflict (paper Fig. 3)
        bank = tuple(idx[d] for d in mt.distributed)
        packed = tuple(idx[d] for d in mt.packed)
        banks = self._port_access.setdefault(key, {})
        prev = banks.get(bank)
        if prev is not None and prev != packed:
            raise SimulationError(
                f"{op.loc}: port conflict on %{port_v.name} at cycle {cycle}: "
                f"addresses {prev} vs {packed} on bank {bank} (UB §4.5)"
            )
        banks[bank] = packed


def simulate(
    module: Module,
    func: str,
    args: Sequence[Any],
    externals: Optional[dict[str, Callable]] = None,
    check_conflicts: bool = True,
) -> dict[str, Any]:
    """Simulate ``module.func(*args)``; numpy-array arguments are mutated in
    place (they model the external memory interfaces).  Returns dict with
    cycle count and scalar returns."""
    return Simulator(module, externals, check_conflicts).run(func, args)


def simulate_batch(
    module: Module,
    func: str,
    args_batch: Sequence[Any],
    check_conflicts: bool = True,
) -> tuple[list[dict], list[Optional[np.ndarray]]]:
    """Event-driven baseline over a batch-first stimulus set: one
    ``simulate`` call per lane.  This is the per-vector reference path the
    vectorized RTL simulator (``codegen.sim``) is measured against — and the
    slow half of the differential harness.

    ``args_batch`` holds one ``(B, *shape)`` array per memref argument and
    ``(B,)`` arrays (or plain ints, broadcast) for scalar arguments.
    Returns ``(results, finals)``: the per-lane ``simulate`` result dicts and
    the final batch-first contents of each memref argument (None for
    scalars).  Unlike ``simulate``, the stimulus arrays are never mutated."""
    f = module.get(func)
    cols = [np.asarray(a) for a in args_batch]
    B = int(cols[0].shape[0]) if cols else 1
    results: list[dict] = []
    finals: list[list] = [[] for _ in cols]
    for k in range(B):
        lane: list[Any] = []
        for a, col in zip(f.args, cols):
            if isinstance(a.type, MemrefType):
                lane.append(np.array(col[k], copy=True))
            else:
                lane.append(int(col[k]) if col.ndim else int(col))
        results.append(
            simulate(module, func, lane, check_conflicts=check_conflicts))
        for i, (a, v) in enumerate(zip(f.args, lane)):
            if isinstance(a.type, MemrefType):
                finals[i].append(v)
    stacked = [np.stack(c) if c else None for c in finals]
    return results, stacked
