"""Shared primitive tables for the HIR <-> JAX lowering boundary.

Three components speak both HIR and JAX:

  * ``lower/to_jax.py``   — HIR -> pure JAX (algorithm extraction),
  * ``lower/to_pallas.py`` — HIR -> Pallas TPU kernel (hardware adaptation),
  * ``frontend/``          — JAX -> HIR (the tracer, the mirror image).

Each used to carry its own copy of the HIR-arith-op -> jnp implementation
table and its own dtype policy; they are lifted here so the three stay in
lockstep (adding an HIR arith op is a one-table change) and so the dtype
*coercion policy* is explicit instead of scattered.

Dtype policy
------------
``np_dtype`` is the faithful mapping used by the functional (to_jax)
lowering: every HIR type maps to a JAX dtype that can represent it
losslessly (``f64 -> float64``).

``pallas_dtype`` is the TPU mapping used by the Pallas lowering, where the
hardware-supported set is narrower.  Coercions are explicit:

  * ``f64`` RAISES ``TypeError`` by default — TPU VMEM kernels compute in
    f32 and a silent f64 -> f32 downcast corrupts precision-sensitive
    designs.  Pass ``allow_downcast=True`` to opt in (a
    ``PrecisionWarning`` is still emitted).
  * ``f16`` maps to ``bfloat16`` (TPU-native) with a ``PrecisionWarning``:
    same width, different mantissa/exponent split.
  * integer types map to ``int32`` (HIR ints are <= 32 bits in this flow).
"""

from __future__ import annotations

import warnings
from typing import Any

from .. import ir


class PrecisionWarning(UserWarning):
    """A lowering changed numeric precision/format (e.g. f16 -> bf16)."""


def np_dtype(t: ir.Type):
    """Faithful HIR type -> jnp dtype (functional lowering)."""
    import jax.numpy as jnp

    if isinstance(t, ir.IntType):
        return jnp.int32 if t.width <= 32 else jnp.int64
    if isinstance(t, ir.FloatType):
        return {16: jnp.bfloat16, 32: jnp.float32, 64: jnp.float64}[t.width]
    raise TypeError(t)


def pallas_dtype(t: ir.Type, allow_downcast: bool = False):
    """TPU (Pallas) HIR type -> jnp dtype with an explicit coercion policy.

    Raises ``TypeError`` on ``f64`` unless ``allow_downcast=True``; warns
    (``PrecisionWarning``) on any lossy/format-changing coercion."""
    import jax.numpy as jnp

    if isinstance(t, ir.IntType):
        return jnp.int32
    if isinstance(t, ir.FloatType):
        if t.width == 64:
            if not allow_downcast:
                raise TypeError(
                    "f64 memrefs cannot be lowered to a Pallas TPU kernel "
                    "without loss (VMEM compute is f32); pass "
                    "allow_downcast=True to lower_to_pallas to accept the "
                    "f64 -> f32 coercion explicitly")
            warnings.warn("lowering f64 -> f32 for Pallas (allow_downcast)",
                          PrecisionWarning, stacklevel=2)
            return jnp.float32
        if t.width == 16:
            warnings.warn(
                "lowering f16 -> bfloat16 for Pallas (TPU-native 16-bit "
                "float; mantissa precision differs)",
                PrecisionWarning, stacklevel=2)
            return jnp.bfloat16
        return jnp.float32
    raise TypeError(t)


def jnp_arith_table() -> dict[str, Any]:
    """HIR arith op name -> jnp implementation.

    Works on both jnp arrays and python scalars (the to_jax lowering feeds
    it python ints for constant operands).  Division is *floor* division on
    integers — matching the RTL semantics (signed floor div) on the domains
    where both are defined; see the frontend docs for the x/0 caveat."""
    import jax.numpy as jnp

    def _as_i32(x):
        return x.astype(jnp.int32) if hasattr(x, "astype") else int(x)

    return {
        "add": lambda a, b: a + b,
        "sub": lambda a, b: a - b,
        "mult": lambda a, b: a * b,
        "div": lambda a, b: (a // b
                             if jnp.issubdtype(jnp.result_type(a), jnp.integer)
                             else a / b),
        "and": lambda a, b: a & b,
        "or": lambda a, b: a | b,
        "xor": lambda a, b: a ^ b,
        "not": lambda a: ~a,
        "shl": lambda a, b: a << b,
        "shr": lambda a, b: a >> b,
        "cmp_lt": lambda a, b: _as_i32(a < b),
        "cmp_le": lambda a, b: _as_i32(a <= b),
        "cmp_eq": lambda a, b: _as_i32(a == b),
        "cmp_ne": lambda a, b: _as_i32(a != b),
        "cmp_gt": lambda a, b: _as_i32(a > b),
        "cmp_ge": lambda a, b: _as_i32(a >= b),
        "select": lambda c, a, b: jnp.where(jnp.asarray(c) != 0, a, b),
        "trunc": lambda a: a,
        "zext": lambda a: a,
        "sext": lambda a: a,
    }


#: ops with memory/timing effects — everything else is pure SSA dataflow
EFFECTFUL_OPS = ("mem_read", "mem_write", "call", "for", "unroll_for")


def schedule_key(op: ir.Operation) -> tuple:
    """Schedule-order sort key: start offset, reads before writes on cycle
    ties (the hardware read phase samples pre-write state)."""
    off = op.start.offset if op.start is not None else 0
    rw = 0 if op.opname == "mem_read" else 1
    return (off, rw)
