"""HIR -> Pallas TPU lowering (the hardware-adaptation component, DESIGN §3).

The paper's three design components map onto a ``pl.pallas_call`` as:

  algorithm  -> the kernel body: HIR ops interpreted into jnp ops on Refs;
  schedule   -> the *main* pipelined loop becomes the (sequential) Pallas
                grid — HIR's II=1 pipelining is the implicitly double-
                buffered grid; cross-iteration state (HIR register windows /
                accumulators) becomes VMEM scratch persisting across grid
                steps; prologue/epilogue phases run under
                ``pl.when(first/last step)``;
  binding    -> memref arguments become VMEM-blocked inputs/outputs
                (BlockSpec = whole array for these register-scale kernels);
                ``hir.alloc`` buffers become VMEM scratch.

Supported subset (covers the paper's benchmark gallery except GEMM): a
function whose top level is a sequence of phases — constant-bound loops and
straight-line memory ops — with one *main* ``for`` loop (the largest trip
count).  The GEMM systolic array is intentionally NOT emulated PE-by-PE: on
TPU the MXU *is* the systolic array, and its binding is the hand-scheduled
``repro.kernels.matmul`` (see DESIGN.md §3 "systolic GEMM").

``hir.delay`` lowers to identity: the *functional* semantics of a verified
schedule-correct design do not depend on the delays (that is the point of
the schedule verifier); the pipeline realisation is Pallas's.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import ir
from ..ir import ForOp, MemrefType, Module, Operation, Region, Value
from .common import (EFFECTFUL_OPS as _EFFECTFUL, jnp_arith_table,
                     pallas_dtype as _dtype, schedule_key as _schedule_key)

_ARITH = jnp_arith_table()
_PURE = set(_ARITH) | {"delay", "constant"}


class _KernelInterp:
    """Executes HIR effects in schedule order over Pallas Refs; pure values
    resolve lazily (recursively through arith/delay/constant defs)."""

    def __init__(self, module: Module, refs: dict[Value, Any],
                 env: dict[Value, Any] | None = None):
        self.module = module
        self.refs = refs                      # memref Value -> Ref
        self.env: dict[Value, Any] = dict(env or {})

    def value(self, v: Value):
        if v in self.env:
            return self.env[v]
        d = v.defining_op
        if d is None or d.opname not in _PURE:
            raise KeyError(f"%{v.name} unbound in pallas interp ({d})")
        if d.opname == "constant":
            out = d.attrs["value"]
        elif d.opname == "delay":
            out = self.value(d.operands[0])
        else:
            out = _ARITH[d.opname](*[self.value(x) for x in d.operands])
        self.env[v] = out
        return out

    def run_effects(self, ops: list[Operation]) -> None:
        for op in sorted((x for x in ops if x.opname in _EFFECTFUL),
                         key=_schedule_key):
            self.run_effect(op)

    def run_region(self, region: Region) -> None:
        self.run_effects(list(region.ops))

    def run_effect(self, op: Operation) -> None:
        o = op.opname
        if o == "mem_read":
            mem, idx = ir.mem_read_parts(op)
            ixs = tuple(self.value(i) for i in idx)
            self.env[op.result] = self.refs[mem][ixs]
            return
        if o == "mem_write":
            val, mem, idx, pred = ir.mem_write_parts(op)
            ref = self.refs[mem]
            ixs = tuple(self.value(i) for i in idx)
            x = jnp.asarray(self.value(val)).astype(ref.dtype)
            if pred is not None:
                old = ref[ixs]
                x = jnp.where(jnp.asarray(self.value(pred)) != 0, x, old)
            ref[ixs] = x
            return
        if o == "call":
            callee = self.module.funcs[op.attrs["callee"]]
            sub = _KernelInterp(self.module, self.refs)
            for formal, actual in zip(callee.args, op.operands):
                if isinstance(formal.type, MemrefType):
                    sub.refs[formal] = self.refs[actual]
                else:
                    sub.env[formal] = self.value(actual)
            sub.run_region(callee.body)
            for bop in callee.body.ops:
                if bop.opname == "return" and bop.operands:
                    for r, v in zip(op.results, bop.operands):
                        self.env[r] = sub.value(v)
            return
        if isinstance(op, ForOp):
            trip = op.trip_count()
            assert trip is not None, "to_pallas: nested loops need constant bounds"
            lb = ir.const_value(op.lb)
            step = ir.const_value(op.step)
            for t in range(trip):                 # fully unrolled in-kernel
                body = _KernelInterp(self.module, self.refs, self.env)
                body.env[op.iv] = lb + t * step
                body.run_region(op.region(0))
            return
        raise NotImplementedError(f"to_pallas: hir.{o}")


def lower_to_pallas(module: Module, func_name: str, *,
                    interpret: bool = True,
                    pipeline: Optional[str] = None,
                    allow_downcast: bool = False) -> Callable:
    """Lower ``@func_name`` to a callable mapping input arrays (one per
    read-port memref arg) to a dict of output arrays (write-port args).

    ``pipeline`` optionally names a ``PassManager`` spec run on ``module``
    (in place) before lowering, mirroring ``lower_to_jax``.

    Dtype policy (see ``lower.common.pallas_dtype``): ``f64`` memrefs raise
    ``TypeError`` unless ``allow_downcast=True`` (TPU VMEM compute is f32);
    ``f16`` maps to TPU-native ``bfloat16`` with a ``PrecisionWarning``."""
    if pipeline:
        from ..passmgr import PassManager

        PassManager.from_spec(pipeline).run(module)
    func = module.get(func_name)
    in_args = [a for a in func.args if isinstance(a.type, MemrefType)
               and a.type.port == ir.PORT_R]
    out_args = [a for a in func.args if isinstance(a.type, MemrefType)
                and a.type.port in (ir.PORT_W, ir.PORT_RW)]
    allocs = [op for op in func.body.ops if op.opname == "alloc"]

    # phase split: the main loop is the largest-trip top-level for
    top = [op for op in func.body.ops if op.opname in _EFFECTFUL]
    loops = [op for op in top if isinstance(op, ForOp)]
    assert loops, "to_pallas needs at least one top-level loop"
    main = max(loops, key=lambda l: l.trip_count() or 0)
    mi = top.index(main)
    prologue, epilogue = top[:mi], top[mi + 1:]

    trip = main.trip_count()
    assert trip is not None, "main loop needs constant bounds"
    lb = ir.const_value(main.lb)
    step = ir.const_value(main.step)

    def kernel(*refs):
        n_in, n_out = len(in_args), len(out_args)
        ref_of: dict[Value, Any] = {}
        for a, r in zip(in_args, refs[:n_in]):
            ref_of[a] = r
        for a, r in zip(out_args, refs[n_in:n_in + n_out]):
            ref_of[a] = r
        for al, r in zip(allocs, refs[n_in + n_out:]):
            for res in al.results:          # every port aliases one buffer
                ref_of[res] = r

        pid = pl.program_id(0)

        @pl.when(pid == 0)
        def _prologue():
            _KernelInterp(module, ref_of).run_effects(prologue)

        body = _KernelInterp(module, ref_of)
        body.env[main.iv] = lb + pid * step
        body.run_region(main.region(0))

        @pl.when(pid == trip - 1)
        def _epilogue():
            _KernelInterp(module, ref_of).run_effects(epilogue)

    out_shapes = [jax.ShapeDtypeStruct(a.type.shape,
                                       _dtype(a.type.elem, allow_downcast))
                  for a in out_args]
    scratch = [pltpu.VMEM(al.attrs["base"].shape,
                          _dtype(al.attrs["base"].elem, allow_downcast))
               for al in allocs]

    def _full_spec(shape):
        rank = len(shape)
        return pl.BlockSpec(shape, lambda i, r=rank: (0,) * r)

    def fn(*arrays):
        assert len(arrays) == len(in_args), (len(arrays), len(in_args))
        ins = [jnp.asarray(x).astype(_dtype(a.type.elem, allow_downcast))
               for x, a in zip(arrays, in_args)]
        outs = pl.pallas_call(
            kernel,
            grid=(trip,),
            in_specs=[_full_spec(a.type.shape) for a in in_args],
            out_specs=[_full_spec(a.type.shape) for a in out_args],
            out_shape=out_shapes,
            scratch_shapes=scratch,
            interpret=interpret,
        )(*ins)
        if not isinstance(outs, (list, tuple)):
            outs = [outs]
        return {a.name: o for a, o in zip(out_args, outs)}

    return fn
