"""Functional (algorithm-component) lowering of HIR to JAX.

The paper decomposes a hardware design into *algorithm*, *schedule* and
*binding* (§4).  This lowering extracts the algorithm component: an HIR
function becomes a pure JAX function over its memref arguments —
``hir.for`` -> ``lax.fori_loop``, ``hir.unroll_for`` -> unrolled trace,
memrefs -> functionally-updated ``jnp`` arrays, ``hir.delay`` -> identity.

It is the cross-check that a *schedule* never changes *functionality*: for
every gallery kernel, ``simulate(...)`` (cycle-accurate) and
``lower_to_jax(...)`` (schedule-free) must agree — a strong property test of
the whole IR stack.  It is also the bridge into the training framework: an
HIR kernel is directly usable inside jitted JAX programs.

Memory-effect ordering: effectful ops execute in schedule order within each
region (reads before writes on ties), iterations in index order.  This agrees
with the cycle-accurate semantics whenever cross-iteration memory dependences
flow forward in time — true for all verified race-free designs in the gallery;
the simulator remains the authority on cycle semantics.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

from .. import ir
from ..ir import ForOp, FuncOp, MemrefType, Module, Operation, Region, Value
from .common import jnp_arith_table as _jax_arith
from .common import np_dtype as _np_dtype
from .common import schedule_key as _schedule_key  # noqa: F401  (re-export)


class _Thunk:
    __slots__ = ("fn", "_val", "_done")

    def __init__(self, fn: Callable[[], Any]):
        self.fn = fn
        self._done = False
        self._val = None

    def force(self) -> Any:
        if not self._done:
            self._val = self.fn()
            self._done = True
        return self._val


class _Env:
    def __init__(self, parent: Optional["_Env"] = None):
        self.vals: dict[Value, Any] = {}
        self.parent = parent

    def get(self, v: Value) -> Any:
        e: Optional[_Env] = self
        while e is not None:
            if v in e.vals:
                return e.vals[v]
            e = e.parent
        raise KeyError(f"%{v.name}")

    def set(self, v: Value, x: Any) -> None:
        self.vals[v] = x


from .common import EFFECTFUL_OPS as _EFFECTFUL  # noqa: E402


class _Lowerer:
    def __init__(self, module: Module):
        self.module = module
        self.arith = _jax_arith()

    # -- public ---------------------------------------------------------
    def lower(self, func: FuncOp) -> Callable:
        import jax.numpy as jnp

        def fn(*args):
            assert len(args) == len(func.args), (len(args), len(func.args))
            env = _Env()
            store: dict[str, Any] = {}
            storage_of: dict[Value, str] = {}
            for a, x in zip(func.args, args):
                if isinstance(a.type, MemrefType):
                    key = f"arg_{a.name}"
                    store[key] = jnp.asarray(x)
                    storage_of[a] = key
                else:
                    env.set(a, x)
            store = self._run_region(func.body, env, store, storage_of)
            return {
                a.name: store[f"arg_{a.name}"]
                for a in func.args
                if isinstance(a.type, MemrefType) and a.type.port in (ir.PORT_W, ir.PORT_RW)
            }

        return fn

    # -- helpers ------------------------------------------------------------
    def _val(self, env: _Env, v: Value) -> Any:
        x = env.get(v)
        return x.force() if isinstance(x, _Thunk) else x

    def _register_pure(self, ops, env: _Env, storage_of: dict[Value, str]) -> None:
        for op in ops:
            o = op.opname
            if o == "constant":
                env.set(op.result, op.attrs["value"])
            elif o == "alloc":
                key = f"alloc_{op.results[0].id}"
                for r in op.results:
                    storage_of[r] = key
                op.attrs["_store_key"] = key
            elif o in ir.ARITH_OPS:
                env.set(op.result, _Thunk(lambda op=op, env=env: self.arith[op.opname](
                    *[self._val(env, v) for v in op.operands])))
            elif o == "delay":
                env.set(op.result, _Thunk(lambda op=op, env=env: self._val(env, op.operands[0])))

    def _run_region(self, region: Region, env: _Env, store: dict, storage_of: dict[Value, str]) -> dict:
        import jax.numpy as jnp

        self._register_pure(region.ops, env, storage_of)
        # allocs create storage immediately
        for op in region.ops:
            if op.opname == "alloc":
                base: MemrefType = op.attrs["base"]
                store = dict(store)
                store[op.attrs["_store_key"]] = jnp.zeros(base.shape, _np_dtype(base.elem))
        for op in sorted([o for o in region.ops if o.opname in _EFFECTFUL], key=_schedule_key):
            store = self._run_effect(op, env, store, storage_of)
        return store

    def _run_effect(self, op: Operation, env: _Env, store: dict, storage_of: dict[Value, str]) -> dict:
        import jax.numpy as jnp

        o = op.opname
        if o == "mem_read":
            key = storage_of[op.operands[0]]
            idx = tuple(self._val(env, v) for v in op.operands[1:])
            env.set(op.result, store[key][idx])
            return store

        if o == "mem_write":
            value_v, mem_v, idx_vs, pred_v = ir.mem_write_parts(op)
            key = storage_of[mem_v]
            idx = tuple(self._val(env, v) for v in idx_vs)
            val = self._val(env, value_v)
            store = dict(store)
            arr = store[key]
            new = jnp.asarray(val).astype(arr.dtype)
            if pred_v is not None:
                p = self._val(env, pred_v)
                new = jnp.where(jnp.asarray(p) != 0, new, arr[idx])
            store[key] = arr.at[idx].set(new)
            return store

        if o == "call":
            callee = self.module.funcs.get(op.attrs["callee"])
            if callee is None or callee.attrs.get("external"):
                raise NotImplementedError(
                    f"functional lowering of external @{op.attrs['callee']} needs a JAX model"
                )
            sub = _Env()
            sub_storage: dict[Value, str] = {}
            for formal, actual in zip(callee.args, op.operands):
                if isinstance(formal.type, MemrefType):
                    sub_storage[formal] = storage_of[actual]
                else:
                    sub.set(formal, self._val(env, actual))
            store = self._run_region(callee.body, sub, store, sub_storage)
            for bop in callee.body.ops:
                if bop.opname == "return" and bop.operands:
                    for r, v in zip(op.results, bop.operands):
                        env.set(r, self._val(sub, v))
            return store

        if isinstance(op, ForOp):
            return self._run_loop(op, env, store, storage_of)

        raise NotImplementedError(f"to_jax: op hir.{o}")  # pragma: no cover

    def _run_loop(self, op: ForOp, env: _Env, store: dict, storage_of: dict[Value, str]) -> dict:
        import jax
        import jax.numpy as jnp

        lbv = self._val(env, op.lb)
        ubv = self._val(env, op.ub)
        stepv = self._val(env, op.step)

        def run_body(it_env: _Env, st: dict) -> dict:
            self._register_pure(op.region(0).ops, it_env, storage_of)
            for inner in op.region(0).ops:
                if inner.opname == "alloc":
                    base: MemrefType = inner.attrs["base"]
                    st = dict(st)
                    st[inner.attrs["_store_key"]] = jnp.zeros(base.shape, _np_dtype(base.elem))
            for inner in sorted([x for x in op.region(0).ops if x.opname in _EFFECTFUL], key=_schedule_key):
                st = self._run_effect(inner, it_env, st, storage_of)
            return st

        if op.opname == "unroll_for":
            assert all(isinstance(x, int) for x in (lbv, ubv, stepv)), "unroll_for needs const bounds"
            for ivv in range(lbv, ubv, stepv):
                it = _Env(env)
                it.set(op.iv, ivv)
                store = run_body(it, store)
            return store

        keys = sorted(store.keys())
        const_bounds = all(isinstance(x, int) for x in (lbv, ubv, stepv))

        def body(k, carry):
            st = dict(zip(keys, carry))
            it = _Env(env)
            it.set(op.iv, jnp.asarray(lbv + k * stepv, jnp.int32))
            st = run_body(it, st)
            return tuple(st[x] for x in keys)

        trip = (ubv - lbv + stepv - 1) // stepv
        carry = jax.lax.fori_loop(0, trip, body, tuple(store[x] for x in keys))
        return dict(zip(keys, carry))


def lower_to_jax(module: Module, func_name: str,
                 pipeline: Optional[str] = None) -> Callable:
    """Lower ``@func_name`` to a pure JAX function: arrays in (one per memref
    arg, scalars for primitives), dict of final writable-memref arrays out.

    ``pipeline`` optionally names a ``PassManager`` spec (e.g.
    ``"canonicalize,cse,dce"``) run on ``module`` (in place) before lowering —
    the declarative way to pre-optimize the IR the trace is built from."""
    if pipeline:
        from ..passmgr import PassManager

        PassManager.from_spec(pipeline).run(module)
    return _Lowerer(module).lower(module.get(func_name))
