"""Recursive-descent parser for the HIR textual form emitted by
``core.printer`` — gives the dialect the MLIR property of a round-trippable
representation (paper §4).  Grammar mirrors the printer exactly.
"""

from __future__ import annotations

import re
from typing import Optional, Union

from . import ir
from .ir import (
    CONST,
    TIME,
    FloatType,
    FuncOp,
    IntType,
    MemrefType,
    Module,
    Operation,
    Time,
    Type,
    Value,
)

_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+|//[^\n]*)
    | (?P<memref>!hir\.memref<[^>]*>)
    | (?P<const_t>!hir\.const|!hir\.time)
    | (?P<num>-?\d+\.\d+|-?\d+)
    | (?P<sym>@[A-Za-z_][\w.]*)
    | (?P<val>%[A-Za-z_][\w.]*|%\d[\w.]*)
    | (?P<kw>[A-Za-z_][\w.]*)
    | (?P<punct>->|[{}()\[\],:=<>*])
    """,
    re.VERBOSE,
)


class ParseError(Exception):
    pass


class _Lexer:
    def __init__(self, text: str):
        self.toks: list[tuple[str, str]] = []
        pos = 0
        while pos < len(text):
            m = _TOKEN_RE.match(text, pos)
            if not m:
                raise ParseError(f"lex error at: {text[pos:pos+40]!r}")
            pos = m.end()
            kind = m.lastgroup
            if kind == "ws":
                continue
            self.toks.append((kind, m.group()))
        self.i = 0

    def peek(self, k: int = 0) -> tuple[str, str]:
        if self.i + k >= len(self.toks):
            return ("eof", "")
        return self.toks[self.i + k]

    def next(self) -> tuple[str, str]:
        t = self.peek()
        self.i += 1
        return t

    def expect(self, text: str) -> str:
        kind, tok = self.next()
        if tok != text:
            raise ParseError(f"expected {text!r}, got {tok!r} (context: {self._ctx()})")
        return tok

    def accept(self, text: str) -> bool:
        if self.peek()[1] == text:
            self.next()
            return True
        return False

    def _ctx(self) -> str:
        return " ".join(t for _, t in self.toks[max(0, self.i - 5): self.i + 5])


def _parse_type(tok: str) -> Type:
    if tok == "!hir.const":
        return CONST
    if tok == "!hir.time":
        return TIME
    if tok.startswith("!hir.memref<"):
        inner = tok[len("!hir.memref<"):-1]
        parts = [p.strip() for p in inner.split(",")]
        dims_elem = parts[0].split("*")
        elem = _parse_type(dims_elem[-1])
        shape = [int(d) for d in dims_elem[:-1]]
        port = ir.PORT_RW
        packed = None
        kind = ir.KIND_BRAM
        for p in parts[1:]:
            if p in (ir.PORT_R, ir.PORT_W, ir.PORT_RW):
                port = p
            elif p.startswith("packing=["):
                body = p[len("packing=["):-1]
                packed = [int(x) for x in body.split(",") if x.strip() != ""]
            elif p.startswith("kind="):
                kind = p[len("kind="):]
        return MemrefType(shape, elem, port, packed, kind)
    m = re.fullmatch(r"([iuf])(\d+)", tok)
    if m:
        k, w = m.group(1), int(m.group(2))
        if k == "f":
            return FloatType(w)
        return IntType(w, signed=(k == "i"))
    raise ParseError(f"unknown type {tok!r}")


class Parser:
    def __init__(self, text: str):
        self.lx = _Lexer(text)
        self.scope: dict[str, Value] = {}

    # ---------------------------------------------------------------
    def _val(self, name: str) -> Value:
        if name not in self.scope:
            raise ParseError(f"use of undefined value %{name}")
        return self.scope[name]

    def _def(self, name: str, v: Value) -> Value:
        v.name = name
        self.scope[name] = v
        return v

    def _parse_value_ref(self) -> Value:
        kind, tok = self.lx.next()
        if kind != "val":
            raise ParseError(f"expected value ref, got {tok!r}")
        return self._val(tok[1:])

    def _parse_time_suffix(self) -> Optional[Time]:
        """Parse optional ``at %t [offset k]``."""
        if self.lx.peek()[1] != "at":
            return None
        self.lx.expect("at")
        tv = self._parse_value_ref()
        off = 0
        if self.lx.accept("offset"):
            off = int(self.lx.next()[1])
        return Time(tv, off)

    def _parse_type_tok(self) -> Type:
        kind, tok = self.lx.next()
        if kind not in ("memref", "const_t", "kw"):
            raise ParseError(f"expected type, got {tok!r}")
        return _parse_type(tok)

    # ---------------------------------------------------------------
    def parse_module(self) -> Module:
        self.lx.expect("hir.module")
        name = self.lx.next()[1][1:]
        mod = Module(name)
        self.lx.expect("{")
        while self.lx.peek()[1] == "hir.func":
            self.scope = {}
            mod.add(self.parse_func())
        self.lx.expect("}")
        return mod

    def parse_func(self) -> FuncOp:
        self.lx.expect("hir.func")
        external = self.lx.accept("external")
        fname = self.lx.next()[1][1:]
        self.lx.expect("at")
        tname = self.lx.next()[1][1:]
        self.lx.expect("(")
        arg_names, arg_types, arg_delays = [], [], []
        while not self.lx.accept(")"):
            an = self.lx.next()[1][1:]
            self.lx.expect(":")
            at = self._parse_type_tok()
            d = 0
            if self.lx.accept("delay"):
                d = int(self.lx.next()[1])
            arg_names.append(an)
            arg_types.append(at)
            arg_delays.append(d)
            self.lx.accept(",")
        result_types, result_delays = [], []
        if self.lx.accept("->"):
            self.lx.expect("(")
            while not self.lx.accept(")"):
                result_types.append(self._parse_type_tok())
                self.lx.expect("delay")
                result_delays.append(int(self.lx.next()[1]))
                self.lx.accept(",")
        f = FuncOp(fname, arg_types, arg_names, arg_delays, result_types, result_delays)
        if external:
            f.attrs["external"] = True
            return f
        f.time_var.name = tname
        for a in f.args:
            self.scope[a.name] = a
        self.scope[tname] = f.time_var
        self.lx.expect("{")
        while not self.lx.accept("}"):
            f.body.add(self.parse_op())
        return f

    # ---------------------------------------------------------------
    def parse_op(self) -> Operation:
        # optional results
        result_names: list[str] = []
        save = self.lx.i
        while self.lx.peek()[0] == "val":
            result_names.append(self.lx.next()[1][1:])
            if not self.lx.accept(","):
                break
        if result_names:
            if not self.lx.accept("="):
                self.lx.i = save
                result_names = []
        kind, opname = self.lx.next()
        if not opname.startswith("hir."):
            raise ParseError(f"expected op name, got {opname!r}")
        o = opname[4:]
        return self._parse_op_body(o, result_names)

    def _parse_op_body(self, o: str, rnames: list[str]) -> Operation:
        lx = self.lx
        if o == "constant":
            v = lx.next()[1]
            val: Union[int, float] = float(v) if "." in v else int(v)
            lx.expect(":")
            t = self._parse_type_tok()
            op = ir.constant(val, t)
            self._def(rnames[0], op.result)
            return op

        if o == "alloc":
            lx.expect("(")
            lx.expect(")")
            lx.expect(":")
            types: list[MemrefType] = []
            while True:
                types.append(self._parse_type_tok())  # type: ignore[arg-type]
                if not lx.accept(","):
                    break
            base = types[0].with_port(ir.PORT_RW)
            op = ir.alloc(base, [t.port for t in types])
            for nm, r in zip(rnames, op.results):
                self._def(nm, r)
            return op

        if o == "mem_read":
            mem = self._parse_value_ref()
            lx.expect("[")
            idx = []
            while not lx.accept("]"):
                idx.append(self._parse_value_ref())
                lx.accept(",")
            t = self._parse_time_suffix()
            lx.expect(":")
            self._parse_type_tok()
            op = ir.mem_read(mem, idx, t)
            self._def(rnames[0], op.result)
            return op

        if o == "mem_write":
            val = self._parse_value_ref()
            lx.expect("to")
            mem = self._parse_value_ref()
            lx.expect("[")
            idx = []
            while not lx.accept("]"):
                idx.append(self._parse_value_ref())
                lx.accept(",")
            pred = None
            if lx.accept("if"):
                pred = self._parse_value_ref()
            t = self._parse_time_suffix()
            return ir.mem_write(val, mem, idx, t, pred=pred)

        if o == "delay":
            v = self._parse_value_ref()
            lx.expect("by")
            by = int(lx.next()[1])
            t = self._parse_time_suffix()
            lx.expect(":")
            self._parse_type_tok()
            op = ir.delay(v, by, t)
            self._def(rnames[0], op.result)
            return op

        if o == "time":
            tv = self._parse_value_ref()
            off = 0
            if lx.accept("offset"):
                off = int(lx.next()[1])
            op = ir.time_offset(Time(tv, off))
            self._def(rnames[0], op.result)
            return op

        if o in ("for", "unroll_for"):
            ivn = lx.next()[1][1:]
            lx.expect(":")
            ivt = self._parse_type_tok()
            lx.expect("=")
            lb = self._parse_value_ref()
            lx.expect("to")
            ub = self._parse_value_ref()
            lx.expect("step")
            step = self._parse_value_ref()
            lx.expect("iter_time")
            lx.expect("(")
            tvn = lx.next()[1][1:]
            if lx.accept("unscheduled"):  # erased IR: loop has no start yet
                start = None
            else:
                lx.expect("=")
                base_tv = self._parse_value_ref()
                lx.expect("offset")
                off = int(lx.next()[1])
                start = Time(base_tv, off)
            lx.expect(")")
            op = ir.ForOp(lb, ub, step, start=start, iv_type=ivt, unroll=(o == "unroll_for"),
                          iv_name=ivn, tv_name=tvn)
            self._def(ivn, op.iv)
            self._def(tvn, op.time_var)
            if rnames:
                self._def(rnames[0], op.end_time)
            lx.expect("{")
            while not lx.accept("}"):
                op.region(0).add(self.parse_op())
            return op

        if o == "yield":
            t = self._parse_time_suffix()
            return ir.yield_op(t)

        if o == "return":
            vals = []
            while self.lx.peek()[0] == "val":
                vals.append(self._parse_value_ref())
                lx.accept(",")
            return ir.return_op(vals)

        if o == "call":
            callee = lx.next()[1][1:]
            lx.expect("(")
            args = []
            while not lx.accept(")"):
                args.append(self._parse_value_ref())
                lx.accept(",")
            t = self._parse_time_suffix()
            rtypes, rdelays = [], []
            if lx.accept(":"):
                lx.expect("(")
                while not lx.accept(")"):
                    rtypes.append(self._parse_type_tok())
                    lx.expect("delay")
                    rdelays.append(int(lx.next()[1]))
                    lx.accept(",")
            op = ir.call(callee, args, t, rtypes, rdelays)
            for nm, r in zip(rnames, op.results):
                self._def(nm, r)
            return op

        if o in ir.ARITH_OPS:
            lx.expect("(")
            args = []
            while not lx.accept(")"):
                args.append(self._parse_value_ref())
                lx.accept(",")
            stages = 0
            if lx.accept("stages"):
                stages = int(lx.next()[1])
            t = self._parse_time_suffix()
            lx.expect(":")
            rt = self._parse_type_tok()
            op = ir.arith(o, args, start=t, result_type=rt, stages=stages)
            self._def(rnames[0], op.result)
            return op

        raise ParseError(f"unknown op hir.{o}")


def parse(text: str) -> Module:
    return Parser(text).parse_module()


def parse_func(text: str) -> FuncOp:
    p = Parser(text)
    return p.parse_func()
