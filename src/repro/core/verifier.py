"""Schedule verification (paper §6.1).

The verifier exploits HIR's two sources of static information — the explicit
schedule of every operation and the validity time of every SSA value — to
detect, at compile time, bugs that an HDL cannot express and an HLS compiler
hides inside its scheduler:

  * *mismatched delay* — an operation consumes a value in a cycle where it is
    not valid (paper Fig. 1: a pipelined loop's induction variable used one
    cycle too late; paper Fig. 2: pipeline imbalance after a retiming).
  * *port conflicts* — two accesses on the same memref port that can occur in
    the same cycle at (potentially) different addresses; with pipelining this
    includes congruence-class overlap (offset mod II).
  * structural errors — unscheduled ops, yields missing, time variables used
    outside their lexical scope, distributed dims indexed dynamically.

Diagnostics carry source locations and a "prior definition here" note, in the
style of the paper's Figure 1b/2b listings.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import ir
from .analysis import (LoopAnalysis, LoopInfo, PortAccessAnalysis,
                       analyze_loops, collect_port_accesses,
                       span_completion_offset)
from .ir import CONST, ForOp, FuncOp, Module, Operation, Region, Time, Value
from .passmgr import AnalysisManager


@dataclass
class Diagnostic:
    severity: str  # "error" | "warning"
    loc: ir.Loc
    message: str
    notes: list[tuple[ir.Loc, str]] = field(default_factory=list)

    def render(self) -> str:
        out = f"{self.loc}: {self.severity}:\n{self.message}"
        for loc, msg in self.notes:
            out += f"\n{loc}: note: {msg}"
        return out


class VerifyError(Exception):
    def __init__(self, diags: list[Diagnostic]):
        self.diags = diags
        super().__init__("\n\n".join(d.render() for d in diags))


OPERAND_DESC = {0: "left operand", 1: "right operand", 2: "third operand"}


class Verifier:
    def __init__(self, func: FuncOp, strict_schedule: bool = True,
                 am: Optional[AnalysisManager] = None):
        self.func = func
        self.strict = strict_schedule
        self.am = am  # shared analysis cache (loop info, port accesses)
        self.diags: list[Diagnostic] = []
        self.loops: dict[ForOp, LoopInfo] = {}
        # validity windows: value -> (root tv, birth offset, window len | None=inf)
        self.windows: dict[Value, Optional[tuple[Value, int, Optional[int]]]] = {}

    # ------------------------------------------------------------------
    def error(self, loc: ir.Loc, msg: str, notes: Optional[list[tuple[ir.Loc, str]]] = None) -> None:
        self.diags.append(Diagnostic("error", loc, msg, notes or []))

    def warn(self, loc: ir.Loc, msg: str, notes: Optional[list[tuple[ir.Loc, str]]] = None) -> None:
        self.diags.append(Diagnostic("warning", loc, msg, notes or []))

    # ------------------------------------------------------------------
    def run(self) -> list[Diagnostic]:
        self.loops = (self.am.get(LoopAnalysis, self.func) if self.am is not None
                      else analyze_loops(self.func))
        self._iv_loop = {l.iv: li for l, li in self.loops.items()}
        self._build_root_tree()
        self._compute_windows()
        self._verify_region(self.func.body, scope_tvs={self.func.time_var})
        self._verify_ports()
        return self.diags

    # -- time-variable hierarchy -------------------------------------------
    def _build_root_tree(self) -> None:
        """parent link + minimum start offset for every time variable, so that
        always-valid values (infinite windows) can be consumed inside
        descendant scopes (e.g. a sequential loop's IV used in a nested
        pipelined loop, as in the paper's transpose listing)."""
        self.root_parent: dict[Value, tuple[Value, int]] = {}
        for op in self.func.body.walk():
            if isinstance(op, ForOp) and op.start is not None:
                self.root_parent[op.time_var] = (op.start.tv, op.start.offset)
                self.root_parent[op.end_time] = (op.start.tv, op.start.offset)
            elif op.opname == "time":
                self.root_parent[op.result] = (op.operands[0], op.attrs.get("offset", 0))

    def _min_abs_offset(self, tv: Value, ancestor: Value) -> Optional[int]:
        """Lower bound on (tv's instant - ancestor's instant); None if tv is
        not a descendant of ancestor."""
        off = 0
        cur = tv
        for _ in range(1000):
            if cur is ancestor:
                return off
            nxt = self.root_parent.get(cur)
            if nxt is None:
                return None
            cur, step = nxt[0], nxt[1]
            off += step
        return None  # pragma: no cover

    # -- validity windows ------------------------------------------------
    def _compute_windows(self) -> None:
        w = self.windows
        # function arguments
        for a, d in zip(self.func.args, self.func.attrs["arg_delays"]):
            if ir.is_primitive(a.type):
                w[a] = (self.func.time_var, d, 1)
            else:
                w[a] = None  # memrefs: always valid
        for op in self.func.body.walk():
            if op.opname == "constant":
                w[op.result] = None
            elif op.opname == "alloc":
                for r in op.results:
                    w[r] = None
            elif op.opname == "time":
                w[op.result] = None
            elif isinstance(op, ForOp):
                li = self.loops[op]
                if op.opname == "unroll_for":
                    # unroll IVs are compile-time constants: always valid
                    w[op.iv] = None
                elif li.ii is not None and op.yield_op() is not None and \
                        op.yield_op().start is not None and op.yield_op().start.tv is op.time_var:
                    # pipelined loop: IV regenerated every II cycles
                    w[op.iv] = (op.time_var, 0, max(1, li.ii))
                else:
                    # sequential loop: IV persists across the whole iteration
                    w[op.iv] = (op.time_var, 0, None)
                w[op.time_var] = None
                w[op.end_time] = None
            elif op.opname == "mem_read":
                lat = op.operands[0].type.read_latency()
                if op.start is not None:
                    w[op.result] = (op.start.tv, op.start.offset + lat, 1)
            elif op.opname == "delay":
                src = w.get(op.operands[0])
                if src is not None:
                    tv, off, ln = src
                    w[op.result] = (tv, off + op.attrs["by"], ln)
                elif op.start is not None:
                    w[op.result] = (op.start.tv, op.start.offset + op.attrs["by"], 1)
                else:
                    w[op.result] = None
            elif op.opname == "call":
                if op.start is not None:
                    for r, d in zip(op.results, op.attrs["result_delays"]):
                        w[r] = (op.start.tv, op.start.offset + d, 1)
            elif op.opname in ir.ARITH_OPS:
                stages = op.attrs.get("stages", 0)
                if op.start is not None:
                    w[op.result] = (op.start.tv, op.start.offset + stages, 1)
                else:
                    # Combinational op without explicit schedule: its result is
                    # valid on the *intersection* of the operand windows.  An
                    # empty intersection is the paper's Fig. 2 pipeline
                    # imbalance (reported in _verify_op).
                    w[op.result] = self._intersect_windows(op, stages)

    def _intersect_windows(self, op: Operation, stages: int):
        wins = [self.windows.get(v) for v in op.operands]
        wins = [x for x in wins if x is not None]
        if not wins:
            return None  # all operands always-valid => result always-valid
        # pick the deepest root; ancestors with infinite windows impose no
        # constraint (they are valid throughout the descendant scope).
        deepest = wins[0][0]
        for tv, _, _ in wins[1:]:
            if tv is deepest:
                continue
            if self._min_abs_offset(tv, deepest) is not None:
                deepest = tv
        lo, hi = 0, None
        ok = True
        for tv, off, ln in wins:
            if tv is deepest:
                lo = max(lo, off)
                if ln is not None:
                    hi = off + ln if hi is None else min(hi, off + ln)
            elif ln is None and self._min_abs_offset(deepest, tv) is not None:
                continue  # infinite-window ancestor value
            else:
                ok = False  # cross-root finite window: flagged at use sites
        if not ok:
            return wins[0]
        if hi is not None and hi <= lo:
            return (deepest, lo, 0)  # empty window -> imbalance
        if stages:
            return (deepest, lo + stages, 1)
        return (deepest, lo, None if hi is None else hi - lo)

    # -- per-op checks -----------------------------------------------------
    def _check_use(self, op: Operation, v: Value, use_time: Time, desc: str) -> None:
        win = self.windows.get(v, None)
        if win is None:
            return  # always-valid (const, memref, time)
        tv, off, ln = win
        if tv is not use_time.tv:
            if ln is None:
                # persistent value (e.g. sequential-loop IV): legal inside any
                # descendant scope that starts no earlier than its birth.
                d = self._min_abs_offset(use_time.tv, tv)
                if d is not None and d + use_time.offset >= off:
                    return
            else:
                # sequential loop IV (II >= body span, HLS-style yield on the
                # loop's own time variable): iterations never overlap and
                # every nested scope completes within the iteration window,
                # so descendant-scope uses after the birth are safe.  Only
                # sound when the span actually bounds the whole body — a
                # nested scope whose latency is not statically derivable is
                # silently absent from body_span and may outlive the window.
                li = self._iv_loop.get(v)
                if li is not None and li.ii is not None and li.ii >= li.body_span \
                        and self._body_statically_bounded(li.op):
                    d = self._min_abs_offset(use_time.tv, tv)
                    if d is not None and d + use_time.offset >= off:
                        return
            self.error(
                op.loc,
                f"Schedule error: operand {desc} is defined under time variable "
                f"%{tv.name} but used under %{use_time.tv.name}; insert hir.delay "
                f"or reschedule.",
                notes=self._def_note(v),
            )
            return
        u = use_time.offset
        end = None if ln is None else off + ln
        if u < off or (end is not None and u >= end):
            self.error(
                op.loc,
                f"Schedule error: mismatched delay ({off} vs {u}) in {desc}!",
                notes=self._def_note(v),
            )

    def _body_statically_bounded(self, loop: ForOp) -> bool:
        """True iff every scheduled child of ``loop``'s body has a completion
        offset that ``analyze_loops`` could derive (and therefore included in
        ``body_span``) — the precondition for treating II >= span as "the
        iteration window contains everything"."""
        cached = getattr(self, "_bounded_cache", None)
        if cached is None:
            cached = self._bounded_cache = {}
        if loop in cached:
            return cached[loop]
        root = loop.time_var
        ok = True
        for op in loop.region(0).ops:
            if op.opname in ("constant", "alloc", "time", "return"):
                continue
            if op.start is None and not isinstance(op, ForOp):
                continue  # unscheduled comb op: anchored via its consumers
            if span_completion_offset(op, root, self.loops) is None:
                ok = False
                break
        cached[loop] = ok
        return ok

    def _def_note(self, v: Value) -> list[tuple[ir.Loc, str]]:
        d = v.defining_op
        if d is not None:
            return [(d.loc, "Prior definition here.")]
        if v in self.func.args:
            return [(self.func.loc, "Function argument defined here.")]
        # loop induction variable / time var
        for op in self.func.body.walk():
            if isinstance(op, ForOp) and (v is op.iv or v is op.time_var):
                return [(op.loc, "Prior definition here.")]
        return []

    def _verify_region(self, region: Region, scope_tvs: set[Value]) -> None:
        seen_yield = False
        parent = region.parent_op
        for op in region.ops:
            # scheduling root must be lexically visible (paper §4.2: ops in a
            # loop body only see the iteration time variable).
            if op.start is not None and op.start.tv not in scope_tvs:
                self.error(
                    op.loc,
                    f"Schedule error: time variable %{op.start.tv.name} is not "
                    f"visible in this scope.",
                )
            if op.start is None and self.strict and op.opname not in (
                "constant", "alloc", "return", "time",
            ) and op.opname not in ir.ARITH_OPS:
                self.error(op.loc, f"unscheduled operation hir.{op.opname} in strict mode")

            self._verify_op(op, scope_tvs)

            if op.opname == "yield":
                seen_yield = True
            # derived time variables become visible after their defining op
            if op.opname == "time":
                scope_tvs = scope_tvs | {op.result}
            if isinstance(op, ForOp):
                scope_tvs = scope_tvs | {op.end_time}
                self._verify_region(op.region(0), {op.time_var})

        if parent is not None and isinstance(parent, ForOp) and not seen_yield:
            self.error(parent.loc, "hir.for body must contain hir.yield")

    def _verify_op(self, op: Operation, scope_tvs: set[Value]) -> None:
        o = op.opname
        if o in ir.ARITH_OPS and op.start is not None:
            for i, v in enumerate(op.operands):
                self._check_use(op, v, op.start, OPERAND_DESC.get(i, f"operand {i}"))
        elif o in ir.ARITH_OPS and op.start is None:
            # empty validity intersection => mismatched operand births (Fig. 2)
            win = self.windows.get(op.result)
            if win is not None and win[2] == 0:
                births = [(v, self.windows.get(v)) for v in op.operands]
                births = [(v, b) for v, b in births if b is not None and b[0] is win[0]]
                offs = sorted(b[1][1] for b in births)
                worst = max(births, key=lambda b: b[1][1])[0]
                self.error(
                    op.loc,
                    f"Schedule error: mismatched delay ({offs[0]} vs {offs[-1]}) in right operand!",
                    notes=self._def_note(worst),
                )
        elif o == "mem_read":
            mem, idx = ir.mem_read_parts(op)
            self._check_indices(op, mem, idx)
        elif o == "mem_write":
            val, mem, idx, pred = ir.mem_write_parts(op)
            if op.start is not None:
                self._check_use(op, val, op.start, "written value")
                if pred is not None:
                    self._check_use(op, pred, op.start, "write predicate")
            self._check_indices(op, mem, idx)
        elif o == "alloc":
            if op.parent_region is not self.func.body:
                self.error(op.loc, "hir.alloc must be at function scope (hardware is statically instantiated)")
        elif o == "delay":
            pass  # delay is precisely the op that legalises cross-cycle moves
        elif o == "call":
            if op.start is not None:
                for i, v in enumerate(op.operands):
                    self._check_use(op, v, op.start, f"argument {i}")
        elif isinstance(op, ForOp):
            for i, v in enumerate((op.lb, op.ub, op.step)):
                if op.start is not None and self.windows.get(v) is not None:
                    self._check_use(op, v, op.start, ("lower bound", "upper bound", "step")[i])
            if op.opname == "unroll_for" and op.trip_count() is None:
                self.error(op.loc, "hir.unroll_for requires compile-time constant bounds")

    def _check_indices(self, op: Operation, mem: Value, idx: list[Value]) -> None:
        mt = mem.type
        if not isinstance(mt, ir.MemrefType):
            self.error(op.loc, f"memory access on non-memref value %{mem.name}")
            return
        for pos, v in enumerate(idx):
            if pos in mt.distributed and not isinstance(v.type, ir.ConstType):
                self.error(
                    op.loc,
                    f"Schedule error: distributed dimension {pos} of %{mem.name} "
                    f"must be indexed by a compile-time constant (!hir.const).",
                    notes=self._def_note(v),
                )
            if op.start is not None:
                self._check_use(op, v, op.start, f"address {pos}")

    # -- memory port conflicts ------------------------------------------------
    def _verify_ports(self) -> None:
        accesses = (self.am.get(PortAccessAnalysis, self.func) if self.am is not None
                    else collect_port_accesses(self.func, self.loops))
        for port, accs in accesses.items():
            for i in range(len(accs)):
                for j in range(i + 1, len(accs)):
                    a, b = accs[i], accs[j]
                    if a.root is not b.root:
                        continue  # cross-root overlap: runtime assertion territory
                    conflict = False
                    if a.offsets_mod and b.offsets_mod and a.offsets_mod[1] == b.offsets_mod[1]:
                        conflict = a.offsets_mod[0] == b.offsets_mod[0]
                    elif a.offset is not None and b.offset is not None and not (a.offsets_mod or b.offsets_mod):
                        conflict = a.offset == b.offset
                    if not conflict:
                        continue
                    if self._same_addresses(a.op, b.op):
                        continue
                    # distinct distributed-dim constants => different banks
                    if self._distinct_banks(a.op, b.op):
                        continue
                    self.error(
                        b.op.loc,
                        f"Schedule error: two accesses on memref port %{port.name} "
                        f"in the same cycle with different addresses (UB §4.5).",
                        notes=[(a.op.loc, "Conflicting access here.")],
                    )

    @staticmethod
    def _indices(op: Operation) -> list[Value]:
        return ir.mem_op_indices(op)

    def _same_addresses(self, a: Operation, b: Operation) -> bool:
        ia, ib = self._indices(a), self._indices(b)
        return all(x is y or (ir.const_value(x) is not None and ir.const_value(x) == ir.const_value(y))
                   for x, y in zip(ia, ib))

    def _distinct_banks(self, a: Operation, b: Operation) -> bool:
        mem = a.operands[0] if a.opname == "mem_read" else a.operands[1]
        mt: ir.MemrefType = mem.type  # type: ignore[assignment]
        ia, ib = self._indices(a), self._indices(b)
        for pos in mt.distributed:
            ca, cb = ir.const_value(ia[pos]), ir.const_value(ib[pos])
            if ca is not None and cb is not None and ca != cb:
                return True
        return False


def verify_func(func: FuncOp, strict_schedule: bool = True,
                am: Optional[AnalysisManager] = None) -> list[Diagnostic]:
    return Verifier(func, strict_schedule, am=am).run()


def validity_windows(func: FuncOp, am: Optional[AnalysisManager] = None) -> Verifier:
    """Compute only the value-validity windows (loop analysis + time-variable
    root tree + window propagation) without running the quadratic op/port
    legality checks.  Linear in the function size; this is what pipeline
    balancing (``core.schedule.balance_delays``) iterates on, where the full
    ``Verifier.run`` would dominate the whole HLS search."""
    v = Verifier(func, strict_schedule=False, am=am)
    v.loops = (am.get(LoopAnalysis, func) if am is not None else analyze_loops(func))
    v._iv_loop = {l.iv: li for l, li in v.loops.items()}
    v._build_root_tree()
    v._compute_windows()
    return v


def verify(module_or_func, strict_schedule: bool = True, raise_on_error: bool = True,
           am: Optional[AnalysisManager] = None) -> list[Diagnostic]:
    """Verify a module or function.  ``am`` shares the cached loop/port
    analyses with the optimizer and codegen (see ``core.passmgr``)."""
    funcs = (
        [module_or_func]
        if isinstance(module_or_func, FuncOp)
        else [f for f in module_or_func.funcs.values() if not f.attrs.get("external")]
    )
    diags: list[Diagnostic] = []
    for f in funcs:
        diags.extend(verify_func(f, strict_schedule, am=am))
    errs = [d for d in diags if d.severity == "error"]
    if errs and raise_on_error:
        raise VerifyError(errs)
    return diags
