"""Structured RTL netlist IR — the layer between scheduled HIR and Verilog.

Scheduled HIR is lowered (``core.codegen.verilog``) into an ``RTLModule`` per
``hir.func``: typed nets, combinational assigns, shift registers, clocked
register writes, loop-controller FSMs, memory primitives (reg / lutram / bram
banks) and **module instances**.  Verilog text is then a thin printer over
this IR (``print_rtl``), and the resource model reads the same structure —
nothing below the HIR level is a string anymore.

The module also hosts the RTL pass pipeline, registered on the same
``core.passmgr`` infrastructure as the HIR-level passes:

  * ``net-fanout``     (analysis)  — per-net reader/writer item indices;
  * ``rtl-dce``        — dead-net elimination: removes items (and their
                         declared nets) that cannot reach an output port,
                         a memory with a live reader, an instance input or
                         an assertion;
  * ``rtl-merge-srl``  — shift-register merging: equal-source chains are
                         shared; a deeper chain re-taps the tail of a
                         shallower equal-source chain instead of keeping a
                         full-depth private copy;
  * ``rtl-share-comb`` — duplicate-comb-expression sharing: structurally
                         identical combinational assigns collapse onto one
                         driver net.

``RTL_PIPELINE_SPEC`` is the default post-lowering pipeline;
``PassManager.from_spec(RTL_PIPELINE_SPEC)`` runs it over an ``RTLDesign``
(the pass classes accept either an ``RTLDesign`` or a plain dict of
``RTLModule``), with per-pass rewrite/wall statistics flowing into
``benchmarks/codegen_speed.py`` exactly like the HIR-level passes.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator, Optional, Union

from ..ir import Loc, UNKNOWN_LOC
from ..passmgr import (AnalysisManager, FunctionAnalysis, Pass,
                       register_analysis, register_pass)

# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


#: Global intern table: flat structural tuple -> small int.  Structurally
#: identical expression trees — across modules and designs — map to the same
#: integer, so ``key()`` equality is O(1) and keys hash as plain ints in the
#: sharing passes' dictionaries.  Bounded per compilation:
#: ``clear_key_intern`` releases it (``generate_verilog`` does so before
#: each lowering), and because ids are allocated from the monotonic
#: ``_key_ids`` counter — never from the table size — a stale key cached on
#: a node from before a clear can never alias a freshly interned structure;
#: the only effect of clearing is that sharing is not detected *across* the
#: clear boundary.
_KEY_TABLE: dict[tuple, int] = {}
_key_ids = itertools.count()


def clear_key_intern() -> int:
    """Drop the intern table (memory bound for long-lived processes).
    Returns the number of released entries.  Safe at any point — see the
    monotonic-id note on ``_KEY_TABLE``."""
    n = len(_KEY_TABLE)
    _KEY_TABLE.clear()
    return n

#: Counters for the hash-consing contract: ``computed`` increments once per
#: node whose structural key is actually derived (the seed recursive path);
#: ``hits`` counts cached O(1) returns.  ``tests/core/test_perf_infra.py``
#: asserts no pass recomputes keys per item.
KEY_STATS = {"computed": 0, "hits": 0}


def reset_key_stats() -> None:
    KEY_STATS["computed"] = 0
    KEY_STATS["hits"] = 0


def _ensure_recursion_headroom(limit: int = 20_000) -> None:
    """Deep expression trees (e.g. the drain-phase bus mux of a 32x32-PE gemm
    is a ~1024-deep ``Mux`` chain) exceed CPython's default recursion limit
    in ``refs()``/``map_refs``/the printers; raise it once, generously."""
    import sys

    if sys.getrecursionlimit() < limit:
        sys.setrecursionlimit(limit)


class Expr:
    """Base class of RTL expressions.  Expressions are immutable trees over
    net *names* (``Ref``) and literals; ``refs()`` yields referenced nets and
    ``key()`` is a structural identity used by CSE-style sharing.

    **Hash-consing invariant** — expression nodes are immutable once
    constructed; every rewrite builds new nodes (``map_refs`` is
    copy-on-write).  ``key()`` is therefore computed at most once per node
    and *interned*: structurally identical trees return the same small
    integer, so key equality/hashing is O(1) instead of O(tree).  Anyone
    adding a new ``Expr`` kind must implement ``_key_parts`` (flat tuple
    over child ``key()`` ints), ``structural_key`` (the uncached recursive
    form, kept for tests/debugging) and ``_children``, and must never mutate
    a node after construction."""

    __slots__ = ("_key",)

    def refs(self) -> Iterator[str]:
        """Referenced net names, in source order.  Iterative: a chain of
        nested ``yield from`` generators costs O(depth) per yielded leaf
        (O(size^2) on the deep bus-mux chains of large designs); the
        explicit stack keeps a full traversal O(size)."""
        stack = [self]
        while stack:
            e = stack.pop()
            if isinstance(e, Ref):
                yield e.name
                continue
            cs = e._children()
            if cs:
                stack.extend(reversed(cs))

    def key(self) -> int:
        """Interned structural identity (small int), cached per node."""
        try:
            k = self._key
        except AttributeError:
            pass
        else:
            KEY_STATS["hits"] += 1
            return k
        # iterative post-order over the uncached subtree: immune to deep
        # chains and O(nodes) total even on first touch
        stack = [self]
        table = _KEY_TABLE
        while stack:
            node = stack[-1]
            if hasattr(node, "_key"):
                stack.pop()
                continue
            pending = [c for c in node._children() if not hasattr(c, "_key")]
            if pending:
                stack.extend(pending)
                continue
            KEY_STATS["computed"] += 1
            parts = node._key_parts()
            k = table.get(parts)
            if k is None:
                k = table[parts] = next(_key_ids)
            node._key = k
            stack.pop()
        return self._key

    def _children(self) -> tuple:
        return ()

    def _key_parts(self) -> tuple:
        """Flat structural tuple over child ``key()`` ints (children must be
        keyed already — ``key()`` guarantees post-order)."""
        raise NotImplementedError

    def structural_key(self) -> tuple:
        """The seed-path fully-recursive structural key (uncached nested
        tuples).  Production code uses the interned ``key()``; this form is
        retained for the hash-consing property tests."""
        raise NotImplementedError

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        """A copy with net names substituted per ``ren`` (identity if no
        referenced name is renamed)."""
        return self


class Const(Expr):
    """A literal: ``32'd5`` when sized, a bare integer when not."""

    __slots__ = ("value", "width", "signed")

    def __init__(self, value: Union[int, float], width: Optional[int] = None,
                 signed: bool = False):
        self.value = value
        self.width = width
        self.signed = signed

    def _key_parts(self) -> tuple:
        return ("c", self.value, self.width, self.signed)

    def structural_key(self) -> tuple:
        return ("c", self.value, self.width, self.signed)

    def __str__(self) -> str:
        if self.width is None or not isinstance(self.value, int):
            return str(self.value)
        if self.signed and self.value < 0:
            return f"-{self.width}'sd{-self.value}"
        if self.value < 0:
            return f"-{self.width}'d{-self.value}"
        return f"{self.width}'d{self.value}"


class Ref(Expr):
    """A reference to a net or port by name."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def refs(self) -> Iterator[str]:
        yield self.name

    def _key_parts(self) -> tuple:
        return ("r", self.name)

    def structural_key(self) -> tuple:
        return ("r", self.name)

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        return Ref(ren[self.name]) if self.name in ren else self

    def __str__(self) -> str:
        return self.name


class Signed(Expr):
    """``$signed(a)`` — arithmetic reinterpretation, zero hardware."""

    __slots__ = ("a",)

    def __init__(self, a: Expr):
        self.a = a

    def _children(self) -> tuple:
        return (self.a,)

    def _key_parts(self) -> tuple:
        return ("s", self.a.key())

    def structural_key(self) -> tuple:
        return ("s", self.a.structural_key())

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        a = self.a.map_refs(ren)
        return self if a is self.a else Signed(a)

    def __str__(self) -> str:
        return f"$signed({self.a})"


class Unop(Expr):
    __slots__ = ("op", "a", "width")

    def __init__(self, op: str, a: Expr, width: int = 1):
        self.op = op
        self.a = a
        self.width = width  # cost width (resource model)

    def _children(self) -> tuple:
        return (self.a,)

    def _key_parts(self) -> tuple:
        return ("u", self.op, self.a.key())

    def structural_key(self) -> tuple:
        return ("u", self.op, self.a.structural_key())

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        a = self.a.map_refs(ren)
        return self if a is self.a else Unop(self.op, a, self.width)

    def __str__(self) -> str:
        return f"{self.op}({self.a})"


class Binop(Expr):
    """A binary operator.  ``width`` is the cost width for the resource
    model; ``impl`` carries the HIR binding for multiplies (``dsp`` /
    ``shift_add`` / ``counter`` / ``div``); ``free=True`` marks wiring-only
    nodes (constant-stride address scaling, shifts by constants) that consume
    no logic."""

    __slots__ = ("op", "a", "b", "width", "impl", "free")

    def __init__(self, op: str, a: Expr, b: Expr, width: int = 32,
                 impl: str = "", free: bool = False):
        self.op = op
        self.a = a
        self.b = b
        self.width = width
        self.impl = impl
        self.free = free

    def _children(self) -> tuple:
        return (self.a, self.b)

    def _key_parts(self) -> tuple:
        return ("b", self.op, self.a.key(), self.b.key())

    def structural_key(self) -> tuple:
        return ("b", self.op, self.a.structural_key(), self.b.structural_key())

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        a, b = self.a.map_refs(ren), self.b.map_refs(ren)
        if a is self.a and b is self.b:
            return self
        return Binop(self.op, a, b, self.width, self.impl, self.free)

    def __str__(self) -> str:
        return f"({self.a} {self.op} {self.b})"


class Mux(Expr):
    """``cond ? a : b`` (one 2:1 mux of ``width`` bits)."""

    __slots__ = ("cond", "a", "b", "width")

    def __init__(self, cond: Expr, a: Expr, b: Expr, width: int = 1):
        self.cond = cond
        self.a = a
        self.b = b
        self.width = width

    def _children(self) -> tuple:
        return (self.cond, self.a, self.b)

    def _key_parts(self) -> tuple:
        return ("m", self.cond.key(), self.a.key(), self.b.key())

    def structural_key(self) -> tuple:
        return ("m", self.cond.structural_key(), self.a.structural_key(),
                self.b.structural_key())

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        c, a, b = (self.cond.map_refs(ren), self.a.map_refs(ren),
                   self.b.map_refs(ren))
        if c is self.cond and a is self.a and b is self.b:
            return self
        return Mux(c, a, b, self.width)

    def __str__(self) -> str:
        return f"(({self.cond}) ? ({self.a}) : ({self.b}))"


class Repeat(Expr):
    """``{n{a}}`` — replication (wiring only)."""

    __slots__ = ("n", "a")

    def __init__(self, n: int, a: Expr):
        self.n = n
        self.a = a

    def _children(self) -> tuple:
        return (self.a,)

    def _key_parts(self) -> tuple:
        return ("rep", self.n, self.a.key())

    def structural_key(self) -> tuple:
        return ("rep", self.n, self.a.structural_key())

    def map_refs(self, ren: dict[str, str]) -> "Expr":
        a = self.a.map_refs(ren)
        return self if a is self.a else Repeat(self.n, a)

    def __str__(self) -> str:
        return f"{{{self.n}{{{self.a}}}}}"


def zeros(width: int) -> Expr:
    return Repeat(width, Const(0, 1)) if width > 1 else Const(0, 1)


def walk_expr(e: Expr) -> Iterator[Expr]:
    """Preorder walk (node before subtrees, ``a``/``b``/``cond`` attribute
    order — the historical ordering ``netlist_of`` depends on).  Iterative
    for the same O(size) reason as ``Expr.refs``."""
    stack = [e]
    while stack:
        cur = stack.pop()
        yield cur
        subs = [getattr(cur, attr, None) for attr in ("a", "b", "cond")]
        stack.extend(s for s in reversed(subs) if isinstance(s, Expr))


# ---------------------------------------------------------------------------
# Nets and items
# ---------------------------------------------------------------------------

WIRE = "wire"
REG = "reg"


@dataclass
class Net:
    """A declared identifier: a wire (driven by one ``CombAssign``) or a reg
    (written by clocked items).  ``origin`` tags special roles for the
    resource model (``"regbank"`` cells) without subclassing."""

    name: str
    width: int
    kind: str = WIRE  # WIRE | REG
    signed: bool = False
    origin: str = ""
    comment: str = ""


@dataclass
class Port:
    name: str
    dir: str  # "input" | "output"
    width: int


class Item:
    """Base class of RTL statements.  ``reads()``/``writes()`` are the net
    names this item consumes/drives — the hooks every RTL pass is built on."""

    loc: Loc = UNKNOWN_LOC

    def reads(self) -> Iterator[str]:
        return iter(())

    def writes(self) -> Iterator[str]:
        return iter(())

    def exprs(self) -> Iterator[Expr]:
        return iter(())

    def map_refs(self, ren: dict[str, str]) -> None:
        """Rename read references in place (dest names are never renamed)."""


class CombAssign(Item):
    """``assign dest = expr;`` (dest is a wire or an output port)."""

    __slots__ = ("dest", "expr", "loc")

    def __init__(self, dest: str, expr: Expr, loc: Loc = UNKNOWN_LOC):
        self.dest = dest
        self.expr = expr
        self.loc = loc

    def reads(self) -> Iterator[str]:
        return self.expr.refs()

    def writes(self) -> Iterator[str]:
        yield self.dest

    def exprs(self) -> Iterator[Expr]:
        yield self.expr

    def map_refs(self, ren: dict[str, str]) -> None:
        self.expr = self.expr.map_refs(ren)


class ShiftReg(Item):
    """``dest`` = ``src`` delayed by ``depth`` cycles (depth >= 1).  Prints
    as an SRL-style chain; ``reset_zero`` chains (pulse networks) clear on
    ``rst``."""

    __slots__ = ("dest", "src", "width", "depth", "reset_zero", "loc")

    def __init__(self, dest: str, src: Expr, width: int, depth: int,
                 reset_zero: bool = False, loc: Loc = UNKNOWN_LOC):
        assert depth >= 1, depth
        self.dest = dest
        self.src = src
        self.width = width
        self.depth = depth
        self.reset_zero = reset_zero
        self.loc = loc

    def reads(self) -> Iterator[str]:
        return self.src.refs()

    def writes(self) -> Iterator[str]:
        yield self.dest

    def exprs(self) -> Iterator[Expr]:
        yield self.src

    def map_refs(self, ren: dict[str, str]) -> None:
        self.src = self.src.map_refs(ren)


class RegAssign(Item):
    """``always @(posedge clk) if (en) dest <= src;`` — one clocked register
    write (en=None writes every cycle)."""

    __slots__ = ("dest", "src", "en", "loc")

    def __init__(self, dest: str, src: Expr, en: Optional[Expr] = None,
                 loc: Loc = UNKNOWN_LOC):
        self.dest = dest
        self.src = src
        self.en = en
        self.loc = loc

    def reads(self) -> Iterator[str]:
        yield from self.src.refs()
        if self.en is not None:
            yield from self.en.refs()

    def writes(self) -> Iterator[str]:
        yield self.dest

    def exprs(self) -> Iterator[Expr]:
        yield self.src
        if self.en is not None:
            yield self.en

    def map_refs(self, ren: dict[str, str]) -> None:
        self.src = self.src.map_refs(ren)
        if self.en is not None:
            self.en = self.en.map_refs(ren)


class Memory(Item):
    """A banked on-chip memory (lutram / bram).  Declares
    ``{name}_ram{bk}[0:depth-1]`` per bank; accessed by MemRead/MemWrite."""

    __slots__ = ("name", "banks", "depth", "width", "kind", "ports", "loc")

    def __init__(self, name: str, banks: int, depth: int, width: int,
                 kind: str, ports: int = 2, loc: Loc = UNKNOWN_LOC):
        self.name = name
        self.banks = banks
        self.depth = depth
        self.width = width
        self.kind = kind  # "lutram" | "bram"
        self.ports = ports
        self.loc = loc


class MemRead(Item):
    """Synchronous read: ``if (en) dest <= mem_ram{bank}[addr];``."""

    __slots__ = ("dest", "mem", "bank", "addr", "en", "loc")

    def __init__(self, dest: str, mem: str, bank: int, addr: Expr, en: Expr,
                 loc: Loc = UNKNOWN_LOC):
        self.dest = dest
        self.mem = mem
        self.bank = bank
        self.addr = addr
        self.en = en
        self.loc = loc

    def reads(self) -> Iterator[str]:
        yield from self.addr.refs()
        yield from self.en.refs()

    def writes(self) -> Iterator[str]:
        yield self.dest

    def exprs(self) -> Iterator[Expr]:
        yield self.addr
        yield self.en

    def map_refs(self, ren: dict[str, str]) -> None:
        self.addr = self.addr.map_refs(ren)
        self.en = self.en.map_refs(ren)


class MemWrite(Item):
    """Synchronous write: ``if (en) mem_ram{bank}[addr] <= data;``."""

    __slots__ = ("mem", "bank", "addr", "data", "en", "loc")

    def __init__(self, mem: str, bank: int, addr: Expr, data: Expr, en: Expr,
                 loc: Loc = UNKNOWN_LOC):
        self.mem = mem
        self.bank = bank
        self.addr = addr
        self.data = data
        self.en = en
        self.loc = loc

    def reads(self) -> Iterator[str]:
        yield from self.addr.refs()
        yield from self.data.refs()
        yield from self.en.refs()

    def exprs(self) -> Iterator[Expr]:
        yield self.addr
        yield self.data
        yield self.en

    def map_refs(self, ren: dict[str, str]) -> None:
        self.addr = self.addr.map_refs(ren)
        self.data = self.data.map_refs(ren)
        self.en = self.en.map_refs(ren)


class LoopController(Item):
    """The counter-based FSM of one ``hir.for``: drives the induction
    variable ``iv``, the per-iteration pulse ``iter``, the completion pulse
    ``endp`` and the ``active`` flag.  ``ii`` is the constant initiation
    interval; ``inner_end`` (sequential loops) launches the next iteration
    from an inner completion pulse instead."""

    __slots__ = ("prefix", "iv", "ivw", "active", "iter_net", "endp",
                 "iicnt", "start", "lb", "ub", "step", "ii", "inner_end",
                 "loc")

    def __init__(self, prefix: str, iv: str, ivw: int, active: str,
                 iter_net: str, endp: str, start: Expr, lb: Expr, ub: Expr,
                 step: Expr, ii: Optional[int] = None,
                 inner_end: Optional[Expr] = None, iicnt: str = "",
                 loc: Loc = UNKNOWN_LOC):
        assert (ii is None) != (inner_end is None), "constant II xor sequential"
        self.prefix = prefix
        self.iv = iv
        self.ivw = ivw
        self.active = active
        self.iter_net = iter_net
        self.endp = endp
        self.iicnt = iicnt
        self.start = start
        self.lb = lb
        self.ub = ub
        self.step = step
        self.ii = ii
        self.inner_end = inner_end
        self.loc = loc

    def reads(self) -> Iterator[str]:
        for e in self.exprs():
            yield from e.refs()

    def writes(self) -> Iterator[str]:
        yield self.iv
        yield self.active
        yield self.iter_net
        if self.endp:  # pruned to "" by rtl-dce when the pulse is unread
            yield self.endp
        if self.iicnt:
            yield self.iicnt

    def exprs(self) -> Iterator[Expr]:
        yield self.start
        yield self.lb
        yield self.ub
        yield self.step
        if self.inner_end is not None:
            yield self.inner_end

    def map_refs(self, ren: dict[str, str]) -> None:
        self.start = self.start.map_refs(ren)
        self.lb = self.lb.map_refs(ren)
        self.ub = self.ub.map_refs(ren)
        self.step = self.step.map_refs(ren)
        if self.inner_end is not None:
            self.inner_end = self.inner_end.map_refs(ren)


class Instance(Item):
    """A module instantiation.  ``conns`` is an ordered list of
    ``(port_name, expr, is_output)``: inputs take arbitrary expressions,
    outputs must be ``Ref`` to a net this instance drives.

    ``share_meta`` is stamped by the hierarchical lowering when the callee
    module is a pure feed-forward pipeline with an all-scalar interface:
    ``(result_delays, scalar_input_ports)`` with ports as ``(name, width)``
    pairs.  Only stamped instances are candidates for
    ``rtl-share-instances``/``rtl-arbitrate``; ``share`` lists the instance
    names a merged (time-multiplexed) instance absorbed, so printers and the
    resource model can surface the sharing degree."""

    __slots__ = ("module", "inst", "conns", "share", "share_meta", "loc")

    def __init__(self, module: str, inst: str,
                 conns: list[tuple[str, Expr, bool]], loc: Loc = UNKNOWN_LOC,
                 share: tuple = (), share_meta: Optional[tuple] = None):
        self.module = module
        self.inst = inst
        self.conns = list(conns)
        self.share = tuple(share)
        self.share_meta = share_meta
        self.loc = loc

    def reads(self) -> Iterator[str]:
        for _p, e, is_out in self.conns:
            if not is_out:
                yield from e.refs()

    def writes(self) -> Iterator[str]:
        for _p, e, is_out in self.conns:
            if is_out:
                assert isinstance(e, Ref), (self.inst, _p)
                yield e.name

    def exprs(self) -> Iterator[Expr]:
        for _p, e, _o in self.conns:
            yield e

    def map_refs(self, ren: dict[str, str]) -> None:
        self.conns = [(p, e if is_out else e.map_refs(ren), is_out)
                      for p, e, is_out in self.conns]


class PortConflictAssert(Item):
    """The §4.5 UB guard: simulation-only ``$error`` when two enables of one
    bus fire in the same cycle."""

    __slots__ = ("bus", "ens", "loc")

    def __init__(self, bus: str, ens: list[Expr], loc: Loc = UNKNOWN_LOC):
        self.bus = bus
        self.ens = list(ens)
        self.loc = loc

    def reads(self) -> Iterator[str]:
        for e in self.ens:
            yield from e.refs()

    def exprs(self) -> Iterator[Expr]:
        return iter(self.ens)

    def map_refs(self, ren: dict[str, str]) -> None:
        self.ens = [e.map_refs(ren) for e in self.ens]


def clone_item(it: Item, ren: Optional[dict[str, str]] = None) -> Item:
    """Copy one item, renaming *both* read references and destination names
    through ``ren``.  Expressions are immutable and shared where unchanged;
    memory and instance names go through the same map as nets, so a single
    ``ren`` built from a module's full namespace relocates the whole item."""
    ren = ren or {}

    def nn(name: str) -> str:
        return ren.get(name, name)

    def ee(e: Expr) -> Expr:
        return e.map_refs(ren) if ren else e

    if isinstance(it, CombAssign):
        return CombAssign(nn(it.dest), ee(it.expr), it.loc)
    if isinstance(it, ShiftReg):
        return ShiftReg(nn(it.dest), ee(it.src), it.width, it.depth,
                        it.reset_zero, it.loc)
    if isinstance(it, RegAssign):
        return RegAssign(nn(it.dest), ee(it.src),
                         None if it.en is None else ee(it.en), it.loc)
    if isinstance(it, Memory):
        return Memory(nn(it.name), it.banks, it.depth, it.width, it.kind,
                      it.ports, it.loc)
    if isinstance(it, MemRead):
        return MemRead(nn(it.dest), nn(it.mem), it.bank, ee(it.addr),
                       ee(it.en), it.loc)
    if isinstance(it, MemWrite):
        return MemWrite(nn(it.mem), it.bank, ee(it.addr), ee(it.data),
                        ee(it.en), it.loc)
    if isinstance(it, LoopController):
        return LoopController(
            nn(it.prefix), nn(it.iv), it.ivw, nn(it.active), nn(it.iter_net),
            nn(it.endp) if it.endp else "", ee(it.start), ee(it.lb),
            ee(it.ub), ee(it.step), it.ii,
            None if it.inner_end is None else ee(it.inner_end),
            nn(it.iicnt) if it.iicnt else "", it.loc)
    if isinstance(it, Instance):
        # output connections are Refs into the surrounding namespace: rename
        # them like any other name (Instance.map_refs deliberately skips them
        # because passes only rewrite *reads*; cloning relocates everything).
        conns = [(p, Ref(nn(e.name)) if is_out else ee(e), is_out)
                 for p, e, is_out in it.conns]
        return Instance(it.module, nn(it.inst), conns, it.loc,
                        share=tuple(nn(s) for s in it.share),
                        share_meta=it.share_meta)
    if isinstance(it, PortConflictAssert):
        return PortConflictAssert(it.bus, [ee(e) for e in it.ens], it.loc)
    raise NotImplementedError(type(it).__name__)


# ---------------------------------------------------------------------------
# Modules and designs
# ---------------------------------------------------------------------------


class RTLModule:
    """One hardware module: ports, net declarations and an ordered item
    list.  ``arg_ports``/``result_ports`` record the interface-port names of
    the originating ``hir.func``'s arguments/results, so callers can build
    ``Instance`` connections without re-deriving naming."""

    def __init__(self, name: str, loc: Loc = UNKNOWN_LOC):
        self.name = name
        self.loc = loc
        self.ports: list[Port] = []
        self.nets: dict[str, Net] = {}
        self.items: list[Item] = []
        # hir.func interface map, filled by the lowering: per argument index,
        # the interface ports as (port_name, dir, role, bank) tuples — role in
        # {"scalar", "rd_addr", "rd_en", "rd_data", "wr_addr", "wr_en",
        # "wr_data"}, bank -1 for non-banked ports.  Callers build Instance
        # connections from this instead of re-deriving the naming scheme.
        self.arg_ports: dict[int, list[tuple[str, str, str, int]]] = {}
        self.result_ports: list[tuple[str, str]] = []  # (data, valid)
        self.source_func: str = name

    # -- construction ------------------------------------------------------
    def add_port(self, name: str, dir: str, width: int = 1) -> str:
        assert not any(p.name == name for p in self.ports), name
        self.ports.append(Port(name, dir, width))
        return name

    def new_net(self, name: str, width: int, kind: str = WIRE,
                signed: bool = False, origin: str = "",
                comment: str = "") -> str:
        assert name not in self.nets, name
        self.nets[name] = Net(name, width, kind, signed, origin, comment)
        return name

    def add(self, item: Item) -> Item:
        self.items.append(item)
        return item

    # -- queries -----------------------------------------------------------
    def port_names(self) -> set[str]:
        return {p.name for p in self.ports}

    def output_ports(self) -> set[str]:
        return {p.name for p in self.ports if p.dir == "output"}

    def memories(self) -> dict[str, Memory]:
        return {it.name: it for it in self.items if isinstance(it, Memory)}

    def instances(self) -> list[Instance]:
        return [it for it in self.items if isinstance(it, Instance)]

    # -- mutation helpers used by the passes ---------------------------------
    def replace_net(self, old: str, new: str) -> int:
        """Rewrite every *read* reference to ``old`` into ``new``; the net
        declaration and its drivers are untouched.  Returns #items touched."""
        ren = {old: new}
        n = 0
        for it in self.items:
            before = list(it.reads())
            if old in before:
                it.map_refs(ren)
                n += 1
        return n

    def drop_items(self, dead: set[int]) -> None:
        self.items = [it for i, it in enumerate(self.items) if i not in dead]

    def prune_nets(self) -> int:
        """Drop net declarations that no remaining item reads or writes and
        that are not ports.  Returns the number removed."""
        used: set[str] = set()
        for it in self.items:
            used.update(it.reads())
            used.update(it.writes())
        used.update(self.port_names())
        dead = [n for n in self.nets if n not in used]
        for n in dead:
            del self.nets[n]
        return len(dead)

    def copy(self, name: Optional[str] = None) -> "RTLModule":
        """Structural copy: fresh ports/nets/items, expressions shared (they
        are immutable).  Snapshotting a module before a pass pipeline costs
        O(items), not a deepcopy of the expression DAG."""
        m = RTLModule(name or self.name, self.loc)
        m.ports = [Port(p.name, p.dir, p.width) for p in self.ports]
        m.nets = {n: Net(v.name, v.width, v.kind, v.signed, v.origin,
                         v.comment) for n, v in self.nets.items()}
        m.items = [clone_item(it) for it in self.items]
        m.arg_ports = {i: list(v) for i, v in self.arg_ports.items()}
        m.result_ports = list(self.result_ports)
        m.source_func = self.source_func
        return m


class RTLDesign:
    """A set of RTL modules with a designated entry — what the RTL pass
    pipeline runs on (duck-typing the PassManager's ``Module``)."""

    def __init__(self, modules: Optional[dict[str, RTLModule]] = None,
                 entry: Optional[str] = None):
        self.modules: dict[str, RTLModule] = modules or {}
        self.entry = entry

    def add(self, m: RTLModule) -> RTLModule:
        self.modules[m.name] = m
        return m

    def __iter__(self) -> Iterator[RTLModule]:
        return iter(self.modules.values())

    def instance_counts(self) -> dict[str, int]:
        """Total instantiation multiplicity per module name, entry-rooted
        (an instance inside a module instantiated k times counts k)."""
        counts: dict[str, int] = {}
        roots = [self.entry] if self.entry in self.modules else list(self.modules)

        def visit(name: str, mult: int, stack: tuple) -> None:
            if name in stack or name not in self.modules:
                return
            for inst in self.modules[name].instances():
                counts[inst.module] = counts.get(inst.module, 0) + mult
                visit(inst.module, mult, stack + (name,))

        for r in roots:
            visit(r, 1, ())
        return counts

    def copy(self) -> "RTLDesign":
        return RTLDesign({n: m.copy() for n, m in self.modules.items()},
                         self.entry)

    def flatten(self, entry: Optional[str] = None) -> RTLModule:
        """Inline every ``Instance`` reachable from ``entry`` into one flat
        module.  Callee nets/memories get an ``{inst}__`` prefix per
        instantiation path; input-port connections become ``CombAssign``s
        into the prefixed port net, output connections alias the parent net
        to the callee's driver.  ``clk``/``rst`` are implicit in the item
        semantics and dropped.  The flat module is what the vectorized
        simulator (``codegen.sim``) interprets."""
        entry = entry or self.entry
        assert entry in self.modules, entry
        flat = self.modules[entry].copy()
        guard = 0
        while True:
            idx = next((i for i, it in enumerate(flat.items)
                        if isinstance(it, Instance)), None)
            if idx is None:
                return flat
            guard += 1
            if guard > 100_000:  # cyclic instantiation would loop forever
                raise RecursionError(f"flatten: instance explosion in {entry}")
            inst = flat.items.pop(idx)
            callee = self.modules[inst.module]
            prefix = f"{inst.inst}__"
            ren: dict[str, str] = {}
            for nname in callee.nets:
                ren[nname] = prefix + nname
            for p in callee.ports:
                if p.name not in ("clk", "rst"):
                    ren.setdefault(p.name, prefix + p.name)
            for mem in callee.memories():
                ren.setdefault(mem, prefix + mem)
            for sub in callee.instances():
                ren.setdefault(sub.inst, prefix + sub.inst)
            for v in callee.nets.values():
                nn = ren[v.name]
                assert nn not in flat.nets, nn
                flat.nets[nn] = Net(nn, v.width, v.kind, v.signed,
                                    v.origin or f"inline:{inst.inst}",
                                    v.comment)
            for p in callee.ports:
                if p.name in ("clk", "rst"):
                    continue
                nn = ren[p.name]
                if nn not in flat.nets:
                    flat.nets[nn] = Net(nn, p.width, WIRE, False,
                                        f"inline:{inst.inst}", "")
            pre: list[Item] = []
            post: list[Item] = []
            conn_map = {p: (e, is_out) for p, e, is_out in inst.conns}
            for p in callee.ports:
                if p.name in ("clk", "rst"):
                    continue
                if p.name in conn_map:
                    e, is_out = conn_map[p.name]
                    if is_out:
                        assert isinstance(e, Ref), (inst.inst, p.name)
                        post.append(CombAssign(e.name, Ref(ren[p.name])))
                    else:
                        pre.append(CombAssign(ren[p.name], e))
                elif p.dir == "input":
                    pre.append(CombAssign(ren[p.name], zeros(p.width)))
            body = [clone_item(it, ren) for it in callee.items]
            flat.items[idx:idx] = pre + body + post


# ---------------------------------------------------------------------------
# Verilog printer (the thin layer the old string emitter became)
# ---------------------------------------------------------------------------


def print_rtl(m: RTLModule) -> str:
    """Print one RTLModule as synthesizable Verilog (the default backend).

    Kept as the historical entry point; it now delegates to the backend
    printer layer (``core.codegen.backends``) — ``VerilogPrinter`` produces
    byte-identical output, and sibling printers emit SystemVerilog, VHDL and
    CIRCT ``hw``-dialect MLIR from the same structure."""
    from .backends import VerilogPrinter

    return VerilogPrinter().print_module(m)


def print_design(d: RTLDesign) -> str:
    from .backends import VerilogPrinter

    return VerilogPrinter().print_design(d)


# ---------------------------------------------------------------------------
# Net fan-out analysis (on the shared AnalysisManager)
# ---------------------------------------------------------------------------


@dataclass
class NetFanout:
    """Reader/writer item indices per net of one RTLModule."""

    readers: dict[str, list[int]] = field(default_factory=dict)
    writers: dict[str, list[int]] = field(default_factory=dict)

    def fanout(self, net: str) -> int:
        return len(self.readers.get(net, ()))


@register_analysis
class NetFanoutAnalysis(FunctionAnalysis):
    """Per-module net fan-out — keyed on the RTLModule through the same
    AnalysisManager cache the HIR analyses use (the manager only relies on
    object identity, so RTL modules slot in beside FuncOps)."""

    name = "net-fanout"

    @staticmethod
    def run(func: Any, am: AnalysisManager) -> NetFanout:
        m: RTLModule = func
        fo = NetFanout()
        for i, it in enumerate(m.items):
            for r in it.reads():
                fo.readers.setdefault(r, []).append(i)
            for w in it.writes():
                fo.writers.setdefault(w, []).append(i)
        return fo


# ---------------------------------------------------------------------------
# RTL passes
# ---------------------------------------------------------------------------


class NetReaderIndex:
    """Per-run reader index: net name -> set of items whose ``reads()``
    include it.  ``replace(old, new)`` applies one rename in O(#readers of
    old) instead of ``RTLModule.replace_net``'s O(items x expr-size) full
    scan — the asymptotic fix that makes the sharing passes linear.  The
    index is keyed on item *objects*, so ``drop_items`` compaction never
    invalidates it (renaming an already-dropped item is a harmless no-op,
    exactly like the full-scan path before compaction)."""

    __slots__ = ("readers",)

    def __init__(self, m: RTLModule):
        readers: dict[str, set[Item]] = {}
        for it in m.items:
            for r in it.reads():
                s = readers.get(r)
                if s is None:
                    s = readers[r] = set()
                s.add(it)
        self.readers = readers

    def replace(self, old: str, new: str) -> int:
        """Rewrite every read of ``old`` into ``new`` and migrate the index
        entries.  Returns the number of items touched."""
        its = self.readers.pop(old, None)
        if not its:
            return 0
        ren = {old: new}
        for it in its:
            it.map_refs(ren)
        tgt = self.readers.get(new)
        if tgt is None:
            self.readers[new] = its
        else:
            tgt.update(its)
        return len(its)

    def note_reads(self, it: Item, names: Iterable[str]) -> None:
        """Register reads added by an in-place item mutation done outside
        ``replace`` (stale entries for removed reads are harmless)."""
        for nm in names:
            self.readers.setdefault(nm, set()).add(it)


class RTLPass(Pass):
    """Base of passes running over an ``RTLDesign`` (or a plain dict of
    RTLModules).  Subclasses implement ``run_module``.

    RTL passes only touch ``RTLModule`` netlists, never HIR functions, so
    every HIR-level analysis cached on a shared ``AnalysisManager`` stays
    valid across them (``preserves``).  ``net-fanout`` is also declared
    preserved *globally* because each pass already invalidates it per
    mutated module (``am.invalidate(func=m)``) — modules the pass did not
    change keep their cached fan-out."""

    preserves = ("loop-info", "port-accesses", "mem-touch", "dependence",
                 "net-fanout")

    def run(self, design) -> int:
        _ensure_recursion_headroom()
        mods = design.modules if isinstance(design, RTLDesign) else dict(design)
        n = 0
        for m in mods.values():
            n += self.run_module(m)
        return n

    def run_module(self, m: RTLModule) -> int:
        raise NotImplementedError


@register_pass
class DeadNetElim(RTLPass):
    """Dead-net elimination.  Liveness roots: output ports, instances (their
    inputs feed other modules) and UB assertions.  Memory writes are live
    only while some live item reads the memory; everything else must
    transitively feed a root to survive."""

    name = "rtl-dce"

    def run_module(self, m: RTLModule) -> int:
        n_pruned = self._prune_controller_outputs(m)
        if n_pruned and self.am is not None:
            self.am.invalidate(func=m)
        fo = self.get_analysis(NetFanoutAnalysis, m)
        items = m.items
        needed: set[str] = set(m.output_ports())
        live: set[int] = set()
        live_mems: set[str] = set()

        def mark(i: int) -> None:
            if i in live:
                return
            live.add(i)
            it = items[i]
            for r in it.reads():
                if r not in needed:
                    needed.add(r)
                    for w in fo.writers.get(r, ()):  # drivers become relevant
                        pending.append(w)
            if isinstance(it, MemRead):
                live_mems.add(it.mem)

        pending: list[int] = []
        for i, it in enumerate(items):
            if isinstance(it, (Instance, PortConflictAssert)):
                pending.append(i)
            elif any(w in needed for w in it.writes()):
                pending.append(i)
        while True:
            while pending:
                i = pending.pop()
                if i in live:
                    continue
                it = items[i]
                if isinstance(it, MemWrite) and it.mem not in live_mems:
                    continue  # revisited below if the memory becomes live
                mark(i)
            # memory writes whose memory just became live
            again = [i for i, it in enumerate(items)
                     if i not in live and isinstance(it, MemWrite)
                     and it.mem in live_mems]
            # memory declarations for live memories
            again += [i for i, it in enumerate(items)
                      if i not in live and isinstance(it, Memory)
                      and it.name in live_mems]
            # drivers of newly-needed nets
            again += [w for n in needed for w in fo.writers.get(n, ())
                      if w not in live]
            if not again:
                break
            pending = again

        dead = {i for i in range(len(items)) if i not in live}
        if not dead:
            self._audit_dangling(m)
            return n_pruned
        m.drop_items(dead)
        removed = n_pruned + len(dead) + m.prune_nets()
        self._invalidate(m)
        self._audit_dangling(m)
        return removed

    @staticmethod
    def _audit_dangling(m: RTLModule) -> None:
        """``REPRO_RTL_AUDIT=1``: assert no pass left a read-but-undriven net
        or an undriven output port (the ``ControllerMerge`` ``iicnt`` bug
        class).  The vectorized simulator deliberately ties undriven reads
        to zero, which silently masks such bugs — this audit makes them loud
        in debug/CI runs.  Runs after DCE so legitimately dead logic never
        trips it."""
        import os

        if os.environ.get("REPRO_RTL_AUDIT", "0") in ("", "0"):
            return
        driven = {p.name for p in m.ports if p.dir == "input"}
        driven.update(("clk", "rst"))
        mems: set[str] = set()
        for it in m.items:
            driven.update(it.writes())
            if isinstance(it, Memory):
                mems.add(it.name)
        dangling = sorted({r for it in m.items for r in it.reads()
                           if r not in driven})
        undriven_out = sorted(p.name for p in m.ports
                              if p.dir == "output" and p.name not in driven)
        if dangling or undriven_out:
            raise AssertionError(
                f"rtl-dce audit: module {m.name!r} reads undriven nets "
                f"{dangling}; undriven output ports {undriven_out}")

    def _invalidate(self, m: RTLModule) -> None:
        if self.am is not None:
            self.am.invalidate(func=m)

    @staticmethod
    def _prune_controller_outputs(m: RTLModule) -> int:
        """A controller's completion pulse (``endp``) is a register even
        when nothing consumes it (the last loop of a function with no
        results); drop the unread register from the FSM."""
        read: set[str] = set()
        for it in m.items:
            read.update(it.reads())
        n = 0
        for it in m.items:
            if isinstance(it, LoopController) and it.endp and it.endp not in read:
                m.nets.pop(it.endp, None)
                it.endp = ""
                n += 1
        return n


@register_pass
class ShiftRegMerge(RTLPass):
    """Shift-register merging/sharing.  Chains with the same source
    expression, width and reset behaviour share hardware: equal depths
    collapse to one chain; a deeper chain re-taps the tail of the deepest
    shallower chain (delay d2 becomes d2-d1 cycles after the shared d1
    tail)."""

    name = "rtl-merge-srl"

    def run_module(self, m: RTLModule) -> int:
        groups: dict[tuple, list[tuple[int, ShiftReg]]] = {}
        multi_written = self._multi_written(m)
        for i, it in enumerate(m.items):
            if isinstance(it, ShiftReg) and it.dest not in multi_written:
                key = (it.src.key(), it.width, it.reset_zero)
                groups.setdefault(key, []).append((i, it))
        if not any(len(c) > 1 for c in groups.values()):
            return 0
        idx = NetReaderIndex(m)
        n = 0
        drop: set[int] = set()
        for chain in groups.values():
            if len(chain) < 2:
                continue
            chain.sort(key=lambda s: s[1].depth)
            kept = chain[0][1]
            kept_total = kept.depth  # cumulative delay of kept.dest from the source
            for di, dup in chain[1:]:
                total = dup.depth
                if total == kept_total:
                    idx.replace(dup.dest, kept.dest)
                    drop.add(di)
                    m.nets.pop(dup.dest, None)
                else:
                    # re-tap: source the deeper chain from the current tail,
                    # keeping only the residual depth beyond it
                    dup.src = Ref(kept.dest)
                    idx.note_reads(dup, (kept.dest,))
                    dup.depth = total - kept_total
                    kept, kept_total = dup, total
                n += 1
        if drop:
            m.drop_items(drop)
        if n:
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return n

    @staticmethod
    def _multi_written(m: RTLModule) -> set[str]:
        seen: set[str] = set()
        multi: set[str] = set()
        for it in m.items:
            for w in it.writes():
                (multi if w in seen else seen).add(w)
        return multi


@register_pass
class CombShare(RTLPass):
    """Duplicate-comb-expression sharing: structurally identical
    ``CombAssign`` right-hand sides collapse onto the first driver.  An
    output-port duplicate keeps its assign but re-points it at the shared
    net (ports must stay driven)."""

    name = "rtl-share-comb"

    def run_module(self, m: RTLModule) -> int:
        n = 0
        idx: Optional[NetReaderIndex] = None  # built on the first rewrite
        changed = True
        while changed:  # sharing can make further items structurally equal
            changed = False
            seen: dict[int, CombAssign] = {}
            ports = m.port_names()
            drop: set[int] = set()
            for i, it in enumerate(m.items):
                if not isinstance(it, CombAssign):
                    continue
                key = it.expr.key()
                first = seen.get(key)
                if first is None:
                    seen[key] = it
                    continue
                if isinstance(it.expr, Ref) or it.dest == first.dest:
                    continue  # plain aliases gain nothing
                if idx is None:
                    idx = NetReaderIndex(m)
                if it.dest in ports:
                    it.expr = Ref(first.dest)
                    idx.note_reads(it, (first.dest,))
                else:
                    idx.replace(it.dest, first.dest)
                    m.nets.pop(it.dest, None)
                    drop.add(i)
                n += 1
                changed = True
            if drop:
                m.drop_items(drop)
        if n:
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return n


@register_pass
class ControllerMerge(RTLPass):
    """Merge structurally identical loop controllers.  After full unrolling,
    replicated loop nests (e.g. the 256 PE k-loops of the gemm systolic
    array) produce byte-identical counter FSMs: same start pulse, bounds,
    step and II.  Two such FSMs are deterministic machines with identical
    inputs, so their outputs (``iv``/``iter``/``endp``/``active``) are
    cycle-for-cycle equal and one copy can drive every consumer."""

    name = "rtl-merge-ctrl"

    def run_module(self, m: RTLModule) -> int:
        groups: dict[tuple, LoopController] = {}
        n = 0
        drop: set[int] = set()
        idx: Optional[NetReaderIndex] = None  # built on the first merge
        for i, it in enumerate(m.items):
            if not isinstance(it, LoopController):
                continue
            key = (it.start.key(), it.lb.key(), it.ub.key(), it.step.key(),
                   it.ii, it.inner_end.key() if it.inner_end is not None else None,
                   it.ivw)
            kept = groups.get(key)
            if kept is None:
                groups[key] = it
                continue
            if idx is None:
                idx = NetReaderIndex(m)
            if it.endp and not kept.endp:
                kept.endp = it.endp  # keep driving the consumed pulse
            else:
                for old, new in (((it.endp, kept.endp),) if it.endp else ()):
                    idx.replace(old, new)
                    m.nets.pop(old, None)
            for old, new in ((it.iv, kept.iv), (it.iter_net, kept.iter_net),
                             (it.active, kept.active)):
                idx.replace(old, new)
                m.nets.pop(old, None)
            if it.iicnt:
                # same ii (part of the key) implies the kept FSM has an
                # iicnt too — redirect the II-phase readers to it
                idx.replace(it.iicnt, kept.iicnt)
                m.nets.pop(it.iicnt, None)
            drop.add(i)
            n += 1
        if drop:
            m.drop_items(drop)
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return n


@register_pass
class MemReadShare(RTLPass):
    """Share duplicate synchronous memory reads: identical (memory, bank,
    address, enable) reads return the same data — the paper's §4.4 broadcast
    (same-address parallel reads are one physical port access), so one read
    register can feed every consumer."""

    name = "rtl-share-mem"

    def run_module(self, m: RTLModule) -> int:
        seen: dict[tuple, MemRead] = {}
        n = 0
        drop: set[int] = set()
        idx: Optional[NetReaderIndex] = None  # built on the first share
        for i, it in enumerate(m.items):
            if not isinstance(it, MemRead):
                continue
            key = (it.mem, it.bank, it.addr.key(), it.en.key())
            kept = seen.get(key)
            if kept is None:
                seen[key] = it
                continue
            if idx is None:
                idx = NetReaderIndex(m)
            idx.replace(it.dest, kept.dest)
            m.nets.pop(it.dest, None)
            drop.add(i)
            n += 1
        if drop:
            m.drop_items(drop)
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return n


def _instance_conn_maps(it: Instance) -> tuple[dict, dict]:
    """(inputs, outputs) port-name -> expr maps of one instance."""
    ins: dict[str, Expr] = {}
    outs: dict[str, Expr] = {}
    for p, e, is_out in it.conns:
        (outs if is_out else ins)[p] = e
    return ins, outs


class _InstanceMergeBase(RTLPass):
    """Shared machinery of ``rtl-share-instances`` / ``rtl-arbitrate``: merge
    k instances of one feed-forward callee into a single physical instance
    behind a time-division mux tree.  Operands are selected by the firing
    member's activation pulse (first member in program order wins on the
    priority chain), the shared activation is the OR of the member pulses,
    and every member keeps its *own* result/valid nets: results alias the
    shared output (the member only samples it at its own firing time),
    valids are re-derived from the member's own pulse delayed by the
    callee's declared result latency through the existing ``ShiftReg``
    machinery.

    Both passes are **entry-module only**: the design entry is invoked
    exactly once, so instance pulse sets are absolute cycle schedules.  A
    non-entry module can be re-invoked while a previous invocation is still
    in flight (e.g. a pipelined caller at II=1), which would overlay the
    relative pulse sets unpredictably — sharing there is unsound."""

    #: name prefix of the nets the merge introduces (per subclass, so a
    #: share-merged lead can later be arbitrate-merged without collisions)
    net_tag = "sh"
    #: arbitrated merges add the §4.5 ``PortConflictAssert`` residual guard
    arbitrated = False

    def run(self, design) -> int:
        _ensure_recursion_headroom()
        if not isinstance(design, RTLDesign) or not design.entry:
            return 0  # no proven single-invocation root: nothing to share
        m = design.modules.get(design.entry)
        return 0 if m is None else self.run_module(m)

    def run_module(self, m: RTLModule) -> int:
        from ..analysis import ActivationIntervalsAnalysis

        cands: dict[str, list[tuple[int, Instance]]] = {}
        for i, it in enumerate(m.items):
            if isinstance(it, Instance) and it.share_meta is not None:
                cands.setdefault(it.module, []).append((i, it))
        n = 0
        drop: set[int] = set()
        ai = None
        for _callee, insts in cands.items():
            if len(insts) < 2:
                continue
            if ai is None:
                ai = self.get_analysis(ActivationIntervalsAnalysis, m)
            for group in self._group(ai, insts):
                if len(group) < 2:
                    continue
                self._merge(m, group)
                drop.update(i for i, _ in group[1:])
                n += len(group) - 1
        if n:
            m.drop_items(drop)
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return n

    @staticmethod
    def _pulse_of(ai, it: Instance):
        ts = _instance_conn_maps(it)[0].get("t_start")
        return None if ts is None else ai.of_expr(ts)

    def _group(self, ai, insts: list) -> list[list]:
        raise NotImplementedError

    def _merge(self, m: RTLModule, group: list[tuple[int, Instance]]) -> None:
        lead = group[0][1]
        delays, in_ports, out_widths = lead.share_meta
        members = [it for _, it in group]
        cmaps = [_instance_conn_maps(it) for it in members]
        ts_exprs = [c[0]["t_start"] for c in cmaps]
        base, tag = lead.inst, self.net_tag

        def fold_first_wins(values: list, width: int) -> Expr:
            v = values[-1]
            for te, mv in zip(reversed(ts_exprs[:-1]), reversed(values[:-1])):
                v = Mux(te, mv, v, width)
            return v

        ts = ts_exprs[0]
        for e in ts_exprs[1:]:
            ts = Binop("|", ts, e, width=1, free=True)
        tsnet = m.new_net(f"{base}_{tag}_ts", 1)
        new_items: list[Item] = [CombAssign(tsnet, ts, loc=lead.loc)]
        conns: list[tuple[str, Expr, bool]] = [
            ("clk", Ref("clk"), False), ("rst", Ref("rst"), False),
            ("t_start", Ref(tsnet), False)]
        for pname, width in in_ports:
            pnet = m.new_net(f"{base}_{tag}_{pname}", width)
            new_items.append(CombAssign(
                pnet, fold_first_wins([c[0][pname] for c in cmaps], width),
                loc=lead.loc))
            conns.append((pname, Ref(pnet), False))
        for ri, d in enumerate(delays):
            rnet = m.new_net(f"{base}_{tag}_r{ri}", out_widths[ri])
            vnet = m.new_net(f"{base}_{tag}_v{ri}", 1)
            conns.append((f"result_{ri}", Ref(rnet), True))
            conns.append((f"result_{ri}_valid", Ref(vnet), True))
            for c, te in zip(cmaps, ts_exprs):
                mr = c[1][f"result_{ri}"].name
                mv = c[1][f"result_{ri}_valid"].name
                new_items.append(CombAssign(mr, Ref(rnet), loc=lead.loc))
                if d > 0:
                    new_items.append(ShiftReg(mv, te, 1, d, reset_zero=True,
                                              loc=lead.loc))
                else:
                    new_items.append(CombAssign(mv, te, loc=lead.loc))
        lead.conns = conns
        lead.share = lead.share + tuple(
            s for it in members[1:] for s in (it.inst,) + it.share)
        if self.arbitrated:
            new_items.append(PortConflictAssert(tsnet, list(ts_exprs),
                                                loc=lead.loc))
        m.items.extend(new_items)


@register_pass
class ShareInstances(_InstanceMergeBase):
    """Cross-instance time-multiplexing (the paper's §4.4 resource story at
    module granularity): instances of one callee whose ``activation-intervals``
    pulse sets are finite and pairwise disjoint provably never compute in the
    same cycle, so they fold onto one physical instance.  Deterministic
    first-fit greedy packing in program order."""

    name = "rtl-share-instances"
    net_tag = "sh"

    def _group(self, ai, insts: list) -> list[list]:
        groups: list[list] = []  # [union_pulses, members...]
        for i, it in insts:
            p = self._pulse_of(ai, it)
            if p is None:
                continue  # unknown schedule: rtl-arbitrate's problem
            for g in groups:
                if not (g[0] & p):
                    g[0] = g[0] | p
                    g.append((i, it))
                    break
            else:
                groups.append([p, (i, it)])
        return [g[1:] for g in groups]


@register_pass
class ArbitrateInstances(_InstanceMergeBase):
    """II-aware arbitration — sharing that degrades gracefully when pulses
    *can* coincide.  Two jobs:

    1. prune ``PortConflictAssert`` guards whose enables are finite and
       pairwise disjoint (the analysis discharged the §4.5 obligation
       statically, so the runtime monitor is dead weight);
    2. merge same-callee instances whose pulse schedules the analysis could
       *not* bound (TOP) behind a static-priority arbiter (first instance in
       program order wins the operand mux) with a ``PortConflictAssert`` on
       the shared activation guarding the residual §4.5 condition.
       Instances with structurally identical activation pulses provably
       coincide every firing and are left alone."""

    name = "rtl-arbitrate"
    net_tag = "arb"
    arbitrated = True

    def run_module(self, m: RTLModule) -> int:
        return self._prune_proven_asserts(m) + super().run_module(m)

    def _prune_proven_asserts(self, m: RTLModule) -> int:
        from ..analysis import ActivationIntervalsAnalysis

        drop: set[int] = set()
        ai = None
        for i, it in enumerate(m.items):
            if not isinstance(it, PortConflictAssert):
                continue
            if ai is None:
                ai = self.get_analysis(ActivationIntervalsAnalysis, m)
            sets = [ai.of_expr(e) for e in it.ens]
            if any(s is None for s in sets):
                continue
            union: frozenset = frozenset()
            total = 0
            for s in sets:
                union |= s
                total += len(s)
            if total == len(union):  # pairwise disjoint: can never trip
                drop.add(i)
        if drop:
            m.drop_items(drop)
            m.prune_nets()
            if self.am is not None:
                self.am.invalidate(func=m)
        return len(drop)

    def _group(self, ai, insts: list) -> list[list]:
        group: list = []
        keys: set[int] = set()
        for i, it in insts:
            if self._pulse_of(ai, it) is not None:
                continue  # bounded schedules are rtl-share-instances' job
            ts = _instance_conn_maps(it)[0].get("t_start")
            k = ts.key() if ts is not None else None
            if k is None or k in keys:
                continue  # identical pulse nets fire together: never share
            keys.add(k)
            group.append((i, it))
        return [group]


#: Default post-lowering RTL pipeline.  Controller merging first (it unifies
#: induction-variable nets, which makes address/compute expressions
#: structurally equal), then comb-expression sharing, then the broadcast
#: read share (now that addresses are unified), shift-register merging, then
#: cross-instance time-multiplexing (proven-disjoint pulses) and the
#: II-aware arbitration fallback, and a final dead-net sweep.  The
#: PassManager's fixpoint loop re-runs the sequence while any pass still
#: fires.
RTL_PIPELINE_SPEC = ("rtl-merge-ctrl,rtl-share-comb,rtl-share-mem,"
                     "rtl-merge-srl,rtl-share-instances,rtl-arbitrate,"
                     "rtl-dce")
