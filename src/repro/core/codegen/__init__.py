from .rtl import (RTL_PIPELINE_SPEC, RTLDesign, RTLModule, print_design,  # noqa: F401
                  print_rtl)
from .backends import (BACKENDS, CIRCTPrinter, NetlistPrinter,  # noqa: F401
                       SystemVerilogPrinter, VerilogPrinter, VHDLPrinter,
                       get_printer)
from .verilog import (Netlist, VerilogModule, generate_verilog,  # noqa: F401
                      lower_to_rtl, netlist_of)
from .resources import (ResourceReport, estimate_resources,  # noqa: F401
                        report_design, report_module, sharing_summary)
from .lint import (DIALECT_LINTERS, lint_backend, lint_circt,  # noqa: F401
                   lint_systemverilog, lint_verilog, lint_vhdl)
from .sim import (HAVE_JAX, DiffReport, RTLSimError, RTLSimulator,  # noqa: F401
                  SimResult, probe_cycles, run_differential, simulator_for,
                  stack_stimulus, verify_rtl_passes)
