from .rtl import (RTL_PIPELINE_SPEC, RTLDesign, RTLModule, print_design,  # noqa: F401
                  print_rtl)
from .verilog import (Netlist, VerilogModule, generate_verilog,  # noqa: F401
                      lower_to_rtl, netlist_of)
from .resources import (ResourceReport, estimate_resources,  # noqa: F401
                        report_design, report_module)
from .lint import lint_verilog  # noqa: F401
