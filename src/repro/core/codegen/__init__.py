from .verilog import VerilogModule, generate_verilog  # noqa: F401
from .resources import ResourceReport, estimate_resources  # noqa: F401
