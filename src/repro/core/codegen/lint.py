"""Pure-Python sanity linters for the generated netlists — one rule set per
backend dialect.

These are not parsers — they are tokenizer-level checkers that catch the
classes of emitter bugs that would make the output unsynthesizable:

  * **verilog** — unbalanced ``begin``/``end`` and ``module``/``endmodule``,
    use of undeclared identifiers, duplicate declarations, instances of
    unknown modules;
  * **systemverilog** — the same checks with SV awareness: ``logic``
    declarations, ``always_ff``/``always_comb``, ``typedef enum`` state
    types (the enum labels and the type name become declarations), immediate
    assertions, and the full SV reserved-word table;
  * **vhdl** — ``entity``/``architecture`` pairing, ``process``/``end
    process``, ``if``/``end if``, ``function``/``end function`` balance,
    per-architecture signal/type/port declaration-before-use (VHDL is
    case-insensitive, so the symbol table is too);
  * **circt** — brace/paren balance, per-``hw.module`` SSA def/use closure
    (graph region: order-insensitive), ``hw.instance @Mod`` references must
    resolve.

``lint_backend(text, backend, known_modules=...)`` dispatches on the backend
name; ``lint_verilog`` remains the historical entry point.  ``python -m
repro.core.codegen.lint [--backend NAME|all]`` runs the matching rule set
over every gallery kernel in both inline and hierarchical emission modes —
the CI backend-matrix step.
"""

from __future__ import annotations

import re
from typing import Iterable, Sequence

from .backends import (SYSTEMVERILOG_KEYWORDS, VERILOG_KEYWORDS,
                       VHDL_KEYWORDS)

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_$]*")
_SIZED_LITERAL = re.compile(r"\d*'s?[bdho][0-9a-fA-FxzXZ_]+")
_MODULE = re.compile(r"^\s*module\s+(?P<name>[A-Za-z_][A-Za-z0-9_]*)")
_INSTANCE = re.compile(
    r"^\s*(?P<mod>[A-Za-z_][A-Za-z0-9_]*)\s+(?P<inst>[A-Za-z_][A-Za-z0-9_]*)\s*\(\s*\.")
_TYPEDEF_ENUM = re.compile(
    r"^\s*typedef\s+enum\b[^{]*\{(?P<labels>[^}]*)\}\s*(?P<tname>\w+)\s*;")


def _decl_re(sv: bool) -> re.Pattern:
    kinds = "input|output|inout|wire|reg"
    if sv:
        kinds += "|logic"
    return re.compile(
        r"^\s*(\(\*.*?\*\)\s*)?(?P<kind>" + kinds + r")\b"
        r"(\s+(wire|logic)\b)?(\s+signed\b)?(\s*\[[^\]]*\])?"
        r"\s*(?P<name>[A-Za-z_][A-Za-z0-9_]*)"
    )


def _strip_comments(text: str) -> str:
    text = re.sub(r"\(\*.*?\*\)", " ", text, flags=re.S)  # (* attributes *)
    text = re.sub(r"/\*.*?\*/", " ", text, flags=re.S)
    text = re.sub(r"//[^\n]*", "", text)
    text = re.sub(r'"(?:[^"\\]|\\.)*"', '""', text)  # string literals
    text = re.sub(r"^\s*`\w+[^\n]*$", "", text, flags=re.M)  # `ifdef etc.
    text = re.sub(r"\$[A-Za-z_][A-Za-z0-9_]*", " ", text)  # system tasks
    return text


def _lint_verilog_family(text: str, known_modules: Iterable[str],
                         keywords: frozenset, sv: bool) -> list[str]:
    diags: list[str] = []
    clean = _strip_comments(text)
    lines = clean.split("\n")
    decl = _decl_re(sv)

    # -- balance checks (whole text) ----------------------------------------
    words = re.findall(r"[A-Za-z_][A-Za-z0-9_]*", clean)
    for opener, closer in (("begin", "end"), ("module", "endmodule"),
                           ("case", "endcase")):
        bal = 0
        for w in words:
            if w == opener:
                bal += 1
            elif w == closer:
                bal -= 1
                if bal < 0:
                    diags.append(f"unbalanced {opener}/{closer}: stray {closer}")
                    break
        if bal > 0:
            diags.append(f"unbalanced {opener}/{closer}: {bal} unclosed {opener}")

    # -- per-module declaration / use checks --------------------------------
    defined_modules = {m.group("name") for ln in lines if (m := _MODULE.match(ln))}
    known = set(known_modules) | defined_modules

    declared: set[str] = set()
    user_types: set[str] = set()
    module_name = None
    pending: list[tuple[int, str]] = []  # (lineno, identifier) awaiting decl

    def flush_module(name):
        for lno, ident in pending:
            if ident not in declared:
                diags.append(
                    f"{name or '<top>'}:{lno}: use of undeclared identifier '{ident}'")

    for lno, ln in enumerate(lines, 1):
        m = _MODULE.match(ln)
        if m:
            flush_module(module_name)
            module_name = m.group("name")
            declared = set()
            user_types = set()
            pending = []
            continue
        if re.match(r"^\s*endmodule\b", ln):
            continue

        decl_names: set[str] = set()
        if sv:
            te = _TYPEDEF_ENUM.match(ln)
            if te:
                labels = [l.strip() for l in te.group("labels").split(",")]
                for nm in labels + [te.group("tname")]:
                    if nm:
                        declared.add(nm)
                        decl_names.add(nm)
                user_types.add(te.group("tname"))
            else:
                tv = re.match(r"^\s*(?P<t>[A-Za-z_]\w*)\s+(?P<n>[A-Za-z_]\w*)\s*;",
                              ln)
                if tv and tv.group("t") in user_types:
                    declared.add(tv.group("n"))
                    decl_names.add(tv.group("n"))

        dm = decl.match(ln)
        if dm:
            nm = dm.group("name")
            if nm in declared:
                diags.append(
                    f"{module_name}:{lno}: duplicate declaration of '{nm}'")
            declared.add(nm)
            decl_names.add(nm)

        im = _INSTANCE.match(ln)
        inst_mod = None
        if im and im.group("mod") not in keywords and im.group("mod") not in user_types:
            inst_mod = im.group("mod")
            if inst_mod not in known:
                diags.append(
                    f"{module_name}:{lno}: instance of unknown module '{inst_mod}'")
            declared.add(im.group("inst"))

        # collect identifier uses on the line
        no_lit = _SIZED_LITERAL.sub(" ", ln)
        for ident in _IDENT.findall(no_lit):
            if (ident in keywords or ident.startswith("$")
                    or ident in decl_names or ident in user_types):
                continue
            if inst_mod is not None and ident == inst_mod:
                continue
            if im and ident == im.group("inst"):
                continue
            # port-connection names (.port(...)) belong to the callee
            if im and re.search(rf"\.\s*{re.escape(ident)}\s*\(", ln):
                continue
            if ident in declared:
                continue
            pending.append((lno, ident))

    flush_module(module_name)
    return diags


def lint_verilog(text: str, known_modules: Iterable[str] = ()) -> list[str]:
    """Lint one or more concatenated Verilog modules.  ``known_modules``
    names modules defined elsewhere (blackboxes) that instances may
    reference."""
    return _lint_verilog_family(text, known_modules, VERILOG_KEYWORDS, sv=False)


def lint_systemverilog(text: str,
                       known_modules: Iterable[str] = ()) -> list[str]:
    """Lint concatenated SystemVerilog modules (``logic``, ``always_ff``,
    ``typedef enum`` state types, immediate assertions)."""
    return _lint_verilog_family(text, known_modules,
                                SYSTEMVERILOG_KEYWORDS, sv=True)


# ---------------------------------------------------------------------------
# VHDL
# ---------------------------------------------------------------------------

_VHDL_PORT = re.compile(r"^\s*(?P<name>\w+)\s*:\s*(in|out|inout)\b")
_VHDL_DECL = re.compile(
    r"^\s*(?P<kind>signal|variable|constant|type|attribute)\s+(?P<name>\w+)")
_VHDL_FUNC = re.compile(r"^\s*function\s+(?P<name>\w+)\s*\((?P<params>[^)]*)\)")
_VHDL_LABEL = re.compile(r"^\s*(?P<name>\w+)\s*:\s*(entity|process)\b")
_VHDL_ENTITY = re.compile(r"^\s*entity\s+(?P<name>\w+)\s+is\b")
_VHDL_ARCH = re.compile(
    r"^\s*architecture\s+(?P<name>\w+)\s+of\s+(?P<ent>\w+)\s+is\b")
_VHDL_INST = re.compile(r":\s*entity\s+work\.(?P<mod>\w+)")
_VHDL_IDENT = re.compile(r"[a-z_]\w*")


def lint_vhdl(text: str, known_modules: Iterable[str] = ()) -> list[str]:
    """Lint concatenated VHDL design units: entity/architecture pairing,
    construct balance, per-architecture declaration-before-use (the symbol
    table is case-insensitive, as VHDL is)."""
    diags: list[str] = []
    low = text.lower()
    low = re.sub(r"--[^\n]*", "", low)
    low = re.sub(r'"(?:[^"\\]|\\.)*"', '""', low)
    low = re.sub(r"'.'", " ", low)  # character literals ('0', '1')
    kws = VHDL_KEYWORDS

    def count(rx: str) -> int:
        return len(re.findall(rx, low))

    for opener, orx, crx in (
            ("if", r"\bif\b", r"\bend\s+if\b"),
            ("process", r"\bprocess\b", r"\bend\s+process\b"),
            ("case", r"\bcase\b", r"\bend\s+case\b"),
            ("function", r"\bfunction\s+[a-z_]\w*\s*\(", r"\bend\s+function\b"),
            ("entity", r"\bentity\s+\w+\s+is\b", r"\bend\s+entity\b"),
            ("architecture", r"\barchitecture\s+\w+\s+of\b",
             r"\bend\s+architecture\b"),
    ):
        nc = count(crx)
        no = count(orx) - (nc if opener in ("if", "process", "case") else 0)
        if no != nc:
            diags.append(f"unbalanced {opener}/end {opener}: "
                         f"{no} opener(s), {nc} closer(s)")

    entities = {m.group("name") for ln in low.split("\n")
                if (m := _VHDL_ENTITY.match(ln))}
    known = {k.lower() for k in known_modules} | entities

    ports_of: dict[str, set[str]] = {}
    declared: set[str] = set()
    unit = None          # current diagnostic scope name
    cur_entity = None    # inside an entity port declaration section
    pending: list[tuple[int, str]] = []

    def flush(name):
        for lno, ident in pending:
            if ident not in declared:
                diags.append(
                    f"{name or '<top>'}:{lno}: use of undeclared identifier "
                    f"'{ident}'")

    for lno, ln in enumerate(low.split("\n"), 1):
        em = _VHDL_ENTITY.match(ln)
        if em:
            flush(unit)
            pending = []
            cur_entity = em.group("name")
            unit = f"entity {cur_entity}"
            ports_of.setdefault(cur_entity, set())
            declared = {cur_entity} | kws
            continue
        am = _VHDL_ARCH.match(ln)
        if am:
            flush(unit)
            pending = []
            ent = am.group("ent")
            unit = f"architecture {am.group('name')} of {ent}"
            if ent not in known:
                diags.append(
                    f"{lno}: architecture of unknown entity '{ent}'")
            cur_entity = None
            declared = ({am.group("name"), ent}
                        | ports_of.get(ent, set()) | kws)
            continue
        if re.match(r"^\s*end\b", ln):
            continue

        decl_names: set[str] = set()
        if cur_entity is not None:
            pm = _VHDL_PORT.match(ln)
            if pm:
                ports_of[cur_entity].add(pm.group("name"))
                declared.add(pm.group("name"))
                decl_names.add(pm.group("name"))
        dm = _VHDL_DECL.match(ln)
        if dm:
            nm = dm.group("name")
            if dm.group("kind") != "attribute" and nm in declared and nm not in kws:
                diags.append(f"{unit}:{lno}: duplicate declaration of '{nm}'")
            declared.add(nm)
            decl_names.add(nm)
        fm = _VHDL_FUNC.match(ln)
        if fm:
            declared.add(fm.group("name"))
            decl_names.add(fm.group("name"))
            for param in fm.group("params").split(";"):
                pname = param.split(":")[0].strip()
                if pname:
                    declared.add(pname)
                    decl_names.add(pname)
        lm = _VHDL_LABEL.match(ln)
        if lm:
            declared.add(lm.group("name"))
            decl_names.add(lm.group("name"))
        inst = _VHDL_INST.search(ln)
        if inst and inst.group("mod") not in known:
            diags.append(
                f"{unit}:{lno}: instantiation of unknown entity "
                f"'{inst.group('mod')}'")

        # formals in a one-line "port map (a => b, ...)" belong to the
        # callee, as does the "work.<entity>" selected name itself
        use_ln = re.sub(r"\bwork\.\w+", " ", ln)
        if "port map" in ln:
            use_ln = re.sub(r"(\w+)\s*=>", "=>", use_ln)
        for ident in _VHDL_IDENT.findall(use_ln):
            if ident in kws or ident in declared or ident in decl_names:
                continue
            pending.append((lno, ident))

    flush(unit)
    return diags


# ---------------------------------------------------------------------------
# CIRCT (hw/comb/seq textual MLIR)
# ---------------------------------------------------------------------------

_MLIR_MODULE = re.compile(r"^\s*hw\.module\s+@(?P<name>[\w$.]+)")
_MLIR_SSA = re.compile(r"%[\w$.-]+")
_MLIR_SYM = re.compile(r"@([\w$.]+)")


def lint_circt(text: str, known_modules: Iterable[str] = ()) -> list[str]:
    """Lint hw/comb/seq-dialect textual MLIR: brace/paren balance and, per
    ``hw.module`` (a graph region, so definition order is irrelevant), SSA
    def/use closure plus ``hw.instance`` symbol resolution."""
    diags: list[str] = []
    clean = re.sub(r'"(?:[^"\\]|\\.)*"', '""', text)
    clean = re.sub(r"//[^\n]*", "", clean)
    for o, c in (("{", "}"), ("(", ")"), ("[", "]")):
        if clean.count(o) != clean.count(c):
            diags.append(f"unbalanced {o}{c}: {clean.count(o)} opener(s), "
                         f"{clean.count(c)} closer(s)")

    lines = clean.split("\n")
    defined = {m.group("name") for ln in lines if (m := _MLIR_MODULE.match(ln))}
    known = set(known_modules) | defined

    module = None
    defs: set[str] = set()
    uses: list[tuple[int, str]] = []

    def flush(name):
        for lno, ssa in uses:
            if ssa not in defs:
                diags.append(f"{name or '<top>'}:{lno}: use of undefined "
                             f"SSA value '{ssa}'")

    for lno, ln in enumerate(lines, 1):
        mm = _MLIR_MODULE.match(ln)
        if mm:
            flush(module)
            module = mm.group("name")
            defs = set()
            uses = []
            for arg in re.findall(r"in\s+(%[\w$.-]+)\s*:", ln):
                defs.add(arg)
            continue
        if ln.strip() == "}":
            continue
        if "=" in ln:
            # results left of the first '=' are definitions (this also
            # matches `seq.firmem.write_port %mem[...] = ...`, where the
            # memory symbol is a re-reference — a harmless re-definition)
            lhs, rhs = ln.split("=", 1)
            for d in _MLIR_SSA.findall(lhs):
                defs.add(d)
        else:
            rhs = ln
        for u in _MLIR_SSA.findall(rhs):
            uses.append((lno, u))
        if "hw.instance" in ln:
            for sym in _MLIR_SYM.findall(ln):
                if sym not in known:
                    diags.append(f"{module}:{lno}: instance of unknown "
                                 f"module '@{sym}'")
    flush(module)
    return diags


# ---------------------------------------------------------------------------
# Dispatch + CLI
# ---------------------------------------------------------------------------

DIALECT_LINTERS = {
    "verilog": lint_verilog,
    "systemverilog": lint_systemverilog,
    "vhdl": lint_vhdl,
    "circt": lint_circt,
}


def lint_backend(text: str, backend: str,
                 known_modules: Iterable[str] = ()) -> list[str]:
    """Run the rule set matching ``backend`` over ``text``."""
    try:
        linter = DIALECT_LINTERS[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{sorted(DIALECT_LINTERS)}") from None
    return linter(text, known_modules=known_modules)


def _iter_gallery_rtl(backend: str = "verilog"
                      ) -> Iterable[tuple[str, str, str, Sequence[str]]]:
    """(kernel, mode, concatenated text, module names) for every gallery
    kernel in both emission modes, emitted by ``backend``."""
    from ..gallery import GALLERY
    from ..passes import DEFAULT_PIPELINE_SPEC, PassManager
    from .verilog import generate_verilog

    for name, gal in sorted(GALLERY.items()):
        module, entry = gal.build()
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(module)
        for mode in ("inline", "modules"):
            mods = generate_verilog(module.clone(), entry, hierarchy=mode,
                                    backend=backend)
            text = "\n".join(vm.text for vm in mods.values())
            yield name, mode, text, list(mods)


def main(backends: Iterable[str] = ("verilog",)) -> int:
    failures = 0
    for backend in backends:
        for name, mode, text, modnames in _iter_gallery_rtl(backend):
            diags = lint_backend(text, backend, known_modules=modnames)
            status = "ok" if not diags else f"{len(diags)} issue(s)"
            print(f"lint[{backend:13s}] {name:12s} [{mode:7s}] {status}")
            for d in diags:
                print(f"  {d}")
                failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="lint the generated netlists of every gallery kernel")
    ap.add_argument("--backend", default="verilog",
                    help="backend dialect to emit+lint, or 'all' "
                         f"({sorted(DIALECT_LINTERS)})")
    args = ap.parse_args()
    names = (sorted(DIALECT_LINTERS) if args.backend == "all"
             else [args.backend])
    raise SystemExit(main(names))
