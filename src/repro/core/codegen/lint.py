"""Pure-Python Verilog sanity linter for the generated RTL.

This is not a parser — it is a tokenizer-level checker that catches the
classes of emitter bugs that would make the output unsynthesizable:

  * unbalanced ``begin``/``end`` and ``module``/``endmodule``;
  * use of identifiers that were never declared (ports, ``wire``/``reg``
    declarations, instance names, genvars);
  * duplicate net/port declarations within one module.

``lint_verilog(text, known_modules=...)`` returns a list of diagnostic
strings (empty = clean).  ``python -m repro.core.codegen.lint`` runs it over
every gallery kernel's emitted RTL in both inline and hierarchical emission
modes — the CI step.
"""

from __future__ import annotations

import re
from typing import Iterable, Sequence

_KEYWORDS = {
    "module", "endmodule", "input", "output", "inout", "wire", "reg",
    "assign", "always", "posedge", "negedge", "if", "else", "begin", "end",
    "case", "endcase", "default", "signed", "unsigned", "generate",
    "endgenerate", "genvar", "for", "integer", "localparam", "parameter",
    "initial", "function", "endfunction",
}

_IDENT = re.compile(r"[A-Za-z_][A-Za-z0-9_$]*")
_SIZED_LITERAL = re.compile(r"\d*'s?[bdho][0-9a-fA-FxzXZ_]+")
_DECL = re.compile(
    r"^\s*(\(\*.*?\*\)\s*)?(?P<kind>input|output|inout|wire|reg)\b"
    r"(\s+wire\b)?(\s+signed\b)?(\s*\[[^\]]*\])?\s*(?P<name>[A-Za-z_][A-Za-z0-9_]*)"
)
_MODULE = re.compile(r"^\s*module\s+(?P<name>[A-Za-z_][A-Za-z0-9_]*)")
_INSTANCE = re.compile(
    r"^\s*(?P<mod>[A-Za-z_][A-Za-z0-9_]*)\s+(?P<inst>[A-Za-z_][A-Za-z0-9_]*)\s*\(\s*\.")


def _strip_comments(text: str) -> str:
    text = re.sub(r"\(\*.*?\*\)", " ", text, flags=re.S)  # (* attributes *)
    text = re.sub(r"/\*.*?\*/", " ", text, flags=re.S)
    text = re.sub(r"//[^\n]*", "", text)
    text = re.sub(r'"(?:[^"\\]|\\.)*"', '""', text)  # string literals
    text = re.sub(r"^\s*`\w+[^\n]*$", "", text, flags=re.M)  # `ifdef etc.
    text = re.sub(r"\$[A-Za-z_][A-Za-z0-9_]*", " ", text)  # system tasks
    return text


def lint_verilog(text: str, known_modules: Iterable[str] = ()) -> list[str]:
    """Lint one or more concatenated Verilog modules.  ``known_modules``
    names modules defined elsewhere (blackboxes) that instances may
    reference."""
    diags: list[str] = []
    clean = _strip_comments(text)
    lines = clean.split("\n")

    # -- balance checks (whole text) ----------------------------------------
    words = re.findall(r"[A-Za-z_][A-Za-z0-9_]*", clean)
    for opener, closer in (("begin", "end"), ("module", "endmodule"),
                           ("case", "endcase")):
        bal = 0
        for w in words:
            if w == opener:
                bal += 1
            elif w == closer:
                bal -= 1
                if bal < 0:
                    diags.append(f"unbalanced {opener}/{closer}: stray {closer}")
                    break
        if bal > 0:
            diags.append(f"unbalanced {opener}/{closer}: {bal} unclosed {opener}")

    # -- per-module declaration / use checks --------------------------------
    defined_modules = {m.group("name") for ln in lines if (m := _MODULE.match(ln))}
    known = set(known_modules) | defined_modules

    declared: set[str] = set()
    module_name = None
    pending: list[tuple[int, str]] = []  # (lineno, identifier) awaiting decl

    def flush_module(name):
        for lno, ident in pending:
            if ident not in declared:
                diags.append(
                    f"{name or '<top>'}:{lno}: use of undeclared identifier '{ident}'")

    for lno, ln in enumerate(lines, 1):
        m = _MODULE.match(ln)
        if m:
            flush_module(module_name)
            module_name = m.group("name")
            declared = set()
            pending = []
            continue
        if re.match(r"^\s*endmodule\b", ln):
            continue

        dm = _DECL.match(ln)
        decl_names: set[str] = set()
        if dm:
            nm = dm.group("name")
            if nm in declared:
                diags.append(
                    f"{module_name}:{lno}: duplicate declaration of '{nm}'")
            declared.add(nm)
            decl_names.add(nm)

        im = _INSTANCE.match(ln)
        inst_mod = None
        if im and im.group("mod") not in _KEYWORDS:
            inst_mod = im.group("mod")
            if inst_mod not in known:
                diags.append(
                    f"{module_name}:{lno}: instance of unknown module '{inst_mod}'")
            declared.add(im.group("inst"))

        # collect identifier uses on the line
        no_lit = _SIZED_LITERAL.sub(" ", ln)
        for ident in _IDENT.findall(no_lit):
            if (ident in _KEYWORDS or ident.startswith("$")
                    or ident in decl_names):
                continue
            if inst_mod is not None and ident == inst_mod:
                continue
            if im and ident == im.group("inst"):
                continue
            # port-connection names (.port(...)) belong to the callee
            if im and re.search(rf"\.\s*{re.escape(ident)}\s*\(", ln):
                continue
            if ident in declared:
                continue
            pending.append((lno, ident))

    flush_module(module_name)

    # resolve pendings against late declarations is already handled per
    # module by flushing at endmodule; nothing else to do.
    return diags


def _iter_gallery_rtl() -> Iterable[tuple[str, str, str, Sequence[str]]]:
    """(kernel, mode, concatenated text, module names) for every gallery
    kernel in both emission modes."""
    from copy import deepcopy

    from ..gallery import GALLERY
    from ..passes import DEFAULT_PIPELINE_SPEC, PassManager
    from .verilog import generate_verilog

    for name, gal in sorted(GALLERY.items()):
        module, entry = gal.build()
        PassManager.from_spec(DEFAULT_PIPELINE_SPEC).run(module)
        for mode in ("inline", "modules"):
            mods = generate_verilog(deepcopy(module), entry, hierarchy=mode)
            text = "\n".join(vm.text for vm in mods.values())
            yield name, mode, text, list(mods)


def main() -> int:
    failures = 0
    for name, mode, text, modnames in _iter_gallery_rtl():
        diags = lint_verilog(text, known_modules=modnames)
        status = "ok" if not diags else f"{len(diags)} issue(s)"
        print(f"lint {name:12s} [{mode:7s}] {status}")
        for d in diags:
            print(f"  {d}")
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
