"""FPGA resource model (paper Table 5 analogue) over the structured netlist.

We cannot run Vivado in this environment, so resource usage is estimated from
the *post-RTL-pipeline* netlist structure with a documented cost model for
Xilinx 7-series (the paper's VC709 = Virtex-7):

  LUTs  — one 6-input LUT per output bit of combinational logic (adders,
          comparators, muxes, bitwise ops); LUTRAM at 1 LUT per 2 bits per
          port-pair (RAM64M-style packing); SRL32 shift registers at 1 LUT
          per bit per 32 stages of depth (Vivado maps deep shift registers
          to SRLs, keeping one output FF per bit).
  FFs   — pipeline/output registers, FSM counters, shallow (depth<=2) delay
          chains, register banks.
  DSPs  — 32x32 multiply = 3 DSP48E1 (this matches the paper's GEMM: 256
          PEs x 3 = 768 DSPs); <=17-bit multiply = 1; shift-add/counter
          strength-reduced multiplies = 0 DSPs.
  BRAM  — RAMB18 blocks: ceil(bits/18Kb) per bank, dual-port within one
          block is free (so port demotion saves LUTs, not BRAMs).

The summary (``Netlist``) is **derived from the RTL IR** by
``verilog.netlist_of`` after the RTL pass pipeline ran, so dead, merged and
shared hardware is counted exactly once.  Hierarchical designs are costed by
``report_design``: every module *definition* is estimated once (memoized)
and then weighted by its instantiation multiplicity — 256 instances of one
``mac`` module cost 256x the mac estimate, without re-deriving it per
instance.

The model's purpose is *relative* comparison between HIR-scheduled and
HLS-baseline-scheduled designs under one consistent cost function, mirroring
how the paper compares HIR vs Vivado HLS under one synthesis flow.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Mapping, Optional

from .verilog import Netlist, VerilogModule


@dataclass
class ResourceReport:
    lut: int = 0
    ff: int = 0
    dsp: int = 0
    bram: int = 0

    def __add__(self, o: "ResourceReport") -> "ResourceReport":
        return ResourceReport(self.lut + o.lut, self.ff + o.ff, self.dsp + o.dsp, self.bram + o.bram)

    def scaled(self, k: int) -> "ResourceReport":
        return ResourceReport(self.lut * k, self.ff * k, self.dsp * k, self.bram * k)

    def as_dict(self) -> dict:
        return {"LUT": self.lut, "FF": self.ff, "DSP": self.dsp, "BRAM": self.bram}


def _dsp_for_mult(width: int) -> int:
    if width <= 17:
        return 1
    if width <= 25:
        return 2
    if width <= 34:
        return 3  # 32x32 on DSP48E1 cascade
    return math.ceil(width / 17) ** 2 // 2 + 1


def estimate_resources(nl: Netlist) -> ResourceReport:
    """Flat (single-module) estimate; instances are *not* included — use
    ``report_design`` for hierarchy-aware totals."""
    r = ResourceReport()

    for w in nl.adders:
        r.lut += w
    for w in nl.cmps:
        r.lut += max(1, w // 2 + 1)
    for w in nl.muxes:
        r.lut += w
    for w in nl.logic:
        r.lut += max(1, w // 2)  # 2 bits/LUT for 2-input bitwise

    for w, impl in nl.mults:
        if impl == "dsp":
            r.dsp += _dsp_for_mult(w)
        elif impl == "shift_add":
            r.lut += 2 * w  # two adder terms typical
        elif impl == "counter":
            r.lut += w
            r.ff += w
        elif impl == "div":
            r.lut += w * max(4, w // 2)

    for w, d in nl.shift_regs:
        if d <= 2:
            r.ff += w * d
        else:
            r.lut += w * math.ceil(d / 32)  # SRL32
            r.ff += w  # output register

    for w in nl.registers:
        r.ff += w
    for w in nl.counters:
        r.ff += w
        r.lut += w  # increment + wrap compare

    for banks, depth, width, ports, kind in nl.rams:
        if kind == "bram":
            r.bram += banks * max(1, math.ceil(depth * width / 18432))
        else:  # distributed RAM
            per_bank = math.ceil(depth / 64) * width
            r.lut += banks * per_bank * max(1, ports - 0)  # per read port
    for nregs, width in nl.reg_banks:
        r.ff += nregs * width

    return r


def report_module(vm: VerilogModule) -> ResourceReport:
    return estimate_resources(vm.netlist)


def report_design(mods: Mapping[str, VerilogModule],
                  entry: Optional[str] = None) -> ResourceReport:
    """Hierarchy-aware estimate rooted at ``entry`` (default: every module
    that is not instantiated by another — the top level(s)).  Each module
    definition is estimated once and cached; instantiation multiplicity then
    weights the shared estimate, so a module instantiated 256 times is
    derived once and counted 256 times."""
    memo: dict[str, ResourceReport] = {}

    def cost(name: str, stack: tuple = ()) -> ResourceReport:
        if name in memo:
            return memo[name]
        vm = mods.get(name)
        if vm is None or name in stack:  # external/blackbox or cycle guard
            return ResourceReport()
        r = estimate_resources(vm.netlist)
        for sub in vm.netlist.instances:
            r = r + cost(sub, stack + (name,))
        memo[name] = r
        return r

    if entry is not None:
        return cost(entry)
    instantiated = {sub for vm in mods.values() for sub in vm.netlist.instances}
    roots = [n for n in mods if n not in instantiated] or list(mods)
    total = ResourceReport()
    for n in roots:
        total = total + cost(n)
    return total


def sharing_summary(mods: Mapping[str, VerilogModule],
                    entry: Optional[str] = None) -> dict:
    """Sharing-degree metadata to read alongside ``report_design``: per
    callee module, how many physical time-multiplexed instances survived and
    how many logical instances they absorbed.  ``report_design`` already
    counts a shared instance once (the absorbed ``Instance`` items are gone
    from the netlist); this surfaces *how much* logical hardware each
    physical instance stands in for.

    Returns ``{"per_module": {callee: {"physical": p, "logical": l,
    "max_degree": d}}, "physical_instances": ..., "logical_instances": ...,
    "absorbed": ...}`` — ``absorbed == 0`` means no sharing fired."""
    names = [entry] if entry is not None else list(mods)
    per: dict[str, dict] = {}
    seen: set[str] = set()

    def visit(name: str) -> None:
        if name in seen:
            return
        seen.add(name)
        vm = mods.get(name)
        if vm is None:
            return
        degrees: dict[str, list[int]] = {}
        for sub in vm.netlist.instances:
            degrees.setdefault(sub, []).append(1)
            visit(sub)
        for sub, deg in vm.netlist.shared:
            degrees[sub][degrees[sub].index(1)] = deg
        for sub, ds in degrees.items():
            row = per.setdefault(sub, {"physical": 0, "logical": 0,
                                       "max_degree": 1})
            row["physical"] += len(ds)
            row["logical"] += sum(ds)
            row["max_degree"] = max(row["max_degree"], max(ds))

    for n in names:
        visit(n)
    return {
        "per_module": per,
        "physical_instances": sum(r["physical"] for r in per.values()),
        "logical_instances": sum(r["logical"] for r in per.values()),
        "absorbed": sum(r["logical"] - r["physical"] for r in per.values()),
    }
