"""FPGA resource model (paper Table 5 analogue).

We cannot run Vivado in this environment, so resource usage is estimated from
the generated netlist structure with a documented cost model for Xilinx
7-series (the paper's VC709 = Virtex-7):

  LUTs  — one 6-input LUT per output bit of combinational logic (adders,
          comparators, muxes, bitwise ops); LUTRAM at 1 LUT per 2 bits per
          port-pair (RAM64M-style packing); SRL32 shift registers at 1 LUT
          per bit per 32 stages of depth (Vivado maps deep shift registers
          to SRLs, keeping one output FF per bit).
  FFs   — pipeline/output registers, FSM counters, shallow (depth<=2) delay
          chains, register banks.
  DSPs  — 32x32 multiply = 3 DSP48E1 (this matches the paper's GEMM: 256
          PEs x 3 = 768 DSPs); <=17-bit multiply = 1; shift-add/counter
          strength-reduced multiplies = 0 DSPs.
  BRAM  — RAMB18 blocks: ceil(bits/18Kb) per bank, dual-port within one
          block is free (so port demotion saves LUTs, not BRAMs).

The model's purpose is *relative* comparison between HIR-scheduled and
HLS-baseline-scheduled designs under one consistent cost function, mirroring
how the paper compares HIR vs Vivado HLS under one synthesis flow.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .verilog import Netlist, VerilogModule


@dataclass
class ResourceReport:
    lut: int = 0
    ff: int = 0
    dsp: int = 0
    bram: int = 0

    def __add__(self, o: "ResourceReport") -> "ResourceReport":
        return ResourceReport(self.lut + o.lut, self.ff + o.ff, self.dsp + o.dsp, self.bram + o.bram)

    def as_dict(self) -> dict:
        return {"LUT": self.lut, "FF": self.ff, "DSP": self.dsp, "BRAM": self.bram}


def _dsp_for_mult(width: int) -> int:
    if width <= 17:
        return 1
    if width <= 25:
        return 2
    if width <= 34:
        return 3  # 32x32 on DSP48E1 cascade
    return math.ceil(width / 17) ** 2 // 2 + 1


def estimate_resources(nl: Netlist) -> ResourceReport:
    r = ResourceReport()

    for w in nl.adders:
        r.lut += w
    for w in nl.cmps:
        r.lut += max(1, w // 2 + 1)
    for w in nl.muxes:
        r.lut += w
    for w in nl.logic:
        r.lut += max(1, w // 2)  # 2 bits/LUT for 2-input bitwise

    for w, impl in nl.mults:
        if impl == "dsp":
            r.dsp += _dsp_for_mult(w)
        elif impl == "shift_add":
            r.lut += 2 * w  # two adder terms typical
        elif impl == "counter":
            r.lut += w
            r.ff += w
        elif impl == "div":
            r.lut += w * max(4, w // 2)

    for w, d in nl.shift_regs:
        if d <= 2:
            r.ff += w * d
        else:
            r.lut += w * math.ceil(d / 32)  # SRL32
            r.ff += w  # output register

    for w in nl.registers:
        r.ff += w
    for w in nl.counters:
        r.ff += w
        r.lut += w  # increment + wrap compare

    for banks, depth, width, ports, kind in nl.rams:
        if kind == "bram":
            r.bram += banks * max(1, math.ceil(depth * width / 18432))
        else:  # distributed RAM
            per_bank = math.ceil(depth / 64) * width
            r.lut += banks * per_bank * max(1, ports - 0)  # per read port
    for nregs, width in nl.reg_banks:
        r.ff += nregs * width

    return r


def report_module(vm: VerilogModule) -> ResourceReport:
    return estimate_resources(vm.netlist)
