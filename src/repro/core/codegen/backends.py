"""Multi-backend netlist printers over the structured RTL IR.

Since PR 3 nothing below the HIR level is a string — ``RTLModule`` /
``RTLDesign`` are real data structures and text generation is a *printer*.
This module turns that printer into a backend abstraction:

  * ``NetlistPrinter``        — base class: per-construct emission hooks
                                (one per RTL item kind plus expression
                                printing, declarations, module assembly) and
                                a per-backend **identifier legalizer** that
                                renames nets/ports/modules colliding with the
                                target language's reserved words;
  * ``VerilogPrinter``        — behaviour-preserving port of the historical
                                ``print_rtl`` output (byte-identical for
                                designs without reserved-word collisions);
  * ``SystemVerilogPrinter``  — ``logic`` types, ``always_ff``/``always_comb``,
                                a typed enum per loop-controller FSM and SV
                                immediate assertions for the §4.5 UB
                                port-conflict guards;
  * ``VHDLPrinter``           — entity/architecture pairs, clocked processes,
                                ``numeric_std`` arithmetic (all multi-bit nets
                                are ``unsigned``, 1-bit nets ``std_logic``);
  * ``CIRCTPrinter``          — a CIRCT-style ``hw``/``comb``/``seq``-dialect
                                textual MLIR exporter (SSA form, graph
                                region) for interop with upstream MLIR
                                tooling.

All four read the same optimized ``RTLModule`` — resource summaries
(``verilog.netlist_of``) are derived from the structure *before* printing,
so they are backend-invariant by construction.  ``BACKENDS`` maps backend
name -> printer class; ``get_printer(name)`` instantiates one.
"""

from __future__ import annotations

import re
from typing import Iterable, Optional

from ..ir import UNKNOWN_LOC
from .rtl import (Binop, CombAssign, Const, Expr, Instance, Item,
                  LoopController, MemRead, Memory, MemWrite, Mux, Net,
                  PortConflictAssert, Ref, RegAssign, Repeat, RTLDesign,
                  RTLModule, ShiftReg, Signed, Unop,
                  _ensure_recursion_headroom, zeros)

# ---------------------------------------------------------------------------
# Reserved-word tables (shared with core.codegen.lint's dialect rule sets)
# ---------------------------------------------------------------------------

VERILOG_KEYWORDS = frozenset("""
always and assign automatic begin buf bufif0 bufif1 case casex casez cell
cmos config deassign default defparam design disable edge else end endcase
endconfig endfunction endgenerate endmodule endprimitive endspecify endtable
endtask event for force forever fork function generate genvar highz0 highz1
if ifnone incdir include initial inout input instance integer join large
liblist library localparam macromodule medium module nand negedge nmos nor
noshowcancelled not notif0 notif1 or output parameter pmos posedge primitive
pull0 pull1 pulldown pullup rcmos real realtime reg release repeat rnmos
rpmos rtran rtranif0 rtranif1 scalared showcancelled signed small specify
specparam strong0 strong1 supply0 supply1 table task time tran tranif0
tranif1 tri tri0 tri1 triand trior trireg unsigned use vectored wait wand
weak0 weak1 while wire wor xnor xor
""".split())

SV_EXTRA_KEYWORDS = frozenset("""
accept_on alias always_comb always_ff always_latch assert assume before bind
bins binsof bit break byte chandle checker class clocking const constraint
context continue cover covergroup coverpoint cross dist do endchecker
endclass endclocking endgroup endinterface endpackage endprogram endproperty
endsequence enum eventually expect export extends extern final first_match
foreach forkjoin global iff ignore_bins illegal_bins implements implies
import inside int interconnect interface intersect join_any join_none let
local logic longint matches modport nettype new nexttime null package packed
priority program property protected pure rand randc randcase randsequence
ref reject_on restrict return sequence shortint shortreal soft solve static
string strong struct super tagged this throughout timeprecision timeunit
type typedef union unique unique0 until until_with untyped var virtual void
wait_order weak wildcard with within
""".split())

SYSTEMVERILOG_KEYWORDS = VERILOG_KEYWORDS | SV_EXTRA_KEYWORDS

#: VHDL-2008 reserved words plus the std/numeric_std names the printer leans
#: on — renaming a net called ``resize`` is cheaper than qualifying every use.
VHDL_KEYWORDS = frozenset("""
abs access after alias all and architecture array assert attribute begin
block body buffer bus case component configuration constant context
disconnect downto else elsif end entity exit file for force function
generate generic group guarded if impure in inertial inout is label library
linkage literal loop map mod nand new next nor not null of on open or others
out package parameter port postponed procedure process protected pure range
record register reject release rem report return rol ror select severity
shared signal sla sll sra srl subtype then to transport type unaffected
units until use variable wait when while with xnor xor
std_logic std_logic_vector unsigned signed natural integer boolean string
bit bit_vector real time rising_edge falling_edge to_unsigned to_signed
to_integer resize shift_left shift_right true false note warning error
failure work ieee std_logic_1164 numeric_std rtl b2sl b2i u1
""".split())


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


class NetlistPrinter:
    """Base class of backend printers.  A printer walks one ``RTLModule``
    and emits text through per-construct hooks (``emit_comb``,
    ``emit_shift_reg``, ...); subclasses override the hooks, the expression
    printer and ``assemble`` (header/declarations/footer layout).

    Identifier legalization is shared: ``build_rename_map`` renames any
    port/net/memory/instance name that collides with the backend's
    ``RESERVED`` words (or is not a legal identifier after ``sanitize``),
    and ``module_name_map`` does the same for module names design-wide so
    instance references stay consistent.
    """

    name = ""
    file_ext = ""
    comment_lead = "//"
    RESERVED: frozenset = frozenset()
    case_sensitive = True

    def __init__(self):
        self.m: Optional[RTLModule] = None
        self._ren: dict[str, str] = {}
        self._modmap: dict[str, str] = {}
        self._design: Optional[RTLDesign] = None
        self._callee_ren: dict[str, dict[str, str]] = {}

    # -- identifier legalization -------------------------------------------
    def sanitize(self, nm: str) -> str:
        s = re.sub(r"[^A-Za-z0-9_]", "_", nm) or "n"
        if s[0].isdigit():
            s = "n" + s
        return s

    def _norm(self, nm: str) -> str:
        return nm if self.case_sensitive else nm.lower()

    def is_reserved(self, nm: str) -> bool:
        return self._norm(nm) in self.RESERVED

    def _legal(self, nm: str, used: set) -> str:
        base = self.sanitize(nm)
        cand, k = base, 0
        while self.is_reserved(cand) or self._norm(cand) in used:
            cand = f"{base}_{k}"
            k += 1
        return cand

    def _legalize_names(self, names: Iterable[str]) -> dict[str, str]:
        """First come keeps its own (already-legal) name; everything else —
        reserved words, names needing sanitizing, case-insensitive dups —
        is renamed to a fresh legal identifier."""
        ordered, seen = [], set()
        for nm in names:
            if nm not in seen:
                seen.add(nm)
                ordered.append(nm)
        ren: dict[str, str] = {}
        used: set[str] = set()
        pending: list[str] = []
        for nm in ordered:
            if (self.sanitize(nm) == nm and not self.is_reserved(nm)
                    and self._norm(nm) not in used):
                used.add(self._norm(nm))
            else:
                pending.append(nm)
        for nm in pending:
            new = self._legal(nm, used)
            used.add(self._norm(new))
            ren[nm] = new
        return ren

    def build_rename_map(self, m: RTLModule) -> dict[str, str]:
        names = [p.name for p in m.ports] + list(m.nets)
        for it in m.items:
            if isinstance(it, Memory):
                names.append(it.name)
            elif isinstance(it, Instance):
                names.append(it.inst)
        return self._legalize_names(names)

    def module_name_map(self, names: Iterable[str]) -> dict[str, str]:
        return self._legalize_names(names)

    def n(self, nm: str) -> str:
        """The legalized spelling of a net/port/memory/instance name."""
        return self._ren.get(nm, nm)

    def mod(self, nm: str) -> str:
        """The legalized spelling of a module name."""
        return self._modmap.get(nm, nm)

    def callee_port_name(self, module: str, pname: str) -> str:
        """The spelling of ``pname`` as the callee module itself prints it
        (the callee's own rename map decides)."""
        if self._design is None or module not in self._design.modules:
            return pname
        ren = self._callee_ren.get(module)
        if ren is None:
            ren = self.build_rename_map(self._design.modules[module])
            self._callee_ren[module] = ren
        return ren.get(pname, pname)

    # -- widths -------------------------------------------------------------
    def width_of(self, name: str) -> Optional[int]:
        net = self.m.nets.get(name)
        if net is not None:
            return net.width
        for p in self.m.ports:
            if p.name == name:
                return p.width
        return None

    _CMPS = ("<", "<=", "==", "!=", ">", ">=")

    def expr_width(self, e: Expr) -> Optional[int]:
        if isinstance(e, Const):
            return e.width
        if isinstance(e, Ref):
            return self.width_of(e.name)
        if isinstance(e, Signed):
            return self.expr_width(e.a)
        if isinstance(e, Unop):
            return self.expr_width(e.a) or e.width
        if isinstance(e, Binop):
            if e.op in self._CMPS or e.op in ("&&", "||"):
                return 1
            ws = [w for w in (self.expr_width(e.a), self.expr_width(e.b)) if w]
            return max(ws) if ws else e.width
        if isinstance(e, Mux):
            ws = [w for w in (self.expr_width(e.a), self.expr_width(e.b)) if w]
            return max(ws) if ws else (e.width or 1)
        if isinstance(e, Repeat):
            return e.n * (self.expr_width(e.a) or 1)
        return None

    # -- public API ----------------------------------------------------------
    def print_module(self, m: RTLModule,
                     modmap: Optional[dict[str, str]] = None,
                     design: Optional[RTLDesign] = None) -> str:
        _ensure_recursion_headroom()
        self.m = m
        self._design = design
        if modmap is not None:
            self._modmap = modmap
        else:
            refs = [m.name] + [it.module for it in m.items
                               if isinstance(it, Instance)]
            self._modmap = self.module_name_map(refs)
        self._ren = self.build_rename_map(m)
        self.reset()
        decls: list[str] = []
        lines: list[str] = []
        for it in m.items:
            self.emit_item(it, lines, decls)
        return self.assemble(m, decls, lines)

    def print_modules(self, design: RTLDesign) -> dict[str, str]:
        modmap = self.module_name_map(design.modules)
        return {name: self.print_module(mm, modmap=modmap, design=design)
                for name, mm in design.modules.items()}

    def print_design(self, design: RTLDesign) -> str:
        return "\n".join(self.print_modules(design).values())

    def reset(self) -> None:
        """Per-module printer state; called after the rename map is built."""

    # -- dispatch ------------------------------------------------------------
    def emit_item(self, it: Item, out: list[str], decls: list[str]) -> None:
        if isinstance(it, CombAssign):
            self.emit_comb(it, out, decls)
        elif isinstance(it, ShiftReg):
            self.emit_shift_reg(it, out, decls)
        elif isinstance(it, RegAssign):
            self.emit_reg_assign(it, out, decls)
        elif isinstance(it, Memory):
            self.emit_memory(it, out, decls)
        elif isinstance(it, MemRead):
            self.emit_mem_read(it, out, decls)
        elif isinstance(it, MemWrite):
            self.emit_mem_write(it, out, decls)
        elif isinstance(it, LoopController):
            self.emit_controller(it, out, decls)
        elif isinstance(it, Instance):
            self.emit_instance(it, out, decls)
        elif isinstance(it, PortConflictAssert):
            self.emit_assert(it, out, decls)
        else:  # pragma: no cover - future item kinds
            raise NotImplementedError(type(it).__name__)

    def loc_of(self, it: Item) -> str:
        if it.loc is UNKNOWN_LOC:
            return ""
        return f" {self.comment_lead} {it.loc}"

    # hooks subclasses must provide
    def emit_comb(self, it, out, decls):  # pragma: no cover - abstract
        raise NotImplementedError

    def emit_shift_reg(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_reg_assign(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_memory(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_mem_read(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_mem_write(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_controller(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_instance(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def emit_assert(self, it, out, decls):  # pragma: no cover
        raise NotImplementedError

    def assemble(self, m, decls, lines) -> str:  # pragma: no cover
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Verilog (the historical printer, byte-identical modulo legalization)
# ---------------------------------------------------------------------------


class VerilogPrinter(NetlistPrinter):
    name = "verilog"
    file_ext = ".v"
    RESERVED = VERILOG_KEYWORDS

    # -- expressions ---------------------------------------------------------
    def x(self, e: Expr) -> str:
        if isinstance(e, Const):
            return self.x_const(e)
        if isinstance(e, Ref):
            return self.n(e.name)
        if isinstance(e, Signed):
            return f"$signed({self.x(e.a)})"
        if isinstance(e, Unop):
            return f"{e.op}({self.x(e.a)})"
        if isinstance(e, Binop):
            return f"({self.x(e.a)} {e.op} {self.x(e.b)})"
        if isinstance(e, Mux):
            return f"(({self.x(e.cond)}) ? ({self.x(e.a)}) : ({self.x(e.b)}))"
        if isinstance(e, Repeat):
            return f"{{{e.n}{{{self.x(e.a)}}}}}"
        raise NotImplementedError(type(e).__name__)

    @staticmethod
    def x_const(e: Const) -> str:
        if e.width is None or not isinstance(e.value, int):
            return str(e.value)
        if e.signed and e.value < 0:
            return f"-{e.width}'sd{-e.value}"
        if e.value < 0:
            return f"-{e.width}'d{-e.value}"
        return f"{e.width}'d{e.value}"

    # -- declarations --------------------------------------------------------
    def decl_net(self, net: Net) -> str:
        sgn = " signed" if net.signed else ""
        rng = f" [{net.width - 1}:0]" if net.width > 1 else ""
        c = f" // {net.comment}" if net.comment else ""
        return f"{net.kind}{sgn}{rng} {self.n(net.name)};{c}"

    def port_decl(self, p) -> str:
        rng = f" [{p.width - 1}:0]" if p.width > 1 else ""
        return f"{p.dir} wire{rng} {self.n(p.name)}"

    def reg_kw(self) -> str:
        return "reg"

    def clocked(self) -> str:
        return "always @(posedge clk)"

    # -- items ---------------------------------------------------------------
    def emit_comb(self, it: CombAssign, out, decls) -> None:
        out.append(f"assign {self.n(it.dest)} = {self.x(it.expr)};{self.loc_of(it)}")

    def emit_shift_reg(self, it: ShiftReg, out, decls) -> None:
        nm, d, w = self.n(it.dest), it.depth, it.width
        loc = self.loc_of(it)
        kw, clk = self.reg_kw(), self.clocked()
        rst = "rst ? " if it.reset_zero else ""
        if d == 1:
            decls.append(f"{kw} [{w - 1}:0] {nm}_q;" if w > 1 else f"{kw} {nm}_q;")
            z = self.x(zeros(w))
            src = f"{z} : {self.x(it.src)}" if it.reset_zero else f"{self.x(it.src)}"
            out.append(f"{clk} {nm}_q <= {rst}{src};{loc}")
            out.append(f"assign {nm} = {nm}_q;")
            return
        decls.append(f"{kw} [{w - 1}:0] {nm}_sr [0:{d - 1}];")
        out.append(f"{clk} begin{loc}")
        if it.reset_zero:
            out.append(f"  {nm}_sr[0] <= rst ? {self.x(zeros(w))} : {self.x(it.src)};")
        else:
            out.append(f"  {nm}_sr[0] <= {self.x(it.src)};")
        for s in range(1, d):
            if it.reset_zero:
                out.append(f"  {nm}_sr[{s}] <= rst ? {self.x(zeros(w))} : {nm}_sr[{s - 1}];")
            else:
                out.append(f"  {nm}_sr[{s}] <= {nm}_sr[{s - 1}];")
        out.append("end")
        out.append(f"assign {nm} = {nm}_sr[{d - 1}];")

    def emit_reg_assign(self, it: RegAssign, out, decls) -> None:
        guard = f"if ({self.x(it.en)}) " if it.en is not None else ""
        out.append(f"{self.clocked()} {guard}{self.n(it.dest)} <= "
                   f"{self.x(it.src)};{self.loc_of(it)}")

    def emit_memory(self, it: Memory, out, decls) -> None:
        style = "block" if it.kind == "bram" else "distributed"
        for bk in range(it.banks):
            decls.append(
                f'(* ram_style = "{style}" *) {self.reg_kw()} [{it.width - 1}:0] '
                f"{self.n(it.name)}_ram{bk} [0:{max(it.depth - 1, 1)}];"
            )

    def emit_mem_read(self, it: MemRead, out, decls) -> None:
        out.append(
            f"{self.clocked()} if ({self.x(it.en)}) "
            f"{self.n(it.dest)} <= {self.n(it.mem)}_ram{it.bank}"
            f"[{self.x(it.addr)}];{self.loc_of(it)}"
        )

    def emit_mem_write(self, it: MemWrite, out, decls) -> None:
        out.append(
            f"{self.clocked()} if ({self.x(it.en)}) "
            f"{self.n(it.mem)}_ram{it.bank}[{self.x(it.addr)}] <= "
            f"{self.x(it.data)};{self.loc_of(it)}"
        )

    def emit_controller(self, it: LoopController, out, decls) -> None:
        iv, act, itr = self.n(it.iv), self.n(it.active), self.n(it.iter_net)
        endp = self.n(it.endp) if it.endp else ""
        clk = self.clocked()
        start = self.x(it.start)
        step_up = f"{iv} + {self.x(it.step)}"
        more = f"({step_up} < {self.x(it.ub)})"
        if it.ii is not None:
            ii = it.ii
            iicnt = self.n(it.iicnt) if it.iicnt else ""
            cond_next = f"{iicnt} == {ii - 1}" if ii > 1 else "1'b1"
            out.append(f"// controller: hir.for %{iv} II={ii} {it.loc}")
            out.append(
                f"assign {itr} = {start} | ({act} && ({cond_next}) && {more});")
            out.append(f"{clk} begin")
            if ii > 1:
                out.append(f"  if (rst) begin {act} <= 0; {iicnt} <= 0; end")
            else:
                out.append(f"  if (rst) {act} <= 0;")
            out.append(f"  else if ({start}) begin")
            init_cnt = f" {iicnt} <= 0;" if ii > 1 else ""
            out.append(f"    {act} <= 1; {iv} <= {self.x(it.lb)};{init_cnt}")
            out.append(f"  end else if ({act}) begin")
            if ii > 1:
                out.append(f"    {iicnt} <= ({cond_next}) ? 0 : {iicnt} + 1;")
            out.append(f"    if ({cond_next}) begin")
            out.append(f"      if ({more}) {iv} <= {step_up};")
            out.append(f"      else {act} <= 0;")
            out.append("    end")
            out.append("  end")
            out.append("end")
            if endp:
                out.append(
                    f"{clk} {endp} <= "
                    f"{act} && ({cond_next}) && ({step_up} >= {self.x(it.ub)});")
        else:
            inner = self.x(it.inner_end)
            out.append(f"// controller: sequential hir.for %{iv} {it.loc}")
            out.append(
                f"assign {itr} = {start} | (({inner}) && {act} && {more});")
            out.append(f"{clk} begin")
            out.append(f"  if (rst) {act} <= 0;")
            out.append(f"  else if ({start}) begin {act} <= 1; "
                       f"{iv} <= {self.x(it.lb)}; end")
            out.append(f"  else if (({inner}) && {act}) begin")
            out.append(f"    if ({more}) {iv} <= {step_up};")
            out.append(f"    else {act} <= 0;")
            out.append("  end")
            out.append("end")
            if endp:
                out.append(
                    f"{clk} {endp} <= ({inner}) && {act} && "
                    f"({step_up} >= {self.x(it.ub)});")

    def emit_instance(self, it: Instance, out, decls) -> None:
        if it.share:
            out.append(f"// time-shared x{1 + len(it.share)}: absorbs "
                       f"{', '.join(self.n(s) for s in it.share)}")
        conns = ", ".join(
            f".{self.callee_port_name(it.module, p)}({self.x(e)})"
            for p, e, _o in it.conns)
        out.append(f"{self.mod(it.module)} {self.n(it.inst)} "
                   f"({conns});{self.loc_of(it)}")

    def emit_assert(self, it: PortConflictAssert, out, decls) -> None:
        out.append("`ifndef SYNTHESIS")
        cond = " + ".join(f"(({self.x(e)}) ? 1 : 0)" for e in it.ens)
        out.append(
            f"always @(posedge clk) if (({cond}) > 1) "
            f'$error("port conflict on {self.n(it.bus)} (UB 4.5)");'
        )
        out.append("`endif")

    def assemble(self, m: RTLModule, decls, lines) -> str:
        hdr = f"// generated by repro.core.codegen from @{m.source_func} ({m.loc})\n"
        ports = ",\n    ".join(self.port_decl(p) for p in m.ports)
        hdr += f"module {self.mod(m.name)} (\n    {ports}\n);\n"
        all_decls = [self.decl_net(n) for n in m.nets.values()] + decls
        body = "\n".join("  " + l for l in all_decls + [""] + lines)
        return hdr + body + "\nendmodule\n"


# ---------------------------------------------------------------------------
# SystemVerilog
# ---------------------------------------------------------------------------


class SystemVerilogPrinter(VerilogPrinter):
    """SystemVerilog: every net is ``logic``, clocked blocks are
    ``always_ff``, each loop-controller FSM gets a typed enum state and the
    §4.5 UB guards become SV immediate assertions."""

    name = "systemverilog"
    file_ext = ".sv"
    RESERVED = SYSTEMVERILOG_KEYWORDS

    def decl_net(self, net: Net) -> str:
        sgn = " signed" if net.signed else ""
        rng = f" [{net.width - 1}:0]" if net.width > 1 else ""
        c = f" // {net.comment}" if net.comment else ""
        return f"logic{sgn}{rng} {self.n(net.name)};{c}"

    def port_decl(self, p) -> str:
        rng = f" [{p.width - 1}:0]" if p.width > 1 else ""
        return f"{p.dir} logic{rng} {self.n(p.name)}"

    def reg_kw(self) -> str:
        return "logic"

    def clocked(self) -> str:
        return "always_ff @(posedge clk)"

    def emit_controller(self, it: LoopController, out, decls) -> None:
        iv, act, itr = self.n(it.iv), self.n(it.active), self.n(it.iter_net)
        endp = self.n(it.endp) if it.endp else ""
        p = self.sanitize(it.prefix) or "loop"
        st, ste = f"{p}_state", f"{p}_state_t"
        idle, run = f"{p.upper()}_IDLE", f"{p.upper()}_RUN"
        decls.append(f"typedef enum logic [0:0] {{{idle}, {run}}} {ste};")
        decls.append(f"{ste} {st};")
        start = self.x(it.start)
        step_up = f"{iv} + {self.x(it.step)}"
        more = f"({step_up} < {self.x(it.ub)})"
        out.append(f"assign {act} = ({st} == {run});")
        if it.ii is not None:
            ii = it.ii
            iicnt = self.n(it.iicnt) if it.iicnt else ""
            cond_next = f"{iicnt} == {ii - 1}" if ii > 1 else "1'b1"
            out.append(f"// controller: hir.for %{iv} II={ii} {it.loc}")
            out.append(
                f"assign {itr} = {start} | ({act} && ({cond_next}) && {more});")
            out.append("always_ff @(posedge clk) begin")
            if ii > 1:
                out.append(f"  if (rst) begin {st} <= {idle}; {iicnt} <= 0; end")
            else:
                out.append(f"  if (rst) {st} <= {idle};")
            out.append(f"  else if ({start}) begin")
            init_cnt = f" {iicnt} <= 0;" if ii > 1 else ""
            out.append(f"    {st} <= {run}; {iv} <= {self.x(it.lb)};{init_cnt}")
            out.append(f"  end else if ({st} == {run}) begin")
            if ii > 1:
                out.append(f"    {iicnt} <= ({cond_next}) ? 0 : {iicnt} + 1;")
            out.append(f"    if ({cond_next}) begin")
            out.append(f"      if ({more}) {iv} <= {step_up};")
            out.append(f"      else {st} <= {idle};")
            out.append("    end")
            out.append("  end")
            out.append("end")
            if endp:
                out.append(
                    f"always_ff @(posedge clk) {endp} <= "
                    f"{act} && ({cond_next}) && ({step_up} >= {self.x(it.ub)});")
        else:
            inner = self.x(it.inner_end)
            out.append(f"// controller: sequential hir.for %{iv} {it.loc}")
            out.append(
                f"assign {itr} = {start} | (({inner}) && {act} && {more});")
            out.append("always_ff @(posedge clk) begin")
            out.append(f"  if (rst) {st} <= {idle};")
            out.append(f"  else if ({start}) begin {st} <= {run}; "
                       f"{iv} <= {self.x(it.lb)}; end")
            out.append(f"  else if (({inner}) && {act}) begin")
            out.append(f"    if ({more}) {iv} <= {step_up};")
            out.append(f"    else {st} <= {idle};")
            out.append("  end")
            out.append("end")
            if endp:
                out.append(
                    f"always_ff @(posedge clk) {endp} <= ({inner}) && {act} && "
                    f"({step_up} >= {self.x(it.ub)});")

    def emit_assert(self, it: PortConflictAssert, out, decls) -> None:
        cond = " + ".join(f"(({self.x(e)}) ? 1 : 0)" for e in it.ens)
        out.append("`ifndef SYNTHESIS")
        out.append(
            f"always @(posedge clk) assert (({cond}) <= 1) "
            f'else $error("port conflict on {self.n(it.bus)} (UB 4.5)");'
        )
        out.append("`endif")


# ---------------------------------------------------------------------------
# VHDL
# ---------------------------------------------------------------------------


class VHDLPrinter(NetlistPrinter):
    """VHDL-2008: one entity/architecture pair per module, ``numeric_std``
    arithmetic.  Typing rule: 1-bit nets are ``std_logic``, wider nets are
    ``unsigned``; three helper functions (``b2sl``/``u1``/``b2i``) bridge the
    boolean/std_logic/unsigned worlds.  Expressions that VHDL cannot nest
    (muxes below an assignment's top level, replications) are hoisted onto
    printer-local auxiliary signals — the RTL IR itself is never mutated."""

    name = "vhdl"
    file_ext = ".vhd"
    comment_lead = "--"
    RESERVED = VHDL_KEYWORDS
    case_sensitive = False

    HELPERS = [
        "function b2sl(b : boolean) return std_logic is",
        "begin",
        "  if b then return '1'; end if;",
        "  return '0';",
        "end function;",
        "function u1(s : std_logic) return unsigned is",
        "begin",
        "  if s = '1' then return to_unsigned(1, 1); end if;",
        "  return to_unsigned(0, 1);",
        "end function;",
        "function b2i(s : std_logic) return natural is",
        "begin",
        "  if s = '1' then return 1; end if;",
        "  return 0;",
        "end function;",
    ]

    def sanitize(self, nm: str) -> str:
        s = re.sub(r"[^A-Za-z0-9_]", "_", nm) or "n"
        s = re.sub(r"_+", "_", s).strip("_") or "n"
        if s[0].isdigit():
            s = "n" + s
        return s

    def reset(self) -> None:
        self._aux: list[str] = []
        self._auxdecl: list[str] = []
        self._auxn = 0
        self._ramstyle_declared = False

    def ty(self, w: Optional[int]) -> str:
        if w is None or w <= 1:
            return "std_logic"
        return f"unsigned({w - 1} downto 0)"

    def fresh_aux(self, w: int) -> str:
        self._auxn += 1
        nm = f"vhx{self._auxn}"
        while self.width_of(nm) is not None:
            self._auxn += 1
            nm = f"vhx{self._auxn}"
        self._auxdecl.append(f"signal {nm} : {self.ty(w)};")
        return nm

    # -- expression typing ---------------------------------------------------
    # vx(e) -> (text, kind, width); kind in {"sl","u","s","int","bool"}
    _VCMP = {"<": "<", "<=": "<=", "==": "=", "!=": "/=", ">": ">", ">=": ">="}

    def vx(self, e: Expr) -> tuple[str, str, Optional[int]]:
        if isinstance(e, Const):
            if e.width is None or not isinstance(e.value, int):
                return str(e.value), "int", None
            if e.width == 1:
                return ("'1'" if int(e.value) & 1 else "'0'"), "sl", 1
            if e.signed and e.value < 0:
                return f"to_signed({e.value}, {e.width})", "s", e.width
            return f"to_unsigned({e.value}, {e.width})", "u", e.width
        if isinstance(e, Ref):
            w = self.width_of(e.name)
            if w == 1:
                return self.n(e.name), "sl", 1
            return self.n(e.name), "u", w
        if isinstance(e, Signed):
            t, k, w = self.vx(e.a)
            if k == "u":
                return f"signed({t})", "s", w
            if k == "sl":
                return f"signed(u1({t}))", "s", 1
            return t, k, w
        if isinstance(e, Unop):
            if e.op == "~":
                t, k, w = self.vx(e.a)
                if k in ("sl", "bool"):
                    return f"(not {self.as_sl(e.a)})", "sl", 1
                return f"(not {t})", k, w
            t, k, w = self.vx(e.a)
            return f"{e.op}({t})", k, w
        if isinstance(e, Binop):
            return self.vx_binop(e)
        if isinstance(e, Mux):
            return self.hoist_mux(e)
        if isinstance(e, Repeat):
            if isinstance(e.a, Const) and e.a.value == 0:
                if e.n == 1:
                    return "'0'", "sl", 1
                return f"to_unsigned(0, {e.n})", "u", e.n
            return self.hoist_repeat(e)
        raise NotImplementedError(type(e).__name__)

    # kind coercion on already-printed triples
    @staticmethod
    def _num(tkw) -> tuple[str, str, Optional[int]]:
        t, k, w = tkw
        if k == "sl":
            return f"u1({t})", "u", 1
        if k == "bool":
            return f"u1(b2sl({t}))", "u", 1
        return t, k, w

    @staticmethod
    def _pair(a, b):
        """Make a numeric pair type-compatible (signed wins)."""
        if a[1] == "s" and b[1] == "u":
            b = (f"signed({b[0]})", "s", b[2])
        elif b[1] == "s" and a[1] == "u":
            a = (f"signed({a[0]})", "s", a[2])
        return a, b

    def as_sl(self, e: Expr) -> str:
        t, k, w = self.vx(e)
        if k == "sl":
            return t
        if k == "bool":
            return f"b2sl({t})"
        if k == "int":
            return "'0'" if t in ("0", "-0") else "'1'"
        if w == 1:
            return f"{t}(0)"
        return f"b2sl({t} /= 0)"

    def as_bool(self, e: Expr) -> str:
        t, k, _w = self.vx(e)
        if k == "bool":
            return t
        if k == "sl":
            return f"({t} = '1')"
        if k == "int":
            return "false" if t in ("0", "-0") else "true"
        return f"({t} /= 0)"

    def as_num(self, e: Expr) -> str:
        return self._num(self.vx(e))[0]

    def as_assign(self, e: Expr, dw: Optional[int]) -> str:
        """RHS text for assignment into a destination of width ``dw``."""
        if dw == 1:
            return self.as_sl(e)
        t, k, w = self.vx(e)
        if dw is None:
            return self._num((t, k, w))[0]
        if k == "int":
            if t.lstrip("-").isdigit() and t.startswith("-"):
                return f"unsigned(to_signed({t}, {dw}))"
            return f"to_unsigned({t}, {dw})"
        if k == "sl":
            return f"resize(u1({t}), {dw})"
        if k == "bool":
            return f"resize(u1(b2sl({t})), {dw})"
        if k == "s":
            return f"unsigned(resize({t}, {dw}))"
        if w == dw:
            return t
        return f"resize({t}, {dw})"

    def vx_binop(self, e: Binop) -> tuple[str, str, Optional[int]]:
        op = e.op
        if op in self._VCMP:
            A, B = self.vx(e.a), self.vx(e.b)
            if A[1] == "sl" and B[1] == "sl" and op in ("==", "!="):
                return f"({A[0]} {'=' if op == '==' else '/='} {B[0]})", "bool", 1
            A, B = self._pair(self._num(A), self._num(B))
            return f"({A[0]} {self._VCMP[op]} {B[0]})", "bool", 1
        if op in ("&&", "||"):
            vop = "and" if op == "&&" else "or"
            return f"({self.as_bool(e.a)} {vop} {self.as_bool(e.b)})", "bool", 1
        if op in ("&", "|", "^"):
            vop = {"&": "and", "|": "or", "^": "xor"}[op]
            wa = self.expr_width(e.a) or 1
            wb = self.expr_width(e.b) or 1
            if wa == 1 and wb == 1:
                return f"({self.as_sl(e.a)} {vop} {self.as_sl(e.b)})", "sl", 1
            w = max(wa, wb)
            return (f"({self.as_assign(e.a, w)} {vop} {self.as_assign(e.b, w)})",
                    "u", w)
        if op in ("+", "-", "*", "/"):
            A, B = self._pair(self._num(self.vx(e.a)), self._num(self.vx(e.b)))
            if A[1] == "int" and B[1] == "int":
                kind: str = "int"
            else:
                kind = "s" if "s" in (A[1], B[1]) else "u"
            ws = [w for w in (A[2], B[2]) if w]
            if op == "*":
                w = (A[2] + B[2]) if (A[2] and B[2]) else None
            elif op == "/":
                w = A[2]
            else:
                w = max(ws) if ws else None
            return f"({A[0]} {op} {B[0]})", kind, w
        if op in ("<<", ">>"):
            A = self._num(self.vx(e.a))
            if A[1] == "int":
                A = (f"to_unsigned({A[0]}, 32)", "u", 32)
            if isinstance(e.b, Const) and isinstance(e.b.value, int):
                amt = str(e.b.value)
            else:
                amt = f"to_integer({self.as_num(e.b)})"
            fn = "shift_left" if op == "<<" else "shift_right"
            return f"{fn}({A[0]}, {amt})", A[1], A[2]
        raise NotImplementedError(op)

    def hoist_mux(self, e: Mux) -> tuple[str, str, Optional[int]]:
        w = self.expr_width(e) or 1
        nm = self.fresh_aux(w)
        self._aux.append(self.cond_assign(nm, e, w))
        return nm, ("sl" if w == 1 else "u"), w

    def hoist_repeat(self, e: Repeat) -> tuple[str, str, Optional[int]]:
        wa = self.expr_width(e.a) or 1
        w = e.n * wa
        nm = self.fresh_aux(w)
        if wa == 1:
            self._aux.append(f"{nm} <= (others => {self.as_sl(e.a)});")
        else:
            t = self.as_num(e.a)
            self._aux.append(f"{nm} <= {' & '.join([t] * e.n)};")
        return nm, ("sl" if w == 1 else "u"), w

    def cond_assign(self, dest: str, e: Expr, dw: Optional[int],
                    loc: str = "") -> str:
        """A (possibly conditional) signal assignment; top-level muxes become
        chained ``when/else`` clauses."""
        if isinstance(e, Mux):
            parts = []
            cur: Expr = e
            while isinstance(cur, Mux):
                parts.append((self.as_bool(cur.cond), self.as_assign(cur.a, dw)))
                cur = cur.b
            tail = self.as_assign(cur, dw)
            rhs = " else ".join(f"{v} when {c}" for c, v in parts)
            return f"{dest} <= {rhs} else {tail};{loc}"
        return f"{dest} <= {self.as_assign(e, dw)};{loc}"

    def vidx(self, e: Expr) -> str:
        if isinstance(e, Const) and isinstance(e.value, int):
            return str(e.value)
        return f"to_integer({self.as_num(e)})"

    # -- items ---------------------------------------------------------------
    def emit_comb(self, it: CombAssign, out, decls) -> None:
        dw = self.width_of(it.dest) or self.expr_width(it.expr) or 1
        out.append(self.cond_assign(self.n(it.dest), it.expr, dw,
                                    self.loc_of(it)))

    def emit_shift_reg(self, it: ShiftReg, out, decls) -> None:
        nm, d, w = self.n(it.dest), it.depth, it.width
        loc = self.loc_of(it)
        zero = "'0'" if w == 1 else "(others => '0')"
        src = self.as_assign(it.src, w)
        if d == 1:
            q = f"{nm}_q"
            decls.append(f"signal {q} : {self.ty(w)};")
            out.append(f"process(clk) begin{loc}")
            if it.reset_zero:
                out.append(f"  if rising_edge(clk) then if rst = '1' then "
                           f"{q} <= {zero}; else {q} <= {src}; end if; end if;")
            else:
                out.append(f"  if rising_edge(clk) then {q} <= {src}; end if;")
            out.append("end process;")
            out.append(f"{nm} <= {q};")
            return
        t, s = f"{nm}_sr_t", f"{nm}_sr"
        decls.append(f"type {t} is array (0 to {d - 1}) of {self.ty(w)};")
        decls.append(f"signal {s} : {t};")
        out.append(f"process(clk) begin{loc}")
        out.append("  if rising_edge(clk) then")
        if it.reset_zero:
            out.append(f"    if rst = '1' then {s}(0) <= {zero}; "
                       f"else {s}(0) <= {src}; end if;")
        else:
            out.append(f"    {s}(0) <= {src};")
        for i in range(1, d):
            if it.reset_zero:
                out.append(f"    if rst = '1' then {s}({i}) <= {zero}; "
                           f"else {s}({i}) <= {s}({i - 1}); end if;")
            else:
                out.append(f"    {s}({i}) <= {s}({i - 1});")
        out.append("  end if;")
        out.append("end process;")
        out.append(f"{nm} <= {s}({d - 1});")

    def emit_reg_assign(self, it: RegAssign, out, decls) -> None:
        d = self.n(it.dest)
        w = self.width_of(it.dest)
        src = self.as_assign(it.src, w)
        out.append(f"process(clk) begin{self.loc_of(it)}")
        if it.en is not None:
            out.append(f"  if rising_edge(clk) then if {self.as_bool(it.en)} "
                       f"then {d} <= {src}; end if; end if;")
        else:
            out.append(f"  if rising_edge(clk) then {d} <= {src}; end if;")
        out.append("end process;")

    def emit_memory(self, it: Memory, out, decls) -> None:
        style = "block" if it.kind == "bram" else "distributed"
        if not self._ramstyle_declared:
            decls.append("attribute ram_style : string;")
            self._ramstyle_declared = True
        base = self.n(it.name)
        et = self.ty(it.width)
        for bk in range(it.banks):
            rn = f"{base}_ram{bk}"
            decls.append(f"type {rn}_t is array (0 to {max(it.depth - 1, 1)}) "
                         f"of {et};")
            decls.append(f"signal {rn} : {rn}_t;")
            decls.append(f'attribute ram_style of {rn} : signal is "{style}";')

    def emit_mem_read(self, it: MemRead, out, decls) -> None:
        rn = f"{self.n(it.mem)}_ram{it.bank}"
        out.append(f"process(clk) begin{self.loc_of(it)}")
        out.append(f"  if rising_edge(clk) then if {self.as_bool(it.en)} then "
                   f"{self.n(it.dest)} <= {rn}({self.vidx(it.addr)}); "
                   f"end if; end if;")
        out.append("end process;")

    def emit_mem_write(self, it: MemWrite, out, decls) -> None:
        rn = f"{self.n(it.mem)}_ram{it.bank}"
        w = it.data and self.expr_width(it.data)
        mem = next((m for m in self.m.items
                    if isinstance(m, Memory) and m.name == it.mem), None)
        dw = mem.width if mem is not None else w
        out.append(f"process(clk) begin{self.loc_of(it)}")
        out.append(f"  if rising_edge(clk) then if {self.as_bool(it.en)} then "
                   f"{rn}({self.vidx(it.addr)}) <= "
                   f"{self.as_assign(it.data, dw)}; end if; end if;")
        out.append("end process;")

    def emit_controller(self, it: LoopController, out, decls) -> None:
        iv, act, itr = self.n(it.iv), self.n(it.active), self.n(it.iter_net)
        endp = self.n(it.endp) if it.endp else ""
        w = it.ivw
        start_b = self.as_bool(it.start)
        lb = self.as_assign(it.lb, w)
        ivn = iv if w > 1 else f"u1({iv})"  # 1-bit IVs are std_logic
        su = f"({ivn} + {self.as_num(it.step)})"
        ub = self.as_num(it.ub)
        more = f"({su} < {ub})"
        ivnext = f"resize({su}, {w})" if w > 1 else f"resize({su}, 1)(0)"
        if it.ii is not None:
            ii = it.ii
            cnt = self.n(it.iicnt) if it.iicnt else ""
            cw = self.width_of(it.iicnt) if it.iicnt else 1
            if ii > 1:
                # a 1-bit counter is std_logic (ii == 2): compare with '1'
                cond = (f"({cnt} = {ii - 1})" if cw and cw > 1
                        else f"({cnt} = '1')")
            else:
                cond = "true"
            out.append(f"-- controller: hir.for {iv} II={ii} {it.loc}")
            out.append(f"{itr} <= b2sl(({start_b}) or (({act} = '1') and "
                       f"({cond}) and {more}));")
            out.append("process(clk) begin")
            out.append("  if rising_edge(clk) then")
            czero = f"to_unsigned(0, {cw})" if cw and cw > 1 else "'0'"
            if ii > 1:
                out.append(f"    if rst = '1' then {act} <= '0'; "
                           f"{cnt} <= {czero};")
            else:
                out.append(f"    if rst = '1' then {act} <= '0';")
            out.append(f"    elsif {start_b} then")
            extra = f" {cnt} <= {czero};" if ii > 1 else ""
            out.append(f"      {act} <= '1'; {iv} <= {lb};{extra}")
            out.append(f"    elsif {act} = '1' then")
            if ii > 1:
                if cw and cw > 1:
                    bump = f"resize({cnt} + 1, {cw})"
                else:
                    bump = f"not {cnt}"
                out.append(f"      if {cond} then {cnt} <= {czero}; "
                           f"else {cnt} <= {bump}; end if;")
            out.append(f"      if {cond} then")
            out.append(f"        if {more} then {iv} <= {ivnext}; "
                       f"else {act} <= '0'; end if;")
            out.append("      end if;")
            out.append("    end if;")
            out.append("  end if;")
            out.append("end process;")
            if endp:
                out.append("process(clk) begin")
                out.append(f"  if rising_edge(clk) then {endp} <= "
                           f"b2sl(({act} = '1') and ({cond}) and "
                           f"({su} >= {ub})); end if;")
                out.append("end process;")
        else:
            inner = self.as_bool(it.inner_end)
            out.append(f"-- controller: sequential hir.for {iv} {it.loc}")
            out.append(f"{itr} <= b2sl(({start_b}) or (({inner}) and "
                       f"({act} = '1') and {more}));")
            out.append("process(clk) begin")
            out.append("  if rising_edge(clk) then")
            out.append(f"    if rst = '1' then {act} <= '0';")
            out.append(f"    elsif {start_b} then {act} <= '1'; {iv} <= {lb};")
            out.append(f"    elsif ({inner}) and {act} = '1' then")
            out.append(f"      if {more} then {iv} <= {ivnext}; "
                       f"else {act} <= '0'; end if;")
            out.append("    end if;")
            out.append("  end if;")
            out.append("end process;")
            if endp:
                out.append("process(clk) begin")
                out.append(f"  if rising_edge(clk) then {endp} <= "
                           f"b2sl(({inner}) and ({act} = '1') and "
                           f"({su} >= {ub})); end if;")
                out.append("end process;")

    def emit_instance(self, it: Instance, out, decls) -> None:
        callee = (self._design.modules.get(it.module)
                  if self._design is not None else None)
        pw = {p.name: p.width for p in callee.ports} if callee else {}
        maps = []
        for pname, e, is_out in it.conns:
            formal = self.callee_port_name(it.module, pname)
            w = pw.get(pname) or self.expr_width(e) or 1
            if isinstance(e, Ref):
                actual = self.n(e.name)
            elif isinstance(e, Const) and w == 1:
                actual = "'1'" if int(e.value or 0) & 1 else "'0'"
            else:
                nm = self.fresh_aux(w)
                self._aux.append(self.cond_assign(nm, e, w))
                actual = nm
            maps.append(f"{formal} => {actual}")
        if it.share:
            out.append(f"-- time-shared x{1 + len(it.share)}: absorbs "
                       f"{', '.join(self.n(s) for s in it.share)}")
        out.append(f"{self.n(it.inst)} : entity work.{self.mod(it.module)}"
                   f" port map ({', '.join(maps)});{self.loc_of(it)}")

    def emit_assert(self, it: PortConflictAssert, out, decls) -> None:
        cnt = " + ".join(f"b2i({self.as_sl(e)})" for e in it.ens)
        out.append("-- pragma translate_off")
        out.append("process(clk) begin")
        out.append("  if rising_edge(clk) then")
        out.append(f'    assert ({cnt}) <= 1 report "port conflict on '
                   f'{self.n(it.bus)} (UB 4.5)" severity error;')
        out.append("  end if;")
        out.append("end process;")
        out.append("-- pragma translate_on")

    def assemble(self, m: RTLModule, decls, lines) -> str:
        name = self.mod(m.name)
        out = [f"-- generated by repro.core.codegen from @{m.source_func} "
               f"({m.loc})",
               "library ieee;",
               "use ieee.std_logic_1164.all;",
               "use ieee.numeric_std.all;",
               "",
               f"entity {name} is"]
        if m.ports:
            out.append("  port (")
            pl = [f"    {self.n(p.name)} : "
                  f"{'in' if p.dir == 'input' else 'out'} {self.ty(p.width)}"
                  for p in m.ports]
            out.append(";\n".join(pl))
            out.append("  );")
        out.append(f"end entity {name};")
        out.append("")
        out.append(f"architecture rtl of {name} is")
        out.extend("  " + h for h in self.HELPERS)
        for net in m.nets.values():
            c = f" -- {net.comment}" if net.comment else ""
            out.append(f"  signal {self.n(net.name)} : {self.ty(net.width)};{c}")
        out.extend("  " + d for d in decls + self._auxdecl)
        out.append("begin")
        out.extend("  " + l for l in lines + self._aux)
        out.append("end architecture rtl;")
        out.append("")
        return "\n".join(out)


# ---------------------------------------------------------------------------
# CIRCT hw/comb/seq textual MLIR
# ---------------------------------------------------------------------------


class CIRCTPrinter(NetlistPrinter):
    """CIRCT-style textual MLIR over the ``hw``/``comb``/``seq`` dialects.
    One ``hw.module`` per RTLModule (graph region, so forward references are
    fine), nets become named SSA values, clocked items become
    ``seq.compreg``/``seq.firmem`` ops and each loop-controller FSM is
    expanded into explicit comb next-state logic + state registers.  Printer
    temporaries use a ``_t``/``_c`` prefix, so net names never collide with
    them (``sanitize`` strips leading underscores)."""

    name = "circt"
    file_ext = ".mlir"
    RESERVED = frozenset()

    def sanitize(self, nm: str) -> str:
        s = re.sub(r"[^A-Za-z0-9_]", "_", nm) or "n"
        if s.startswith("_"):
            s = "n" + s.lstrip("_")
        return s

    def reset(self) -> None:
        self._tmp = 0
        self._consts: dict[tuple, str] = {}
        self._outvals: dict[str, str] = {}
        self._reggroups: dict[str, list[RegAssign]] = {}
        self._regdone: set[str] = set()
        self._written: set[str] = set()
        for it in self.m.items:
            if isinstance(it, RegAssign):
                self._reggroups.setdefault(it.dest, []).append(it)
            self._written.update(it.writes())

    # -- SSA helpers ---------------------------------------------------------
    def tmp(self) -> str:
        self._tmp += 1
        return f"%_t{self._tmp}"

    def emit_op(self, text: str, out: list[str]) -> str:
        nm = self.tmp()
        out.append(f"{nm} = {text}")
        return nm

    def kconst(self, v: int, w: int, out: list[str]) -> str:
        key = (v, w)
        got = self._consts.get(key)
        if got is not None:
            return got
        nm = f"%_c{len(self._consts)}"
        out.append(f"{nm} = hw.constant {v} : i{w}")
        self._consts[key] = nm
        return nm

    def fit(self, ssa: str, w: int, tow: int, out: list[str],
            signed: bool = False) -> str:
        if w == tow:
            return ssa
        if w < tow:
            if signed:
                msb = self.emit_op(
                    f"comb.extract {ssa} from {w - 1} : (i{w}) -> i1", out)
                ext = self.emit_op(
                    f"comb.replicate {msb} : (i1) -> i{tow - w}", out)
            else:
                ext = self.kconst(0, tow - w, out)
            return self.emit_op(
                f"comb.concat {ext}, {ssa} : i{tow - w}, i{w}", out)
        return self.emit_op(
            f"comb.extract {ssa} from 0 : (i{w}) -> i{tow}", out)

    def c1(self, e: Expr, out: list[str]) -> str:
        v, w = self.cval(e, out, 1)
        if w == 1:
            return v
        z = self.kconst(0, w, out)
        return self.emit_op(f"comb.icmp ne {v}, {z} : i{w}", out)

    def cmux(self, c: str, a: str, b: str, w: int, out: list[str]) -> str:
        return self.emit_op(f"comb.mux {c}, {a}, {b} : i{w}", out)

    # -- expressions ---------------------------------------------------------
    def cval(self, e: Expr, out: list[str],
             ctxw: Optional[int] = None) -> tuple[str, int]:
        if isinstance(e, Const):
            w = e.width or ctxw or 32
            v = int(e.value) if isinstance(e.value, (int, bool)) else 0
            return self.kconst(v, w, out), w
        if isinstance(e, Ref):
            return f"%{self.n(e.name)}", self.width_of(e.name) or ctxw or 1
        if isinstance(e, Signed):
            return self.cval(e.a, out, ctxw)
        if isinstance(e, Unop):
            a, w = self.cval(e.a, out, ctxw)
            ones = self.kconst(-1, w, out)
            return self.emit_op(f"comb.xor {a}, {ones} : i{w}", out), w
        if isinstance(e, Binop):
            return self.cbinop(e, out, ctxw)
        if isinstance(e, Mux):
            a, wa = self.cval(e.a, out, ctxw)
            b, wb = self.cval(e.b, out, wa)
            w = max(wa, wb)
            a, b = self.fit(a, wa, w, out), self.fit(b, wb, w, out)
            c = self.c1(e.cond, out)
            return self.cmux(c, a, b, w, out), w
        if isinstance(e, Repeat):
            a, wa = self.cval(e.a, out)
            return self.emit_op(
                f"comb.replicate {a} : (i{wa}) -> i{e.n * wa}", out), e.n * wa
        raise NotImplementedError(type(e).__name__)

    _ICMP = {"<": "lt", "<=": "le", ">": "gt", ">=": "ge",
             "==": "eq", "!=": "ne"}

    def cbinop(self, e: Binop, out: list[str],
               ctxw: Optional[int]) -> tuple[str, int]:
        op = e.op
        if op in ("&&", "||"):
            a, b = self.c1(e.a, out), self.c1(e.b, out)
            mnem = "and" if op == "&&" else "or"
            return self.emit_op(f"comb.{mnem} {a}, {b} : i1", out), 1
        # Verilog rule: the operation is signed only when *all* operands are
        # signed; signed ops then widen by sign extension
        def _sgn(x):
            return isinstance(x, Signed) or (isinstance(x, Const) and x.signed)
        sgn = _sgn(e.a) and _sgn(e.b)
        a, wa = self.cval(e.a, out, ctxw)
        b, wb = self.cval(e.b, out, wa or ctxw)
        w = max(wa, wb)
        a = self.fit(a, wa, w, out, signed=sgn)
        b = self.fit(b, wb, w, out, signed=sgn)
        if op in self._ICMP:
            pred = self._ICMP[op]
            if pred not in ("eq", "ne"):
                pred = ("s" if sgn else "u") + pred
            return self.emit_op(f"comb.icmp {pred} {a}, {b} : i{w}", out), 1
        if op == "/":
            mnem = "divs" if sgn else "divu"
        elif op == ">>":
            mnem = "shrs" if sgn else "shru"
        else:
            mnem = {"+": "add", "-": "sub", "*": "mul", "&": "and",
                    "|": "or", "^": "xor", "<<": "shl"}[op]
        return self.emit_op(f"comb.{mnem} {a}, {b} : i{w}", out), w

    # -- items ---------------------------------------------------------------
    def emit_comb(self, it: CombAssign, out, decls) -> None:
        dw = self.width_of(it.dest)
        v, w = self.cval(it.expr, out, dw)
        if dw:
            v = self.fit(v, w, dw, out)
            w = dw
        d = self.n(it.dest)
        out.append(f"%{d} = hw.wire {v} : i{w}{self.loc_of(it)}")
        if it.dest in self.m.output_ports():
            self._outvals[it.dest] = f"%{d}"

    def emit_shift_reg(self, it: ShiftReg, out, decls) -> None:
        w = it.width
        v, w0 = self.cval(it.src, out, w)
        v = self.fit(v, w0, w, out)
        rst = ""
        if it.reset_zero:
            z = self.kconst(0, w, out)
            rst = f" reset %rst, {z}"
        for s in range(it.depth):
            if s == it.depth - 1:
                nm = f"%{self.n(it.dest)}"
                out.append(f"{nm} = seq.compreg {v}, %clk{rst} : "
                           f"i{w}{self.loc_of(it)}")
            else:
                nm = self.emit_op(f"seq.compreg {v}, %clk{rst} : i{w}", out)
            v = nm

    def emit_reg_assign(self, it: RegAssign, out, decls) -> None:
        if it.dest in self._regdone:
            return
        self._regdone.add(it.dest)
        group = self._reggroups[it.dest]
        w = self.width_of(it.dest) or 32
        d = f"%{self.n(it.dest)}"
        if len(group) == 1 and group[0].en is None:
            v, w0 = self.cval(group[0].src, out, w)
            v = self.fit(v, w0, w, out)
            out.append(f"{d} = seq.compreg {v}, %clk : i{w}{self.loc_of(it)}")
            return
        if len(group) == 1:
            g = group[0]
            v, w0 = self.cval(g.src, out, w)
            v = self.fit(v, w0, w, out)
            en = self.c1(g.en, out)
            out.append(f"{d} = seq.compreg.ce {v}, %clk, {en} : "
                       f"i{w}{self.loc_of(it)}")
            return
        # several §4.5-exclusive writers: one register, a mux chain for the
        # next value (hold when no enable fires)
        acc = d
        for g in reversed(group):
            v, w0 = self.cval(g.src, out, w)
            v = self.fit(v, w0, w, out)
            en = self.c1(g.en, out) if g.en is not None else self.kconst(1, 1, out)
            acc = self.cmux(en, v, acc, w, out)
        out.append(f"{d} = seq.compreg {acc}, %clk : i{w}{self.loc_of(it)}")

    def emit_memory(self, it: Memory, out, decls) -> None:
        depth = max(it.depth, 1)
        for bk in range(it.banks):
            out.append(f"%{self.n(it.name)}_ram{bk} = seq.firmem 0, 1, "
                       f"undefined, undefined : <{depth} x {it.width}>"
                       f"{self.loc_of(it)}")

    def _mem_depth_width(self, mem: str) -> tuple[int, int]:
        m = next((i for i in self.m.items
                  if isinstance(i, Memory) and i.name == mem), None)
        if m is None:
            return 1, 32
        return max(m.depth, 1), m.width

    def emit_mem_read(self, it: MemRead, out, decls) -> None:
        depth, w = self._mem_depth_width(it.mem)
        a, _aw = self.cval(it.addr, out)
        en = self.c1(it.en, out)
        out.append(f"%{self.n(it.dest)} = seq.firmem.read_port "
                   f"%{self.n(it.mem)}_ram{it.bank}[{a}], clock %clk "
                   f"enable {en} : <{depth} x {w}>{self.loc_of(it)}")

    def emit_mem_write(self, it: MemWrite, out, decls) -> None:
        depth, w = self._mem_depth_width(it.mem)
        a, _aw = self.cval(it.addr, out)
        v, w0 = self.cval(it.data, out, w)
        v = self.fit(v, w0, w, out)
        en = self.c1(it.en, out)
        out.append(f"seq.firmem.write_port "
                   f"%{self.n(it.mem)}_ram{it.bank}[{a}] = {v}, clock %clk "
                   f"enable {en} : <{depth} x {w}>{self.loc_of(it)}")

    def emit_controller(self, it: LoopController, out, decls) -> None:
        w = it.ivw
        iv = f"%{self.n(it.iv)}"
        act = f"%{self.n(it.active)}"
        tag = f"II={it.ii}" if it.ii is not None else "sequential"
        out.append(f"// controller: hir.for {self.n(it.iv)} {tag} ({it.loc})")
        start = self.c1(it.start, out)
        lb, wlb = self.cval(it.lb, out, w)
        lb = self.fit(lb, wlb, w, out)
        ub, wub = self.cval(it.ub, out, w)
        ub = self.fit(ub, wub, w, out)
        st, wst = self.cval(it.step, out, w)
        st = self.fit(st, wst, w, out)
        su = self.emit_op(f"comb.add {iv}, {st} : i{w}", out)
        more = self.emit_op(f"comb.icmp ult {su}, {ub} : i{w}", out)
        done = self.emit_op(f"comb.icmp uge {su}, {ub} : i{w}", out)
        if it.ii is not None and it.ii > 1:
            cnt = f"%{self.n(it.iicnt)}"
            cw = self.width_of(it.iicnt) or 1
            cm1 = self.kconst(it.ii - 1, cw, out)
            cn = self.emit_op(f"comb.icmp eq {cnt}, {cm1} : i{cw}", out)
        elif it.ii is not None:
            cn = self.kconst(1, 1, out)
        else:
            cn = self.c1(it.inner_end, out)
        live = self.emit_op(f"comb.and {act}, {cn} : i1", out)
        adv = self.emit_op(f"comb.and {live}, {more} : i1", out)
        stop = self.emit_op(f"comb.and {live}, {done} : i1", out)
        out.append(f"%{self.n(it.iter_net)} = comb.or {start}, {adv} : i1")
        one = self.kconst(1, 1, out)
        zero1 = self.kconst(0, 1, out)
        a1 = self.cmux(stop, zero1, act, 1, out)
        a2 = self.cmux(start, one, a1, 1, out)
        out.append(f"{act} = seq.compreg {a2}, %clk reset %rst, {zero1} : i1")
        i1 = self.cmux(adv, su, iv, w, out)
        i2 = self.cmux(start, lb, i1, w, out)
        out.append(f"{iv} = seq.compreg {i2}, %clk : i{w}")
        if it.ii is not None and it.ii > 1:
            cnt = f"%{self.n(it.iicnt)}"
            cw = self.width_of(it.iicnt) or 1
            zc = self.kconst(0, cw, out)
            onec = self.kconst(1, cw, out)
            bump = self.emit_op(f"comb.add {cnt}, {onec} : i{cw}", out)
            cngz = self.cmux(cn, zc, bump, cw, out)
            chold = self.cmux(act, cngz, cnt, cw, out)
            cnext = self.cmux(start, zc, chold, cw, out)
            out.append(f"{cnt} = seq.compreg {cnext}, %clk reset %rst, "
                       f"{zc} : i{cw}")
        if it.endp:
            out.append(f"%{self.n(it.endp)} = seq.compreg {stop}, %clk : i1")

    def emit_instance(self, it: Instance, out, decls) -> None:
        callee = (self._design.modules.get(it.module)
                  if self._design is not None else None)
        pw = {p.name: p.width for p in callee.ports} if callee else {}
        ins: list[tuple[str, str, str]] = []
        outs: list[tuple[str, str, int]] = []
        for pname, e, is_out in it.conns:
            formal = self.callee_port_name(it.module, pname)
            if is_out:
                w = pw.get(pname) or self.width_of(e.name) or 1
                outs.append((formal, f"%{self.n(e.name)}", w))
                continue
            if pname == "clk":
                ins.append((formal, "%clk", "!seq.clock"))
                continue
            v, w = self.cval(e, out, pw.get(pname))
            if pw.get(pname):
                v = self.fit(v, w, pw[pname], out)
                w = pw[pname]
            ins.append((formal, v, f"i{w}"))
        argtxt = ", ".join(f"{p}: {v}: {t}" for p, v, t in ins)
        restxt = ", ".join(f"{p}: i{w}" for p, _v, w in outs)
        lhs = ", ".join(v for _p, v, _w in outs)
        line = f'hw.instance "{self.n(it.inst)}" @{self.mod(it.module)}' \
               f"({argtxt}) -> ({restxt})"
        if lhs:
            line = f"{lhs} = {line}"
        if it.share:
            out.append(f"// time-shared x{1 + len(it.share)}: absorbs "
                       f"{', '.join(self.n(s) for s in it.share)}")
        out.append(line + self.loc_of(it))

    def emit_assert(self, it: PortConflictAssert, out, decls) -> None:
        n = len(it.ens)
        w = max(2, n.bit_length() + 1)
        total = self.kconst(0, w, out)
        for e in it.ens:
            b = self.c1(e, out)
            b = self.fit(b, 1, w, out)
            total = self.emit_op(f"comb.add {total}, {b} : i{w}", out)
        one = self.kconst(1, w, out)
        ok = self.emit_op(f"comb.icmp ule {total}, {one} : i{w}", out)
        out.append(f'verif.assert {ok} label "port conflict on '
                   f'{self.n(it.bus)} (UB 4.5)" : i1')

    def assemble(self, m: RTLModule, decls, lines) -> str:
        name = self.mod(m.name)
        pl = []
        for p in m.ports:
            if p.dir == "input":
                ty = "!seq.clock" if p.name == "clk" else f"i{p.width}"
                pl.append(f"in %{self.n(p.name)} : {ty}")
            else:
                pl.append(f"out {self.n(p.name)} : i{p.width}")
        body = list(lines)
        outs = [p for p in m.ports if p.dir == "output"]
        vals, tys = [], []
        for p in outs:
            v = self._outvals.get(p.name)
            if v is None and p.name in self._written:
                # driven by a clocked item / instance whose result op is
                # already named after the port
                v = f"%{self.n(p.name)}"
            if v is None:
                v = self.kconst(0, p.width, body)  # genuinely undriven
            vals.append(v)
            tys.append(f"i{p.width}")
        final = (f"hw.output {', '.join(vals)} : {', '.join(tys)}"
                 if vals else "hw.output")
        hdr = (f"// generated by repro.core.codegen from @{m.source_func} "
               f"({m.loc})\n")
        return (hdr + f"hw.module @{name}({', '.join(pl)}) {{\n"
                + "\n".join("  " + l for l in body + [final]) + "\n}\n")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

BACKENDS: dict[str, type[NetlistPrinter]] = {
    "verilog": VerilogPrinter,
    "systemverilog": SystemVerilogPrinter,
    "vhdl": VHDLPrinter,
    "circt": CIRCTPrinter,
}


def get_printer(backend: str) -> NetlistPrinter:
    """Instantiate the printer for ``backend`` (one of ``BACKENDS``)."""
    try:
        return BACKENDS[backend]()
    except KeyError:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of "
            f"{sorted(BACKENDS)}") from None


# ---------------------------------------------------------------------------
# Parallel per-module emission (PR 8)
# ---------------------------------------------------------------------------


def _walk_ops(m, visit) -> None:
    """Visit every FuncOp/Operation of ``m`` in deterministic print order
    (funcs in module order, ops pre-order through nested regions)."""
    def rec(region):
        for op in region.ops:
            visit(op)
            for r in op.regions:
                rec(r)

    for f in m.funcs.values():
        visit(f)
        if not f.attrs.get("external"):
            rec(f.body)


def _op_values(op) -> list:
    vals = list(op.results)
    for reg in op.regions:
        vals.extend(reg.args)
    return vals


def _module_sidecar(m) -> list:
    """Per-op ``(loc, value-names)`` sidecar, in ``_walk_ops`` order.  The
    HIR printer neither serializes source locations nor preserves raw value
    names (duplicates are legalized ``lj`` -> ``lj_1``, anonymous values
    print as ``v<id>`` with a process-local id), and both feed the RTL
    backends — locs as netlist comments, names through ``FuncLowering``'s
    signal naming.  Parallel-emission payloads carry this sidecar so workers
    reconstruct the parent's exact in-memory module after parsing."""
    out = []

    def visit(op):
        out.append((op.loc, tuple(v.name for v in _op_values(op))))

    _walk_ops(m, visit)
    return out


def _attach_sidecar(m, sidecar) -> None:
    """Re-attach a ``_module_sidecar`` onto a parsed module.  The print/parse
    round trip preserves the op tree exactly, so the same deterministic walk
    pairs ops 1:1 with the sidecar — keeping emitted text byte-identical to
    the serial path."""
    it = iter(sidecar)

    def put(op):
        loc, names = next(it)
        op.loc = loc
        vals = _op_values(op)
        if len(vals) != len(names):  # pragma: no cover - round-trip invariant
            raise RuntimeError(f"sidecar mismatch at {op.opname}")
        for v, nm in zip(vals, names):
            v.name = nm

    _walk_ops(m, put)


def _emit_module_payload(payload) -> tuple:
    """Pool worker: re-lower and print ONE emitted module from printed HIR
    text.  Top-level by necessity (the pool pickles the callable by
    reference); the payload carries text and plain config only — never RTL
    trees, whose interned expression keys (PR 5) are process-local.

    Byte-identity with the serial path holds because (a) ``FuncLowering``'s
    anonymous naming is positional per lowering, (b) the RTL passes are
    strictly per-module, and (c) the design-wide module name map is rebuilt
    from the full ordered name list the parent passes in, so the printer's
    first-come legalization sees the same sequence."""
    (module_text, sidecar, target, order, hierarchy, rtl_spec, backend,
     entry) = payload
    from ..parser import parse
    from ..passmgr import PassManager
    from .verilog import lower_to_rtl, netlist_of

    m = parse(module_text)
    _attach_sidecar(m, sidecar)
    # the entry annotation gates the instance-sharing passes; a worker whose
    # target is not the entry must not see one (its sub-design is rooted at
    # a callee), matching what the serial pipeline does to that module
    design = lower_to_rtl(m, [target], hierarchy=hierarchy,
                          entry=entry if target == entry else None)
    if rtl_spec:
        PassManager.from_spec(rtl_spec).run(design)
    printer = get_printer(backend)
    modmap = printer.module_name_map(order)
    tm = design.modules[target]
    text = printer.print_module(tm, modmap=modmap, design=design)
    return target, text, netlist_of(tm)


def emit_design_parallel(module, order: list, hierarchy: str,
                         rtl_spec, backend: str,
                         max_workers: int, entry=None):
    """Emit the design's modules concurrently, one pool task per emitted
    module: each worker parses the printed post-pipeline module, lowers its
    target (plus, hierarchically, the callees the target instantiates), runs
    the RTL pass pipeline and prints the target.  Results come back as
    ``[(name, text, netlist), ...]`` in ``order`` — the same deterministic
    order the serial loop produces — or ``None`` when no pool is available
    (the caller then falls back to the byte-identical serial path)."""
    from ..pool import pool_map
    from ..printer import print_module

    text = print_module(module)
    sidecar = _module_sidecar(module)
    payloads = [(text, sidecar, t, tuple(order), hierarchy, rtl_spec or "",
                 backend, entry)
                for t in order]
    return pool_map(_emit_module_payload, payloads, max_workers,
                    label="backend emission")
