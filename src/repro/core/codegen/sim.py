"""Vectorized cycle-accurate RTL simulation.

Compiles a structured RTL design (``rtl.RTLModule``/``rtl.RTLDesign``) into a
pure array-program step function and runs whole stimulus *batches* through it:

  * the design is flattened (``RTLDesign.flatten``) and its external memref
    interface ports are *closed* — replaced by internal storage models that
    reproduce the interface timing exactly (register banks respond
    combinationally, RAM ports one cycle later);
  * combinational items are topologically sorted and compiled to a linear
    tape of ``int64`` array operations with explicit width masking;
  * ``ShiftReg``/``RegAssign``/``Memory``/``LoopController`` state is
    threaded through the step function with nonblocking (read-old,
    write-new) semantics;
  * on the JAX backend the single-lane step is ``jax.vmap``-ed over the
    stimulus batch axis and ``jax.lax.scan``-ed over cycles under
    ``jax.experimental.enable_x64`` (the global x64 flag is never touched);
    the NumPy fallback runs the same tape batch-first with a Python cycle
    loop — still vectorized over stimulus.

Semantics follow the event-driven oracle (``lower.to_sim``): values are bit
patterns masked to their net width, ``Signed`` sign-extends, division is
floor division (``//``), right shift is arithmetic on signed operands, and
division by zero yields 0 (the event simulator would fault; random stimulus
must not rely on it).  Widths above 63 bits are rejected.

On top of the simulator, ``run_differential`` is the verification harness:
it checks the vectorized simulator against the event-driven oracle lane by
lane, and ``verify_rtl_passes`` checks every RTL pass in
``RTL_PIPELINE_SPEC`` by comparing per-cycle result-port traces and final
memory/return state of each pass's input design against its output design.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .. import ir
from ..ir import FuncOp, IntType, MemrefType, Module
from ..passmgr import PassManager
from . import rtl
from .rtl import (REG, WIRE, Binop, CombAssign, Const, Expr, Instance,
                  LoopController, MemRead, Memory, MemWrite, Mux, Net,
                  PortConflictAssert, Ref, Repeat, RegAssign, RTLDesign,
                  RTLModule, ShiftReg, Signed, Unop)

try:  # pragma: no cover - absence exercised via the numpy backend
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    HAVE_JAX = True
except ImportError:  # pragma: no cover
    jax = None
    jnp = None
    enable_x64 = None
    HAVE_JAX = False

I64 = np.int64


class RTLSimError(Exception):
    pass


def _clog2(n: int) -> int:
    return max(1, (n - 1).bit_length())


def _mask_of(w: int) -> int:
    """Python-int AND mask for a ``w``-bit pattern."""
    if w >= 64:
        raise RTLSimError(f"width {w} exceeds the 63-bit simulation domain")
    return (1 << w) - 1


def _signed_fix(p: np.ndarray, w: int, signed: bool) -> np.ndarray:
    """Pattern -> math value (sign-extend when the element type is signed)."""
    p = np.asarray(p, dtype=I64)
    if not signed or w >= 64:
        return p
    s = I64(1) << I64(w - 1)
    return ((p & ((I64(1) << I64(w)) - I64(1))) ^ s) - s


# ---------------------------------------------------------------------------
# Array-op backends.  The compiled tape is backend-agnostic: every closure
# takes (env, ops).  _JaxOps values are per-lane scalars (vmap adds the batch
# axis); _NumpyOps values are batch-first (B,) arrays.
# ---------------------------------------------------------------------------


class _JaxOps:
    def __init__(self):
        self.zero = jnp.int64(0)
        self.one = jnp.int64(1)

    def where(self, c, a, b):
        return jnp.where(c, a, b)

    def minimum(self, a, b):
        return jnp.minimum(a, b)

    def b2i(self, c):
        return jnp.where(c, self.one, self.zero)

    def sr_out(self, chain):
        return chain[-1]

    def sr_push(self, chain, v):
        head = jnp.asarray(v, dtype=jnp.int64).reshape(1)
        return jnp.concatenate([head, chain[:-1]])

    def read_mem(self, mem, addr):
        a = jnp.clip(jnp.asarray(addr, dtype=jnp.int64), 0, mem.shape[0] - 1)
        return mem[a]

    def write_mem(self, mem, addr, data, enb):
        a = jnp.clip(jnp.asarray(addr, dtype=jnp.int64), 0, mem.shape[0] - 1)
        return mem.at[a].set(jnp.where(enb, data, mem[a]))


class _NumpyOps:
    def __init__(self, batch: int):
        self.B = int(batch)
        self.zero = I64(0)
        self.one = I64(1)

    def where(self, c, a, b):
        return np.where(c, a, b)

    def minimum(self, a, b):
        return np.minimum(a, b)

    def b2i(self, c):
        return np.where(c, self.one, self.zero)

    def _bcast(self, v):
        return np.broadcast_to(np.asarray(v, dtype=I64), (self.B,))

    def sr_out(self, chain):
        return chain[:, -1]

    def sr_push(self, chain, v):
        return np.concatenate([self._bcast(v)[:, None], chain[:, :-1]],
                              axis=1)

    def read_mem(self, mem, addr):
        a = np.clip(self._bcast(addr), 0, mem.shape[1] - 1)
        return np.take_along_axis(mem, a[:, None], axis=1)[:, 0]

    def write_mem(self, mem, addr, data, enb):
        a = np.clip(self._bcast(addr), 0, mem.shape[1] - 1)
        cur = np.take_along_axis(mem, a[:, None], axis=1)[:, 0]
        d = self._bcast(np.where(enb, data, cur))
        out = mem.copy()
        np.put_along_axis(out, a[:, None], d[:, None], axis=1)
        return out


# ---------------------------------------------------------------------------
# Expression compiler: Expr -> closure(env, ops) returning the *math* value
# (exact modulo 2**64; patterns are materialized by masking at assignment).
# Static widths mirror backends.NetlistPrinter.expr_width.
# ---------------------------------------------------------------------------

_CMP_FNS = {
    "<": (lambda a, b: a < b), "<=": (lambda a, b: a <= b),
    ">": (lambda a, b: a > b), ">=": (lambda a, b: a >= b),
    "==": (lambda a, b: a == b), "!=": (lambda a, b: a != b),
}
_ARITH_FNS = {
    "+": (lambda a, b: a + b), "-": (lambda a, b: a - b),
    "*": (lambda a, b: a * b), "&": (lambda a, b: a & b),
    "|": (lambda a, b: a | b), "^": (lambda a, b: a ^ b),
}


def _compile_expr(e: Expr, widths: dict[str, int]):
    """Return ``(fn, width)``; ``fn(env, ops)`` evaluates the math value."""
    if isinstance(e, Const):
        if not isinstance(e.value, int):
            raise RTLSimError(f"non-integer constant {e.value!r} unsupported")
        v = int(e.value)
        w = e.width if e.width is not None else max(1, v.bit_length())
        return (lambda env, ops: v), w
    if isinstance(e, Ref):
        nm = e.name
        if nm not in widths:
            raise RTLSimError(f"reference to undeclared net {nm!r}")
        return (lambda env, ops: env[nm]), widths[nm]
    if isinstance(e, Signed):
        fa, w = _compile_expr(e.a, widths)
        m, s = _mask_of(w), 1 << (w - 1)
        return (lambda env, ops: ((fa(env, ops) & m) ^ s) - s), w
    if isinstance(e, Unop):
        if e.op != "~":
            raise RTLSimError(f"unop {e.op!r} unsupported")
        fa, w = _compile_expr(e.a, widths)
        return (lambda env, ops: ~fa(env, ops)), w
    if isinstance(e, Mux):
        fc, _ = _compile_expr(e.cond, widths)
        fa, wa = _compile_expr(e.a, widths)
        fb, wb = _compile_expr(e.b, widths)
        return (lambda env, ops: ops.where(
            fc(env, ops) != 0, fa(env, ops), fb(env, ops))), max(wa, wb)
    if isinstance(e, Repeat):
        fa, wa = _compile_expr(e.a, widths)
        w = e.n * wa
        if w >= 64:
            if isinstance(e.a, Const) and int(e.a.value) == 0:
                return (lambda env, ops: 0), 63
            raise RTLSimError(f"repeat to {w} bits unsupported")
        m = _mask_of(wa)
        factor = sum(1 << (i * wa) for i in range(e.n))
        return (lambda env, ops: (fa(env, ops) & m) * factor), w
    if isinstance(e, Binop):
        fa, wa = _compile_expr(e.a, widths)
        fb, wb = _compile_expr(e.b, widths)
        op = e.op
        if op in _CMP_FNS:
            cf = _CMP_FNS[op]
            return (lambda env, ops: ops.b2i(cf(fa(env, ops),
                                               fb(env, ops)))), 1
        if op == "&&":
            return (lambda env, ops: ops.b2i(
                (fa(env, ops) != 0) & (fb(env, ops) != 0))), 1
        if op == "||":
            return (lambda env, ops: ops.b2i(
                (fa(env, ops) != 0) | (fb(env, ops) != 0))), 1
        w = max(wa, wb)
        if op in _ARITH_FNS:
            af = _ARITH_FNS[op]
            return (lambda env, ops: af(fa(env, ops), fb(env, ops))), w
        if op == "/":
            # floor division, matching the event-driven oracle's `//`;
            # division by zero yields 0 instead of faulting per lane.
            def fdiv(env, ops):
                a, b = fa(env, ops), fb(env, ops)
                z = (b == 0)
                return ops.where(z, 0, a // ops.where(z, 1, b))
            return fdiv, w
        if op == "<<":
            if isinstance(e.b, Const):
                k = int(e.b.value)
                if k >= 64:
                    return (lambda env, ops: 0), w
                return (lambda env, ops: fa(env, ops) << k), w

            def fshl(env, ops):
                a, b = fa(env, ops), fb(env, ops)
                return ops.where(b >= 63, 0, a << ops.minimum(b, 62))
            return fshl, w
        if op == ">>":
            if isinstance(e.b, Const):
                k = min(int(e.b.value), 63)
                return (lambda env, ops: fa(env, ops) >> k), w

            def fshr(env, ops):
                a, b = fa(env, ops), fb(env, ops)
                return a >> ops.minimum(b, 63)
            return fshr, w
        raise RTLSimError(f"binop {op!r} unsupported")
    raise RTLSimError(f"expression {type(e).__name__} unsupported")


# ---------------------------------------------------------------------------
# Closing the external interface: memref argument ports become internal
# storage with the exact interface timing of verilog.FuncLowering.
# ---------------------------------------------------------------------------


@dataclass
class _Bind:
    index: int
    kind: str                      # "scalar" | "bank" | "ram"
    port: str = ""                 # scalar input port
    width: int = 0
    signed: bool = False
    mt: Optional[MemrefType] = None
    cells: list = field(default_factory=list)  # bank: [[net per elem]/bank]
    memkey: str = ""               # ram: state key of the backing array


def close_module(flat: RTLModule, func: FuncOp
                 ) -> tuple[list[_Bind], list[str]]:
    """Convert ``flat``'s memref interface ports into internal storage items
    (mutating ``flat``), returning ``(bindings, traced)``: the argument
    bindings the runner uses to load stimulus and read back final state, and
    the demoted interface-port nets (the design's observable boundary — what
    per-cycle differential checks compare).  Register-bank arguments become
    per-cell registers with combinational (same-cycle) read response and
    address-decoded clocked writes; packed arguments become a ``Memory`` with
    the interface's one-cycle read latency."""
    binds: list[_Bind] = []
    traced: list[str] = []
    port_by = {p.name: p for p in flat.ports}
    for i, a in enumerate(func.args):
        ports = flat.arg_ports.get(i, [])
        if not isinstance(a.type, MemrefType):
            if not ports:
                raise RTLSimError(f"argument {i} has no interface ports")
            pname = ports[0][0]
            w = port_by[pname].width
            signed = isinstance(a.type, IntType) and a.type.signed
            binds.append(_Bind(i, "scalar", port=pname, width=w,
                               signed=signed))
            continue
        mt = a.type
        dw = mt.elem_bits()
        roles: dict[tuple[str, int], str] = {}
        for pname, _pdir, role, bank in ports:
            roles[(role, bank)] = pname
            p = port_by.pop(pname, None)
            if p is not None:
                flat.ports.remove(p)
                traced.append(pname)
                kind = REG if (role == "rd_data" and bank == -1) else WIRE
                if pname not in flat.nets:
                    flat.nets[pname] = Net(pname, p.width, kind, False,
                                           f"extif:{i}", "")
        signed = isinstance(mt.elem, IntType) and mt.elem.signed
        if mt.distributed:
            aw = _clog2(mt.bank_elems)
            cells: list[list[str]] = []
            for bk in range(mt.num_banks):
                row = []
                for d in range(mt.bank_elems):
                    cn = f"__ext{i}_b{bk}_{d}"
                    flat.nets[cn] = Net(cn, dw, REG, False, "extbank", "")
                    row.append(cn)
                cells.append(row)
                rd = roles.get(("rd_data", bk))
                if rd is not None:
                    ra = roles.get(("rd_addr", bk))
                    ex: Expr = Ref(row[0])
                    if ra is not None and mt.bank_elems > 1:
                        for d in range(1, mt.bank_elems):
                            ex = Mux(Binop("==", Ref(ra), Const(d, aw),
                                           free=True), Ref(row[d]), ex, dw)
                    flat.items.append(CombAssign(rd, ex))
                we = roles.get(("wr_en", bk))
                if we is not None:
                    wa = roles.get(("wr_addr", bk))
                    wd = roles[("wr_data", bk)]
                    for d in range(mt.bank_elems):
                        en: Expr = Ref(we)
                        if wa is not None:
                            en = Binop("&&", Ref(we),
                                       Binop("==", Ref(wa), Const(d, aw),
                                             free=True), free=True)
                        flat.items.append(RegAssign(row[d], Ref(wd), en))
            binds.append(_Bind(i, "bank", width=dw, signed=signed, mt=mt,
                               cells=cells))
        else:
            memname = f"__ext{i}"
            flat.items.append(Memory(memname, 1, mt.bank_elems, dw, "bram"))
            rd = roles.get(("rd_data", -1))
            if rd is not None:
                flat.items.append(MemRead(
                    rd, memname, 0, Ref(roles[("rd_addr", -1)]),
                    Ref(roles[("rd_en", -1)])))
            we = roles.get(("wr_en", -1))
            if we is not None:
                flat.items.append(MemWrite(
                    memname, 0, Ref(roles[("wr_addr", -1)]),
                    Ref(roles[("wr_data", -1)]), Ref(we)))
            binds.append(_Bind(i, "ram", width=dw, signed=signed, mt=mt,
                               memkey=f"mem:{memname}:0"))
    return binds, traced


# ---------------------------------------------------------------------------
# The compiled step program and the batched runner
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    """Outcome of one batched run.  All arrays are batch-first numpy int64.

    ``returns[j]``/``returns_valid[j]`` are the captured ``result_j`` values
    (sign-corrected per the function's result types) and whether the valid
    pulse fired; ``arrays[i]`` is the final content of memref argument ``i``
    in its original tensor shape; ``conflicts`` counts §4.5 port-conflict
    cycles per lane; ``trace[p]`` is the per-cycle (T, B) pattern of output
    port ``p`` when tracing was requested."""

    backend: str
    cycles: int
    batch: int
    returns: list[np.ndarray]
    returns_valid: list[np.ndarray]
    arrays: dict[int, np.ndarray]
    conflicts: np.ndarray
    conflict_buses: list[str]
    trace: Optional[dict[str, np.ndarray]] = None


class RTLSimulator:
    """Batched cycle-accurate interpreter for one RTL design entry.

    ``design`` is the (possibly hierarchical) RTL design; ``func`` the
    originating ``hir.func`` (argument/result types and memory layout).
    ``backend`` is ``"jax"``, ``"numpy"`` or ``"auto"`` (jax when present).
    """

    def __init__(self, design: RTLDesign, func: FuncOp,
                 entry: Optional[str] = None, backend: str = "auto"):
        entry = entry or design.entry
        assert entry is not None, "entry module required"
        self.entry = entry
        self.func = func
        flat = design.flatten(entry)
        self.binds, self._ext_traced = close_module(flat, func)
        self.flat = flat
        self.backend = self._resolve_backend(backend)
        self._jitted: Optional[Callable] = None
        self._build()

    @staticmethod
    def _resolve_backend(backend: str) -> str:
        if backend == "auto":
            return "jax" if HAVE_JAX else "numpy"
        if backend == "jax" and not HAVE_JAX:
            raise RTLSimError("jax backend requested but jax is unavailable")
        assert backend in ("jax", "numpy"), backend
        return backend

    # -- compilation ---------------------------------------------------------
    def _build(self) -> None:
        m = self.flat
        rtl._ensure_recursion_headroom()
        widths = {n: v.width for n, v in m.nets.items()}
        for p in m.ports:
            widths.setdefault(p.name, p.width)
        self.widths = widths

        mems: dict[str, Memory] = {}
        driven: set[str] = set()
        for it in m.items:
            if isinstance(it, Instance):
                raise RTLSimError("flatten left an Instance behind")
            if isinstance(it, Memory):
                mems[it.name] = it
            driven.update(it.writes())
        inputs = {p.name for p in m.ports if p.dir == "input"}
        driven |= inputs | {"clk", "rst"}

        # undriven wires read somewhere float to 0 (Verilog would read X;
        # the lowering never relies on such reads — this keeps the tape total)
        tied: list[tuple[str, int]] = []
        for it in m.items:
            for r in it.reads():
                if r not in driven:
                    driven.add(r)
                    tied.append((r, widths.get(r, 1)))

        self.state_nets: list[str] = []                 # REG nets, per lane
        self.sr_loads: list[tuple[str, str]] = []       # (dest, state key)
        self.scalar_inputs = [(b.port, f"in:{b.port}") for b in self.binds
                              if b.kind == "scalar"]
        state_shape: dict[str, tuple] = {k: () for _, k in self.scalar_inputs}
        seen_state: set[str] = set()

        def mark_state(net: str) -> None:
            if net and net not in seen_state:
                seen_state.add(net)
                self.state_nets.append(net)
                state_shape[net] = ()

        for nm, mem in mems.items():
            for bk in range(mem.banks):
                state_shape[f"mem:{nm}:{bk}"] = (mem.depth,)

        # comb node: (dest, kind, payload, reads) — kind "assign" payload is
        # (fn, mask); kind "ctrl" payload is the controller spec (iter pulse)
        comb_nodes: list[tuple] = []
        clocked: list[tuple] = []
        asserts: list[tuple[str, list]] = []

        for nm, w in tied:
            comb_nodes.append((nm, "assign", ((lambda env, ops: 0),
                                              _mask_of(w)), ()))

        for it in m.items:
            if isinstance(it, CombAssign):
                fn, _ = _compile_expr(it.expr, widths)
                w = widths.get(it.dest)
                if w is None:
                    raise RTLSimError(f"assign to undeclared {it.dest!r}")
                comb_nodes.append((it.dest, "assign", (fn, _mask_of(w)),
                                   tuple(it.reads())))
            elif isinstance(it, ShiftReg):
                key = f"sr:{it.dest}"
                state_shape[key] = (it.depth,)
                self.sr_loads.append((it.dest, key))
                fn, _ = _compile_expr(it.src, widths)
                clocked.append(("sr", key, fn, _mask_of(it.width)))
            elif isinstance(it, RegAssign):
                mark_state(it.dest)
                fn, _ = _compile_expr(it.src, widths)
                en = (None if it.en is None
                      else _compile_expr(it.en, widths)[0])
                clocked.append(("reg", it.dest, fn, en,
                                _mask_of(widths[it.dest])))
            elif isinstance(it, MemRead):
                mark_state(it.dest)
                afn, _ = _compile_expr(it.addr, widths)
                efn, _ = _compile_expr(it.en, widths)
                clocked.append(("memrd", it.dest, f"mem:{it.mem}:{it.bank}",
                                afn, efn, _mask_of(widths[it.dest])))
            elif isinstance(it, MemWrite):
                afn, _ = _compile_expr(it.addr, widths)
                dfn, _ = _compile_expr(it.data, widths)
                efn, _ = _compile_expr(it.en, widths)
                clocked.append(("memwr", f"mem:{it.mem}:{it.bank}", afn, dfn,
                                efn, _mask_of(mems[it.mem].width)))
            elif isinstance(it, LoopController):
                mark_state(it.iv)
                mark_state(it.active)
                if it.endp:
                    mark_state(it.endp)
                if it.iicnt:
                    mark_state(it.iicnt)
                spec = {
                    "iv": it.iv, "active": it.active, "endp": it.endp,
                    "iicnt": it.iicnt, "ii": it.ii,
                    "ivmask": _mask_of(it.ivw),
                    "start": _compile_expr(it.start, widths)[0],
                    "lb": _compile_expr(it.lb, widths)[0],
                    "ub": _compile_expr(it.ub, widths)[0],
                    "step": _compile_expr(it.step, widths)[0],
                    "inner": (None if it.inner_end is None
                              else _compile_expr(it.inner_end, widths)[0]),
                }
                clocked.append(("ctrl", spec))
                deps = tuple(r for e in it.exprs() for r in e.refs())
                comb_nodes.append((it.iter_net, "ctrl", spec, deps))
            elif isinstance(it, Memory):
                pass
            elif isinstance(it, PortConflictAssert):
                ens = [_compile_expr(e, widths)[0] for e in it.ens]
                asserts.append((it.bus, ens))
            else:
                raise RTLSimError(f"item {type(it).__name__} unsupported")

        self.clocked = clocked
        self.asserts = asserts
        self.conflict_buses = [bus for bus, _ in asserts]
        if asserts:
            state_shape["cf"] = (len(asserts),)
        self.results = list(m.result_ports)
        for j in range(len(self.results)):
            state_shape[f"ret:{j}:val"] = ()
            state_shape[f"ret:{j}:seen"] = ()
        self.state_shape = state_shape
        self.trace_names = ([p.name for p in m.ports if p.dir == "output"]
                            + list(self._ext_traced))
        self.comb_tape = self._topo_sort(comb_nodes)

    @staticmethod
    def _topo_sort(nodes: list[tuple]) -> list[tuple]:
        """Order combinational nodes so every read of a comb-driven net
        follows its producer (state nets and input ports are leaves)."""
        producer: dict[str, int] = {}
        for i, (dest, _k, _p, _r) in enumerate(nodes):
            if dest in producer:
                raise RTLSimError(
                    f"multiple combinational drivers of {dest!r}")
            producer[dest] = i
        succs: list[list[int]] = [[] for _ in nodes]
        indeg = [0] * len(nodes)
        for i, (_d, _k, _p, reads) in enumerate(nodes):
            for r in set(reads):
                j = producer.get(r)
                if j is not None and j != i:
                    succs[j].append(i)
                    indeg[i] += 1
        ready = [i for i, d in enumerate(indeg) if d == 0]
        order: list[int] = []
        while ready:
            i = ready.pop()
            order.append(i)
            for j in succs[i]:
                indeg[j] -= 1
                if indeg[j] == 0:
                    ready.append(j)
        if len(order) != len(nodes):
            cyc = [nodes[i][0] for i, d in enumerate(indeg) if d > 0]
            raise RTLSimError(f"combinational cycle through {cyc[:8]}")
        return [nodes[i] for i in order]

    # -- the per-cycle step --------------------------------------------------
    def _make_step(self, ops, trace: bool):
        comb_tape = self.comb_tape
        clocked = self.clocked
        asserts = self.asserts
        results = self.results
        scalar_inputs = self.scalar_inputs
        state_nets = self.state_nets
        sr_loads = self.sr_loads
        trace_names = self.trace_names if trace else [
            p for pair in results for p in pair]

        def step(state, t_start):
            env: dict[str, Any] = {"t_start": t_start, "clk": 0, "rst": 0}
            for pn, key in scalar_inputs:
                env[pn] = state[key]
            for n in state_nets:
                env[n] = state[n]
            for dest, key in sr_loads:
                env[dest] = ops.sr_out(state[key])
            for dest, kind, payload, _reads in comb_tape:
                if kind == "assign":
                    fn, mk = payload
                    env[dest] = fn(env, ops) & mk
                else:  # controller iter pulse
                    c = payload
                    act = state[c["active"]]
                    iv = state[c["iv"]]
                    sv = c["start"](env, ops) != 0
                    step_up = iv + c["step"](env, ops)
                    more = step_up < c["ub"](env, ops)
                    if c["ii"] is not None:
                        cn = (state[c["iicnt"]] == c["ii"] - 1) \
                            if c["ii"] > 1 else (act == act)
                    else:
                        cn = c["inner"](env, ops) != 0
                    env[dest] = ops.b2i(sv | ((act != 0) & cn & more))
            pend: dict[str, Any] = {}

            def cur(k):
                return pend[k] if k in pend else state[k]

            for ent in clocked:
                tag = ent[0]
                if tag == "sr":
                    _t, key, fn, mk = ent
                    pend[key] = ops.sr_push(cur(key), fn(env, ops) & mk)
                elif tag == "reg":
                    _t, dest, fn, en, mk = ent
                    enb = True if en is None else (en(env, ops) != 0)
                    pend[dest] = ops.where(enb, fn(env, ops) & mk, cur(dest))
                elif tag == "memrd":
                    _t, dest, memkey, afn, efn, mk = ent
                    enb = efn(env, ops) != 0
                    v = ops.read_mem(state[memkey], afn(env, ops)) & mk
                    pend[dest] = ops.where(enb, v, cur(dest))
                elif tag == "memwr":
                    _t, memkey, afn, dfn, efn, mk = ent
                    enb = efn(env, ops) != 0
                    pend[memkey] = ops.write_mem(
                        cur(memkey), afn(env, ops), dfn(env, ops) & mk, enb)
                else:  # controller clocked half
                    c = ent[1]
                    act = state[c["active"]]
                    iv = state[c["iv"]]
                    actb = act != 0
                    sv = c["start"](env, ops) != 0
                    lbv = c["lb"](env, ops)
                    stepv = c["step"](env, ops)
                    ubv = c["ub"](env, ops)
                    step_up = iv + stepv
                    more = step_up < ubv
                    if c["ii"] is not None:
                        if c["ii"] > 1:
                            iicnt = state[c["iicnt"]]
                            cn = iicnt == c["ii"] - 1
                            nxt = ops.where(cn, ops.zero, iicnt + ops.one)
                            pend[c["iicnt"]] = ops.where(
                                sv, ops.zero, ops.where(actb, nxt, iicnt))
                        else:
                            cn = actb | True  # constant true, array-shaped
                    else:
                        cn = c["inner"](env, ops) != 0
                    ivm = c["ivmask"]
                    pend[c["iv"]] = ops.where(
                        sv, lbv & ivm,
                        ops.where(actb & cn & more, step_up & ivm, iv))
                    pend[c["active"]] = ops.where(
                        sv, ops.one,
                        ops.where(actb & cn & (step_up >= ubv), ops.zero,
                                  act))
                    if c["endp"]:
                        pend[c["endp"]] = ops.b2i(
                            actb & cn & (step_up >= ubv))
            for j, (dp, vp) in enumerate(results):
                validb = env[vp] != 0
                seen = state[f"ret:{j}:seen"]
                pend[f"ret:{j}:val"] = ops.where(
                    validb & (seen == 0), env[dp], state[f"ret:{j}:val"])
                pend[f"ret:{j}:seen"] = ops.where(validb, ops.one, seen)
            if asserts:
                viols = [ops.b2i(sum(ops.b2i(en(env, ops) != 0)
                                     for en in ens) > 1)
                         for _bus, ens in asserts]
                cf = state["cf"]
                stacked = (jnp if ops.__class__ is _JaxOps
                           else np).stack(viols, axis=-1)
                pend["cf"] = cf + stacked
            ns = dict(state)
            ns.update(pend)
            outs = tuple(env[p] for p in trace_names)
            return ns, outs

        return step, trace_names

    # -- stimulus packing ----------------------------------------------------
    def _layout(self, b: _Bind, arr: np.ndarray) -> np.ndarray:
        """(B, *shape) tensor -> (B, banks, elems) interface layout."""
        mt = b.mt
        perm = (0,) + tuple(d + 1 for d in mt.distributed) \
            + tuple(d + 1 for d in mt.packed)
        r = np.ascontiguousarray(np.transpose(arr, perm))
        return r.reshape(arr.shape[0], mt.num_banks, mt.bank_elems)

    def _unlayout(self, b: _Bind, r: np.ndarray) -> np.ndarray:
        mt = b.mt
        B = r.shape[0]
        dist_shape = tuple(mt.shape[d] for d in mt.distributed)
        packed_shape = tuple(mt.shape[d] for d in mt.packed)
        r = r.reshape((B,) + dist_shape + packed_shape)
        perm = (0,) + tuple(d + 1 for d in mt.distributed) \
            + tuple(d + 1 for d in mt.packed)
        inv = np.argsort(perm)
        return np.ascontiguousarray(np.transpose(r, inv))

    def _init_state(self, args: Sequence[Any], B: int) -> dict[str, np.ndarray]:
        state: dict[str, np.ndarray] = {}
        for key, shape in self.state_shape.items():
            state[key] = np.zeros((B,) + shape, dtype=I64)
        for b in self.binds:
            a = args[b.index]
            if b.kind == "scalar":
                v = np.broadcast_to(np.asarray(a, dtype=I64), (B,))
                state[f"in:{b.port}"] = (v & _mask_of(b.width)).astype(I64)
                continue
            arr = np.asarray(a, dtype=I64)
            if arr.shape != (B,) + b.mt.shape:
                raise RTLSimError(
                    f"arg {b.index}: expected batch shape {(B,) + b.mt.shape},"
                    f" got {arr.shape}")
            r = self._layout(b, arr & _mask_of(b.width))
            if b.kind == "ram":
                state[b.memkey] = r[:, 0, :].copy()
            else:
                for bk, row in enumerate(b.cells):
                    for d, cn in enumerate(row):
                        state[cn] = r[:, bk, d].copy()
        return state

    # -- execution -----------------------------------------------------------
    def run(self, args: Sequence[Any], cycles: int, batched: bool = False,
            check_conflicts: bool = True, trace: bool = False) -> SimResult:
        """Simulate ``cycles`` cycles of the design over a stimulus batch.

        ``args`` mirrors the hir.func arguments: scalars (python ints or
        (B,) arrays) and numpy arrays of the memref shape ((B, *shape) when
        ``batched``).  ``t_start`` pulses at cycle 0.  Unlike the
        event-driven simulator the input arrays are never mutated."""
        if not batched:
            lifted = []
            for b, a in zip(self.binds, list(args)):
                if b.kind == "scalar":
                    lifted.append(np.asarray([a], dtype=I64))
                else:
                    lifted.append(np.asarray(a, dtype=I64)[None])
            res = self.run(lifted, cycles, batched=True,
                           check_conflicts=check_conflicts, trace=trace)
            return res
        if len(args) != len(self.binds):
            raise RTLSimError(f"expected {len(self.binds)} args")
        B = None
        for b, a in zip(self.binds, args):
            if b.kind != "scalar":
                B = np.asarray(a).shape[0]
                break
            a = np.asarray(a)
            if a.ndim == 1:
                B = a.shape[0]
        if B is None:
            B = 1
        state = self._init_state(args, B)
        xs = np.zeros(cycles, dtype=I64)
        xs[0] = 1
        if self.backend == "jax":
            final, ys = self._run_jax(state, xs, trace)
        else:
            final, ys = self._run_numpy(state, xs, B, trace)
        return self._collect(final, ys, B, cycles, check_conflicts, trace)

    def _run_jax(self, state, xs, trace: bool):
        key = ("trace" if trace else "plain")
        with enable_x64():
            if self._jitted is None or self._jitted[0] != key:
                step, names = self._make_step(_JaxOps(), trace)
                vstep = jax.vmap(step, in_axes=(0, None))

                def scanner(s0, xs):
                    return jax.lax.scan(vstep, s0, xs)

                self._jitted = (key, jax.jit(scanner), names)
            _, fn, names = self._jitted
            s0 = {k: jnp.asarray(v) for k, v in state.items()}
            final, ys = fn(s0, jnp.asarray(xs))
            final = {k: np.asarray(v) for k, v in final.items()}
            ys = {n: np.asarray(y) for n, y in zip(names, ys)}
        return final, ys

    def _run_numpy(self, state, xs, B: int, trace: bool):
        step, names = self._make_step(_NumpyOps(B), trace)
        recs: list[tuple] = []
        for t in range(len(xs)):
            state, outs = step(state, I64(xs[t]))
            recs.append(outs)
        ys = {n: np.stack([np.broadcast_to(np.asarray(r[i], dtype=I64), (B,))
                           for r in recs])
              for i, n in enumerate(names)}
        return state, ys

    def _collect(self, final, ys, B, cycles, check_conflicts, trace):
        rts = self.func.attrs.get("result_types", [])
        returns, valids = [], []
        for j, (dp, _vp) in enumerate(self.results):
            p = np.asarray(final[f"ret:{j}:val"], dtype=I64)
            w = self.widths[dp]
            signed = (isinstance(rts[j], IntType) and rts[j].signed
                      if j < len(rts) else True)
            returns.append(_signed_fix(p, w, signed))
            valids.append(np.asarray(final[f"ret:{j}:seen"], dtype=I64))
        arrays: dict[int, np.ndarray] = {}
        for b in self.binds:
            if b.kind == "scalar":
                continue
            if b.kind == "ram":
                r = np.asarray(final[b.memkey], dtype=I64)[:, None, :]
            else:
                r = np.zeros((B, b.mt.num_banks, b.mt.bank_elems), dtype=I64)
                for bk, row in enumerate(b.cells):
                    for d, cn in enumerate(row):
                        r[:, bk, d] = np.asarray(final[cn], dtype=I64)
            r = r.reshape(B, b.mt.num_banks, b.mt.bank_elems)
            arr = self._unlayout(b, r)
            arrays[b.index] = _signed_fix(arr, b.width, b.signed)
        if self.asserts:
            per_bus = np.asarray(final["cf"], dtype=I64).reshape(
                B, len(self.asserts))
            conflicts = per_bus.sum(axis=1)
        else:
            conflicts = np.zeros(B, dtype=I64)
        if check_conflicts and conflicts.any():
            lanes = np.nonzero(conflicts)[0][:4].tolist()
            raise RTLSimError(
                f"port conflict (UB 4.5) in lanes {lanes}; "
                f"buses={self.conflict_buses[:4]}")
        tr = None
        if trace:
            tr = {n: np.asarray(y, dtype=I64) for n, y in ys.items()}
        return SimResult(self.backend, cycles, B, returns, valids, arrays,
                         conflicts, list(self.conflict_buses), tr)


# ---------------------------------------------------------------------------
# Convenience drivers
# ---------------------------------------------------------------------------


def design_of(mods: dict[str, Any], entry: str) -> RTLDesign:
    """Rebuild an ``RTLDesign`` from ``generate_verilog``'s output map."""
    d = RTLDesign(entry=entry)
    for name, vm in mods.items():
        m = getattr(vm, "rtl", None) or vm
        if not isinstance(m, RTLModule):
            raise RTLSimError(f"module {name} carries no RTL structure")
        d.add(m)
    return d


def simulator_for(module: Module, entry: str, *, hierarchy: str = "inline",
                  backend: str = "auto", rtl_spec: Optional[str] = "default",
                  ) -> tuple[RTLSimulator, Module]:
    """Clone ``module``, run the codegen pipeline and build a simulator.

    Returns ``(sim, prepared)`` where ``prepared`` is the cloned module
    *after* the pre-codegen pipeline — the exact HIR the event-driven oracle
    (``lower.simulate``) should run for lane-by-lane comparison."""
    from .verilog import generate_verilog

    prepared = module.clone()
    kw = {} if rtl_spec == "default" else {"rtl_spec": rtl_spec}
    mods = generate_verilog(prepared, entry, hierarchy=hierarchy, **kw)
    design = design_of(mods, entry)
    sim = RTLSimulator(design, prepared.funcs[entry], entry, backend=backend)
    return sim, prepared


def probe_cycles(prepared: Module, entry: str, args: Sequence[Any],
                 margin: int = 16) -> int:
    """Cycle budget for a batched run: one event-driven simulation on fresh
    zero-filled copies of ``args`` (loop trip counts are static in this flow,
    so the latency is data-independent)."""
    from ..lower.to_sim import simulate

    probe_args = []
    for a in args:
        if isinstance(a, np.ndarray):
            probe_args.append(np.zeros_like(a))
        else:
            probe_args.append(0)
    res = simulate(prepared, entry, probe_args)
    return int(res["cycles"]) + margin


def stack_stimulus(make_inputs: Callable[..., list], n_vectors: int,
                   base_seed: int = 0, **kw) -> list[np.ndarray]:
    """Stack ``n_vectors`` calls of a gallery-style ``make_inputs(seed=k)``
    into batch-first arrays — domain-respecting random stimulus."""
    cols = None
    for k in range(n_vectors):
        row = make_inputs(seed=base_seed + k, **kw)
        if cols is None:
            cols = [[] for _ in row]
        for c, v in zip(cols, row):
            c.append(np.asarray(v))
    return [np.stack(c).astype(I64) for c in cols]


def fold_in_stimulus(widths: Sequence[int], n_lanes: int,
                     seed: int = 0) -> list[np.ndarray]:
    """Per-lane random stimulus from jax-native counter-based PRNG streams:
    one scalar per (input, lane), each drawn from an independent stream
    derived by ``jax.random.fold_in(fold_in(key(seed), input), lane)`` and
    masked to the input's bit width.  Unlike sequential generators, fold_in
    streams are stable under lane/input reordering — adding a lane never
    perturbs the values the existing lanes see, so seed-pinned differential
    suites stay reproducible as they grow.  Falls back to equivalent-shape
    ``numpy.random.SeedSequence`` spawn streams when jax is absent (values
    differ across the two generators; each is deterministic per seed)."""
    masks = [(1 << min(int(w), 63)) - 1 for w in widths]
    out: list[np.ndarray] = []
    if HAVE_JAX:
        key = jax.random.key(seed) if hasattr(jax.random, "key") \
            else jax.random.PRNGKey(seed)
        for i, mask in enumerate(masks):
            ki = jax.random.fold_in(key, i)
            lanes = []
            for lane in range(n_lanes):
                kl = jax.random.fold_in(ki, lane)
                hi, lo = (int(b) for b in
                          jax.random.bits(kl, (2,), dtype=jnp.uint32))
                lanes.append(((hi << 32) | lo) & mask)
            out.append(np.asarray(lanes, dtype=I64))
        return out
    for i, mask in enumerate(masks):
        lanes = []
        for lane in range(n_lanes):
            rng = np.random.default_rng(
                np.random.SeedSequence(entropy=seed, spawn_key=(i, lane)))
            lanes.append(int(rng.integers(0, 1 << 63, dtype=np.int64)) & mask)
        out.append(np.asarray(lanes, dtype=I64))
    return out


# ---------------------------------------------------------------------------
# Differential verification harness
# ---------------------------------------------------------------------------


@dataclass
class DiffReport:
    kernel: str
    hierarchy: str
    backend: str
    n_vectors: int
    cycles: int
    event_lanes_checked: int
    event_ok: bool
    oracle_ok: Optional[bool]
    passes_ok: Optional[dict[str, bool]]
    mismatches: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return (self.event_ok and self.oracle_ok in (None, True)
                and (self.passes_ok is None
                     or all(self.passes_ok.values())))


def _result_args(sim: RTLSimulator, res: SimResult, lane: int,
                 args_batch: Sequence[np.ndarray]) -> list[np.ndarray]:
    """Final memref contents for one lane, in argument order."""
    out = []
    for b in sim.binds:
        if b.kind == "scalar":
            out.append(None)
        else:
            out.append(res.arrays[b.index][lane])
    return out


def run_differential(module: Module, entry: str,
                     args_batch: Sequence[np.ndarray], *,
                     kernel: str = "", hierarchy: str = "inline",
                     backend: str = "auto", event_lanes: int = 2,
                     oracle: Optional[Callable] = None,
                     oracle_nargs: int = 0, result_arg: int = -1,
                     check_passes: bool = True,
                     pass_lanes: int = 16) -> DiffReport:
    """Differentially verify one kernel over a stimulus batch.

    (a) runs the vectorized simulator over the whole batch and re-runs
    ``event_lanes`` sample lanes through the event-driven oracle, comparing
    final memory arrays and scalar returns; (b) when ``oracle`` is given,
    checks the memref written by the design (``result_arg``) against
    ``oracle(*args[:oracle_nargs])`` on every lane; (c) when
    ``check_passes``, re-lowers without RTL passes and replays the pass
    pipeline one pass at a time, asserting per-cycle result-port traces and
    final state match between every pass input and output
    (``verify_rtl_passes``)."""
    from ..lower.to_sim import simulate

    mismatches: list[str] = []
    sim, prepared = simulator_for(module, entry, hierarchy=hierarchy,
                                  backend=backend)
    B = int(np.asarray(args_batch[0]).shape[0]) if args_batch else 1
    single0 = [np.asarray(a)[0] for a in args_batch]
    cycles = probe_cycles(prepared, entry, single0)
    res = sim.run(args_batch, cycles, batched=True)

    # (a) event-driven oracle on sample lanes
    event_ok = True
    lanes = list(range(min(event_lanes, B)))
    for k in lanes:
        ev_args: list[Any] = []
        for b, a in zip(sim.binds, args_batch):
            al = np.asarray(a)[k]
            ev_args.append(int(al) if b.kind == "scalar" else al.copy())
        ev = simulate(prepared, entry, ev_args)
        for b in sim.binds:
            if b.kind == "scalar":
                continue
            got = res.arrays[b.index][k]
            want = ev_args[b.index]
            if not np.array_equal(got, want):
                event_ok = False
                mismatches.append(
                    f"lane {k} arg {b.index}: vectorized != event-driven")
        ev_rets = ev.get("returns") or {}
        for j in range(len(sim.results)):
            if f"ret{j}" not in ev_rets:
                continue
            rv = ev_rets[f"ret{j}"]
            if res.returns_valid[j][k] == 0:
                event_ok = False
                mismatches.append(f"lane {k} result_{j}: no valid pulse")
            elif int(res.returns[j][k]) != int(rv):
                event_ok = False
                mismatches.append(
                    f"lane {k} result_{j}: {int(res.returns[j][k])} != {rv}")

    # (b) jax/numpy functional oracle on every lane
    oracle_ok: Optional[bool] = None
    if oracle is not None:
        oracle_ok = True
        ridx = result_arg if result_arg >= 0 else len(args_batch) - 1
        for k in range(B):
            want = np.asarray(
                oracle(*[np.asarray(args_batch[i])[k]
                         for i in range(oracle_nargs)]))
            got = res.arrays[ridx][k]
            if not np.array_equal(got.astype(I64), want.astype(I64)):
                oracle_ok = False
                mismatches.append(f"lane {k}: vectorized != oracle")
                break

    passes_ok = None
    if check_passes:
        sub = [np.asarray(a)[:min(pass_lanes, B)] for a in args_batch]
        passes_ok, pmism = verify_rtl_passes(
            prepared, entry, sub, cycles, hierarchy=hierarchy)
        mismatches.extend(pmism)

    return DiffReport(kernel or entry, hierarchy, sim.backend, B, cycles,
                      len(lanes), event_ok, oracle_ok, passes_ok, mismatches)


def verify_rtl_passes(prepared: Module, entry: str,
                      args_batch: Sequence[np.ndarray], cycles: int, *,
                      hierarchy: str = "inline",
                      spec: Optional[str] = None,
                      backend: str = "numpy",
                      ) -> tuple[dict[str, bool], list[str]]:
    """Per-pass differential check: starting from the raw lowering, run each
    RTL pass of ``spec`` on a copy of the design and assert the pass output
    is cycle-accurate-equivalent to its input (result-port traces every
    cycle, final memref arrays, captured returns).  ``prepared`` must
    already be through the pre-codegen pipeline (see ``simulator_for``)."""
    from .verilog import RTL_PIPELINE_SPEC, lower_to_rtl

    spec = spec if spec is not None else RTL_PIPELINE_SPEC
    func = prepared.funcs[entry]
    rtl.clear_key_intern()
    emit = [entry] if hierarchy == "inline" else None
    design = lower_to_rtl(prepared, emit or [entry], hierarchy=hierarchy,
                          entry=entry)

    def signature(d: RTLDesign):
        s = RTLSimulator(d.copy(), func, entry, backend=backend)
        r = s.run(args_batch, cycles, batched=True, check_conflicts=False,
                  trace=True)
        return r

    ok: dict[str, bool] = {}
    mism: list[str] = []
    prev = signature(design)
    for name in [p.strip() for p in spec.split(",") if p.strip()]:
        pm = PassManager.from_spec(name)
        pm.run(design)
        cur = signature(design)
        good = True
        for p, tr in prev.trace.items():
            if p not in cur.trace or not np.array_equal(tr, cur.trace[p]):
                good = False
                mism.append(f"{name}: trace of {p} diverged")
        for i, arr in prev.arrays.items():
            if not np.array_equal(arr, cur.arrays[i]):
                good = False
                mism.append(f"{name}: final arg {i} diverged")
        if not np.array_equal(prev.conflicts, cur.conflicts):
            good = False
            mism.append(f"{name}: conflict counts diverged")
        ok[name] = good
        prev = cur
    return ok, mism


__all__ = [
    "HAVE_JAX", "RTLSimError", "RTLSimulator", "SimResult", "DiffReport",
    "close_module", "design_of", "simulator_for", "probe_cycles",
    "stack_stimulus", "run_differential", "verify_rtl_passes",
]
