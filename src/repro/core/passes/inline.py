"""Call inlining (module-hierarchy flattening).

Internal ``hir.call`` sites are replaced by a clone of the callee body with

  * formals bound to actuals (memrefs alias the caller's storage — so state
    passed by memref stays shared across call instances),
  * the callee's root time variable rebased to the call's start time,
  * results bound to the callee's returned values.

Internal allocs are replicated per call site, which matches the paper's §4.5
semantics (no persistent function-local state across calls).  External
(blackbox Verilog) calls are left intact — they become module instantiations.

This runs before Verilog codegen so memref plumbing across the hierarchy
becomes ordinary same-module wiring.
"""

from __future__ import annotations

from .. import ir
from ..ir import FuncOp, Module, Operation, Region, Time, Value
from .unroll import _clone_op


def _inline_region(module: Module, func: FuncOp, region: Region,
                   only: set[str] | None = None) -> int:
    n = 0
    new_ops: list[Operation] = []
    for op in region.ops:
        for r in op.regions:
            n += _inline_region(module, func, r, only)
        if op.opname == "call":
            callee = module.funcs.get(op.attrs["callee"])
            if (callee is not None and not callee.attrs.get("external")
                    and (only is None or callee.name in only)):
                assert op.start is not None, "call must be scheduled"
                vmap: dict[Value, Value] = {}
                for formal, actual in zip(callee.args, op.operands):
                    vmap[formal] = actual
                tmap = {callee.time_var: (op.start.tv, op.start.offset)}
                ret_vals: list[Value] = []
                clones: list[Operation] = []
                for b in callee.body.ops:
                    if b.opname == "return":
                        ret_vals = list(b.operands)
                        continue
                    c = _clone_op(b, vmap, tmap)
                    c.parent_region = region
                    clones.append(c)
                from .unroll import _remap_operands

                _remap_operands(clones, vmap)
                new_ops.extend(clones)
                # bind call results to the cloned returned values
                for res, rv in zip(op.results, ret_vals):
                    res.replace_all_uses_with(vmap.get(rv, rv))
                op.drop_all_uses()  # the call op is replaced by the clones
                n += 1
                continue
        new_ops.append(op)
    region.ops[:] = new_ops
    return n


def inline_calls(module: Module, entry: str | None = None,
                 only: set[str] | None = None) -> int:
    """Inline internal calls (transitively).  ``only`` restricts inlining to
    the named callees (hierarchical emission uses this to flatten trivial
    functions while keeping non-trivial ones as modules).  Returns call
    sites inlined."""
    total = 0
    for _ in range(16):  # bounded transitive inlining
        n = 0
        for f in module.funcs.values():
            if f.attrs.get("external"):
                continue
            n += _inline_region(module, f, f.body, only)
        total += n
        if n == 0:
            break
    return total


from ..passmgr import Pass, register_pass  # noqa: E402


@register_pass
class Inline(Pass):
    """Module-hierarchy flattening (pre-codegen)."""

    name = "inline"

    def run(self, module: Module) -> int:
        return inline_calls(module)
