"""Schedule-aware RAM port demotion (paper §2 "Ease of optimization"):

    "if a distributed RAM is defined as simple dual port but the read and
     write operation's schedules do not overlap, we can replace it with a
     single port RAM to save resources."

For every ``hir.alloc`` with both a read and a write port we prove, from the
explicit schedule, that no read and write can ever land in the same cycle:

  * same pipelined loop: disjoint congruence classes (offset mod II);
  * same root, no pipelining: distinct constant offsets;
  * different roots: one root's chain passes through the other loop's end
    time (phases are sequentially ordered, e.g. a drain loop scheduled at
    ``%loop_end offset k``).

Provably-disjoint allocs get ``attrs["single_port"] = True``; the resource
model then costs one RAM port instead of two."""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..analysis import MemAccess
from ..ir import ForOp, FuncOp, Module, Value


def _roots_ordered(func: FuncOp, a_root: Value, b_root: Value) -> bool:
    """True if every instant under one root is provably after every instant
    under the other (chain passes through the other's loop end_time)."""
    loop_of_root: dict[Value, ForOp] = {}
    parent: dict[Value, Value] = {}
    for op in func.body.walk():
        if isinstance(op, ForOp):
            loop_of_root[op.time_var] = op
            if op.start is not None:
                parent[op.time_var] = op.start.tv
                parent[op.end_time] = op.start.tv
        elif op.opname == "time":
            parent[op.result] = op.operands[0]

    def chain(tv: Value) -> list[Value]:
        out = [tv]
        seen = {tv}
        while tv in parent:
            tv = parent[tv]
            if tv in seen:
                break
            seen.add(tv)
            out.append(tv)
        return out

    def passes_through_end_of(tv: Value, other_root: Value) -> bool:
        other_loop = loop_of_root.get(other_root)
        if other_loop is None:
            return False
        # does tv's derivation chain include other_loop.end_time, or the end
        # time of any loop enclosing other_root?
        ends = {other_loop.end_time}
        cur = other_root
        while cur in parent:
            cur = parent[cur]
            if cur in loop_of_root:
                ends.add(loop_of_root[cur].end_time)
        return any(v in ends for v in chain(tv))

    return passes_through_end_of(a_root, b_root) or passes_through_end_of(b_root, a_root)


def _disjoint(func: FuncOp, a: MemAccess, b: MemAccess) -> bool:
    if a.root is b.root:
        if a.offsets_mod and b.offsets_mod and a.offsets_mod[1] == b.offsets_mod[1]:
            return a.offsets_mod[0] != b.offsets_mod[0]
        if not a.offsets_mod and not b.offsets_mod and a.offset is not None and b.offset is not None:
            return a.offset != b.offset
        return False
    return _roots_ordered(func, a.root, b.root)


def _demote_func(f: FuncOp, accesses: dict[Value, list[MemAccess]]) -> int:
    n = 0
    for op in f.body.walk():
        if op.opname != "alloc" or op.attrs.get("single_port") or len(op.results) < 2:
            continue
        reads: list[MemAccess] = []
        writes: list[MemAccess] = []
        for port in op.results:
            for acc in accesses.get(port, []):
                (writes if acc.is_write else reads).append(acc)
        if not reads or not writes:
            continue
        if all(_disjoint(f, r, w) for r in reads for w in writes):
            op.attrs["single_port"] = True
            n += 1
    return n


from ..passmgr import Pass, register_pass  # noqa: E402
from ..analysis import PortAccessAnalysis  # noqa: E402


@register_pass
class PortDemotion(Pass):
    """Schedule-disjointness proof over whole functions (not a local
    pattern); the schedule/port tables come from the shared analysis cache
    (computed by the verifier or a prior pass, reused here)."""

    name = "port-demotion"
    preserves_all = True  # attribute-only rewrite (alloc "single_port")

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):
            n += _demote_func(f, self.get_analysis(PortAccessAnalysis, f))
        return n


def port_demotion(module: Module) -> int:
    return PortDemotion().run(module)
