"""Canonicalization, constant propagation and DCE (paper §6.2), expressed as
rewrite patterns on the worklist driver (``core.rewrite``).

  * ``CanonicalizePattern`` — commutative operands ordered constants-last
    (LLVM-style), then by SSA id — the stable form is what enables CSE —
    plus the identity folds (x+0, x-0, x<<0, x>>0, x|0, x^0, x*1), which
    forward their operand and erase themselves;
  * ``ConstFoldPattern``    — pure arith over all-constant operands folds
    to an ``hir.constant``; the driver then revisits exactly the users of
    the folded value, so constant chains collapse in one worklist drain
    instead of the seed's repeated full-region walks;
  * ``dce``                 — use-count driven erasure of dead pure ops
    (O(#ops), not the seed's O(#ops²) re-walk loop).
"""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..ir import FuncOp, Module, Operation, Region, Value, const_value
from ..passmgr import Pass, PatternRewritePass, register_pass
from ..rewrite import PatternRewriter, RewritePattern, RewritePatternSet


def _fold(opname: str, vals: list) -> Optional[int]:
    try:
        if opname == "add":
            return vals[0] + vals[1]
        if opname == "sub":
            return vals[0] - vals[1]
        if opname == "mult":
            return vals[0] * vals[1]
        if opname == "div":
            return vals[0] // vals[1]
        if opname == "and":
            return vals[0] & vals[1]
        if opname == "or":
            return vals[0] | vals[1]
        if opname == "xor":
            return vals[0] ^ vals[1]
        if opname == "shl":
            return vals[0] << vals[1]
        if opname == "shr":
            return vals[0] >> vals[1]
        if opname.startswith("cmp_"):
            import operator

            f = {"lt": operator.lt, "le": operator.le, "eq": operator.eq,
                 "ne": operator.ne, "gt": operator.gt, "ge": operator.ge}[opname[4:]]
            return int(f(vals[0], vals[1]))
        if opname == "select":
            return vals[1] if vals[0] else vals[2]
        if opname in ("trunc", "zext", "sext", "not"):
            return ~vals[0] if opname == "not" else vals[0]
    except (ZeroDivisionError, OverflowError, TypeError, ValueError):
        # arithmetic on the literal operands failed (e.g. div by const 0,
        # or a non-integer attr leaked in) — simply decline to fold
        return None
    return None


_IDENTITY_ZERO_OPS = ("add", "sub", "shl", "shr", "or", "xor")


class CanonicalizePattern(RewritePattern):
    """The canonicalization rules, bundled into one pattern so each visit
    computes the operand constants once:

      * commutative operand order — constants last, then by SSA id;
      * x+0 / x-0 / x<<0 / x>>0 / x|0 / x^0 -> x;  x*1 -> x.

    One rule fires per visit (the driver revisits until quiescent), so
    rewrite counts match applying the rules separately."""

    ops = tuple(set(ir.COMMUTATIVE_OPS) | set(_IDENTITY_ZERO_OPS))

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if len(op.operands) != 2:
            return False
        opname = op.opname
        a, b = op.operands
        ca, cb = const_value(a), const_value(b)
        if opname in ir.COMMUTATIVE_OPS:
            if ((ca is not None, a.id)) > ((cb is not None, b.id)):
                rewriter.set_operands(op, [b, a])
                return True
        if not op.results:
            return False
        if opname == "mult":
            if cb == 1:
                rewriter.replace_op(op, [a])
                return True
            if ca == 1:
                rewriter.replace_op(op, [b])
                return True
        elif opname in _IDENTITY_ZERO_OPS and cb == 0:
            rewriter.replace_op(op, [a])
            return True
        return False


class ConstFoldPattern(RewritePattern):
    """Fold pure arith ops whose operands are all compile-time constants."""

    ops = tuple(ir.ARITH_OPS)
    benefit = 2  # fold before reordering/identity rules bother

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if not op.results:
            return False
        vals = [const_value(v) for v in op.operands]
        if any(v is None for v in vals):
            return False
        folded = _fold(op.opname, vals)
        if folded is None:
            return False
        cst = ir.constant(folded, ir.CONST)
        rewriter.insert_before(op, cst)
        rewriter.replace_op(op, [cst.result])
        return True


# pattern sets are stateless: built once at import, shared by every run
_CANONICALIZE_SET = RewritePatternSet([CanonicalizePattern()])
_CONSTFOLD_SET = RewritePatternSet([ConstFoldPattern()])


@register_pass
class Canonicalize(PatternRewritePass):
    name = "canonicalize"
    # folded pure ops always complete no later than their consumers start, so
    # loop spans / IIs and the port congruence classes are untouched
    preserves = ("loop-info", "port-accesses")

    def patterns(self, func: FuncOp) -> RewritePatternSet:
        return _CANONICALIZE_SET


@register_pass
class ConstProp(PatternRewritePass):
    name = "constprop"
    preserves = ("loop-info", "port-accesses")

    def patterns(self, func: FuncOp) -> RewritePatternSet:
        return _CONSTFOLD_SET


def _is_pure(op: Operation) -> bool:
    return op.opname in ir.ARITH_OPS or op.opname in ("constant", "delay")


@register_pass
class DCE(Pass):
    """Remove pure ops whose results are unused — worklist over the use-def
    chains: erasing an op may make its operands' defining ops dead, and only
    those are revisited."""

    name = "dce"

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):
            work = [op for op in f.body.walk() if _is_pure(op)]
            dead_by_region: dict[int, Region] = {}
            while work:
                op = work.pop()
                if op.is_erased or not op.results:
                    continue
                if any(r.has_uses() for r in op.results):
                    continue
                producers = {v.defining_op for v in op.operands if v.defining_op is not None}
                region = op.parent_region
                op.drop_all_uses()  # lazy: compact each region once at the end
                if region is not None:
                    dead_by_region[id(region)] = region
                n += 1
                work.extend(p for p in producers if _is_pure(p) and not p.is_erased)
            for region in dead_by_region.values():
                region.ops[:] = [o for o in region.ops if not o.is_erased]
        return n


# -- legacy callable forms (same names/signatures as the seed) --------------


def canonicalize(module: Module) -> int:
    """Order commutative operands + identity folds; returns rewrites."""
    return Canonicalize().run(module)


def constprop(module: Module) -> int:
    """Fold pure ops whose operands are all compile-time constants."""
    return ConstProp().run(module)


def dce(module: Module) -> int:
    """Remove pure ops whose results are unused."""
    return DCE().run(module)
