"""Canonicalization, constant propagation and DCE (paper §6.2)."""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..ir import ForOp, FuncOp, Module, Operation, Region, Value, const_value, replace_all_uses


def _fold(opname: str, vals: list) -> Optional[int]:
    try:
        if opname == "add":
            return vals[0] + vals[1]
        if opname == "sub":
            return vals[0] - vals[1]
        if opname == "mult":
            return vals[0] * vals[1]
        if opname == "div":
            return vals[0] // vals[1]
        if opname == "and":
            return vals[0] & vals[1]
        if opname == "or":
            return vals[0] | vals[1]
        if opname == "xor":
            return vals[0] ^ vals[1]
        if opname == "shl":
            return vals[0] << vals[1]
        if opname == "shr":
            return vals[0] >> vals[1]
        if opname.startswith("cmp_"):
            import operator

            f = {"lt": operator.lt, "le": operator.le, "eq": operator.eq,
                 "ne": operator.ne, "gt": operator.gt, "ge": operator.ge}[opname[4:]]
            return int(f(vals[0], vals[1]))
        if opname == "select":
            return vals[1] if vals[0] else vals[2]
        if opname in ("trunc", "zext", "sext", "not"):
            return ~vals[0] if opname == "not" else vals[0]
    except Exception:
        return None
    return None


def _each_func(module: Module):
    for f in module.funcs.values():
        if not f.attrs.get("external"):
            yield f


def canonicalize(module: Module) -> int:
    """Order commutative operands by SSA id (enables CSE); fold identities
    (x+0, x*1, x*0)."""
    n = 0
    for f in _each_func(module):
        for op in f.body.walk():
            if op.opname in ir.COMMUTATIVE_OPS and len(op.operands) == 2:
                # canonical operand order: constants last (LLVM-style), then
                # by SSA id — stable form enables CSE and the identity folds
                a, b = op.operands
                ka = (const_value(a) is not None, a.id)
                kb = (const_value(b) is not None, b.id)
                if ka > kb:
                    op.operands[0], op.operands[1] = b, a
                    n += 1
            # identity folds
            if op.opname in ("add", "sub", "shl", "shr", "or", "xor") and len(op.operands) == 2:
                cb = const_value(op.operands[1])
                if cb == 0 and op.results:
                    replace_all_uses(f.body, op.result, op.operands[0])
                    n += 1
            elif op.opname == "mult" and op.results:
                for i in (0, 1):
                    c = const_value(op.operands[i])
                    if c == 1:
                        replace_all_uses(f.body, op.result, op.operands[1 - i])
                        n += 1
                        break
    return n


def constprop(module: Module) -> int:
    """Fold pure ops whose operands are all compile-time constants."""
    n = 0
    for f in _each_func(module):
        changed = True
        while changed:
            changed = False
            for op in list(f.body.walk()):
                if op.opname not in ir.ARITH_OPS or not op.results:
                    continue
                vals = [const_value(v) for v in op.operands]
                if any(v is None for v in vals):
                    continue
                folded = _fold(op.opname, vals)
                if folded is None:
                    continue
                cst = ir.constant(folded, ir.CONST)
                region = op.parent_region or f.body
                region.ops.insert(region.ops.index(op), cst)
                cst.parent_region = region
                replace_all_uses(f.body, op.result, cst.result)
                region.ops.remove(op)  # the folded op is dead: drop it now so
                # the fixpoint loop terminates instead of refolding it forever
                changed = True
                n += 1
    return n


def _is_pure(op: Operation) -> bool:
    return op.opname in ir.ARITH_OPS or op.opname in ("constant", "delay")


def dce(module: Module) -> int:
    """Remove pure ops whose results are unused."""
    n = 0
    for f in _each_func(module):
        changed = True
        while changed:
            changed = False
            used: set[int] = set()
            for op in f.body.walk():
                for v in op.operands:
                    used.add(v.id)
            # returns/yields handled above (operands); function results too

            def sweep(region: Region) -> None:
                nonlocal n, changed
                keep = []
                for op in region.ops:
                    if _is_pure(op) and op.results and all(r.id not in used for r in op.results):
                        changed = True
                        n += 1
                        continue
                    for r in op.regions:
                        sweep(r)
                    keep.append(op)
                region.ops[:] = keep

            sweep(f.body)
    return n
