"""First-class schedule transforms (the paper's actual pitch: "optimizations
such as retiming and pipelining" expressed as ordinary IR transformations
over the explicit schedule):

  * ``pipeline-loop``  — rewrite a sequential ``hir.for``'s schedule to a
    legal minimum-II pipeline.  Candidates are innermost loops whose yield
    fires at II = body span (e.g. the output of
    ``hls_schedule(pipeline_loops=False)`` or any conservatively scheduled
    design).  The pass strips the old balancing delays, rebuilds the body
    schedule with the shared modulo engine at the smallest feasible II
    (bounded below by the recurrence and port-bank resource constraints,
    from the cached dependence/touch analyses), then re-inserts the
    ``hir.delay`` balancing so every operand arrives exactly at its
    consumption cycle.

  * ``retime``         — hoist delays across combinational ops to shorten
    critical chains and shrink shift-register depth: when every non-constant
    operand of a comb op is a single-use ``hir.delay`` arriving exactly at
    the op's cycle, the op moves k cycles earlier and a single output delay
    replaces the input chains.  Fires only when it strictly reduces shift
    register bits (several input chains merge into one output chain, or a
    narrowing op moves ahead of its delay); the saving shows up in the
    ``Netlist`` resource model.

Both passes are driven by the AnalysisManager-cached analyses declared in
``core.analysis`` and preserve/invalidate them accordingly.
"""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..analysis import (DependenceAnalysis, MemTouchAnalysis,
                        scheduled_op_latency)
from ..ir import ForOp, FuncOp, Module, Operation, Time
from ..passmgr import Pass, PatternRewritePass, register_pass
from ..rewrite import PatternRewriter, RewritePattern, RewritePatternSet
from ..schedule import (CLOCK_NS, COMB_DELAY, access_bank_key, balance_delays,
                        try_modulo_schedule)

# ---------------------------------------------------------------------------
# pipeline-loop
# ---------------------------------------------------------------------------


def _body_latency(op: Operation) -> int:
    """Latency of an innermost-loop body op (no loop children, so the shared
    timing model needs no loop-latency table)."""
    return scheduled_op_latency(op, {})


def _pipeline_candidate(loop: ForOp) -> Optional[int]:
    """Current (sequential) II if ``loop`` is an innermost hir.for whose
    whole body is scheduled on its own time variable; None otherwise."""
    if loop.opname != "for":
        return None
    y = loop.yield_op()
    if y is None or y.start is None or y.start.tv is not loop.time_var:
        return None
    if loop.attrs.get("pipelined_ii") == y.start.offset:
        return None  # already at the II this pass found; don't re-churn
    for op in loop.region(0).ops:
        if isinstance(op, ForOp):
            return None
        if op.opname in ("constant", "yield"):
            continue
        if op.start is None or op.start.tv is not loop.time_var:
            return None
    return y.start.offset


def _strip_delays(loop: ForOp) -> int:
    """Remove pure balancing delays from the loop body (forward sources);
    the fresh schedule re-balances from scratch.  One pass suffices: SSA
    dominance orders a delay-of-delay after its source, whose own RAUW has
    already rewritten the outer delay's operand."""
    n = 0
    for op in list(loop.region(0).ops):
        if op.opname == "delay":
            op.result.replace_all_uses_with(op.operands[0])
            op.erase()
            n += 1
    return n


@register_pass
class PipelineLoop(Pass):
    """Minimum-II modulo pipelining of sequential innermost loops."""

    name = "pipeline-loop"
    # schedules move: loop info, port congruence classes and the dependence
    # graph all change; nothing is preserved.
    preserves: tuple[str, ...] = ()

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):
            n += self.run_on_func(f)
        return n

    def run_on_func(self, f: FuncOp) -> int:
        # candidates, prefiltered by the resource lower bound — one access
        # per cycle per port bank, computable before stripping delays (bank
        # keys never involve delay results: distributed indices are
        # compile-time constants)
        candidates: list[tuple[ForOp, int, int]] = []  # (loop, cur_ii, res_mii)
        for loop in f.body.walk():
            if not isinstance(loop, ForOp):
                continue
            cur_ii = _pipeline_candidate(loop)
            if cur_ii is None or cur_ii < 2:
                continue
            per_bank: dict[tuple, int] = {}
            for o in loop.region(0).ops:
                if o.opname in ("mem_read", "mem_write"):
                    k = access_bank_key(o)
                    per_bank[k] = per_bank.get(k, 0) + 1
            res_mii = max(per_bank.values(), default=1)
            if res_mii >= cur_ii:
                loop.attrs["pipelined_ii"] = cur_ii  # provably no better II
                continue
            candidates.append((loop, cur_ii, res_mii))
        if not candidates:
            return 0

        # strip every candidate's balancing delays up front, then compute
        # the cached analyses once for the whole function
        stripped = {loop: _strip_delays(loop) for loop, _, _ in candidates}
        if self.am is not None:
            self.am.invalidate(func=f)  # stripped delays: op operands changed
        touches = self.get_analysis(MemTouchAnalysis, f)
        dep = self.get_analysis(DependenceAnalysis, f)

        rewrites = churn = 0
        for loop, cur_ii, res_mii in candidates:
            if self._pipeline(loop, cur_ii, res_mii, dep, touches):
                rewrites += 1
            else:
                # infeasible probe: its stripped delays are churn that the
                # final balance pass re-inserts
                churn += stripped[loop]
        # schedules changed (or balancing delays were stripped while probing
        # an infeasible candidate): refresh the cached analyses, then
        # re-balance — the repeated verification inside reuses the fresh
        # loop info across its fixpoint iterations.
        if self.am is not None:
            self.am.invalidate(func=f)
        balance_delays(f, am=self.am)
        if self.am is not None:
            self.am.invalidate(func=f)
        # churn counts as rewrites: the IR did change, and the PassManager's
        # clean-pass bookkeeping must not treat the module as untouched.
        return rewrites + churn

    @staticmethod
    def _pipeline(loop: ForOp, cur_ii: int, res_mii: int, dep, touches) -> bool:
        """Re-schedule one candidate at the smallest feasible II < cur_ii;
        True iff the loop was pipelined."""
        tv = loop.time_var
        ops = [o for o in loop.region(0).ops
               if o.opname not in ("constant", "alloc", "yield", "return", "time")]
        edges = dep.for_loop(loop)
        for ii in range(max(1, res_mii), cur_ii):
            t = try_modulo_schedule(ops, edges, ii, _body_latency, touches.of)
            if t is None:
                continue
            for op, cyc in t.items():
                op.start = Time(tv, cyc)
                for r in op.results:
                    if ir.is_primitive(r.type):
                        r.birth = Time(tv, cyc + _body_latency(op))
            loop.yield_op().start = Time(tv, ii)
            loop.attrs["pipelined_ii"] = ii
            return True
        # infeasible below cur_ii: remember so later runs don't re-probe
        loop.attrs["pipelined_ii"] = cur_ii
        return False


def pipeline_loops(module: Module) -> int:
    return PipelineLoop().run(module)


# ---------------------------------------------------------------------------
# retime
# ---------------------------------------------------------------------------


def _width(t: ir.Type) -> int:
    if isinstance(t, (ir.IntType, ir.FloatType)):
        return t.width
    return 32  # !hir.const: placeholder width, never materialised


def _chain_arrival_ns(v, tv, off) -> float:
    """Worst-case combinational arrival time (ns) of ``v`` within cycle
    ``(tv, off)``: 0 for registered / other-cycle producers, else the
    producer's own chain plus its gate delay — the scheduler's operator
    chaining model (``core.schedule``)."""
    d = v.defining_op
    if d is None or d.opname not in ir.ARITH_OPS or d.attrs.get("stages", 0):
        return 0.0
    if d.start is None or d.start.tv is not tv or d.start.offset != off:
        return 0.0
    depth = max((_chain_arrival_ns(o, tv, off) for o in d.operands), default=0.0)
    return depth + COMB_DELAY.get(d.opname, 0.0)


class HoistDelayPattern(RewritePattern):
    """``op(delay(a, k), delay(b, k'), ...) at t`` — when every non-constant
    operand is a single-use delay arriving exactly at ``t`` — becomes
    ``delay(op(a', b', ...) at t-k, k)`` with the input chains shortened by
    ``k = min depth``.  Sound because each stripped operand is, by
    construction, valid exactly at the op's new earlier cycle; the output
    delay reproduces the original result timing bit-for-bit."""

    ops = tuple(ir.ARITH_OPS)

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if op.start is None or op.attrs.get("stages", 0) or not op.results:
            return False
        use = Time(op.start.tv, op.start.offset)
        delays: list[Operation] = []
        for v in op.operands:
            d = v.defining_op
            if d is not None and d.opname == "delay" and d.attrs["by"] >= 1 \
                    and v.num_uses == 1 and v.birth is not None \
                    and v.birth.tv is use.tv and v.birth.offset == use.offset:
                delays.append(d)
            elif ir.const_value(v) is not None:
                continue  # constants are always valid, at any cycle
            else:
                return False
        if not delays:
            return False
        k = min(d.attrs["by"] for d in delays)
        if op.start.offset - k < 0:
            return False
        # strict register saving: input chain bits removed > output bits added
        if sum(_width(d.result.type) for d in delays) <= _width(op.result.type):
            return False
        # clock budget: fully folding a delay (by == k) merges the op into
        # its source's cycle — the combinational chain through the source
        # must still fit the 200 MHz budget the scheduler enforced when it
        # split them.  Shortened delays (by > k) stay registered: arrival 0.
        new_off = op.start.offset - k
        arrival = max((_chain_arrival_ns(d.operands[0], use.tv, new_off)
                       for d in delays if d.attrs["by"] == k), default=0.0)
        if arrival + COMB_DELAY.get(op.opname, 0.0) > CLOCK_NS:
            return False

        # shorten (or fold away) each input chain
        for d in delays:
            i = op.operands.index(d.result)
            if d.attrs["by"] == k:
                rewriter.set_operand(op, i, d.operands[0])
                rewriter.erase_op(d)
            else:
                d.attrs["by"] -= k
                src = d.operands[0]
                d.result.birth = (src.birth + d.attrs["by"] if src.birth is not None
                                  else (d.start + d.attrs["by"] if d.start is not None else None))
                rewriter.notify_modified(d)
        # move the op k cycles earlier
        op.start = Time(use.tv, use.offset - k)
        op.result.birth = op.start
        rewriter.notify_modified(op)
        # one shared output delay restores the original timing
        users = [u for u in op.result.uses]
        nd = ir.delay(op.result, k, start=op.start, loc=op.loc)
        rewriter.insert_after(op, nd)
        for u in users:
            rewriter.set_operand(u.op, u.index, nd.result)
        return True


_RETIME_SET = RewritePatternSet([HoistDelayPattern()])


@register_pass
class Retime(PatternRewritePass):
    """Delay hoisting across combinational ops (shift-register sharing).
    Completion times are bit-for-bit preserved, so the loop analysis and the
    port congruence classes stay valid."""

    name = "retime"
    preserves = ("loop-info", "port-accesses")

    def patterns(self, func: FuncOp) -> RewritePatternSet:
        return _RETIME_SET


def retime(module: Module) -> int:
    return Retime().run(module)
