"""Delay elimination / shift-register sharing (paper §6.4).

  * ``delay %v by 0``            -> forwarded to %v (worklist pattern)
  * two delays of the same source with depths a < b: the deeper one re-taps
    the shallower chain — ``delay %v by b`` becomes ``delay (delay %v by a)
    by b-a`` — so codegen emits one shared shift-register chain with taps
    instead of two parallel chains (a+b-a registers instead of a+b).
  * exact duplicates are removed by ``cse``; this pass handles partial overlap.

Zero-delay forwarding is a local pattern on the greedy driver; chain sharing
needs to see all delays of a region at once and stays a region walk."""

from __future__ import annotations

from collections import defaultdict

from .. import ir
from ..ir import FuncOp, Module, Operation, Region
from ..passmgr import Pass, register_pass
from ..rewrite import PatternRewriter, RewritePattern, RewritePatternSet, apply_patterns_greedily


class ZeroDelayForwardPattern(RewritePattern):
    """delay %v by 0 -> %v."""

    ops = ("delay",)

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if op.attrs["by"] != 0:
            return False
        rewriter.replace_op(op, [op.operands[0]])
        return True


def _share_chains(region: Region) -> int:
    n = 0
    by_src: dict[int, list[Operation]] = defaultdict(list)
    for op in region.ops:
        if op.opname == "delay" and op.attrs["by"] > 0 and not op.attrs.get("shared"):
            by_src[op.operands[0].id].append(op)
        for r in op.regions:
            n += _share_chains(r)
    order = {id(op): i for i, op in enumerate(region.ops)}
    for _, group in by_src.items():
        if len(group) < 2:
            continue
        group.sort(key=lambda o: o.attrs["by"])
        for prev, cur in zip(group, group[1:]):
            # preserve SSA textual dominance: only re-tap when the
            # shallower chain is defined first
            if cur.attrs["by"] > prev.attrs["by"] and order.get(id(prev), 1 << 30) < order.get(id(cur), -1):
                cur.set_operand(0, prev.result)
                cur.attrs["by"] = cur.attrs["by"] - prev.attrs["by"]
                cur.attrs["shared"] = True
                n += 1
    return n


_ZERO_DELAY_SET = RewritePatternSet([ZeroDelayForwardPattern()])


@register_pass
class DelayElim(Pass):
    name = "delay-elim"
    # re-tapped chains keep every tap's absolute completion time; no memory
    # ops are touched
    preserves = ("loop-info", "port-accesses")

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):
            n += apply_patterns_greedily(f.body, _ZERO_DELAY_SET)
            n += _share_chains(f.body)
        return n


def delay_elim(module: Module) -> int:
    return DelayElim().run(module)
