"""Delay elimination / shift-register sharing (paper §6.4).

  * ``delay %v by 0``            -> forwarded to %v
  * two delays of the same source with depths a < b: the deeper one re-taps
    the shallower chain — ``delay %v by b`` becomes ``delay (delay %v by a)
    by b-a`` — so codegen emits one shared shift-register chain with taps
    instead of two parallel chains (a+b-a registers instead of a+b).
  * exact duplicates are removed by ``cse``; this pass handles partial overlap.
"""

from __future__ import annotations

from collections import defaultdict

from .. import ir
from ..ir import Module, Operation, Region, replace_all_uses


def delay_elim(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue

        # zero-delay forwarding
        for op in list(f.body.walk()):
            if op.opname == "delay" and op.attrs["by"] == 0:
                replace_all_uses(f.body, op.result, op.operands[0])
                n += 1

        # chain-sharing within each region (taps must be in the same scope)
        def share(region: Region) -> None:
            nonlocal n
            by_src: dict[int, list[Operation]] = defaultdict(list)
            for op in region.ops:
                if op.opname == "delay" and op.attrs["by"] > 0 and not op.attrs.get("shared"):
                    by_src[op.operands[0].id].append(op)
                for r in op.regions:
                    share(r)
            order = {id(op): i for i, op in enumerate(region.ops)}
            for _, group in by_src.items():
                if len(group) < 2:
                    continue
                group.sort(key=lambda o: o.attrs["by"])
                for prev, cur in zip(group, group[1:]):
                    # preserve SSA textual dominance: only re-tap when the
                    # shallower chain is defined first
                    if cur.attrs["by"] > prev.attrs["by"] and order.get(id(prev), 1 << 30) < order.get(id(cur), -1):
                        cur.operands[0] = prev.result
                        cur.attrs["by"] = cur.attrs["by"] - prev.attrs["by"]
                        cur.attrs["shared"] = True
                        n += 1

        share(f.body)
    return n
