"""Precision optimization (paper §6.3, Table 4).

Interval (range) analysis over the SSA graph: constant loop bounds bound the
induction variables; ranges propagate through arithmetic; every integer value
is then narrowed to the minimal signed/unsigned width that holds its range.
The codegen sizes wires, registers, shift registers and address buses from
these narrowed types, which is where the paper's Table 4 LUT/FF savings come
from (transpose: i32 loop counters -> i5)."""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..ir import ForOp, FuncOp, Module, Operation, Value, const_value

Range = tuple[int, int]  # inclusive


def _width_for(lo: int, hi: int) -> tuple[int, bool]:
    """Minimal (width, signed) holding [lo, hi]."""
    if lo >= 0:
        w = max(1, hi.bit_length())
        return w, False
    w = max(lo.bit_length() + 1 if lo < 0 else 1, hi.bit_length() + 1, 2)
    # need w st -2^(w-1) <= lo and hi <= 2^(w-1)-1
    while -(1 << (w - 1)) > lo or hi > (1 << (w - 1)) - 1:
        w += 1
    return w, True


def _type_range(t: ir.Type) -> Optional[Range]:
    if isinstance(t, ir.IntType):
        if t.signed:
            return (-(1 << (t.width - 1)), (1 << (t.width - 1)) - 1)
        return (0, (1 << t.width) - 1)
    return None


def _prop(opname: str, rs: list[Optional[Range]]) -> Optional[Range]:
    if any(r is None for r in rs):
        return None
    (a_lo, a_hi) = rs[0]
    if opname in ("zext", "sext", "trunc", "delay"):
        return rs[0]
    if opname == "not":
        return (~a_hi, ~a_lo)
    (b_lo, b_hi) = rs[1] if len(rs) > 1 else (0, 0)
    if opname == "add":
        return (a_lo + b_lo, a_hi + b_hi)
    if opname == "sub":
        return (a_lo - b_hi, a_hi - b_lo)
    if opname == "mult":
        cands = [a_lo * b_lo, a_lo * b_hi, a_hi * b_lo, a_hi * b_hi]
        return (min(cands), max(cands))
    if opname == "shl":
        if b_lo < 0 or b_hi > 63:
            return None
        return (min(a_lo << b_lo, a_lo << b_hi), max(a_hi << b_lo, a_hi << b_hi))
    if opname == "shr":
        if b_lo < 0 or b_hi > 63 or a_lo < 0:
            return None
        return (a_lo >> b_hi, a_hi >> b_lo)
    if opname == "and":
        if a_lo >= 0 and b_lo >= 0:
            return (0, min(a_hi, b_hi))
        return None
    if opname == "or" or opname == "xor":
        if a_lo >= 0 and b_lo >= 0:
            m = max(a_hi, b_hi)
            bits = m.bit_length()
            return (0, (1 << bits) - 1)
        return None
    if opname.startswith("cmp_"):
        return (0, 1)
    if opname == "select":
        return (min(rs[1][0], rs[2][0]), max(rs[1][1], rs[2][1]))
    if opname == "div":
        if b_lo > 0 and a_lo >= 0:
            return (a_lo // b_hi, a_hi // b_lo)
        return None
    return None


def precision_opt(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        ranges: dict[Value, Optional[Range]] = {}

        # seeds: constants, typed args, loop bounds
        for a in f.args:
            ranges[a] = _type_range(a.type)

        def visit(region) -> None:
            for op in region.ops:
                if op.opname == "constant":
                    v = op.attrs["value"]
                    ranges[op.result] = (v, v) if isinstance(v, int) else None
                elif isinstance(op, ForOp):
                    lb, ub, st = const_value(op.lb), const_value(op.ub), const_value(op.step)
                    if lb is not None and ub is not None and st is not None and st > 0:
                        ranges[op.iv] = (lb, max(lb, ub - 1))
                    else:
                        ranges[op.iv] = _type_range(op.iv.type)
                    for r in op.regions:
                        visit(r)
                elif op.opname == "mem_read":
                    ranges[op.result] = _type_range(op.result.type)
                elif op.opname == "call":
                    for r in op.results:
                        ranges[r] = _type_range(r.type)
                elif op.opname == "delay":
                    ranges[op.result] = ranges.get(op.operands[0], _type_range(op.result.type))
                elif op.opname in ir.ARITH_OPS:
                    rs = [ranges.get(v) for v in op.operands]
                    ranges[op.result] = _prop(op.opname, rs) or _type_range(op.result.type)
                else:
                    for r in op.regions:
                        visit(r)

        visit(f.body)

        # narrow integer-typed values (never const/float): signedness follows
        # the proven range (non-negative values become unsigned — sound, and
        # exactly what a hand-written RTL design would use)
        for v, rng in ranges.items():
            if rng is None or not isinstance(v.type, ir.IntType):
                continue
            w, signed = _width_for(*rng)
            if w < v.type.width:
                v.type = ir.IntType(w, signed)
                n += 1
    return n


from ..passmgr import Pass, register_pass  # noqa: E402


@register_pass
class PrecisionOpt(Pass):
    """Interval analysis + bitwidth narrowing (whole-function analysis; not
    a local pattern)."""

    name = "precision-opt"
    preserves_all = True  # narrows types in place; schedules/IR shape untouched

    def run(self, module: Module) -> int:
        return precision_opt(module)
