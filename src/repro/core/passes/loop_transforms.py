"""Pre-scheduling loop restructuring: tiling and interchange.

These transforms run on *unscheduled* (erased) HIR — they are design-space
knobs applied before the HLS schedule search (ScaleHLS-style), not schedule
transforms: tiling splits an innermost sequential loop into an outer/inner
nest so the scheduler pipelines a shorter inner body, and interchange swaps
a perfect 2-deep nest to move a different induction variable innermost
(changing which accesses are loop-carried).

Neither transform proves legality from dependence analysis; the DSE
containment does that end-to-end — every candidate's simulation output is
checked against the source-module oracle, and a restructuring that changes
results is scored out of the Pareto front (``verified=False``) instead of
silently shipping.  Tiling is always iteration-order-preserving (hence
always legal); interchange is the speculative one.
"""

from __future__ import annotations

from .. import ir
from ..ir import ForOp, Module, Region

__all__ = ["tile_innermost", "interchange_loops", "Tile", "Interchange"]


# ---------------------------------------------------------------------------
# Tiling
# ---------------------------------------------------------------------------


def tile_innermost(module: Module, factor: int) -> int:
    """Tile every innermost sequential ``hir.for`` whose constant trip count
    divides evenly: ``for i in [lb, ub, s)`` becomes

        for i_o in [0, trip/factor):
          for i_i in [0, factor):
            i = lb + (i_o*factor + i_i)*s

    with the body moved into the inner loop and the induction variable
    recomputed — same iteration order, so semantics are preserved exactly.
    Loops with unknown bounds, non-dividing trips, or trivial outer trips
    are left alone.  Returns the number of loops tiled."""
    if factor < 2:
        return 0
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        n += _tile_region(f.body, factor)
    return n


def _tile_region(region: Region, factor: int) -> int:
    n = 0
    for op in list(region.ops):
        if not isinstance(op, ForOp):
            continue
        if any(isinstance(o, ForOp) for o in op.region(0).ops):
            n += _tile_region(op.region(0), factor)
        elif op.opname == "for":  # unroll_for is a spatial knob, not temporal
            n += _tile_loop(region, op, factor)
    return n


def _tile_loop(parent: Region, loop: ForOp, factor: int) -> int:
    trip = loop.trip_count()
    lb = ir.const_value(loop.lb)
    step = ir.const_value(loop.step)
    if (trip is None or lb is None or step is None
            or trip % factor or trip // factor < 2):
        return 0
    ivt = loop.iv.type

    c0 = ir.constant(0, name=f"{loop.iv.name}_t0")
    c1 = ir.constant(1, name=f"{loop.iv.name}_t1")
    cf = ir.constant(factor, name=f"{loop.iv.name}_tf")
    ct = ir.constant(trip // factor, name=f"{loop.iv.name}_tn")
    outer = ForOp(c0.result, ct.result, c1.result, start=None, iv_type=ivt,
                  iv_name=f"{loop.iv.name}_o", tv_name=f"{loop.time_var.name}_o",
                  loc=loop.loc)
    inner = ForOp(c0.result, cf.result, c1.result, start=None, iv_type=ivt,
                  iv_name=f"{loop.iv.name}_i", tv_name=f"{loop.time_var.name}_i",
                  loc=loop.loc)
    outer.region(0).add(inner)

    # i = lb + (i_o*factor + i_i)*step, computed at the top of the inner body
    t = ir.arith("mult", [outer.iv, cf.result], loc=loop.loc)
    inner.region(0).add(t)
    t2 = ir.arith("add", [t.result, inner.iv], loc=loop.loc)
    inner.region(0).add(t2)
    iv_val = t2.result
    if step != 1:
        t3 = ir.arith("mult", [iv_val, loop.step], loc=loop.loc)
        inner.region(0).add(t3)
        iv_val = t3.result
    if lb != 0:
        t4 = ir.arith("add", [iv_val, loop.lb], loc=loop.loc)
        inner.region(0).add(t4)
        iv_val = t4.result
    iv_val.name = loop.iv.name

    moved = [o for o in loop.region(0).ops if o.opname != "yield"]
    for o in moved:
        inner.region(0).add(o)
    loop.iv.replace_all_uses_with(iv_val)
    loop.time_var.replace_all_uses_with(inner.time_var)
    loop.end_time.replace_all_uses_with(outer.end_time)

    i = parent.ops.index(loop)
    parent.remove(loop)
    # Region.add reparents but does not unlink — scrub the moved ops from the
    # old shell before drop_all_uses recurses into it.
    loop.regions[0].ops = [o for o in loop.regions[0].ops if o not in moved]
    loop.drop_all_uses()
    for k, op in enumerate((c0, c1, cf, ct, outer)):
        parent.insert(i + k, op)
    return 1


# ---------------------------------------------------------------------------
# Interchange
# ---------------------------------------------------------------------------


def interchange_loops(module: Module) -> int:
    """Swap every perfect 2-deep sequential ``hir.for`` nest (outer body =
    constants + one inner loop): the inner induction variable becomes the
    outer one and vice versa.  Rectangular nests only — a nest whose inner
    bounds depend on the outer IV is skipped.  Legality is *not* proven
    here; the DSE sim-verification contains illegal swaps (see module
    docstring).  Returns the number of nests swapped."""
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        n += _interchange_region(f.body)
    return n


def _interchange_region(region: Region) -> int:
    n = 0
    for op in list(region.ops):
        if not isinstance(op, ForOp) or op.opname != "for":
            continue
        inner = _perfect_inner(op)
        if inner is not None and _rectangular(op, inner):
            _swap_nest(region, op, inner)
            n += 1  # the swapped nest is not re-visited (it would swap back)
        else:
            n += _interchange_region(op.region(0))
    return n


def _perfect_inner(outer: ForOp):
    body = [o for o in outer.region(0).ops
            if o.opname not in ("constant", "yield")]
    if len(body) == 1 and isinstance(body[0], ForOp) and body[0].opname == "for":
        return body[0]
    return None


def _rectangular(outer: ForOp, inner: ForOp) -> bool:
    """Inner bounds must not be computed from the outer IV (or anything else
    defined inside the outer body except constants)."""
    for v in (inner.lb, inner.ub, inner.step):
        if v is outer.iv:
            return False
        d = v.defining_op
        if (d is not None and d.opname != "constant"
                and d.parent_region is outer.region(0)):
            return False
    return True


def _swap_nest(parent: Region, outer: ForOp, inner: ForOp) -> None:
    new_outer = ForOp(inner.lb, inner.ub, inner.step, start=None,
                      iv_type=inner.iv.type, iv_name=inner.iv.name,
                      tv_name=inner.time_var.name, loc=inner.loc)
    new_inner = ForOp(outer.lb, outer.ub, outer.step, start=None,
                      iv_type=outer.iv.type, iv_name=outer.iv.name,
                      tv_name=outer.time_var.name, loc=outer.loc)
    new_outer.region(0).add(new_inner)
    moved = [o for o in inner.region(0).ops if o.opname != "yield"]
    for o in moved:
        new_inner.region(0).add(o)
    inner.iv.replace_all_uses_with(new_outer.iv)
    outer.iv.replace_all_uses_with(new_inner.iv)
    inner.time_var.replace_all_uses_with(new_inner.time_var)
    outer.time_var.replace_all_uses_with(new_outer.time_var)
    inner.end_time.replace_all_uses_with(new_inner.end_time)
    outer.end_time.replace_all_uses_with(new_outer.end_time)

    hoisted = [o for o in outer.region(0).ops if o.opname == "constant"]
    i = parent.ops.index(outer)
    parent.remove(outer)
    # Scrub relocated ops from the old shells so drop_all_uses only erases
    # the discarded loop ops and their yields (Region.add does not unlink).
    inner.regions[0].ops = [o for o in inner.regions[0].ops if o not in moved]
    outer.regions[0].ops = [o for o in outer.regions[0].ops
                            if o is not inner and o not in hoisted]
    inner.drop_all_uses()
    outer.drop_all_uses()
    for k, op in enumerate(hoisted + [new_outer]):
        parent.insert(i + k, op)


from ..passmgr import Pass, register_pass  # noqa: E402


@register_pass
class Tile(Pass):
    """Innermost-loop tiling (default factor 2; the DSE drives
    ``tile_innermost`` directly with per-candidate factors)."""

    name = "tile"
    factor = 2

    def run(self, module: Module) -> int:
        return tile_innermost(module, self.factor)


@register_pass
class Interchange(Pass):
    """Perfect-nest loop interchange (speculative; sim-verified by the DSE)."""

    name = "interchange"

    def run(self, module: Module) -> int:
        return interchange_loops(module)
