"""The seed's original fixpoint-sweep optimizer *algorithm*, preserved as the
measured baseline for ``benchmarks/codegen_speed.py``.

Every rewrite query here re-walks the whole function region
(``_replace_all_uses_in_region`` and the repeated full walks in constprop /
dce), making the sweep O(region²); the worklist driver + maintained use-def
chains in ``core.rewrite`` / ``core.passmgr`` replace it.

Benchmark-fidelity note: this baseline runs on the *current* IR substrate —
its operand writes pay the same OperandList chain bookkeeping and it gets
the same eager ``Region.walk`` as the new driver.  Both flows therefore pay
identical per-mutation constants, and the measured gap isolates the
algorithmic difference (blind O(region) sweeps vs O(#uses) worklist
rewriting) rather than incidental substrate changes.

Do not use this module outside benchmarking — it may leave use-def chains
stale (it removes ops from regions without erasing them)."""

from __future__ import annotations

from collections import defaultdict
from typing import Callable, Optional

from .. import ir
from ..ir import ForOp, Module, Operation, Region, const_value, _replace_all_uses_in_region
from .precision_opt import precision_opt
from .port_demotion import port_demotion


def _fold(opname: str, vals: list) -> Optional[int]:
    try:
        if opname == "add":
            return vals[0] + vals[1]
        if opname == "sub":
            return vals[0] - vals[1]
        if opname == "mult":
            return vals[0] * vals[1]
        if opname == "div":
            return vals[0] // vals[1]
        if opname == "and":
            return vals[0] & vals[1]
        if opname == "or":
            return vals[0] | vals[1]
        if opname == "xor":
            return vals[0] ^ vals[1]
        if opname == "shl":
            return vals[0] << vals[1]
        if opname == "shr":
            return vals[0] >> vals[1]
        if opname.startswith("cmp_"):
            import operator

            f = {"lt": operator.lt, "le": operator.le, "eq": operator.eq,
                 "ne": operator.ne, "gt": operator.gt, "ge": operator.ge}[opname[4:]]
            return int(f(vals[0], vals[1]))
        if opname == "select":
            return vals[1] if vals[0] else vals[2]
        if opname in ("trunc", "zext", "sext", "not"):
            return ~vals[0] if opname == "not" else vals[0]
    except (ZeroDivisionError, OverflowError, TypeError, ValueError):
        # arithmetic on the literal operands failed (e.g. div by const 0,
        # or a non-integer attr leaked in) — simply decline to fold
        return None
    return None


def _each_func(module: Module):
    for f in module.funcs.values():
        if not f.attrs.get("external"):
            yield f


def legacy_canonicalize(module: Module) -> int:
    n = 0
    for f in _each_func(module):
        for op in f.body.walk():
            if op.opname in ir.COMMUTATIVE_OPS and len(op.operands) == 2:
                a, b = op.operands
                ka = (const_value(a) is not None, a.id)
                kb = (const_value(b) is not None, b.id)
                if ka > kb:
                    op.operands[0], op.operands[1] = b, a
                    n += 1
            if op.opname in ("add", "sub", "shl", "shr", "or", "xor") and len(op.operands) == 2:
                cb = const_value(op.operands[1])
                if cb == 0 and op.results:
                    _replace_all_uses_in_region(f.body, op.result, op.operands[0])
                    n += 1
            elif op.opname == "mult" and op.results:
                for i in (0, 1):
                    c = const_value(op.operands[i])
                    if c == 1:
                        _replace_all_uses_in_region(f.body, op.result, op.operands[1 - i])
                        n += 1
                        break
    return n


def legacy_constprop(module: Module) -> int:
    n = 0
    for f in _each_func(module):
        changed = True
        while changed:
            changed = False
            for op in list(f.body.walk()):
                if op.opname not in ir.ARITH_OPS or not op.results:
                    continue
                vals = [const_value(v) for v in op.operands]
                if any(v is None for v in vals):
                    continue
                folded = _fold(op.opname, vals)
                if folded is None:
                    continue
                cst = ir.constant(folded, ir.CONST)
                region = op.parent_region or f.body
                region.ops.insert(region.ops.index(op), cst)
                cst.parent_region = region
                _replace_all_uses_in_region(f.body, op.result, cst.result)
                region.ops.remove(op)
                changed = True
                n += 1
    return n


def _is_pure(op: Operation) -> bool:
    return op.opname in ir.ARITH_OPS or op.opname in ("constant", "delay")


def legacy_dce(module: Module) -> int:
    n = 0
    for f in _each_func(module):
        changed = True
        while changed:
            changed = False
            used: set[int] = set()
            for op in f.body.walk():
                for v in op.operands:
                    used.add(v.id)

            def sweep(region: Region) -> None:
                nonlocal n, changed
                keep = []
                for op in region.ops:
                    if _is_pure(op) and op.results and all(r.id not in used for r in op.results):
                        changed = True
                        n += 1
                        continue
                    for r in op.regions:
                        sweep(r)
                    keep.append(op)
                region.ops[:] = keep

            sweep(f.body)
    return n


def _cse_key(op: Operation):
    if op.opname in ir.ARITH_OPS:
        stages = op.attrs.get("stages", 0)
        if stages:
            st = (op.start.tv.id, op.start.offset) if op.start is not None else None
            return ("arith", op.opname, tuple(v.id for v in op.operands), stages, st)
        return ("arith", op.opname, tuple(v.id for v in op.operands), 0, None)
    if op.opname == "delay":
        return ("delay", op.operands[0].id, op.attrs["by"])
    if op.opname == "constant":
        return ("const", str(op.result.type), op.attrs["value"])
    return None


def legacy_cse(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue

        def run(region: Region, seen: dict) -> None:
            nonlocal n
            keep = []
            for op in region.ops:
                k = _cse_key(op)
                if k is not None and op.results:
                    if k in seen:
                        _replace_all_uses_in_region(f.body, op.result, seen[k])
                        n += 1
                        continue
                    seen[k] = op.result
                for r in op.regions:
                    run(r, dict(seen))
                keep.append(op)
            region.ops[:] = keep

        run(f.body, {})
    return n


def _popcount(c: int) -> int:
    return bin(c).count("1")


def legacy_strength_reduce(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        ivs = set()
        for op in f.body.walk():
            if isinstance(op, ForOp):
                ivs.add(op.iv)
        for op in f.body.walk():
            if op.opname == "mult" and not op.attrs.get("impl"):
                for i in (0, 1):
                    c = const_value(op.operands[i])
                    x = op.operands[1 - i]
                    if c is None or not isinstance(c, int) or c <= 0:
                        continue
                    if x in ivs and x.type != ir.CONST:
                        op.attrs["impl"] = "counter"
                        n += 1
                        break
                    if c & (c - 1) == 0:
                        k = c.bit_length() - 1
                        op.opname = "shl"
                        cst = ir.constant(k, ir.CONST)
                        region = op.parent_region or f.body
                        region.ops.insert(region.ops.index(op), cst)
                        cst.parent_region = region
                        op.operands[:] = [x, cst.result]
                        n += 1
                        break
                    if _popcount(c) <= 3:
                        op.attrs["impl"] = "shift_add"
                        op.attrs["terms"] = _popcount(c)
                        n += 1
                        break
            elif op.opname == "div" and not op.attrs.get("impl"):
                c = const_value(op.operands[1])
                if isinstance(c, int) and c > 0 and c & (c - 1) == 0:
                    k = c.bit_length() - 1
                    op.opname = "shr"
                    cst = ir.constant(k, ir.CONST)
                    region = op.parent_region or f.body
                    region.ops.insert(region.ops.index(op), cst)
                    cst.parent_region = region
                    op.operands[:] = [op.operands[0], cst.result]
                    n += 1
    return n


def legacy_delay_elim(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue

        for op in list(f.body.walk()):
            if op.opname == "delay" and op.attrs["by"] == 0:
                _replace_all_uses_in_region(f.body, op.result, op.operands[0])
                n += 1

        def share(region: Region) -> None:
            nonlocal n
            by_src: dict[int, list[Operation]] = defaultdict(list)
            for op in region.ops:
                if op.opname == "delay" and op.attrs["by"] > 0 and not op.attrs.get("shared"):
                    by_src[op.operands[0].id].append(op)
                for r in op.regions:
                    share(r)
            order = {id(op): i for i, op in enumerate(region.ops)}
            for _, group in by_src.items():
                if len(group) < 2:
                    continue
                group.sort(key=lambda o: o.attrs["by"])
                for prev, cur in zip(group, group[1:]):
                    if cur.attrs["by"] > prev.attrs["by"] and order.get(id(prev), 1 << 30) < order.get(id(cur), -1):
                        cur.operands[0] = prev.result
                        cur.attrs["by"] = cur.attrs["by"] - prev.attrs["by"]
                        cur.attrs["shared"] = True
                        n += 1

        share(f.body)
    return n


LEGACY_PIPELINE: list[Callable[[Module], int]] = [
    legacy_canonicalize,
    legacy_constprop,
    legacy_cse,
    legacy_strength_reduce,
    precision_opt,
    legacy_delay_elim,
    port_demotion,
    legacy_dce,
]


def run_legacy_sweep(module: Module, max_iters: int = 3) -> dict[str, int]:
    """The seed's ``run_pipeline``: blind bounded-fixpoint sweep over the
    whole pipeline, every pass re-walking the whole region."""
    stats: dict[str, int] = {}
    for _ in range(max_iters):
        changed = 0
        for p in LEGACY_PIPELINE:
            n = p(module)
            stats[p.__name__] = stats.get(p.__name__, 0) + n
            changed += n
        if changed == 0:
            break
    return stats
