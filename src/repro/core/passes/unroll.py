"""Full expansion of ``hir.unroll_for`` (paper §7.3): the loop body is
replicated in hardware once per iteration, with the induction variable
substituted by a compile-time constant and each replica's schedule shifted by
the iteration stagger (the yield offset).

Runs before Verilog codegen and before resource estimation — after this pass
every distributed-dim bank index is a literal constant, so banked RAMs and PE
arrays become static structure."""

from __future__ import annotations

from typing import Optional

from .. import ir
from ..ir import ForOp, FuncOp, Module, Operation, Region, Time, Value


def _clone_op(op: Operation, vmap: dict[Value, Value], tmap: dict[Value, tuple[Value, int]],
              extra_shift: int = 0) -> Operation:
    """Clone ``op`` remapping operand values via ``vmap`` and rebasing its
    schedule via ``tmap`` (time var -> (new tv, added offset))."""

    def mv(v: Value) -> Value:
        return vmap.get(v, v)

    start: Optional[Time] = None
    if op.start is not None:
        tv, add = tmap.get(op.start.tv, (op.start.tv, 0))
        start = Time(mv(tv) if tv in vmap else tv, op.start.offset + add + extra_shift)

    if op.opname == "time":
        # derived time variables: rebase the referenced tv through tmap
        tv0 = op.operands[0]
        base_tv, add0 = tmap.get(tv0, (tv0, 0))
        new = Operation(
            "time",
            [mv(base_tv)],
            [op.results[0].type],
            attrs={"offset": op.attrs.get("offset", 0) + add0 + extra_shift},
            loc=op.loc,
            result_names=[op.results[0].name],
        )
        new.results[0].birth = None
        new.results[0].validity_end = None
        vmap[op.results[0]] = new.results[0]
        return new

    if isinstance(op, ForOp):
        new = ForOp(
            mv(op.lb), mv(op.ub), mv(op.step),
            start=start,
            iv_type=op.iv.type,
            iter_arg_offset=op.attrs.get("iter_arg_offset", 0),
            unroll=(op.opname == "unroll_for"),
            iv_name=op.iv.name,
            tv_name=op.time_var.name,
            loc=op.loc,
        )
        inner_vmap = dict(vmap)
        inner_vmap[op.iv] = new.iv
        inner_vmap[op.time_var] = new.time_var
        inner_vmap[op.end_time] = new.end_time
        for b in op.region(0).ops:
            c = _clone_op(b, inner_vmap, tmap)
            new.region(0).add(c)
        _remap_operands(new.region(0).ops, inner_vmap)  # forward refs in body
        vmap[op.end_time] = new.end_time
        return new

    new = Operation(
        op.opname,
        [mv(v) for v in op.operands],
        [r.type for r in op.results],
        attrs=dict(op.attrs),
        start=start,
        loc=op.loc,
        result_names=[r.name for r in op.results],
    )
    for old_r, new_r in zip(op.results, new.results):
        vmap[old_r] = new_r
        new_r.birth = old_r.birth
        new_r.validity_end = old_r.validity_end
    return new


def _remap_operands(ops: list[Operation], vmap: dict[Value, Value]) -> None:
    """Second pass after cloning: resolve forward references (an op may use a
    value whose defining op appears later in the region — textual order is not
    semantic in HIR)."""
    for op in ops:
        for i, v in enumerate(op.operands):
            if v in vmap:
                op.operands[i] = vmap[v]
        for r in op.regions:
            _remap_operands(r.ops, vmap)


def _expand_unroll(func: FuncOp, region: Region) -> int:
    n = 0
    new_ops: list[Operation] = []
    for op in region.ops:
        # expand innermost-first
        for r in op.regions:
            n += _expand_unroll(func, r)
        if isinstance(op, ForOp) and op.opname == "unroll_for":
            trip = op.trip_count()
            assert trip is not None, "unroll_for requires constant bounds"
            y = op.yield_op()
            stagger = 0
            if y is not None and y.start is not None and y.start.tv is op.time_var:
                stagger = y.start.offset
            lb = ir.const_value(op.lb) or 0
            step = ir.const_value(op.step) or 1
            assert op.start is not None
            for m in range(trip):
                ivv = lb + m * step
                cst = ir.constant(ivv, op.iv.type, name=f"{op.iv.name}{ivv}")
                cst.parent_region = region
                new_ops.append(cst)
                vmap: dict[Value, Value] = {op.iv: cst.result}
                tmap = {op.time_var: (op.start.tv, op.start.offset + m * stagger)}
                clones = []
                for b in op.region(0).ops:
                    if b.opname == "yield":
                        continue
                    c = _clone_op(b, vmap, tmap)
                    c.parent_region = region
                    clones.append(c)
                _remap_operands(clones, vmap)  # resolve forward references
                new_ops.extend(clones)
            # rebind the end time: a derived time op at start + trip*stagger
            endt = ir.time_offset(Time(op.start.tv, op.start.offset + trip * stagger),
                                  name=op.end_time.name)
            endt.parent_region = region
            new_ops.append(endt)
            op.end_time.replace_all_uses_with(endt.result)
            op.drop_all_uses()  # the loop (and its body) is replaced by clones
            n += 1
        else:
            new_ops.append(op)
    region.ops[:] = new_ops
    return n


def unroll_loops(module: Module) -> int:
    """Expand every unroll_for in every function; returns loops expanded."""
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        # fixpoint: nested unrolls
        while True:
            k = _expand_unroll(f, f.body)
            n += k
            if k == 0:
                break
    return n


from ..passmgr import Pass, register_pass  # noqa: E402


@register_pass
class Unroll(Pass):
    """Full unroll_for expansion (pre-codegen)."""

    name = "unroll"

    def run(self, module: Module) -> int:
        return unroll_loops(module)
