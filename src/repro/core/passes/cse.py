"""Common-subexpression elimination (paper §6.2).

Combinational ops are time-free wires, so two arith ops with identical
(opname, operands, attrs) compute the same signal regardless of their
schedule annotation and can share hardware.  Delays additionally require the
same source *and* the same depth (partial sharing of shift-register chains is
done by ``delay_elim``).

CSE is inherently a scoped-hash-table pass, not a local pattern: it runs as a
single region walk, but replacement now goes through the maintained use-def
chains (O(#uses) per merged op instead of O(region))."""

from __future__ import annotations

from .. import ir
from ..ir import Module, Operation, Region
from ..passmgr import Pass, register_pass


def _key(op: Operation):
    if op.opname in ir.ARITH_OPS:
        stages = op.attrs.get("stages", 0)
        if stages:
            # pipelined units also need identical schedules to share
            st = (op.start.tv.id, op.start.offset) if op.start is not None else None
            return ("arith", op.opname, tuple(v.id for v in op.operands), stages, st)
        return ("arith", op.opname, tuple(v.id for v in op.operands), 0, None)
    if op.opname == "delay":
        return ("delay", op.operands[0].id, op.attrs["by"])
    if op.opname == "constant":
        return ("const", str(op.result.type), op.attrs["value"])
    return None


@register_pass
class CSE(Pass):
    name = "cse"
    # only pure ops merge (never memory accesses); merged ops share identical
    # completion times, so schedules and port tables are unchanged
    preserves = ("loop-info", "port-accesses")

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):

            def run_region(region: Region, seen: dict) -> int:
                m = 0
                keep = []
                for op in region.ops:
                    k = _key(op)
                    if k is not None and op.results:
                        if k in seen:
                            op.result.replace_all_uses_with(seen[k])
                            op.drop_all_uses()
                            m += 1
                            continue
                        seen[k] = op.result
                    for r in op.regions:
                        # nested scopes may reuse outer expressions but not
                        # vice versa: pass a child view of the map
                        m += run_region(r, dict(seen))
                    keep.append(op)
                region.ops[:] = keep
                return m

            n += run_region(f.body, {})
        return n


def cse(module: Module) -> int:
    return CSE().run(module)
