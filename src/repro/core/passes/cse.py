"""Common-subexpression elimination (paper §6.2).

Combinational ops are time-free wires, so two arith ops with identical
(opname, operands, attrs) compute the same signal regardless of their
schedule annotation and can share hardware.  Delays additionally require the
same source *and* the same depth (partial sharing of shift-register chains is
done by ``delay_elim``)."""

from __future__ import annotations

from .. import ir
from ..ir import Module, Operation, Region, replace_all_uses


def _key(op: Operation):
    if op.opname in ir.ARITH_OPS:
        stages = op.attrs.get("stages", 0)
        if stages:
            # pipelined units also need identical schedules to share
            st = (op.start.tv.id, op.start.offset) if op.start is not None else None
            return ("arith", op.opname, tuple(v.id for v in op.operands), stages, st)
        return ("arith", op.opname, tuple(v.id for v in op.operands), 0, None)
    if op.opname == "delay":
        return ("delay", op.operands[0].id, op.attrs["by"])
    if op.opname == "constant":
        return ("const", str(op.result.type), op.attrs["value"])
    return None


def cse(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue

        def run(region: Region, seen: dict) -> None:
            nonlocal n
            keep = []
            for op in region.ops:
                k = _key(op)
                if k is not None and op.results:
                    if k in seen:
                        replace_all_uses(f.body, op.result, seen[k])
                        n += 1
                        continue
                    seen[k] = op.result
                for r in op.regions:
                    # nested scopes may reuse outer expressions but not
                    # vice versa: pass a child view of the map
                    run(r, dict(seen))
                keep.append(op)
            region.ops[:] = keep

        run(f.body, {})
    return n
