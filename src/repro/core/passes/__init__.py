"""HIR optimization passes (paper §6.2–§6.4), on the ``core.passmgr`` /
``core.rewrite`` compiler infrastructure.

Registered passes (spec names in parentheses — use them in
``PassManager.from_spec("...")`` pipeline specs):

  * canonicalize    (``canonicalize``)    — commutative-operand ordering +
                     identity folds (x+0, x*1), as worklist rewrite patterns
  * constprop       (``constprop``)       — compile-time constant folding;
                     the worklist driver cascades through constant chains
  * cse             (``cse``)             — common-subexpression elimination
                     on pure ops (scoped hash table, O(#uses) replacement)
  * strength_reduce (``strength-reduce``) — const-mult -> shift/shift-add;
                     IV*const -> scaled counter; const-div -> shift
  * precision_opt   (``precision-opt``)   — bitwidth narrowing from
                     loop-bound range analysis
  * delay_elim      (``delay-elim``)      — zero-delay forwarding (pattern)
                     + shift-register chain sharing
  * port_demotion   (``port-demotion``)   — dual-port -> single-port RAM
                     when schedules are provably disjoint (paper §2)
  * dce             (``dce``)             — dead pure-op removal driven by
                     the maintained use-def chains
  * inline_calls    (``inline``)          — module-hierarchy flattening
                     (pre-codegen)
  * unroll_loops    (``unroll``)          — full hir.unroll_for expansion
                     (pre-codegen)
  * pipeline_loops  (``pipeline-loop``)   — minimum-II modulo pipelining of
                     sequential innermost loops (schedule transform)
  * tile_innermost  (``tile``)            — innermost-loop tiling on erased
                     HIR (DSE structural knob)
  * interchange_loops (``interchange``)   — perfect-nest loop interchange on
                     erased HIR (speculative; DSE sim-verified)
  * retime          (``retime``)          — delay hoisting across
                     combinational ops (shift-register sharing)

Each pass also remains importable as a plain ``Callable[[Module], int]``
(``canonicalize(module)`` etc.) for direct use and unit tests.

``run_pipeline(module)`` is a thin compatibility shim over ``PassManager``:
prefer ``PassManager.from_spec(DEFAULT_PIPELINE_SPEC)``, which exposes
per-pass timing/rewrite statistics and declarative pipeline selection.
``passes.legacy_sweep`` preserves the seed's O(region²) fixpoint sweep purely
as the baseline measured by ``benchmarks/codegen_speed.py``.
"""

from __future__ import annotations

from typing import Callable, Optional

from ..ir import Module
from ..passmgr import (CODEGEN_PIPELINE_SPEC, DEFAULT_PIPELINE_SPEC,
                       SCHEDULE_PIPELINE_SPEC, AnalysisManager,
                       FunctionAnalysis, Pass, PassManager, PassStatistics,
                       create_pass, parse_pipeline_spec, register_analysis)
from .canonicalize import Canonicalize, ConstProp, DCE, canonicalize, constprop, dce
from .cse import CSE, cse
from .delay_elim import DelayElim, delay_elim
from .port_demotion import PortDemotion, port_demotion
from .precision_opt import PrecisionOpt, precision_opt
from .strength_reduce import StrengthReduce, strength_reduce
from .inline import Inline, inline_calls
from .unroll import Unroll, unroll_loops
from .schedule_transforms import PipelineLoop, Retime, pipeline_loops, retime
from .loop_transforms import (Interchange, Tile, interchange_loops,
                              tile_innermost)
# RTL-level passes (they run on an RTLDesign, not an HIR Module, but share
# the registry/PassManager infrastructure and spec naming)
from ..codegen.rtl import (RTL_PIPELINE_SPEC, CombShare, ControllerMerge,
                           DeadNetElim, MemReadShare, ShiftRegMerge)

#: Legacy list-of-callables form of the default pipeline (kept for direct
#: imports; the declarative form is ``DEFAULT_PIPELINE_SPEC``).
DEFAULT_PIPELINE: list[Callable[[Module], int]] = [
    canonicalize,
    constprop,
    cse,
    strength_reduce,
    precision_opt,
    delay_elim,
    port_demotion,
    dce,
]


def run_pipeline(module: Module, passes: Optional[list[Callable[[Module], int]]] = None,
                 max_iters: int = 3) -> dict[str, int]:
    """Compatibility shim over ``PassManager``: run ``passes`` (default: the
    paper-benchmark pipeline) to a bounded fixpoint; returns per-pass rewrite
    counts keyed by pass function name."""
    if passes is None:
        pm = PassManager.from_spec(DEFAULT_PIPELINE_SPEC, max_iterations=max_iters)
    else:
        pm = PassManager(list(passes), max_iterations=max_iters)
    return pm.run(module)


__all__ = [
    "run_pipeline",
    "DEFAULT_PIPELINE",
    "DEFAULT_PIPELINE_SPEC",
    "CODEGEN_PIPELINE_SPEC",
    "SCHEDULE_PIPELINE_SPEC",
    "RTL_PIPELINE_SPEC",
    "AnalysisManager",
    "FunctionAnalysis",
    "register_analysis",
    "Pass",
    "PassManager",
    "PassStatistics",
    "create_pass",
    "parse_pipeline_spec",
    "canonicalize",
    "constprop",
    "cse",
    "strength_reduce",
    "precision_opt",
    "delay_elim",
    "port_demotion",
    "dce",
    "unroll_loops",
    "inline_calls",
    "pipeline_loops",
    "retime",
    "tile_innermost",
    "interchange_loops",
    "Tile",
    "Interchange",
    "Canonicalize",
    "ConstProp",
    "CSE",
    "StrengthReduce",
    "PrecisionOpt",
    "DelayElim",
    "PortDemotion",
    "DCE",
    "Inline",
    "Unroll",
    "PipelineLoop",
    "Retime",
    "DeadNetElim",
    "ShiftRegMerge",
    "CombShare",
    "ControllerMerge",
    "MemReadShare",
]
