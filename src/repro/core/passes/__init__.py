"""HIR optimization passes (paper §6.2–§6.4).

  * canonicalize        — constant folding + commutative-operand ordering
  * constprop           — compile-time constant propagation
  * cse                 — common-subexpression elimination on pure ops
  * strength_reduce     — const-mult -> shift/add; IV*const -> counter
  * precision_opt       — bitwidth narrowing from loop-bound range analysis
  * delay_elim          — shift-register sharing/chaining, zero-delay removal
  * port_demotion       — dual-port -> single-port RAM when schedules are
                          provably disjoint (paper §2 "Ease of optimization")
  * dce                 — dead pure-op removal
  * unroll              — full expansion of hir.unroll_for (pre-codegen)

``run_pipeline(module)`` applies the default optimization pipeline in the
order used for the paper-benchmark evaluation.
"""

from __future__ import annotations

from typing import Callable, Optional

from ..ir import Module
from .canonicalize import canonicalize, constprop, dce
from .cse import cse
from .delay_elim import delay_elim
from .port_demotion import port_demotion
from .precision_opt import precision_opt
from .strength_reduce import strength_reduce
from .inline import inline_calls
from .unroll import unroll_loops

DEFAULT_PIPELINE: list[Callable[[Module], int]] = [
    canonicalize,
    constprop,
    cse,
    strength_reduce,
    precision_opt,
    delay_elim,
    port_demotion,
    dce,
]


def run_pipeline(module: Module, passes: Optional[list[Callable[[Module], int]]] = None,
                 max_iters: int = 3) -> dict[str, int]:
    """Run passes to a fixpoint (bounded); returns per-pass rewrite counts."""
    stats: dict[str, int] = {}
    for _ in range(max_iters):
        changed = 0
        for p in passes or DEFAULT_PIPELINE:
            n = p(module)
            stats[p.__name__] = stats.get(p.__name__, 0) + n
            changed += n
        if changed == 0:
            break
    return stats


__all__ = [
    "run_pipeline",
    "DEFAULT_PIPELINE",
    "canonicalize",
    "constprop",
    "cse",
    "strength_reduce",
    "precision_opt",
    "delay_elim",
    "port_demotion",
    "dce",
    "unroll_loops",
    "inline_calls",
]
