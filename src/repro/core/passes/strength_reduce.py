"""Strength reduction (paper §6.2), as worklist rewrite patterns.

  * ``x * 2^k``   -> ``x << k``                       (free in hardware)
  * ``x * c``     -> shift-add decomposition when c has <= 3 set bits
                    (marked ``impl="shift_add"`` — costed as adders, 0 DSPs;
                    this is how the paper's convolution uses no DSP blocks)
  * ``iv * c``    -> marked ``impl="counter"``: the loop controller maintains
                    a scaled running counter (adder) instead of a multiplier —
                    the paper's "multiplication between loop induction
                    variables and constants" rewrite.
  * ``x / 2^k``   -> ``x >> k``
"""

from __future__ import annotations

from .. import ir
from ..ir import ForOp, FuncOp, Module, Operation, const_value
from ..passmgr import PatternRewritePass, register_pass
from ..rewrite import PatternRewriter, RewritePattern, RewritePatternSet


def _popcount(c: int) -> int:
    return bin(c).count("1")


class MultStrengthReducePattern(RewritePattern):
    """mult-by-constant: counter (IVs), shift (powers of two) or shift-add
    (few set bits).  Needs the function's loop-IV set as context."""

    ops = ("mult",)

    def __init__(self, ivs: set):
        self.ivs = ivs

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if op.attrs.get("impl"):
            return False
        for i in (0, 1):
            c = const_value(op.operands[i])
            x = op.operands[1 - i]
            if c is None or not isinstance(c, int) or c <= 0:
                continue
            if x in self.ivs and x.type != ir.CONST:
                op.attrs["impl"] = "counter"  # scaled loop counter
                rewriter.notify_modified(op)
                return True
            if c & (c - 1) == 0:  # power of two -> shl
                k = c.bit_length() - 1
                cst = ir.constant(k, ir.CONST)
                rewriter.insert_before(op, cst)
                op.opname = "shl"
                rewriter.set_operands(op, [x, cst.result])
                return True
            if _popcount(c) <= 3:  # few-term shift-add
                op.attrs["impl"] = "shift_add"
                op.attrs["terms"] = _popcount(c)
                rewriter.notify_modified(op)
                return True
        return False


class DivStrengthReducePattern(RewritePattern):
    """div-by-power-of-two -> shr."""

    ops = ("div",)

    def match_and_rewrite(self, op: Operation, rewriter: PatternRewriter) -> bool:
        if op.attrs.get("impl"):
            return False
        c = const_value(op.operands[1])
        if isinstance(c, int) and c > 0 and c & (c - 1) == 0:
            k = c.bit_length() - 1
            cst = ir.constant(k, ir.CONST)
            rewriter.insert_before(op, cst)
            op.opname = "shr"
            rewriter.set_operands(op, [op.operands[0], cst.result])
            return True
        return False


@register_pass
class StrengthReduce(PatternRewritePass):
    name = "strength-reduce"
    # in-place opname/attr rewrites of comb ops at unchanged schedules
    preserves = ("loop-info", "port-accesses")

    def __init__(self):
        self._mult = MultStrengthReducePattern(set())
        self._set = RewritePatternSet([self._mult, DivStrengthReducePattern()])

    def patterns(self, func: FuncOp) -> RewritePatternSet:
        # the IV set is per-function context; the pattern set itself is reused
        self._mult.ivs = {op.iv for op in func.body.walk() if isinstance(op, ForOp)}
        return self._set


def strength_reduce(module: Module) -> int:
    return StrengthReduce().run(module)
