"""Strength reduction (paper §6.2).

  * ``x * 2^k``   -> ``x << k``                       (free in hardware)
  * ``x * c``     -> shift-add decomposition when c has <= 3 set bits
                    (marked ``impl="shift_add"`` — costed as adders, 0 DSPs;
                    this is how the paper's convolution uses no DSP blocks)
  * ``iv * c``    -> marked ``impl="counter"``: the loop controller maintains
                    a scaled running counter (adder) instead of a multiplier —
                    the paper's "multiplication between loop induction
                    variables and constants" rewrite.
  * ``x / 2^k``   -> ``x >> k``
"""

from __future__ import annotations

from .. import ir
from ..ir import ForOp, Module, Operation, const_value, replace_all_uses


def _popcount(c: int) -> int:
    return bin(c).count("1")


def _is_loop_iv(v) -> bool:
    # region args have no defining op; check loop membership via name match
    return v.defining_op is None


def strength_reduce(module: Module) -> int:
    n = 0
    for f in module.funcs.values():
        if f.attrs.get("external"):
            continue
        ivs = set()
        for op in f.body.walk():
            if isinstance(op, ForOp):
                ivs.add(op.iv)
        for op in f.body.walk():
            if op.opname == "mult" and not op.attrs.get("impl"):
                for i in (0, 1):
                    c = const_value(op.operands[i])
                    x = op.operands[1 - i]
                    if c is None or not isinstance(c, int) or c <= 0:
                        continue
                    if x in ivs and x.type != ir.CONST:
                        op.attrs["impl"] = "counter"  # scaled loop counter
                        n += 1
                        break
                    if c & (c - 1) == 0:  # power of two -> shl
                        k = c.bit_length() - 1
                        op.opname = "shl"
                        cst = ir.constant(k, ir.CONST)
                        region = op.parent_region or f.body
                        region.ops.insert(region.ops.index(op), cst)
                        cst.parent_region = region
                        op.operands[:] = [x, cst.result]
                        n += 1
                        break
                    if _popcount(c) <= 3:  # few-term shift-add
                        op.attrs["impl"] = "shift_add"
                        op.attrs["terms"] = _popcount(c)
                        n += 1
                        break
            elif op.opname == "div" and not op.attrs.get("impl"):
                c = const_value(op.operands[1])
                if isinstance(c, int) and c > 0 and c & (c - 1) == 0:
                    k = c.bit_length() - 1
                    op.opname = "shr"
                    cst = ir.constant(k, ir.CONST)
                    region = op.parent_region or f.body
                    region.ops.insert(region.ops.index(op), cst)
                    cst.parent_region = region
                    op.operands[:] = [op.operands[0], cst.result]
                    n += 1
    return n
