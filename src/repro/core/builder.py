"""Ergonomic builder for HIR programs.

The builder keeps an insertion point (a Region) and a current scope so that
gallery kernels and tests can construct IR close to the paper's textual form:

    b = Builder("transpose", ...)
    with b.func([...]) as f:
        with b.for_(0, 16, 1, at=f.t + 1) as i_loop:
            ...

All builder methods attach source locations from the caller's frame so the
verifier's diagnostics mimic the paper's Figure 1/2 error listings.
"""

from __future__ import annotations

import inspect
from contextlib import contextmanager
from typing import Optional, Sequence, Union

from . import ir
from .ir import (
    CONST,
    ConstType,
    FuncOp,
    Loc,
    MemrefType,
    Module,
    Operation,
    Region,
    Time,
    Type,
    Value,
)

ValueLike = Union[Value, int, float]


def _caller_loc(depth: int = 2) -> Loc:
    try:
        fr = inspect.stack()[depth]
        return Loc(fr.filename.split("/")[-1], fr.lineno, 0)
    except (IndexError, OSError):  # pragma: no cover - shallow/exotic stacks
        return ir.UNKNOWN_LOC


class LoopHandle:
    def __init__(self, op: ir.ForOp):
        self.op = op

    @property
    def iv(self) -> Value:
        return self.op.iv

    @property
    def time(self) -> Time:
        return Time(self.op.time_var, 0)

    @property
    def end(self) -> Time:
        return Time(self.op.end_time, 0)


class FuncHandle:
    def __init__(self, op: FuncOp):
        self.op = op

    @property
    def t(self) -> Time:
        return Time(self.op.time_var, 0)

    @property
    def args(self) -> list[Value]:
        return self.op.args

    def arg(self, name: str) -> Value:
        for a in self.op.args:
            if a.name == name:
                return a
        raise KeyError(name)


class Builder:
    def __init__(self, module: Optional[Module] = None):
        self.module = module or Module()
        self._region_stack: list[Region] = []
        self._const_cache: dict[tuple, Value] = {}
        self._n_prelude = 0

    # -- region / insertion management -------------------------------------
    @property
    def region(self) -> Region:
        return self._region_stack[-1]

    def insert(self, op: Operation) -> Operation:
        self.region.add(op)
        return op

    # -- functions ----------------------------------------------------------
    @contextmanager
    def func(
        self,
        name: str,
        arg_types: Sequence[Type],
        arg_names: Sequence[str] = (),
        arg_delays: Optional[Sequence[int]] = None,
        result_types: Sequence[Type] = (),
        result_delays: Optional[Sequence[int]] = None,
    ):
        f = FuncOp(
            name,
            arg_types,
            arg_names,
            arg_delays,
            result_types,
            result_delays,
            loc=_caller_loc(3),
        )
        self.module.add(f)
        self._region_stack.append(f.body)
        self._const_cache = {}
        self._n_prelude = 0
        try:
            yield FuncHandle(f)
        finally:
            self._region_stack.pop()

    def external_func(
        self,
        name: str,
        arg_types: Sequence[Type],
        result_types: Sequence[Type],
        result_delays: Sequence[int],
        arg_delays: Optional[Sequence[int]] = None,
    ) -> FuncOp:
        """Declare an external (blackbox Verilog) module: signature only
        (paper §5.4 — schedule captured in the signature, no handshake)."""
        f = FuncOp(
            name,
            arg_types,
            arg_delays=arg_delays,
            result_types=result_types,
            result_delays=result_delays,
            loc=_caller_loc(2),
        )
        f.attrs["external"] = True
        self.module.add(f)
        return f

    # -- values --------------------------------------------------------------
    def _as_value(self, v: ValueLike, type: Optional[Type] = None) -> Value:
        if isinstance(v, Value):
            return v
        return self.const(v, type or CONST)

    def const(self, value: Union[int, float], type: Type = CONST, name: str = "") -> Value:
        key = (value, str(type))
        # cache constants per function for readable IR + free CSE of consts
        if not name and key in self._const_cache:
            return self._const_cache[key]
        op = ir.constant(value, type, name=name, loc=_caller_loc(2))
        # constants are always-valid and scope-free: hoist to the function
        # prelude so they dominate every use in nested regions
        froot = self._region_stack[0]
        froot.ops.insert(self._n_prelude, op)
        op.parent_region = froot
        self._n_prelude += 1
        if not name:
            self._const_cache[key] = op.result
        return op.result

    # -- arithmetic -----------------------------------------------------------
    def _arith(self, opname: str, *vs: ValueLike, at: Optional[Time] = None, result_type: Optional[Type] = None,
               stages: int = 0) -> Value:
        ops = [self._as_value(v) for v in vs]
        return self.insert(
            ir.arith(opname, ops, start=at, result_type=result_type, stages=stages, loc=_caller_loc(3))
        ).result

    def add(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None, result_type: Optional[Type] = None) -> Value:
        return self._arith("add", a, b, at=at, result_type=result_type)

    def sub(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None, result_type: Optional[Type] = None) -> Value:
        return self._arith("sub", a, b, at=at, result_type=result_type)

    def mult(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None, result_type: Optional[Type] = None,
             stages: int = 0) -> Value:
        return self._arith("mult", a, b, at=at, result_type=result_type, stages=stages)

    def select(self, c: ValueLike, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith("select", c, a, b, at=at)

    def cmp(self, kind: str, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith(f"cmp_{kind}", a, b, at=at)

    def and_(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith("and", a, b, at=at)

    def or_(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith("or", a, b, at=at)

    def xor_(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith("xor", a, b, at=at)

    def shl(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None,
            result_type: Optional[Type] = None) -> Value:
        return self._arith("shl", a, b, at=at, result_type=result_type)

    def shr(self, a: ValueLike, b: ValueLike, at: Optional[Time] = None) -> Value:
        return self._arith("shr", a, b, at=at)

    def zext(self, v: ValueLike, t: Type, at: Optional[Time] = None) -> Value:
        return self._arith("zext", v, at=at, result_type=t)

    def sext(self, v: ValueLike, t: Type, at: Optional[Time] = None) -> Value:
        return self._arith("sext", v, at=at, result_type=t)

    def trunc(self, v: ValueLike, t: Type, at: Optional[Time] = None) -> Value:
        return self._arith("trunc", v, at=at, result_type=t)

    # -- memory -----------------------------------------------------------------
    def alloc(self, memref: MemrefType, ports: Sequence[str] = (ir.PORT_R, ir.PORT_W), names: Sequence[str] = ()):
        op = self.insert(ir.alloc(memref, ports, names, loc=_caller_loc(2)))
        if len(op.results) == 1:
            return op.results[0]
        return tuple(op.results)

    def read(self, mem: Value, indices: Sequence[ValueLike], at: Time) -> Value:
        idx = [self._as_value(i) for i in indices]
        return self.insert(ir.mem_read(mem, idx, at, loc=_caller_loc(2))).result

    def write(self, value: ValueLike, mem: Value, indices: Sequence[ValueLike], at: Time,
              pred: Optional[Value] = None) -> Operation:
        idx = [self._as_value(i) for i in indices]
        mt = mem.type
        val = self._as_value(value, mt.elem if isinstance(mt, MemrefType) else None)
        return self.insert(ir.mem_write(val, mem, idx, at, pred=pred, loc=_caller_loc(2)))

    def delay(self, v: Value, by: int, at: Optional[Time] = None) -> Value:
        # default schedule: the instant the source becomes valid (paper form
        # ``hir.delay %v by k at %t``)
        if at is None and isinstance(v, Value) and v.birth is not None:
            at = v.birth
        return self.insert(ir.delay(v, by, at, loc=_caller_loc(2))).result

    def time_at(self, t: Time, name: str = "") -> Time:
        op = self.insert(ir.time_offset(t, name=name, loc=_caller_loc(2)))
        return Time(op.result, 0)

    # -- control flow -------------------------------------------------------------
    @contextmanager
    def for_(
        self,
        lb: ValueLike,
        ub: ValueLike,
        step: ValueLike,
        at: Time,
        iter_offset: int = 0,
        iv_type: Optional[Type] = None,
        unroll: bool = False,
        iv_name: str = "i",
        tv_name: str = "ti",
    ):
        if iv_type is None:
            # unroll_for IVs are compile-time constants (they select banks of
            # distributed memrefs — paper Fig. 3); dynamic loops default i32.
            iv_type = ir.CONST if unroll else ir.i32
        op = ir.ForOp(
            self._as_value(lb),
            self._as_value(ub),
            self._as_value(step),
            start=at,
            iv_type=iv_type,
            iter_arg_offset=iter_offset,
            unroll=unroll,
            iv_name=iv_name,
            tv_name=tv_name,
            loc=_caller_loc(3),
        )
        self.insert(op)
        self._region_stack.append(op.region(0))
        try:
            yield LoopHandle(op)
        finally:
            self._region_stack.pop()

    def yield_(self, at: Time) -> Operation:
        return self.insert(ir.yield_op(at, loc=_caller_loc(2)))

    def call(
        self,
        callee: Union[str, FuncOp],
        operands: Sequence[ValueLike],
        at: Time,
        result_types: Sequence[Type] = (),
        result_delays: Sequence[int] = (),
    ):
        if isinstance(callee, str):
            callee = self.module.get(callee)
        ops = [self._as_value(v) for v in operands]
        op = self.insert(ir.call(callee, ops, at, result_types, result_delays, loc=_caller_loc(2)))
        if len(op.results) == 1:
            return op.results[0]
        return tuple(op.results)

    def ret(self, values: Sequence[Value] = ()) -> Operation:
        return self.insert(ir.return_op(values, loc=_caller_loc(2)))
