"""Schedule analyses shared by the verifier, the codegen, the HLS baseline
and the schedule-transform passes: initiation intervals, iteration latencies,
loop/function latency bounds, access tables per memref port, memory-touch /
banking analysis, and the dependence graph (SSA + memory edges with
distances).

Each analysis is registered with the ``core.passmgr`` AnalysisManager
(``loop-info``, ``port-accesses``, ``mem-touch``, ``dependence``) so
consumers share one cached computation per function instead of re-deriving
private copies; passes declare which analyses they preserve.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

from . import ir
from .ir import ForOp, FuncOp, MemrefType, Operation, Region, Time, Value
from .passmgr import AnalysisManager, FunctionAnalysis, register_analysis


@dataclass
class LoopInfo:
    op: ForOp
    ii: Optional[int]  # constant initiation interval, None if data-dependent
    trip: Optional[int]  # constant trip count, None if dynamic
    body_span: int  # max completion offset of body ops relative to %ti
    total_latency: Optional[int]  # cycles from loop start to %tf, if static

    @property
    def pipelined(self) -> bool:
        return self.ii is not None and self.ii < self.body_span


def op_completion_offset(op: Operation, root: Value, loops: dict[ForOp, "LoopInfo"]) -> Optional[int]:
    """Completion cycle of ``op`` relative to time variable ``root``; None if
    it is not statically tied to ``root``."""
    if op.start is None or op.start.tv is not root:
        return None
    base = op.start.offset
    if op.opname == "mem_read":
        mt = op.operands[0].type
        return base + mt.read_latency()
    if op.opname == "mem_write":
        return base + 1  # writes take one cycle (paper §4.1)
    if op.opname == "delay":
        return base + op.attrs["by"]
    if op.opname == "call":
        ds = op.attrs.get("result_delays", ())
        return base + (max(ds) if ds else 0)
    if op.opname in ("for", "unroll_for"):
        li = loops.get(op)  # type: ignore[arg-type]
        if li is None or li.total_latency is None:
            return None
        return base + li.total_latency
    if op.opname in ir.ARITH_OPS:
        return base + op.attrs.get("stages", 0)
    return base


def span_completion_offset(op: Operation, root: Value,
                           loops: dict[ForOp, "LoopInfo"]) -> Optional[int]:
    """Completion cycle of ``op`` relative to ``root`` as counted into a
    loop's body span: directly scheduled on ``root``, or chained off an inner
    loop's end time whose latency is statically derivable.  None when the
    completion cannot be bounded."""
    c = op_completion_offset(op, root, loops)
    if c is not None:
        return c
    if op.start is not None and isinstance(op.start.tv.defining_op, ForOp):
        fop: ForOp = op.start.tv.defining_op  # type: ignore[assignment]
        li = loops.get(fop)
        if li is not None and li.total_latency is not None \
                and fop.start is not None and fop.start.tv is root:
            c2 = op_completion_offset(op, op.start.tv, loops)
            if c2 is not None:
                return fop.start.offset + li.total_latency + c2
    return None


def analyze_loops(func: FuncOp) -> dict[ForOp, LoopInfo]:
    """Bottom-up loop analysis: II, trip count, body span, total latency."""
    loops: dict[ForOp, LoopInfo] = {}

    def visit_region(region: Region) -> None:
        for op in region.ops:
            for r in op.regions:
                visit_region(r)
            if isinstance(op, ForOp):
                loops[op] = _analyze_loop(op, loops)

    def _analyze_loop(op: ForOp, loops: dict[ForOp, LoopInfo]) -> LoopInfo:
        root = op.time_var
        trip = op.trip_count()
        span = 0
        for inner in op.region(0).ops:
            c = span_completion_offset(inner, root, loops)
            if c is not None:
                span = max(span, c)
        y = op.yield_op()
        ii: Optional[int] = None
        seq_iter_len: Optional[int] = None
        if y is not None and y.start is not None:
            if y.start.tv is root:
                ii = y.start.offset
            else:
                # sequential loop: yield chained off an inner loop's end time
                d = y.start.tv.defining_op
                if isinstance(d, ForOp) and d in loops and d.start is not None and d.start.tv is root:
                    li = loops[d]
                    if li.total_latency is not None:
                        seq_iter_len = d.start.offset + li.total_latency + y.start.offset
        if op.opname == "unroll_for":
            # all iterations replicated in space; ii is the per-iteration time
            # stagger (0 = fully parallel).
            ii = ii if ii is not None else 0
            total = None if trip is None else (trip * ii + span if trip else 0)
            return LoopInfo(op, ii, trip, span, total)
        total: Optional[int] = None
        if trip is not None:
            if ii is not None:
                total = trip * ii
            elif seq_iter_len is not None:
                total = trip * seq_iter_len
        return LoopInfo(op, ii if ii is not None else seq_iter_len, trip, span, total)

    visit_region(func.body)
    return loops


def func_latency(func: FuncOp, loops: Optional[dict[ForOp, LoopInfo]] = None) -> Optional[int]:
    """Static latency (cycles from %t to all effects complete), if derivable."""
    loops = loops if loops is not None else analyze_loops(func)
    root = func.time_var
    worst = 0
    derived_roots: dict[Value, Optional[int]] = {root: 0}

    # two passes to resolve chains of derived time variables
    for _ in range(2):
        for op in func.body.walk():
            if op.opname == "time":
                base = derived_roots.get(op.operands[0])
                if base is not None:
                    derived_roots[op.result] = base + op.attrs.get("offset", 0)
            if isinstance(op, ForOp):
                li = loops[op]
                if op.start is not None and op.start.tv in derived_roots and li.total_latency is not None:
                    b = derived_roots[op.start.tv]
                    if b is not None:
                        derived_roots[op.end_time] = b + op.start.offset + li.total_latency

    for op in func.body.walk():
        if op.start is None:
            continue
        base = derived_roots.get(op.start.tv)
        if base is None:
            # op scheduled relative to a loop-local or unknown time var;
            # loop spans are already accounted for via total_latency.
            continue
        local_root = op.start.tv
        c = op_completion_offset(op, local_root, loops)
        if c is None:
            return None
        # for loops: completion already includes total; body spans beyond II
        if isinstance(op, ForOp):
            li = loops[op]
            if li.total_latency is None:
                return None
            extra = max(0, li.body_span - (li.ii or 0))
            worst = max(worst, base + op.start.offset + li.total_latency + extra)
        else:
            worst = max(worst, base + c)
    return worst


@dataclass
class MemAccess:
    op: Operation
    is_write: bool
    port_value: Value  # the memref SSA value (= the port)
    offsets_mod: Optional[tuple[int, int]]  # (offset mod II, II) within pipelined loop
    offset: Optional[int]  # absolute offset under its root tv
    root: Value


def collect_port_accesses(func: FuncOp, loops: dict[ForOp, LoopInfo]) -> dict[Value, list[MemAccess]]:
    """Group memory accesses by memref port value, annotated with their
    schedule congruence class (offset mod II inside pipelined loops)."""
    out: dict[Value, list[MemAccess]] = {}

    def visit(region: Region, encl: Optional[ForOp]) -> None:
        for op in region.ops:
            if op.opname in ("mem_read", "mem_write"):
                port = op.operands[0] if op.opname == "mem_read" else op.operands[1]
                acc = MemAccess(
                    op,
                    op.opname == "mem_write",
                    port,
                    None,
                    op.start.offset if op.start is not None else None,
                    op.start.tv if op.start is not None else func.time_var,
                )
                if encl is not None and op.start is not None and op.start.tv is encl.time_var:
                    li = loops[encl]
                    if li.ii is not None and li.ii > 0 and li.pipelined:
                        acc.offsets_mod = (op.start.offset % li.ii, li.ii)
                out.setdefault(port, []).append(acc)
            for r in op.regions:
                visit(r, op if isinstance(op, ForOp) else encl)

    visit(func.body, None)
    return out


# --------------------------------------------------------------------------
# Memory-touch / banking analysis (lifted out of the HLS scheduler so the
# scheduler, the unroll-legality check and the schedule-transform passes all
# share one definition of "which storage does this op touch, and how").
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Touch:
    """One storage access (or the summary of a nested region's accesses).

    ``banked_by``   region-arg values (loop IVs, including compile-time
                    ``!hir.const`` unroll IVs) indexing *distributed* dims:
                    distinct IV values select physically distinct banks.
    ``addr_ivs``    region-arg values appearing anywhere in the address —
                    iterations with different IV values touch different
                    addresses.
    ``private_to``  dynamic (non-const) IVs making the access
                    iteration-private (no loop-carried memory dependence).
    ``bank_consts`` literal constant indices of the distributed dims
                    (``None`` where dynamic): two touches with a differing
                    pair address provably distinct banks."""

    storage: object          # alloc op or arg Value
    is_write: bool
    banked_by: frozenset     # IVs appearing in distributed dims
    addr_ivs: frozenset      # IVs appearing anywhere in the address
    private_to: frozenset    # IVs making the access iteration-private
    bank_consts: tuple = ()  # constant distributed-dim indices (None if dyn)

    def distinct_bank(self, other: "Touch") -> bool:
        return any(
            a is not None and b is not None and a != b
            for a, b in zip(self.bank_consts, other.bank_consts)
        )


def storage_of(mem: Value):
    """The physical storage a memref port belongs to: its defining alloc, or
    the argument value itself for interface memrefs."""
    d = mem.defining_op
    return d if d is not None and d.opname == "alloc" else mem


class MemTouches:
    """Per-op memory-touch query with a memo for every op (``ForOp`` touches
    are the union of their bodies').  Registered as the ``mem-touch``
    analysis; also usable standalone on unscheduled IR.  The memo matters:
    the dependence builders query the same ops many times, and recomputing a
    leaf ``Touch`` involves type/banking introspection per call."""

    def __init__(self):
        self._cache: dict[Operation, list[Touch]] = {}

    def of(self, op: Operation) -> list[Touch]:
        cached = self._cache.get(op)
        if cached is not None:
            return cached
        out = self._compute(op)
        self._cache[op] = out
        return out

    def _compute(self, op: Operation) -> list[Touch]:
        if op.opname in ("mem_read", "mem_write"):
            mem = op.operands[0] if op.opname == "mem_read" else op.operands[1]
            mt: MemrefType = mem.type  # type: ignore[assignment]
            idx = ir.mem_op_indices(op)
            region_args = [v for v in idx if v.defining_op is None]
            # every region-arg index in a distributed dim selects a distinct
            # bank per iteration — including compile-time-constant unroll IVs
            # (the seed's dead `and False` clause dropped those, pessimizing
            # legal unroll parallelism to staggered execution)
            banked = frozenset(idx[d] for d in mt.distributed if idx[d].defining_op is None)
            ivs = frozenset(region_args)
            private = frozenset(v for v in region_args if not isinstance(v.type, ir.ConstType))
            bank_consts = tuple(ir.const_value(idx[d]) for d in mt.distributed)
            return [Touch(storage_of(mem), op.opname == "mem_write", banked, ivs,
                          private, bank_consts)]
        if op.opname == "call":
            out = []
            for v in op.operands:
                if isinstance(v.type, MemrefType):
                    out.append(Touch(storage_of(v), True, frozenset(), frozenset(), frozenset()))
            return out
        if isinstance(op, ForOp):
            out = []
            for b in op.region(0).ops:
                out.extend(self.of(b))
            return out
        return []


# --------------------------------------------------------------------------
# Dependence graph: SSA dataflow + memory edges with iteration distances
# (lifted out of the HLS scheduler; shared with the pipeline-loop pass).
# --------------------------------------------------------------------------


class DepEdge(NamedTuple):
    """``dst`` must start at least ``latency`` cycles after ``src`` (minus
    ``distance`` * II when the edge is loop-carried)."""

    src: Operation
    dst: Operation
    latency: int
    distance: int


def _tuples_conflict(a: tuple, b: tuple) -> bool:
    """Inverse of ``Touch.distinct_bank`` on bare bank-const tuples: two
    accesses conflict unless some distributed dim is constant on both sides
    with different values."""
    return not any(x is not None and y is not None and x != y
                   for x, y in zip(a, b))


class _BankGroup:
    """Per (storage, exact bank-const tuple) serialization frontier."""

    __slots__ = ("last_write", "reads")

    def __init__(self):
        self.last_write: Optional[Operation] = None
        self.reads: list[Operation] = []


class _StorageChain:
    """Per-storage chained serialization state: the last non-plain toucher
    (``barrier`` — a loop/call child conflicts with *every* access on the
    storage) plus one :class:`_BankGroup` per exact bank-const tuple.  Fully
    constant tuples conflict only with equal tuples (dict hit); tuples with
    dynamic dims (``dyn``) must be checked pairwise."""

    __slots__ = ("barrier", "groups", "dyn")

    def __init__(self):
        self.barrier: Optional[Operation] = None
        self.groups: dict[tuple, _BankGroup] = {}
        self.dyn: list[tuple] = []

    def conflicting(self, key: tuple) -> list[_BankGroup]:
        out = []
        if None in key:
            for k, g in self.groups.items():
                if _tuples_conflict(key, k):
                    out.append(g)
            return out
        g = self.groups.get(key)
        if g is not None:
            out.append(g)
        for k in self.dyn:
            if _tuples_conflict(key, k):
                out.append(self.groups[k])
        return out

    def group(self, key: tuple) -> _BankGroup:
        g = self.groups.get(key)
        if g is None:
            g = self.groups[key] = _BankGroup()
            if None in key:
                self.dyn.append(key)
        return g


def _chained_memory_edges(
    ops: list[Operation],
    touches_of: Callable[[Operation], list[Touch]],
    latency_of: Callable[[Operation], int],
    edges: list[DepEdge],
) -> None:
    """Memory serialization edges for a non-pipelined region, transitively
    reduced: instead of the all-pairs scan (every later access vs every
    earlier conflicting access), each access depends only on the current
    *frontier* of its storage — the reads since the last conflicting write,
    the last write itself, and the last non-plain (loop/call) toucher.  Every
    dropped all-pairs edge is implied by a frontier chain with total latency
    at least as large (latencies are non-negative), so the least fixpoint of
    the difference constraints — and therefore the schedule — is identical;
    the edge count drops from quadratic to near-linear in the region size.
    """
    state: dict[object, _StorageChain] = {}
    for o in ops:
        to = touches_of(o)
        if not to:
            continue
        if o.opname in ("mem_read", "mem_write"):
            tch = to[0]
            s = state.get(tch.storage)
            if s is None:
                s = state[tch.storage] = _StorageChain()
            targets: list[Operation] = []
            if s.barrier is not None:
                targets.append(s.barrier)
            key = tch.bank_consts
            if tch.is_write:
                for g in s.conflicting(key):
                    if g.reads:
                        targets.extend(g.reads)
                    elif g.last_write is not None:
                        targets.append(g.last_write)
                g = s.group(key)
                g.last_write = o
                g.reads.clear()
            else:
                for g in s.conflicting(key):
                    if g.last_write is not None:
                        targets.append(g.last_write)
                s.group(key).reads.append(o)
            for p in targets:
                edges.append(DepEdge(p, o, latency_of(p), 0))
        else:
            # loop/call child: conflicts with everything on every storage it
            # touches — collect each storage's frontier, then become its
            # barrier
            for storage in {tc.storage for tc in to}:
                s = state.get(storage)
                if s is None:
                    s = state[storage] = _StorageChain()
                targets = []
                if s.barrier is not None:
                    targets.append(s.barrier)
                for g in s.groups.values():
                    if g.reads:
                        targets.extend(g.reads)
                    elif g.last_write is not None:
                        targets.append(g.last_write)
                s.groups.clear()
                s.dyn.clear()
                s.barrier = o
                for p in targets:
                    edges.append(DepEdge(p, o, latency_of(p), 0))


def build_dependence_edges(
    ops: list[Operation],
    touches_of: Callable[[Operation], list[Touch]],
    latency_of: Callable[[Operation], int],
    loop: Optional[ForOp] = None,
    carried: bool = False,
) -> list[DepEdge]:
    """Dependence edges among the ops of one region, in program order:

      * SSA edges (producer -> consumer, weighted by the producer latency),
        including uses held by ops nested inside a consumer's regions;
      * memory edges per shared storage — conservative serialization, with
        read-read pairs and provably-distinct banks exempt; non-pipelined
        regions use the transitively-reduced frontier chains
        (``_chained_memory_edges``, same least fixpoint, near-linear size);
      * distance-1 carried edges for non-iteration-private accesses and for
        loop/call children that reoccupy their resources (``carried=True``;
        pipelining candidates are innermost loops, small enough for the
        exact all-pairs scan the carried analysis needs).
    """
    edges: list[DepEdge] = []
    producer: dict[Value, Operation] = {}
    for o in ops:
        for r in o.results:
            producer[r] = o

    def ssa_deps(o: Operation):
        for v in o.operands:
            if v in producer:
                edges.append(DepEdge(producer[v], o, latency_of(producer[v]), 0))
        if isinstance(o, ForOp):
            for b in o.region(0).walk():
                for v in b.operands:
                    if v in producer and producer[v] is not o:
                        edges.append(DepEdge(producer[v], o, latency_of(producer[v]), 0))

    if not carried:
        for o in ops:
            ssa_deps(o)
        _chained_memory_edges(ops, touches_of, latency_of, edges)
        return edges

    seen: list[Operation] = []
    for o in ops:
        ssa_deps(o)
        to = touches_of(o)
        if to:
            for prev in seen:
                tp = touches_of(prev)
                for a in tp:
                    for b in to:
                        if a.storage is not b.storage:
                            continue
                        plain = (o.opname in ("mem_read", "mem_write")
                                 and prev.opname in ("mem_read", "mem_write"))
                        if plain and not a.is_write and not b.is_write:
                            continue  # same-region read-read: MRT handles
                        if plain and a.distinct_bank(b):
                            continue  # physically parallel banks
                        edges.append(DepEdge(prev, o, latency_of(prev), 0))
                        if carried and plain and loop is not None:
                            private = (loop.iv in a.private_to and loop.iv in b.private_to)
                            if not private:
                                edges.append(DepEdge(o, prev, latency_of(o), 1))
                        break
                    else:
                        continue
                    break
            seen.append(o)
        # sequential outer loops: a loop child reoccupies its resources
        if carried and isinstance(o, ForOp):
            edges.append(DepEdge(o, o, latency_of(o), 1))
        if carried and o.opname == "call":
            edges.append(DepEdge(o, o, 1, 1))
    return edges


def scheduled_op_latency(op: Operation, loops: dict[ForOp, LoopInfo]) -> int:
    """Result latency of ``op`` under the standard timing model (RAM reads 1,
    writes 1, delays their depth, calls their declared delay, loops their
    statically-derived total latency)."""
    if op.opname == "mem_read":
        return op.operands[0].type.read_latency()
    if op.opname == "mem_write":
        return 1
    if op.opname == "delay":
        return op.attrs["by"]
    if op.opname == "call":
        ds = op.attrs.get("result_delays", ())
        return max(ds) if ds else 0
    if isinstance(op, ForOp):
        li = loops.get(op)
        return li.total_latency if li is not None and li.total_latency is not None else 1
    if op.opname in ir.ARITH_OPS:
        return op.attrs.get("stages", 0)
    return 0


@dataclass
class DependenceInfo:
    """Per-region dependence edges for the whole function; regions are keyed
    by their owning op (the ``FuncOp`` for the body).  Innermost loop bodies
    carry distance-1 edges (the pipelining candidates)."""

    edges: dict[Operation, list[DepEdge]]
    touches: MemTouches

    def for_loop(self, loop: ForOp) -> list[DepEdge]:
        return self.edges.get(loop, [])


# --------------------------------------------------------------------------
# Registered analyses
# --------------------------------------------------------------------------


@register_analysis
class LoopAnalysis(FunctionAnalysis):
    """``analyze_loops``: II / trip / body span / total latency per loop."""

    name = "loop-info"

    @staticmethod
    def run(func: FuncOp, am: AnalysisManager) -> dict[ForOp, LoopInfo]:
        return analyze_loops(func)


@register_analysis
class PortAccessAnalysis(FunctionAnalysis):
    """``collect_port_accesses`` keyed on the cached loop analysis."""

    name = "port-accesses"

    @staticmethod
    def run(func: FuncOp, am: AnalysisManager) -> dict[Value, list[MemAccess]]:
        return collect_port_accesses(func, am.get(LoopAnalysis, func))


@register_analysis
class MemTouchAnalysis(FunctionAnalysis):
    """Lazy memory-touch/banking table (see ``MemTouches``)."""

    name = "mem-touch"

    @staticmethod
    def run(func: FuncOp, am: AnalysisManager) -> MemTouches:
        return MemTouches()


@register_analysis
class DependenceAnalysis(FunctionAnalysis):
    """Dependence edges for every region of the function, with carried
    (distance-1) edges in innermost loop bodies."""

    name = "dependence"

    @staticmethod
    def run(func: FuncOp, am: AnalysisManager) -> DependenceInfo:
        touches = am.get(MemTouchAnalysis, func)
        loops = am.get(LoopAnalysis, func)

        def latency_of(op: Operation) -> int:
            return scheduled_op_latency(op, loops)

        edges: dict[Operation, list[DepEdge]] = {}

        def visit(owner: Operation, region: Region) -> None:
            loop = owner if isinstance(owner, ForOp) else None
            inner = [o for o in region.ops
                     if o.opname not in ("constant", "alloc", "yield", "return", "time")]
            innermost = loop is not None and not any(isinstance(o, ForOp) for o in inner)
            edges[owner] = build_dependence_edges(
                inner, touches.of, latency_of, loop, carried=innermost)
            for o in region.ops:
                for r in o.regions:
                    visit(o, r)

        visit(func, func.body)
        return DependenceInfo(edges, touches)


# ---------------------------------------------------------------------------
# Activation intervals (RTL-level pulse schedules)
# ---------------------------------------------------------------------------

#: Lattice top: "may be nonzero at any cycle" (unknown pulse schedule).
PULSES_TOP = None

#: Finite pulse sets larger than this collapse to ``PULSES_TOP`` so the
#: fixpoint stays bounded on pathological schedules.
PULSE_SET_CAP = 4096


def _pulse_join(a, b):
    """Join of two pulse sets (``frozenset`` of cycle offsets, or TOP)."""
    if a is PULSES_TOP or b is PULSES_TOP:
        return PULSES_TOP
    u = a | b
    return PULSES_TOP if len(u) > PULSE_SET_CAP else u


def _pulse_shift(s, d):
    if s is PULSES_TOP:
        return PULSES_TOP
    return frozenset(t + d for t in s)


_EMPTY_PULSES = frozenset()


@dataclass
class ActivationIntervals:
    """Result of the ``activation-intervals`` analysis over one RTL module.

    ``pulses[net]`` is the *sound superset* of cycle offsets — relative to
    the module's ``t_start`` pulse — at which ``net`` can be nonzero, or
    ``PULSES_TOP`` (``None``) when unknown.  Only single-bit pulse networks
    get finite sets (activation pulses derived from ``t_start`` through
    ``ShiftReg`` delay taps, ``LoopController`` iteration pulses with
    constant bounds, and the boolean algebra over them); datapath nets are
    TOP.  ``rtl-share-instances`` proves two instances may share one body by
    showing their ``t_start`` pulse sets are finite and disjoint."""

    pulses: "dict[str, Optional[frozenset]]" = field(default_factory=dict)

    def of_net(self, name: str):
        return self.pulses.get(name, PULSES_TOP)

    def of_expr(self, e):
        """Pulse set of an arbitrary RTL expression under this solution."""
        return _pulses_of_expr(e, self.pulses)


#: expr operators through which a pulse on either operand propagates
#: (output can only be nonzero when some operand is)
_PULSE_UNION_OPS = frozenset({"|", "||", "^", "+", "-"})
#: operators whose output is zero whenever *either* operand is zero
_PULSE_MEET_OPS = frozenset({"&", "&&", "*"})


def _pulses_of_expr(e, env):
    """Evaluate the pulse set of expression ``e`` under net solution ``env``
    (missing nets are TOP — reads of undriven nets stay unknown).  Iterative
    post-order so ~256-deep bus-mux chains don't recurse."""
    from .codegen import rtl

    memo: dict[int, object] = {}
    stack = [e]
    while stack:
        cur = stack[-1]
        if id(cur) in memo:
            stack.pop()
            continue
        if isinstance(cur, rtl.Const):
            memo[id(cur)] = _EMPTY_PULSES if cur.value == 0 else PULSES_TOP
            stack.pop()
            continue
        if isinstance(cur, rtl.Ref):
            memo[id(cur)] = env.get(cur.name, PULSES_TOP)
            stack.pop()
            continue
        kids = cur._children()
        pending = [c for c in kids if id(c) not in memo]
        if pending:
            stack.extend(pending)
            continue
        stack.pop()
        if isinstance(cur, rtl.Binop):
            a, b = memo[id(cur.a)], memo[id(cur.b)]
            if cur.op in _PULSE_UNION_OPS:
                r = _pulse_join(a, b)
            elif cur.op in _PULSE_MEET_OPS:
                if a == _EMPTY_PULSES or b == _EMPTY_PULSES:
                    r = _EMPTY_PULSES
                elif a is PULSES_TOP:
                    r = b
                elif b is PULSES_TOP:
                    r = a
                else:
                    r = a & b
            elif cur.op in ("<<", ">>", ">>>"):
                # a shifted by zero/any amount is zero iff a is zero
                r = memo[id(cur.a)]
            else:  # comparisons etc. can be nonzero when operands are zero
                r = PULSES_TOP
        elif isinstance(cur, rtl.Mux):
            # cond only *selects*; output nonzero => a or b nonzero
            r = _pulse_join(memo[id(cur.a)], memo[id(cur.b)])
        elif isinstance(cur, (rtl.Signed, rtl.Repeat)):
            r = memo[id(cur.a)]
        elif isinstance(cur, rtl.Unop):
            r = memo[id(cur.a)] if cur.op == "-" else PULSES_TOP
        else:
            r = PULSES_TOP
        memo[id(cur)] = r
    return memo[id(e)]


def _controller_pulses(it, env):
    """(iter_pulses, endp_pulses) for one ``LoopController`` under ``env``.

    For a pipelined controller started at cycle ``s`` with constant bounds,
    the iteration pulse fires at ``{s + m*ii : 0 <= m < trip}`` with
    ``trip = max(1, ceil((ub-lb)/step))`` and the completion pulse at
    ``s + trip*ii + 1`` (registered).  Sequential controllers advance on the
    inner loop's completion pulse instead.  Everything non-constant is TOP."""
    from .codegen import rtl

    start = _pulses_of_expr(it.start, env)
    if it.ii is None:  # sequential: advances on inner_end
        inner = (_pulses_of_expr(it.inner_end, env)
                 if it.inner_end is not None else PULSES_TOP)
        return _pulse_join(start, inner), _pulse_shift(inner, 1)
    if start is PULSES_TOP:
        return PULSES_TOP, PULSES_TOP
    consts = []
    for b in (it.lb, it.ub, it.step):
        if not isinstance(b, rtl.Const) or not isinstance(b.value, int):
            return PULSES_TOP, PULSES_TOP
        consts.append(b.value)
    lb, ub, step = consts
    if step <= 0 or ub > (1 << it.ivw):  # iv wrap would extend the trip
        return PULSES_TOP, PULSES_TOP
    trip = max(1, -((lb - ub) // step)) if ub > lb else 1
    if trip * max(1, len(start)) > PULSE_SET_CAP:
        return PULSES_TOP, PULSES_TOP
    iters = frozenset(s + m * it.ii for s in start for m in range(trip))
    endp = frozenset(s + trip * it.ii + 1 for s in start)
    return iters, endp


@register_analysis
class ActivationIntervalsAnalysis(FunctionAnalysis):
    """Per-net activation pulse schedules of one ``RTLModule`` (keyed on the
    module object, like ``net-fanout``).  Worklist fixpoint from bottom
    (``frozenset()``); every transfer is monotone w.r.t. the
    join-semilattice ``∅ ⊑ finite ⊑ TOP``, and finite sets are capped, so
    the fixpoint terminates.  Nets driven by data-dependent state
    (registers, memories, instance results, loop induction variables) are
    TOP; the interesting finite sets are the ``t_start``-derived pulse
    networks the lowering builds for operand/result timing."""

    name = "activation-intervals"

    @staticmethod
    def run(func, am: AnalysisManager) -> ActivationIntervals:
        from .codegen import rtl

        m = func  # an RTLModule
        env: dict[str, object] = {}
        readers: dict[str, list] = {}
        for it in m.items:
            for r in it.reads():
                readers.setdefault(r, []).append(it)
        for p in m.ports:
            if p.dir == "input":
                env[p.name] = _EMPTY_PULSES if p.name == "t_start" else PULSES_TOP
        # nets written by clocked/data items are TOP from the start; pulse
        # networks (CombAssign / 1-bit reset_zero ShiftReg / controller
        # iter+endp) start at bottom and grow monotonically
        pulse_driven: set = set()
        for it in m.items:
            if isinstance(it, rtl.CombAssign):
                pulse_driven.add(it.dest)
            elif isinstance(it, rtl.ShiftReg):
                if it.width == 1 and it.reset_zero:
                    pulse_driven.add(it.dest)
                else:
                    env[it.dest] = PULSES_TOP
            elif isinstance(it, rtl.LoopController):
                pulse_driven.add(it.iter_net)
                if it.endp:
                    pulse_driven.add(it.endp)
                for n in (it.iv, it.active, it.iicnt):
                    if n:
                        env[n] = PULSES_TOP
            else:
                for w in it.writes():
                    env[w] = PULSES_TOP
        # t_start seeds the input-port entry {0}; multi-driven pulse nets
        # join all driver contributions (env entries above win as TOP)
        if "t_start" in env and env["t_start"] is not PULSES_TOP:
            env["t_start"] = frozenset((0,))
        for n in pulse_driven:
            env.setdefault(n, _EMPTY_PULSES)

        def contribution(it):
            if isinstance(it, rtl.CombAssign):
                return ((it.dest, _pulses_of_expr(it.expr, env)),)
            if isinstance(it, rtl.ShiftReg):
                return ((it.dest, _pulse_shift(_pulses_of_expr(it.src, env),
                                               it.depth)),)
            if isinstance(it, rtl.LoopController):
                iters, endp = _controller_pulses(it, env)
                out = [(it.iter_net, iters)]
                if it.endp:
                    out.append((it.endp, endp))
                return out
            return ()

        work = list(m.items)
        seen = set(map(id, work))
        while work:
            it = work.pop()
            seen.discard(id(it))
            for dest, val in contribution(it):
                if dest not in pulse_driven:
                    continue  # also written by a TOP item: stays TOP
                old = env.get(dest, _EMPTY_PULSES)
                new = _pulse_join(old, val)
                if new != old:
                    env[dest] = new
                    for rd in readers.get(dest, ()):
                        if id(rd) not in seen:
                            seen.add(id(rd))
                            work.append(rd)
        return ActivationIntervals(pulses=env)
