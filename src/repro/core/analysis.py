"""Schedule analyses shared by the verifier, the codegen and the HLS
baseline: initiation intervals, iteration latencies, loop/function latency
bounds, and access tables per memref port.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import ir
from .ir import ForOp, FuncOp, Operation, Region, Time, Value


@dataclass
class LoopInfo:
    op: ForOp
    ii: Optional[int]  # constant initiation interval, None if data-dependent
    trip: Optional[int]  # constant trip count, None if dynamic
    body_span: int  # max completion offset of body ops relative to %ti
    total_latency: Optional[int]  # cycles from loop start to %tf, if static

    @property
    def pipelined(self) -> bool:
        return self.ii is not None and self.ii < self.body_span


def op_completion_offset(op: Operation, root: Value, loops: dict[ForOp, "LoopInfo"]) -> Optional[int]:
    """Completion cycle of ``op`` relative to time variable ``root``; None if
    it is not statically tied to ``root``."""
    if op.start is None or op.start.tv is not root:
        return None
    base = op.start.offset
    if op.opname == "mem_read":
        mt = op.operands[0].type
        return base + mt.read_latency()
    if op.opname == "mem_write":
        return base + 1  # writes take one cycle (paper §4.1)
    if op.opname == "delay":
        return base + op.attrs["by"]
    if op.opname == "call":
        ds = op.attrs.get("result_delays", ())
        return base + (max(ds) if ds else 0)
    if op.opname in ("for", "unroll_for"):
        li = loops.get(op)  # type: ignore[arg-type]
        if li is None or li.total_latency is None:
            return None
        return base + li.total_latency
    if op.opname in ir.ARITH_OPS:
        return base + op.attrs.get("stages", 0)
    return base


def analyze_loops(func: FuncOp) -> dict[ForOp, LoopInfo]:
    """Bottom-up loop analysis: II, trip count, body span, total latency."""
    loops: dict[ForOp, LoopInfo] = {}

    def visit_region(region: Region) -> None:
        for op in region.ops:
            for r in op.regions:
                visit_region(r)
            if isinstance(op, ForOp):
                loops[op] = _analyze_loop(op, loops)

    def _analyze_loop(op: ForOp, loops: dict[ForOp, LoopInfo]) -> LoopInfo:
        root = op.time_var
        trip = op.trip_count()
        span = 0
        for inner in op.region(0).ops:
            c = op_completion_offset(inner, root, loops)
            if c is not None:
                span = max(span, c)
            # ops chained off an inner loop's end time extend the span too
            elif inner.start is not None and inner.start.tv.defining_op in loops:
                fop: ForOp = inner.start.tv.defining_op  # type: ignore[assignment]
                li = loops[fop]
                if li.total_latency is not None and fop.start is not None and fop.start.tv is root:
                    c2 = op_completion_offset(inner, inner.start.tv, loops)
                    if c2 is not None:
                        span = max(span, fop.start.offset + li.total_latency + c2)
        y = op.yield_op()
        ii: Optional[int] = None
        seq_iter_len: Optional[int] = None
        if y is not None and y.start is not None:
            if y.start.tv is root:
                ii = y.start.offset
            else:
                # sequential loop: yield chained off an inner loop's end time
                d = y.start.tv.defining_op
                if isinstance(d, ForOp) and d in loops and d.start is not None and d.start.tv is root:
                    li = loops[d]
                    if li.total_latency is not None:
                        seq_iter_len = d.start.offset + li.total_latency + y.start.offset
        if op.opname == "unroll_for":
            # all iterations replicated in space; ii is the per-iteration time
            # stagger (0 = fully parallel).
            ii = ii if ii is not None else 0
            total = None if trip is None else (trip * ii + span if trip else 0)
            return LoopInfo(op, ii, trip, span, total)
        total: Optional[int] = None
        if trip is not None:
            if ii is not None:
                total = trip * ii
            elif seq_iter_len is not None:
                total = trip * seq_iter_len
        return LoopInfo(op, ii if ii is not None else seq_iter_len, trip, span, total)

    visit_region(func.body)
    return loops


def func_latency(func: FuncOp, loops: Optional[dict[ForOp, LoopInfo]] = None) -> Optional[int]:
    """Static latency (cycles from %t to all effects complete), if derivable."""
    loops = loops if loops is not None else analyze_loops(func)
    root = func.time_var
    worst = 0
    derived_roots: dict[Value, Optional[int]] = {root: 0}

    # two passes to resolve chains of derived time variables
    for _ in range(2):
        for op in func.body.walk():
            if op.opname == "time":
                base = derived_roots.get(op.operands[0])
                if base is not None:
                    derived_roots[op.result] = base + op.attrs.get("offset", 0)
            if isinstance(op, ForOp):
                li = loops[op]
                if op.start is not None and op.start.tv in derived_roots and li.total_latency is not None:
                    b = derived_roots[op.start.tv]
                    if b is not None:
                        derived_roots[op.end_time] = b + op.start.offset + li.total_latency

    for op in func.body.walk():
        if op.start is None:
            continue
        base = derived_roots.get(op.start.tv)
        if base is None:
            # op scheduled relative to a loop-local or unknown time var;
            # loop spans are already accounted for via total_latency.
            continue
        local_root = op.start.tv
        c = op_completion_offset(op, local_root, loops)
        if c is None:
            return None
        # for loops: completion already includes total; body spans beyond II
        if isinstance(op, ForOp):
            li = loops[op]
            if li.total_latency is None:
                return None
            extra = max(0, li.body_span - (li.ii or 0))
            worst = max(worst, base + op.start.offset + li.total_latency + extra)
        else:
            worst = max(worst, base + c)
    return worst


@dataclass
class MemAccess:
    op: Operation
    is_write: bool
    port_value: Value  # the memref SSA value (= the port)
    offsets_mod: Optional[tuple[int, int]]  # (offset mod II, II) within pipelined loop
    offset: Optional[int]  # absolute offset under its root tv
    root: Value


def collect_port_accesses(func: FuncOp, loops: dict[ForOp, LoopInfo]) -> dict[Value, list[MemAccess]]:
    """Group memory accesses by memref port value, annotated with their
    schedule congruence class (offset mod II inside pipelined loops)."""
    out: dict[Value, list[MemAccess]] = {}

    def visit(region: Region, encl: Optional[ForOp]) -> None:
        for op in region.ops:
            if op.opname in ("mem_read", "mem_write"):
                port = op.operands[0] if op.opname == "mem_read" else op.operands[1]
                acc = MemAccess(
                    op,
                    op.opname == "mem_write",
                    port,
                    None,
                    op.start.offset if op.start is not None else None,
                    op.start.tv if op.start is not None else func.time_var,
                )
                if encl is not None and op.start is not None and op.start.tv is encl.time_var:
                    li = loops[encl]
                    if li.ii is not None and li.ii > 0 and li.pipelined:
                        acc.offsets_mod = (op.start.offset % li.ii, li.ii)
                out.setdefault(port, []).append(acc)
            for r in op.regions:
                visit(r, op if isinstance(op, ForOp) else encl)

    visit(func.body, None)
    return out
