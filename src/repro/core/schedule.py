"""Shared schedule math: the 200 MHz timing model, SDC-style difference
constraint relaxation, the modulo-reservation scheduling engine, and pipeline
balancing (``hir.delay`` insertion).

Two consumers share this module:

  * the HLS baseline (``core.hls.scheduler``) — the paper's Vivado stand-in,
    which must *search* for a schedule starting from erased IR;
  * the schedule-transform passes (``core.passes.schedule_transforms``) —
    which re-schedule already-legal HIR (pipeline-loop / retime) as ordinary
    IR transformations over the cached analyses, the paper's actual pitch.

The engine is built around :class:`SearchState`, which caches everything
about one region that is *independent of the II being probed*: adjacency
lists, per-op latencies, reservation-table bank keys, the classical MII
lower bounds (resMII/recMII) and — crucially — the least fixpoint of the
distance-0 difference constraints.  Carried (distance ≥ 1) constraints only
*tighten* as II shrinks, so that fixpoint is a sound lower bound on the
schedule at every II; each probe seeds its worklist relaxation from it
instead of re-running Bellman–Ford from zero.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Optional, Sequence

from . import ir
from .analysis import DepEdge, Touch
from .ir import ForOp, FuncOp, MemrefType, Operation, Region, Time, Value

# 200 MHz timing model: 5 ns budget per cycle, combinational delays in ns
CLOCK_NS = 5.0
COMB_DELAY = {
    "add": 2.0, "sub": 2.0, "mult": 4.5, "div": 8.0,
    "and": 0.5, "or": 0.5, "xor": 0.6, "not": 0.3,
    "shl": 0.2, "shr": 0.2,
    "cmp_lt": 1.6, "cmp_le": 1.6, "cmp_eq": 1.2, "cmp_ne": 1.2,
    "cmp_gt": 1.6, "cmp_ge": 1.6,
    "select": 0.9, "trunc": 0.0, "zext": 0.0, "sext": 0.1,
}
MAX_II = 256


def access_bank_key(op: Operation):
    """(port id, distributed-dim bank selector) of a memory access: two
    accesses with different keys use physically distinct ports/banks and
    never conflict in the modulo reservation table."""
    port = op.operands[0] if op.opname == "mem_read" else op.operands[1]
    mt: MemrefType = port.type  # type: ignore[assignment]
    idx = ir.mem_op_indices(op)
    bank = tuple(
        ir.const_value(idx[d]) if ir.const_value(idx[d]) is not None
        else (idx[d].name if idx[d].defining_op is None else "?")
        for d in mt.distributed
    )
    return port.id, bank


def resource_mii(ops: Sequence[Operation]) -> int:
    """resMII: every memref port bank admits one access per cycle, so a loop
    issuing k accesses to the same bank per iteration cannot beat II = k."""
    per_bank: dict[tuple, int] = {}
    for o in ops:
        if o.opname in ("mem_read", "mem_write"):
            k = access_bank_key(o)
            per_bank[k] = per_bank.get(k, 0) + 1
    return max(per_bank.values(), default=1)


def recurrence_mii(ops: Sequence[Operation], edges: Sequence[DepEdge]) -> int:
    """recMII: for every dependence cycle closed by a carried edge,
    II >= ceil(cycle latency / cycle distance).  Distance-0 edges form a DAG
    (program order), so each cycle is one carried edge ``dst -> src`` plus
    the longest distance-0 path ``src .. dst``; we take the max over carried
    edges of ceil((carried latency + longest path) / distance)."""
    carried = [e for e in edges if e.distance]
    if not carried:
        return 1
    index = {o: i for i, o in enumerate(ops)}
    # forward distance-0 adjacency + in-degrees for Kahn topological order
    out0: dict[Operation, list[tuple[Operation, int]]] = {o: [] for o in ops}
    for e in edges:
        if not e.distance and e.src in index and e.dst in index:
            out0[e.src].append((e.dst, e.latency))
    topo = sorted(ops, key=lambda o: index[o])  # program order is topological
    rec = 1
    for ce in carried:
        # longest distance-0 path from the carried edge's *dst* (= the cycle
        # re-entry point) to its *src*, by DP over the program-order DAG
        start = ce.dst
        if start not in index or ce.src not in index:
            continue
        dist: dict[Operation, int] = {start: 0}
        for o in topo:
            if index[o] < index[start]:
                continue
            d = dist.get(o)
            if d is None:
                continue
            for (v, lat) in out0[o]:
                if dist.get(v, -1) < d + lat:
                    dist[v] = d + lat
        path = dist.get(ce.src)
        if path is None:
            continue  # carried edge closes no distance-0 cycle
        cyc_lat = ce.latency + path
        rec = max(rec, -(-cyc_lat // ce.distance))
    return rec


class SearchState:
    """II-independent state for scheduling one region, shared across every
    ``try_modulo_schedule`` probe during the II search:

      * ``out``:      adjacency lists of the dependence edges (src-indexed);
      * ``lat``:      cached ``latency_of`` per op;
      * ``t0``:       least fixpoint of the distance-0 constraints — the seed
                      every probe starts from (carried constraints only add
                      lower bounds on top, so this is sound at any II);
      * ``mem_like``/``bank_key``: reservation-table participants and keys;
      * ``res_mii``:  resource MII (``recurrence_mii`` needs the edges and is
                      exposed as the module-level helper).
    """

    __slots__ = ("ops", "edges", "index", "out", "lat", "horizon", "clock_ns",
                 "t0", "infeasible", "mem_like", "bank_key", "res_mii",
                 "carried_srcs", "occupiers", "touch_storages", "comb")

    def __init__(self, ops: Sequence[Operation], edges: Sequence[DepEdge],
                 latency_of: Callable[[Operation], int],
                 touches_of: Callable[[Operation], list[Touch]],
                 clock_ns: float = CLOCK_NS):
        self.ops = list(ops)
        self.edges = list(edges)
        self.clock_ns = clock_ns
        self.index = {o: i for i, o in enumerate(self.ops)}
        self.lat = {o: latency_of(o) for o in self.ops}
        self.comb = {o: COMB_DELAY.get(o.opname, 0.0) for o in self.ops}
        # horizon scales with total child latency (long-running loop children
        # are legitimately serialized hundreds of cycles apart)
        self.horizon = 4 * sum(max(1, l) for l in self.lat.values()) + 512
        self.out = {o: [] for o in self.ops}
        for e in self.edges:
            if e.src in self.index and e.dst in self.index:
                self.out[e.src].append(e)
        self.carried_srcs = [e.src for e in self.edges
                             if e.distance and e.src in self.index]
        self.mem_like = [o for o in self.ops
                         if o.opname in ("mem_read", "mem_write")]
        self.bank_key = {o: access_bank_key(o) for o in self.mem_like}
        per_bank: dict[tuple, int] = {}
        for o in self.mem_like:
            k = self.bank_key[o]
            per_bank[k] = per_bank.get(k, 0) + 1
        self.res_mii = max(per_bank.values(), default=1)
        # loop/call children and the storages they occupy (sequential-region
        # interval serialization)
        self.occupiers = [o for o in self.ops
                          if isinstance(o, ForOp) or o.opname == "call"]
        self.touch_storages = {o: {tc.storage for tc in touches_of(o)}
                               for o in self.occupiers}
        self.infeasible = False
        self.t0 = self._asap0()

    def _asap0(self) -> dict[Operation, int]:
        """Least fixpoint of the distance-0 constraints via Kahn longest-path
        (program order is topological for distance-0 edges).  Falls back to
        bounded Bellman–Ford if a distance-0 cycle sneaks in (sets
        ``infeasible`` when divergent, matching the old relax() behavior)."""
        t = {o: 0 for o in self.ops}
        ordered = self.ops  # program order; distance-0 edges point forward
        acyclic = all(
            self.index[e.src] < self.index[e.dst]
            for e in self.edges if not e.distance
            if e.src in self.index and e.dst in self.index)
        if acyclic:
            for o in ordered:
                base = t[o]
                for e in self.out[o]:
                    if e.distance:
                        continue
                    lo = base + e.latency
                    if t[e.dst] < lo:
                        t[e.dst] = lo
            if any(v > self.horizon for v in t.values()):
                self.infeasible = True
            return t
        for _ in range(len(self.ops) + 2):  # pragma: no cover - defensive
            changed = False
            for e in self.edges:
                if e.distance:
                    continue
                lo = t[e.src] + e.latency
                if t[e.dst] < lo:
                    t[e.dst] = lo
                    changed = True
                    if lo > self.horizon:
                        self.infeasible = True
                        return t
            if not changed:
                return t
        self.infeasible = True
        return t


def _relax_from(state: SearchState, t: dict[Operation, int], ii: int,
                seeds: Sequence[Operation]) -> bool:
    """Monotone worklist longest-path relaxation: propagate lower-bound
    increases from ``seeds`` until fixpoint.  Equivalent to re-running the
    full Bellman–Ford from the current ``t`` (which is a fixpoint everywhere
    except at the seeds), but only touches the affected cone.  Returns False
    when any bound exceeds the horizon (infeasible at this II)."""
    out = state.out
    horizon = state.horizon
    dq = deque(s for s in seeds if s in out)
    in_dq = set(dq)
    while dq:
        u = dq.popleft()
        in_dq.discard(u)
        tu = t[u]
        for e in out[u]:
            if e.distance and not ii:
                continue  # carried deps inactive outside pipelining
            lo = tu + e.latency - (e.distance * ii if ii else 0)
            if t[e.dst] < lo:
                if lo > horizon:
                    return False
                t[e.dst] = lo
                if e.dst not in in_dq:
                    dq.append(e.dst)
                    in_dq.add(e.dst)
    return True


def try_modulo_schedule(
    ops: list[Operation],
    edges: Sequence[DepEdge],
    ii: int,
    latency_of: Callable[[Operation], int],
    touches_of: Callable[[Operation], list[Touch]],
    state: Optional[SearchState] = None,
) -> Optional[dict[Operation, int]]:
    """Resource-constrained list scheduling at a fixed ``ii`` (0 = no
    pipelining): worklist longest-path relaxation of the dependence
    difference constraints (seeded from the shared distance-0 fixpoint when a
    ``SearchState`` is supplied), operator chaining under the clock budget,
    and a modulo reservation table (one access per congruence class per
    memref port bank).  Returns op -> cycle, or None if infeasible."""
    if state is None:
        state = SearchState(ops, edges, latency_of, touches_of)
    if state.infeasible:
        return None
    horizon = state.horizon
    t = dict(state.t0)
    if ii and state.carried_srcs:
        if not _relax_from(state, t, ii, state.carried_srcs):
            return None

    # operator chaining under the clock budget
    lat = state.lat
    comb = state.comb
    clock_ns = state.clock_ns
    arrival: dict[Operation, float] = {}
    for o in sorted(ops, key=lambda o: t[o]):
        start_ns = 0.0
        for v in o.operands:
            p = v.defining_op
            if p in arrival and t.get(p) == t[o] and lat[p] == 0:
                start_ns = max(start_ns, arrival[p])
        d = comb[o]
        if start_ns + d > clock_ns:
            t[o] += 1
            if not _relax_from(state, t, ii, (o,)):
                return None
            start_ns = 0.0
        arrival[o] = start_ns + d

    # modulo reservation table: one access per congruence class per port
    # *bank* (distinct distributed-dim banks are physically parallel)
    mem_like = state.mem_like
    bank_key = state.bank_key
    if ii and state.res_mii > ii:
        return None  # more same-bank accesses than congruence classes

    index = state.index
    for _sweep in range(16 * len(ops) + 64):
        moved: list[Operation] = []
        # (a) reservation sweep in program order; a conflicting access jumps
        # to the next free congruence class instead of bumping one cycle at
        # a time (each +1 bump used to cost a full relaxation round)
        taken: dict[tuple, set[int]] = {}
        for o in mem_like:
            kk = bank_key[o]
            s = taken.get(kk)
            if s is None:
                s = taken[kk] = set()
            if ii:
                c = t[o]
                cls = c % ii
                if cls in s:
                    c += 1
                    while (c % ii) in s:
                        c += 1
                    if c > horizon:
                        return None
                    t[o] = c
                    moved.append(o)
                    cls = c % ii
                s.add(cls)
            else:
                c = t[o]
                if c in s:
                    c += 1
                    while c in s:
                        c += 1
                    if c > horizon:
                        return None
                    t[o] = c
                    moved.append(o)
                s.add(c)
        # (b) loop/call children occupy their ports for their whole latency:
        # serialize overlapping [t, t+lat) intervals on shared storage (one
        # ordered sweep per storage replaces the old all-pairs scan)
        if not ii and not moved and state.occupiers:
            placed: set[Operation] = set()
            for a in state.occupiers:
                if a in placed:
                    continue
                group = [b for b in state.occupiers
                         if b is a or (state.touch_storages[a]
                                       & state.touch_storages[b])]
                if len(group) < 2:
                    placed.add(a)
                    continue
                group.sort(key=lambda o: (t[o], index[o]))
                end: Optional[int] = None
                for o in group:
                    if end is not None and t[o] < end:
                        if end > horizon:
                            return None
                        t[o] = end
                        moved.append(o)
                    end = t[o] + max(1, lat[o])
                placed.update(group)
        if not moved:
            break
        if not _relax_from(state, t, ii, moved):
            return None
    else:
        return None

    for (u, v, elat, dist) in edges:
        if dist and not ii:
            continue
        if t[v] < t[u] + elat - (dist * ii if ii else 0):
            return None
    return t


def balance_delays(func: FuncOp, am=None) -> int:
    """Pipeline balancing: insert ``hir.delay`` ops so every operand arrives
    exactly at its consumption cycle (the transformation that legalises a
    freshly computed schedule).  Uses the verifier's validity windows
    (windows-only pass — no quadratic legality checks) and inserts every
    violating operand's delay in one batch per sweep; delays never interfere
    with each other's windows, so the sweep converges in a couple of
    iterations instead of one full verification per delay.  ``am`` (an
    AnalysisManager) lets repeated sweeps re-use the cached loop analysis.
    Returns the number of delays inserted."""
    from .verifier import validity_windows

    inserted = 0
    for _ in range(256):
        v = validity_windows(func, am=am)
        # collect every (op, operand index, window) violation in one pass
        to_fix: list[tuple[Operation, int, Value, tuple]] = []
        for op in list(func.body.walk()):
            if op.start is None or op.opname in ("constant", "alloc", "time", "yield", "return"):
                continue
            if isinstance(op, ForOp):
                continue
            for i, val in enumerate(list(op.operands)):
                win = v.windows.get(val)
                if win is None:
                    continue
                tv, off, ln = win
                use_off = op.start.offset
                if tv is op.start.tv and use_off > off and (ln is not None and use_off >= off + ln):
                    to_fix.append((op, i, val, win))
        if not to_fix:
            return inserted
        # batch-splice the delays, rebuilding each touched region once
        by_region: dict[Region, dict[Operation, list[Operation]]] = {}
        for op, i, val, (tv, off, ln) in to_fix:
            d = ir.delay(val, op.start.offset - off, Time(tv, off))
            region = op.parent_region or func.body
            d.parent_region = region
            by_region.setdefault(region, {}).setdefault(op, []).append(d)
            op.operands[i] = d.result
            inserted += 1
        for region, before in by_region.items():
            new_ops: list[Operation] = []
            for op in region.ops:
                new_ops.extend(before.get(op, ()))
                new_ops.append(op)
            region.ops[:] = new_ops
    return inserted
