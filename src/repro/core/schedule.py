"""Shared schedule math: the 200 MHz timing model, SDC-style difference
constraint relaxation, the modulo-reservation scheduling engine, and pipeline
balancing (``hir.delay`` insertion).

Two consumers share this module:

  * the HLS baseline (``core.hls.scheduler``) — the paper's Vivado stand-in,
    which must *search* for a schedule starting from erased IR;
  * the schedule-transform passes (``core.passes.schedule_transforms``) —
    which re-schedule already-legal HIR (pipeline-loop / retime) as ordinary
    IR transformations over the cached analyses, the paper's actual pitch.
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

from . import ir
from .analysis import DepEdge, Touch
from .ir import ForOp, FuncOp, MemrefType, Operation, Time

# 200 MHz timing model: 5 ns budget per cycle, combinational delays in ns
CLOCK_NS = 5.0
COMB_DELAY = {
    "add": 2.0, "sub": 2.0, "mult": 4.5, "div": 8.0,
    "and": 0.5, "or": 0.5, "xor": 0.6, "not": 0.3,
    "shl": 0.2, "shr": 0.2,
    "cmp_lt": 1.6, "cmp_le": 1.6, "cmp_eq": 1.2, "cmp_ne": 1.2,
    "cmp_gt": 1.6, "cmp_ge": 1.6,
    "select": 0.9, "trunc": 0.0, "zext": 0.0, "sext": 0.1,
}
MAX_II = 256


def access_bank_key(op: Operation):
    """(port id, distributed-dim bank selector) of a memory access: two
    accesses with different keys use physically distinct ports/banks and
    never conflict in the modulo reservation table."""
    port = op.operands[0] if op.opname == "mem_read" else op.operands[1]
    mt: MemrefType = port.type  # type: ignore[assignment]
    idx = ir.mem_op_indices(op)
    bank = tuple(
        ir.const_value(idx[d]) if ir.const_value(idx[d]) is not None
        else (idx[d].name if idx[d].defining_op is None else "?")
        for d in mt.distributed
    )
    return port.id, bank


def try_modulo_schedule(
    ops: list[Operation],
    edges: Sequence[DepEdge],
    ii: int,
    latency_of: Callable[[Operation], int],
    touches_of: Callable[[Operation], list[Touch]],
) -> Optional[dict[Operation, int]]:
    """Resource-constrained list scheduling at a fixed ``ii`` (0 = no
    pipelining): Bellman–Ford longest-path relaxation of the dependence
    difference constraints, operator chaining under the clock budget, and a
    modulo reservation table (one access per congruence class per memref
    port bank).  Returns op -> cycle, or None if infeasible."""
    t = {o: 0 for o in ops}
    # horizon scales with total child latency (long-running loop children
    # are legitimately serialized hundreds of cycles apart)
    horizon = 4 * sum(max(1, latency_of(o)) for o in ops) + 512

    def relax() -> bool:
        for _ in range(len(ops) + 2):
            changed = False
            for (u, v, lat, dist) in edges:
                lo = t[u] + lat - (dist * ii if ii else 0)
                if dist and not ii:
                    continue  # carried deps inactive outside pipelining
                if t[v] < lo:
                    t[v] = lo
                    changed = True
                    if t[v] > horizon:
                        return False
            if not changed:
                return True
        return False

    if not relax():
        return None

    # operator chaining under the clock budget
    arrival: dict[Operation, float] = {}
    for o in sorted(ops, key=lambda o: t[o]):
        start_ns = 0.0
        for v in o.operands:
            p = v.defining_op
            if p in arrival and t.get(p) == t[o] and latency_of(p) == 0:
                start_ns = max(start_ns, arrival[p])
        d = COMB_DELAY.get(o.opname, 0.0)
        if start_ns + d > CLOCK_NS:
            t[o] += 1
            if not relax():
                return None
            start_ns = 0.0
        arrival[o] = start_ns + d

    # modulo reservation table: one access per congruence class per port
    # *bank* (distinct distributed-dim banks are physically parallel)
    mem_like = [o for o in ops if o.opname in ("mem_read", "mem_write")]

    for _attempt in range(16 * len(ops) + 64):
        mrt: dict[tuple, Operation] = {}
        conflict = None
        for o in mem_like:
            pid, bank = access_bank_key(o)
            cls = (t[o] % ii) if ii else t[o]
            key = (pid, bank, cls)
            if key in mrt and mrt[key] is not o:
                conflict = o
                break
            mrt[key] = o
        # loop children occupy their ports for their whole latency: treat
        # any overlap of [t, t+lat) ranges on shared storage as conflicts
        bump_to = None
        if conflict is None and not ii:
            loops_ = [o for o in ops if isinstance(o, ForOp) or o.opname == "call"]
            for i in range(len(loops_)):
                for j in range(len(loops_)):
                    if i == j:
                        continue
                    a, b = loops_[i], loops_[j]
                    sa = {tc.storage for tc in touches_of(a)}
                    sb = {tc.storage for tc in touches_of(b)}
                    if not (sa & sb):
                        continue
                    a0, a1 = t[a], t[a] + max(1, latency_of(a))
                    b0 = t[b]
                    if a0 <= b0 < a1:
                        conflict, bump_to = b, a1  # push past the occupant
                        break
                if conflict is not None:
                    break
        if conflict is None:
            break
        t[conflict] = bump_to if bump_to is not None else t[conflict] + 1
        if not relax():
            return None
        if max(t.values(), default=0) > horizon:
            return None
    else:
        return None

    for (u, v, lat, dist) in edges:
        if dist and not ii:
            continue
        if t[v] < t[u] + lat - (dist * ii if ii else 0):
            return None
    return t


def balance_delays(func: FuncOp, am=None) -> int:
    """Pipeline balancing: insert ``hir.delay`` ops so every operand arrives
    exactly at its consumption cycle (the transformation that legalises a
    freshly computed schedule).  Uses the verifier's validity windows;
    ``am`` (an AnalysisManager) lets the repeated verification re-use the
    cached loop analysis across fixpoint iterations.  Returns the number of
    delays inserted."""
    from .verifier import Verifier

    inserted = 0
    for _ in range(256):
        v = Verifier(func, strict_schedule=False, am=am)
        v.run()
        fixed = False
        for op in list(func.body.walk()):
            if op.start is None or op.opname in ("constant", "alloc", "time", "yield", "return"):
                continue
            if isinstance(op, ForOp):
                continue
            for i, val in enumerate(list(op.operands)):
                win = v.windows.get(val)
                if win is None:
                    continue
                tv, off, ln = win
                use_off = op.start.offset
                if tv is op.start.tv and use_off > off and (ln is not None and use_off >= off + ln):
                    d = ir.delay(val, use_off - off, Time(tv, off))
                    region = op.parent_region or func.body
                    try:
                        pos = region.ops.index(op)
                    except ValueError:
                        continue
                    region.ops.insert(pos, d)
                    d.parent_region = region
                    op.operands[i] = d.result
                    inserted += 1
                    fixed = True
            if fixed:
                break
        if not fixed:
            return inserted
    return inserted
