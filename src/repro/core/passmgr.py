"""MLIR-style pass manager: ``Pass`` base classes, a registry addressable by
textual pipeline specs, and a ``PassManager`` with per-pass statistics.

The paper's headline codegen-speed result comes from HIR being a *thin,
composable* MLIR pass pipeline instead of a monolithic search; this module
gives the reproduction the same shape:

  * ``Pass``               — unit of transformation, ``run(module) -> int``
                             (number of rewrites applied);
  * ``PatternRewritePass`` — a pass defined as a ``RewritePatternSet``
                             applied by the greedy worklist driver
                             (``core.rewrite``), one driver run per function;
  * ``register_pass``      — adds a pass class to the global registry under
                             its spec name (e.g. ``strength-reduce``);
  * ``PassManager``        — runs an ordered pipeline (optionally iterated to
                             a fixpoint), records per-pass wall time and
                             rewrite counts, and optionally verifies the IR
                             between passes;
  * ``PassManager.from_spec("canonicalize,cse,strength-reduce")`` — builds a
                             pipeline from a declarative textual spec, the
                             form benchmarks and examples use.

Spec names accept ``-`` or ``_`` interchangeably; unknown names raise
``ValueError`` listing the registered passes.

The module also hosts the analysis layer (MLIR's AnalysisManager):

  * ``FunctionAnalysis``   — a named, construct-on-demand per-function
                             analysis (``run(func, am) -> result``);
  * ``register_analysis``  — adds an analysis class to the global registry;
  * ``AnalysisManager``    — caches analysis results per (func, analysis)
                             with hit/miss statistics; passes declare which
                             analyses they *preserve* (``Pass.preserves`` /
                             ``preserves_all``) and the PassManager
                             invalidates everything else after a pass that
                             rewrote the module.  A pass reporting 0 rewrites
                             preserves all analyses implicitly.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Type, Union

from .ir import FuncOp, Module
from .rewrite import RewritePatternSet, apply_patterns_greedily

# ---------------------------------------------------------------------------
# Analyses: registry + AnalysisManager
# ---------------------------------------------------------------------------


class FunctionAnalysis:
    """A named per-function analysis.  Subclasses set ``name`` and implement
    ``run(func, am)``; ``am`` lets an analysis pull other cached analyses
    (e.g. the dependence graph consumes loop info and memory touches)."""

    name: str = ""

    @staticmethod
    def run(func: FuncOp, am: "AnalysisManager") -> Any:
        raise NotImplementedError


ANALYSIS_REGISTRY: dict[str, Type[FunctionAnalysis]] = {}


def register_analysis(cls: Type[FunctionAnalysis]) -> Type[FunctionAnalysis]:
    """Class decorator: adds ``cls`` to the analysis registry under its
    ``name``."""
    assert cls.name, f"{cls} needs an analysis name"
    ANALYSIS_REGISTRY[cls.name] = cls
    return cls


def _ensure_analyses_registered() -> None:
    # built-in analyses live in core.analysis; import lazily (cycle-free).
    if "loop-info" not in ANALYSIS_REGISTRY:
        from . import analysis  # noqa: F401


@dataclass
class AnalysisStatistics:
    """Per-analysis cache counters."""

    name: str
    computed: int = 0
    hits: int = 0
    invalidated: int = 0

    def as_dict(self) -> dict:
        return {"computed": self.computed, "hits": self.hits,
                "invalidated": self.invalidated}


class AnalysisManager:
    """Construct-on-demand, per-function analysis cache with explicit
    invalidation (the MLIR AnalysisManager shape).

    ``get(analysis, func)``   returns the cached result or computes it;
    ``invalidate(...)``       drops cached entries, keeping only the analyses
                              named in ``preserve`` (or everything when
                              ``preserve_all``);
    ``stats`` / ``stats_dict()``  cache hit/miss/invalidation counters, the
                              numbers ``benchmarks/codegen_speed.py`` reports.
    """

    def __init__(self):
        self._cache: dict[tuple[int, str], Any] = {}
        self._funcs: dict[int, FuncOp] = {}  # keep keys meaningful for func=
        self.stats: dict[str, AnalysisStatistics] = {}

    @staticmethod
    def _resolve(analysis: Union[str, Type[FunctionAnalysis]]) -> Type[FunctionAnalysis]:
        if isinstance(analysis, str):
            _ensure_analyses_registered()
            if analysis not in ANALYSIS_REGISTRY:
                known = ", ".join(sorted(ANALYSIS_REGISTRY))
                raise ValueError(f"unknown analysis {analysis!r} (registered: {known})")
            return ANALYSIS_REGISTRY[analysis]
        return analysis

    def _stat(self, name: str) -> AnalysisStatistics:
        st = self.stats.get(name)
        if st is None:
            st = self.stats[name] = AnalysisStatistics(name)
        return st

    # -- queries ------------------------------------------------------------
    def get(self, analysis: Union[str, Type[FunctionAnalysis]], func: FuncOp) -> Any:
        cls = self._resolve(analysis)
        key = (id(func), cls.name)
        st = self._stat(cls.name)
        if key in self._cache:
            st.hits += 1
            return self._cache[key]
        result = cls.run(func, self)
        st.computed += 1
        self._cache[key] = result
        self._funcs[id(func)] = func
        return result

    def cached(self, analysis: Union[str, Type[FunctionAnalysis]], func: FuncOp) -> Optional[Any]:
        """The cached result if present (no computation, no hit counted)."""
        cls = self._resolve(analysis)
        return self._cache.get((id(func), cls.name))

    # -- invalidation --------------------------------------------------------
    def invalidate(self, func: Optional[FuncOp] = None,
                   preserve: Sequence[str] = (), preserve_all: bool = False) -> int:
        """Drop cached analyses (all funcs, or just ``func``), keeping those
        named in ``preserve``.  Returns the number of dropped entries."""
        if preserve_all:
            return 0
        keep = set(preserve)
        dropped = 0
        for key in list(self._cache):
            fid, name = key
            if func is not None and fid != id(func):
                continue
            if name in keep:
                continue
            del self._cache[key]
            self._stat(name).invalidated += 1
            dropped += 1
        # release func pins with no remaining cached results (the pin only
        # exists to keep id() stable while a result is cached)
        live = {fid for (fid, _name) in self._cache}
        for fid in list(self._funcs):
            if fid not in live:
                del self._funcs[fid]
        return dropped

    # -- reporting ----------------------------------------------------------
    def stats_dict(self) -> dict:
        """JSON-able counters: per-analysis computed/hits/invalidated plus
        totals (``hits`` > 0 means at least one analysis was reused)."""
        per = {name: st.as_dict() for name, st in sorted(self.stats.items())}
        return {
            "per_analysis": per,
            "computed": sum(st.computed for st in self.stats.values()),
            "hits": sum(st.hits for st in self.stats.values()),
            "invalidated": sum(st.invalidated for st in self.stats.values()),
        }


# ---------------------------------------------------------------------------
# Pass base classes
# ---------------------------------------------------------------------------


class Pass:
    """Base class for all passes.  ``name`` is the spec name; ``run`` applies
    the pass to a module and returns the number of rewrites performed.

    ``preserves`` names the analyses whose cached results remain valid even
    when this pass rewrites the IR (e.g. a pass that never moves schedules
    preserves ``"loop-info"``); ``preserves_all`` marks passes that cannot
    invalidate anything (attribute-only rewrites).  A pass that reports 0
    rewrites implicitly preserves everything.  The PassManager injects its
    ``AnalysisManager`` as ``self.am`` before each run; passes fetch cached
    analyses through ``self.get_analysis``."""

    name: str = ""
    preserves: tuple[str, ...] = ()
    preserves_all: bool = False
    am: Optional[AnalysisManager] = None

    def run(self, module: Module) -> int:
        raise NotImplementedError

    def get_analysis(self, analysis: Union[str, Type[FunctionAnalysis]], func: FuncOp) -> Any:
        """Cached analysis lookup; standalone pass instances (run outside a
        PassManager) get a private AnalysisManager on first use."""
        if self.am is None:
            self.am = AnalysisManager()
        return self.am.get(analysis, func)

    # convenience shared by subclasses
    @staticmethod
    def each_func(module: Module):
        for f in module.funcs.values():
            if not f.attrs.get("external"):
                yield f


class PatternRewritePass(Pass):
    """A pass expressed as rewrite patterns, driven by the greedy worklist
    rewriter over each function body.  Subclasses implement ``patterns``
    (optionally per-function, for patterns that need function-level context
    such as the set of loop induction variables)."""

    def patterns(self, func: FuncOp) -> RewritePatternSet:
        raise NotImplementedError

    def run(self, module: Module) -> int:
        n = 0
        for f in self.each_func(module):
            n += apply_patterns_greedily(f.body, self.patterns(f))
        return n


class ModuleFnPass(Pass):
    """Adapter wrapping a legacy ``Callable[[Module], int]`` as a Pass."""

    def __init__(self, fn: Callable[[Module], int], name: Optional[str] = None):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "anonymous")

    def run(self, module: Module) -> int:
        return self.fn(module)


# ---------------------------------------------------------------------------
# Registry + textual pipeline specs
# ---------------------------------------------------------------------------

PASS_REGISTRY: dict[str, Type[Pass]] = {}


def _canon(name: str) -> str:
    return name.strip().replace("_", "-")


def register_pass(cls: Type[Pass]) -> Type[Pass]:
    """Class decorator: adds ``cls`` to the registry under ``cls.name``."""
    assert cls.name, f"{cls} needs a spec name"
    PASS_REGISTRY[_canon(cls.name)] = cls
    return cls


def _ensure_registry_populated() -> None:
    # Pass classes live next to their implementations; importing the passes
    # package registers all of them (lazy to avoid an import cycle).  Keyed
    # on a known HIR pass, not registry emptiness: the RTL passes register
    # themselves when core.codegen.rtl is imported first, and a non-empty
    # registry must not mask the still-unloaded HIR passes.
    if "canonicalize" not in PASS_REGISTRY:
        from . import passes  # noqa: F401


def create_pass(name: str) -> Pass:
    """Instantiate a registered pass by spec name."""
    _ensure_registry_populated()
    key = _canon(name)
    if key not in PASS_REGISTRY:
        known = ", ".join(sorted(PASS_REGISTRY))
        raise ValueError(f"unknown pass {name!r} in pipeline spec (registered: {known})")
    return PASS_REGISTRY[key]()


def parse_pipeline_spec(spec: str) -> list[Pass]:
    """Parse ``"canonicalize,cse,strength-reduce"`` into pass instances.
    Empty segments are rejected; unknown names raise ``ValueError``."""
    names = [s.strip() for s in spec.split(",")]
    if any(not s for s in names) or not names:
        raise ValueError(f"malformed pipeline spec {spec!r}")
    return [create_pass(n) for n in names]


# The default optimization pipeline (paper-benchmark order; matches the
# seed's DEFAULT_PIPELINE).
DEFAULT_PIPELINE_SPEC = ("canonicalize,constprop,cse,strength-reduce,"
                         "precision-opt,delay-elim,port-demotion,dce")

# The pre-codegen lowering pipeline: hierarchy flattening + unroll expansion.
CODEGEN_PIPELINE_SPEC = "inline,unroll"

# The schedule-transform pipeline: pipeline sequential loops to their minimum
# legal II, shrink the combinational chains (strength-reduce before retime:
# const-mults become cheap shifts, so delay hoists fit the clock budget),
# retime the delay chains, then clean up.
SCHEDULE_PIPELINE_SPEC = "pipeline-loop,strength-reduce,canonicalize,retime,cse"


# ---------------------------------------------------------------------------
# PassManager
# ---------------------------------------------------------------------------


@dataclass
class PassStatistics:
    """Per-pass counters accumulated across a PassManager run."""

    name: str
    invocations: int = 0
    rewrites: int = 0
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return {"invocations": self.invocations, "rewrites": self.rewrites,
                "wall_s": round(self.wall_s, 6)}


class PassManager:
    """Runs an ordered pass pipeline over a module.

    ``fixpoint``        re-run the whole pipeline until no pass reports a
                        rewrite (bounded by ``max_iterations``) — pattern
                        passes converge internally, but one pass can unlock
                        another (constprop feeding cse), so a short outer
                        loop remains useful;
    ``verify_each``     run the IR verifier after every pass and raise on
                        the first error (debugging aid);
    ``statistics``      list of ``PassStatistics``, one per pipeline entry,
                        filled by ``run``;
    ``analysis_manager``  the shared ``AnalysisManager`` injected into every
                        pass (``self.am``) and invalidated per the pass's
                        ``preserves`` declaration after each rewriting run.
                        Pass one in to share cached analyses with the
                        verifier and codegen; a fresh one is created
                        otherwise.
    """

    def __init__(self, passes: Sequence[Union[Pass, str, Callable[[Module], int]]] = (),
                 *, fixpoint: bool = True, max_iterations: int = 3,
                 verify_each: bool = False,
                 analysis_manager: Optional[AnalysisManager] = None):
        self.passes: list[Pass] = [self._as_pass(p) for p in passes]
        self.fixpoint = fixpoint
        self.max_iterations = max_iterations
        self.verify_each = verify_each
        self.analysis_manager = analysis_manager or AnalysisManager()
        self.statistics: list[PassStatistics] = []
        self.iterations_run = 0

    # -- construction -------------------------------------------------------
    @staticmethod
    def _as_pass(p: Union[Pass, str, Callable[[Module], int]]) -> Pass:
        if isinstance(p, Pass):
            return p
        if isinstance(p, str):
            return create_pass(p)
        if callable(p):
            return ModuleFnPass(p)
        raise TypeError(f"not a pass: {p!r}")

    def add(self, p: Union[Pass, str, Callable[[Module], int]]) -> "PassManager":
        self.passes.append(self._as_pass(p))
        return self

    @classmethod
    def from_spec(cls, spec: str, **kwargs) -> "PassManager":
        return cls(parse_pipeline_spec(spec), **kwargs)

    @property
    def spec(self) -> str:
        return ",".join(p.name for p in self.passes)

    # -- running ------------------------------------------------------------
    def run(self, module: Module) -> dict[str, int]:
        """Run the pipeline.  Returns ``{pass_name: rewrites}`` with
        underscored names (the shape the seed's ``run_pipeline`` returned);
        full statistics (timing, invocations) are on ``self.statistics``."""
        self.statistics = [PassStatistics(p.name) for p in self.passes]
        self.iterations_run = 0
        iters = self.max_iterations if self.fixpoint else 1
        # clean-pass skipping: a pass that reported 0 rewrites is a
        # deterministic no-op until some other pass rewrites the module, so
        # re-running it in a later fixpoint iteration is pure waste.
        total = 0                       # module version: rewrites so far
        seen_at: dict[int, int] = {}    # pass idx -> version after last run
        last_n: dict[int, int] = {}     # pass idx -> rewrites of last run
        for _ in range(max(1, iters)):
            self.iterations_run += 1
            changed = 0
            for i, (p, st) in enumerate(zip(self.passes, self.statistics)):
                if seen_at.get(i) == total and last_n.get(i) == 0:
                    continue  # clean and module untouched since: skip
                p.am = self.analysis_manager
                t0 = time.perf_counter()
                n = p.run(module)
                st.wall_s += time.perf_counter() - t0
                st.invocations += 1
                st.rewrites += n
                total += n
                seen_at[i], last_n[i] = total, n
                changed += n
                if n:  # 0 rewrites preserves every cached analysis
                    self.analysis_manager.invalidate(
                        preserve=p.preserves, preserve_all=p.preserves_all)
                if self.verify_each:
                    self._verify(module, after=p.name)
            if changed == 0:
                break
        out: dict[str, int] = {}
        for st in self.statistics:
            key = st.name.replace("-", "_")
            out[key] = out.get(key, 0) + st.rewrites
        return out

    def _verify(self, module: Module, after: str) -> None:
        from .verifier import verify

        diags = verify(module, strict_schedule=False, raise_on_error=False,
                       am=self.analysis_manager)
        errs = [d for d in diags if d.severity == "error"]
        if errs:
            msgs = "\n".join(d.render() for d in errs)
            raise RuntimeError(f"verifier failed after pass '{after}':\n{msgs}")

    # -- reporting ----------------------------------------------------------
    def stats_dict(self) -> dict[str, dict]:
        """JSON-able per-pass statistics of the last ``run``."""
        out: dict[str, dict] = {}
        for st in self.statistics:
            if st.name in out:  # same pass listed twice in one pipeline
                prev = out[st.name]
                prev["invocations"] += st.invocations
                prev["rewrites"] += st.rewrites
                prev["wall_s"] = round(prev["wall_s"] + st.wall_s, 6)
            else:
                out[st.name] = st.as_dict()
        return out

    def render_stats(self) -> str:
        """Human-readable per-pass statistics table."""
        lines = [f"{'pass':18s} {'runs':>5s} {'rewrites':>9s} {'wall(ms)':>9s}"]
        for st in self.statistics:
            lines.append(f"{st.name:18s} {st.invocations:5d} {st.rewrites:9d} "
                         f"{st.wall_s * 1e3:9.2f}")
        return "\n".join(lines)
