"""Pattern-based IR rewriting: ``RewritePattern`` + greedy worklist driver.

This is the MLIR ``applyPatternsAndFoldGreedily`` shape of the optimizer:
each pattern is a local match-and-rewrite anchored on op names, and the
driver keeps a worklist seeded with every op in the region.  All mutation
goes through the ``PatternRewriter``, which both keeps the use-def chains of
``core.ir`` consistent and tells the driver exactly which ops to revisit —
only the ops whose operands changed (plus newly created ops), never a blind
re-walk of the whole region.  Combined with O(#uses) RAUW this replaces the
seed's O(region²) fixpoint sweep.

Erasure is lazy: erased ops are unlinked from the chains immediately and
compacted out of the region op-lists once, when the driver finishes.
"""

from __future__ import annotations

from collections import deque
from typing import Iterable, Optional, Sequence

from . import ir
from .ir import Operation, Region, Value


class RewritePattern:
    """A local rewrite.  Subclasses set ``ops`` to the anchor op names they
    match (``None`` matches every op) and implement ``match_and_rewrite``,
    returning True iff the IR was changed.  All mutation must go through the
    supplied ``PatternRewriter`` so the driver can track what to revisit.

    ``benefit`` orders patterns tried on the same op (higher first)."""

    ops: Optional[tuple[str, ...]] = None
    benefit: int = 1

    def match_and_rewrite(self, op: Operation, rewriter: "PatternRewriter") -> bool:
        raise NotImplementedError

    @property
    def name(self) -> str:
        return type(self).__name__


class RewritePatternSet:
    """A collection of patterns indexed by anchor op name."""

    def __init__(self, patterns: Iterable[RewritePattern] = ()):
        self._by_op: dict[str, list[RewritePattern]] = {}
        self._generic: list[RewritePattern] = []
        self._all: list[RewritePattern] = []
        for p in patterns:
            self.add(p)

    def add(self, pattern: RewritePattern) -> "RewritePatternSet":
        self._all.append(pattern)
        if pattern.ops is None:
            self._generic.append(pattern)
            self._generic.sort(key=lambda p: -p.benefit)
            for lst in self._by_op.values():
                lst.append(pattern)
                lst.sort(key=lambda p: -p.benefit)
        else:
            for name in pattern.ops:
                lst = self._by_op.setdefault(name, list(self._generic))
                lst.append(pattern)
                lst.sort(key=lambda p: -p.benefit)
        return self

    def get(self, opname: str) -> list[RewritePattern]:
        lst = self._by_op.get(opname)
        return lst if lst is not None else self._generic

    def __len__(self) -> int:
        return len(self._all)


class PatternRewriter:
    """The mutation facade handed to patterns.  Every edit updates use-def
    chains (via the ``core.ir`` APIs) and enqueues exactly the ops affected
    by the edit."""

    def __init__(self, driver: "_GreedyDriver"):
        self._driver = driver

    # -- insertion ----------------------------------------------------------
    def insert_before(self, anchor: Operation, op: Operation) -> Operation:
        region = anchor.parent_region
        assert region is not None, "anchor is detached"
        region.insert_before(anchor, op)
        self._driver.enqueue(op)
        return op

    def insert_after(self, anchor: Operation, op: Operation) -> Operation:
        region = anchor.parent_region
        assert region is not None, "anchor is detached"
        region.insert(region.ops.index(anchor) + 1, op)
        self._driver.enqueue(op)
        return op

    def insert_at_start(self, region: Region, op: Operation) -> Operation:
        region.insert(0, op)
        self._driver.enqueue(op)
        return op

    # -- operand mutation ---------------------------------------------------
    def set_operand(self, op: Operation, i: int, v: Value) -> None:
        op.set_operand(i, v)
        self._driver.enqueue(op)

    def set_operands(self, op: Operation, vs: Sequence[Value]) -> None:
        op.operands[:] = list(vs)
        self._driver.enqueue(op)

    def replace_all_uses_with(self, old: Value, new: Value) -> int:
        for user in old.users():
            self._driver.enqueue(user)
        return old.replace_all_uses_with(new)

    # -- replacement / erasure ---------------------------------------------
    def replace_op(self, op: Operation, new_values: Sequence[Value]) -> None:
        """Replace ``op``'s results with ``new_values`` and erase it."""
        assert len(new_values) == len(op.results), (op, new_values)
        for r, nv in zip(op.results, new_values):
            self.replace_all_uses_with(r, nv)
        self.erase_op(op)

    def erase_op(self, op: Operation) -> None:
        """Erase ``op`` lazily: chains update now, the region op-list is
        compacted when the driver finishes."""
        op.drop_all_uses()
        self._driver.notify_erased(op)

    # -- in-place notification ---------------------------------------------
    def notify_modified(self, op: Operation) -> None:
        """Pattern mutated ``op`` in place (opname/attrs): revisit it and
        its users."""
        self._driver.enqueue(op)
        for r in op.results:
            for user in r.users():
                self._driver.enqueue(user)


class _GreedyDriver:
    def __init__(self, region: Region, patterns: RewritePatternSet,
                 max_rewrites: Optional[int] = None):
        self.region = region
        self.patterns = patterns
        self.max_rewrites = max_rewrites
        self.worklist: deque[Operation] = deque()
        self.in_list: set[Operation] = set()
        self.num_rewrites = 0
        self.any_erased = False

    def enqueue(self, op: Operation) -> None:
        # ops whose opname no pattern anchors on can never match: skip them
        # entirely — the driver's constant cost scales with candidate ops,
        # not region size
        if (op is not None and not op.is_erased and op not in self.in_list
                and self.patterns.get(op.opname)):
            self.worklist.append(op)
            self.in_list.add(op)

    def notify_erased(self, op: Operation) -> None:
        self.any_erased = True
        self.in_list.discard(op)

    def run(self) -> int:
        rewriter = PatternRewriter(self)
        get_patterns = self.patterns.get
        seed = [op for op in self.region.walk() if get_patterns(op.opname)]
        self.worklist.extend(seed)
        self.in_list.update(seed)
        worklist, in_list = self.worklist, self.in_list
        while worklist:
            op = worklist.popleft()
            in_list.discard(op)
            if op._dead:
                continue
            for pattern in get_patterns(op.opname):
                if pattern.match_and_rewrite(op, rewriter):
                    self.num_rewrites += 1
                    if (self.max_rewrites is not None
                            and self.num_rewrites >= self.max_rewrites):
                        self._compact(self.region)
                        return self.num_rewrites
                    # re-examine the op itself (unless erased): another
                    # pattern — or the same one again — may now apply
                    self.enqueue(op)
                    break
        if self.any_erased:
            self._compact(self.region)
        return self.num_rewrites

    def _compact(self, region: Region) -> None:
        if any(op.is_erased for op in region.ops):
            region.ops[:] = [op for op in region.ops if not op.is_erased]
        for op in region.ops:
            for r in op.regions:
                self._compact(r)


def apply_patterns_greedily(region: Region, patterns: RewritePatternSet,
                            max_rewrites: Optional[int] = None) -> int:
    """Greedily apply ``patterns`` over ``region`` (recursively) until no
    pattern matches.  Returns the number of rewrites applied."""
    return _GreedyDriver(region, patterns, max_rewrites).run()
