"""Textual printer for HIR (paper §4: round-trippable, human readable form).

The printed syntax follows the paper's listings, e.g.::

    hir.func @transpose at %t (%Ai : !hir.memref<16*16*i32, r>, ...) {
      %c0 = hir.constant 0 : !hir.const
      %tf = hir.for %i : i32 = %c0 to %c16 step %c1 iter_time(%ti = %t offset 1) {
        %v = hir.mem_read %Ai[%i, %j] at %tj : i32
        hir.mem_write %v to %Co[%j1, %i] at %tj offset 1
        hir.yield at %tj offset 1
      }
      hir.return
    }

``core.parser.parse`` reads this form back; round-tripping is property-tested.
"""

from __future__ import annotations

from typing import Optional

from . import ir
from .ir import FuncOp, Module, Operation, Region, Time, Value


class _Namer:
    def __init__(self):
        self.names: dict[Value, str] = {}
        self.used: set[str] = set()

    def name(self, v: Value) -> str:
        if v in self.names:
            return self.names[v]
        base = v.name or f"v{v.id}"
        nm, k = base, 0
        while nm in self.used:
            k += 1
            nm = f"{base}_{k}"
        self.used.add(nm)
        self.names[v] = nm
        return nm

    def ref(self, v: Value) -> str:
        return "%" + self.name(v)


def _time_str(n: _Namer, t: Optional[Time]) -> str:
    if t is None:
        return ""
    s = f" at {n.ref(t.tv)}"
    if t.offset:
        s += f" offset {t.offset}"
    return s


def print_op(op: Operation, n: _Namer, indent: int = 0) -> str:
    pad = "  " * indent
    rs = ", ".join(n.ref(r) for r in op.results)
    eq = f"{rs} = " if rs else ""
    o = op.opname

    if o == "constant":
        return f"{pad}{eq}hir.constant {op.attrs['value']} : {op.result.type}"

    if o == "alloc":
        types = ", ".join(str(r.type) for r in op.results)
        return f"{pad}{eq}hir.alloc() : {types}"

    if o == "mem_read":
        mem, idx = op.operands[0], op.operands[1:]
        ix = ", ".join(n.ref(i) for i in idx)
        return f"{pad}{eq}hir.mem_read {n.ref(mem)}[{ix}]{_time_str(n, op.start)} : {op.result.type}"

    if o == "mem_write":
        val, mem, idx, pred = ir.mem_write_parts(op)
        ix = ", ".join(n.ref(i) for i in idx)
        pr = f" if {n.ref(pred)}" if pred is not None else ""
        return f"{pad}hir.mem_write {n.ref(val)} to {n.ref(mem)}[{ix}]{pr}{_time_str(n, op.start)}"

    if o == "delay":
        return (
            f"{pad}{eq}hir.delay {n.ref(op.operands[0])} by {op.attrs['by']}"
            f"{_time_str(n, op.start)} : {op.result.type}"
        )

    if o == "time":
        s = f"{pad}{eq}hir.time {n.ref(op.operands[0])}"
        if op.attrs.get("offset"):
            s += f" offset {op.attrs['offset']}"
        return s

    if o in ("for", "unroll_for"):
        f: ir.ForOp = op  # type: ignore[assignment]
        iv, tv = f.iv, f.time_var
        # unscheduled loops (erased IR) have no start: round-trippable form
        it = (f"{n.ref(tv)} = {n.ref(f.start.tv)} offset "
              f"{f.start.offset + f.attrs.get('iter_arg_offset', 0)}"
              if f.start is not None else f"{n.ref(tv)} unscheduled")
        hdr = (
            f"{pad}{eq}hir.{o} {n.ref(iv)} : {iv.type} = {n.ref(f.lb)} to {n.ref(f.ub)} "
            f"step {n.ref(f.step)} iter_time({it})"
        )
        body = "\n".join(print_op(x, n, indent + 1) for x in f.region(0).ops)
        return f"{hdr} {{\n{body}\n{pad}}}"

    if o == "yield":
        return f"{pad}hir.yield{_time_str(n, op.start)}"

    if o == "return":
        vals = ", ".join(n.ref(v) for v in op.operands)
        return f"{pad}hir.return {vals}".rstrip()

    if o == "call":
        args = ", ".join(n.ref(v) for v in op.operands)
        outs = ", ".join(
            f"{r.type} delay {d}" for r, d in zip(op.results, op.attrs["result_delays"])
        )
        sig = f" : ({outs})" if outs else ""
        return f"{pad}{eq}hir.call @{op.attrs['callee']}({args}){_time_str(n, op.start)}{sig}"

    if o in ir.ARITH_OPS:
        args = ", ".join(n.ref(v) for v in op.operands)
        st = f" stages {op.attrs['stages']}" if op.attrs.get("stages") else ""
        return f"{pad}{eq}hir.{o}({args}){st}{_time_str(n, op.start)} : {op.result.type}"

    raise NotImplementedError(f"printer: unknown op {o}")  # pragma: no cover


def print_func(f: FuncOp, indent: int = 0, namer: Optional[_Namer] = None) -> str:
    """Print one function.  ``namer`` lets callers substitute a different
    naming policy — e.g. the structural (positional) namer the HLS search
    cache uses for build-independent function fingerprints."""
    n = namer if namer is not None else _Namer()
    pad = "  " * indent
    tv = n.ref(f.time_var)
    args = []
    for a, d in zip(f.args, f.attrs["arg_delays"]):
        s = f"{n.ref(a)} : {a.type}"
        if ir.is_primitive(a.type) and d:
            s += f" delay {d}"
        args.append(s)
    outs = ", ".join(
        f"{t} delay {d}" for t, d in zip(f.attrs["result_types"], f.attrs["result_delays"])
    )
    sig = f" -> ({outs})" if outs else ""
    ext = "external " if f.attrs.get("external") else ""
    hdr = f"{pad}hir.func {ext}@{f.name} at {tv} ({', '.join(args)}){sig}"
    if f.attrs.get("external"):
        return hdr
    body = "\n".join(print_op(op, n, indent + 1) for op in f.body.ops)
    return f"{hdr} {{\n{body}\n{pad}}}"


def print_module(m: Module) -> str:
    funcs = "\n\n".join(print_func(f, 1) for f in m.funcs.values())
    return f"hir.module @{m.name} {{\n{funcs}\n}}\n"
