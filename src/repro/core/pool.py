"""Shared process-pool mapper with graceful serial fallback.

Every parallel path in the compiler (per-function scheduling, per-candidate
DSE evaluation, per-module backend emission) funnels through
:func:`pool_map`: the worker function must be a top-level callable (the pool
pickles it by reference) and the payloads must be picklable — in practice,
printed IR text plus plain config objects, never live RTL trees (whose
interned expression keys are process-local, see PR 5).

When no pool can be created — sandboxes without ``/dev/shm`` semaphores, a
missing ``multiprocessing`` start method, restricted CI runners — the mapper
returns ``None`` after emitting a :class:`RuntimeWarning`, and the caller
runs its serial path, which by contract produces the identical result."""

from __future__ import annotations

import pickle
import warnings
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Callable, Optional, Sequence

#: failures that mean "no usable pool here", not "the work itself is broken":
#: semaphore/fork denials (OSError/PermissionError), missing start methods
#: (RuntimeError), workers dying (BrokenProcessPool), unpicklable payloads
#: (PicklingError).  Anything else — including MemoryError and the worker
#: function's own exceptions — propagates to the caller.
POOL_FALLBACK_ERRORS = (OSError, RuntimeError, BrokenProcessPool,
                        pickle.PicklingError)


def pool_map(fn: Callable, payloads: Sequence, max_workers: int, *,
             label: str = "work") -> Optional[list]:
    """Map ``fn`` over ``payloads`` on a ``ProcessPoolExecutor``.

    Returns the result list in payload order, or ``None`` when the pool is
    unavailable (or pointless: one worker / one payload) — the caller then
    falls back to serial execution.  Only pool-infrastructure failures
    (:data:`POOL_FALLBACK_ERRORS`) degrade to the serial path; a genuine
    error raised by ``fn`` is re-raised so bugs are not retried serially."""
    if max_workers <= 1 or len(payloads) <= 1:
        return None
    try:
        with ProcessPoolExecutor(max_workers=max_workers) as ex:
            return list(ex.map(fn, payloads))
    except POOL_FALLBACK_ERRORS as e:
        warnings.warn(
            f"process pool unavailable for {label} "
            f"({type(e).__name__}: {e}); falling back to serial execution",
            RuntimeWarning, stacklevel=2)
        return None
