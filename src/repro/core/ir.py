"""HIR core IR: SSA values, time variables, operations, regions, functions.

This module reproduces the HIR dialect of Majumder & Bondhugula (2021) as an
in-Python MLIR-style IR.  The three orthogonal components of a hardware design
(paper §4) map to:

  * algorithm  -> the SSA dataflow graph (ops + values),
  * schedule   -> every op carries a ``Time`` (time-variable + constant offset),
  * binding    -> memref kinds (``reg``/``lutram``/``bram``) and banking
                  (packed vs. distributed dims).

Nothing here depends on JAX; lowering lives in ``core.lower``.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator, NamedTuple, Optional, Sequence, Union

# --------------------------------------------------------------------------
# Source locations (used by the verifier for paper-style diagnostics)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Loc:
    file: str = "<unknown>"
    line: int = 0
    col: int = 0

    def __str__(self) -> str:
        return f"{self.file}:{self.line}:{self.col}"


UNKNOWN_LOC = Loc()


# --------------------------------------------------------------------------
# Types
# --------------------------------------------------------------------------


class Type:
    """Base class for HIR types."""

    def __eq__(self, other: Any) -> bool:
        return type(self) is type(other) and self.__dict__ == other.__dict__

    def __hash__(self) -> int:
        return hash((type(self).__name__, tuple(sorted(self.__dict__.items(), key=lambda kv: kv[0]))))

    def __repr__(self) -> str:
        return str(self)


class IntType(Type):
    """Arbitrary bit-width integer (paper §4.3)."""

    def __init__(self, width: int, signed: bool = True):
        assert width >= 1, f"integer width must be >=1, got {width}"
        self.width = int(width)
        self.signed = bool(signed)

    def __str__(self) -> str:
        return f"i{self.width}" if self.signed else f"u{self.width}"

    def __hash__(self) -> int:
        return hash(("IntType", self.width, self.signed))


class FloatType(Type):
    def __init__(self, width: int = 32):
        assert width in (16, 32, 64), f"unsupported float width {width}"
        self.width = int(width)

    def __str__(self) -> str:
        return f"f{self.width}"

    def __hash__(self) -> int:
        return hash(("FloatType", self.width))


class ConstType(Type):
    """Compile-time constant integer (``!hir.const``).  Always-valid, consumes
    no hardware; used for loop bounds, bank indices and delays."""

    def __str__(self) -> str:
        return "!hir.const"

    def __hash__(self) -> int:
        return hash("ConstType")


class TimeType(Type):
    """A time variable (``!hir.time``): a specific cycle within a lexical
    scope, the paper's key abstraction (§4.2)."""

    def __str__(self) -> str:
        return "!hir.time"

    def __hash__(self) -> int:
        return hash("TimeType")


# memref port kinds
PORT_R = "r"
PORT_W = "w"
PORT_RW = "rw"

# memref storage kinds (binding component)
KIND_REG = "reg"
KIND_LUTRAM = "lutram"  # distributed RAM
KIND_BRAM = "bram"  # block RAM


class MemrefType(Type):
    """Multi-dimensional memory reference (paper §4.4).

    ``shape``        tensor dims.
    ``elem``         element type.
    ``port``         access permission of *this* memref value: r / w / rw.
    ``packed``       indices of the *packed* dims (same buffer, linearised
                     layout).  Every other dim is *distributed* (banked):
                     distinct indices go to distinct physical buffers and may
                     be accessed in parallel (paper Fig. 3).
    ``kind``         physical binding: registers, distributed RAM, block RAM.
    """

    def __init__(
        self,
        shape: Sequence[int],
        elem: Type,
        port: str = PORT_RW,
        packed: Optional[Sequence[int]] = None,
        kind: str = KIND_BRAM,
    ):
        assert port in (PORT_R, PORT_W, PORT_RW), port
        assert kind in (KIND_REG, KIND_LUTRAM, KIND_BRAM), kind
        self.shape = tuple(int(d) for d in shape)
        assert all(d >= 1 for d in self.shape), self.shape
        self.elem = elem
        self.port = port
        self.packed = tuple(sorted(int(i) for i in (packed if packed is not None else range(len(self.shape)))))
        assert all(0 <= i < len(self.shape) for i in self.packed), (self.packed, self.shape)
        self.kind = kind

    # -- helpers ----------------------------------------------------------
    @property
    def distributed(self) -> tuple[int, ...]:
        return tuple(i for i in range(len(self.shape)) if i not in self.packed)

    @property
    def num_banks(self) -> int:
        n = 1
        for i in self.distributed:
            n *= self.shape[i]
        return n

    @property
    def bank_elems(self) -> int:
        n = 1
        for i in self.packed:
            n *= self.shape[i]
        return n

    def elem_bits(self) -> int:
        if isinstance(self.elem, (IntType, FloatType)):
            return self.elem.width
        raise TypeError(f"memref of non-primitive elem {self.elem}")

    def read_latency(self) -> int:
        """Registers read combinationally; RAMs take one cycle (paper §4.1)."""
        return 0 if self.kind == KIND_REG else 1

    def with_port(self, port: str) -> "MemrefType":
        return MemrefType(self.shape, self.elem, port, self.packed, self.kind)

    def __str__(self) -> str:
        dims = "*".join(str(d) for d in self.shape)
        extra = ""
        if self.packed != tuple(range(len(self.shape))):
            extra += f", packing=[{','.join(str(i) for i in self.packed)}]"
        if self.kind != KIND_BRAM:
            extra += f", kind={self.kind}"
        return f"!hir.memref<{dims}*{self.elem}, {self.port}{extra}>"

    def __hash__(self) -> int:
        return hash(("MemrefType", self.shape, self.elem, self.port, self.packed, self.kind))


# Singletons / helpers
CONST = ConstType()
TIME = TimeType()
i1 = IntType(1)
i8 = IntType(8)
i16 = IntType(16)
i32 = IntType(32)
i64 = IntType(64)
f32 = FloatType(32)


def IntT(width: int, signed: bool = True) -> IntType:
    return IntType(width, signed)


def is_primitive(t: Type) -> bool:
    return isinstance(t, (IntType, FloatType))


# --------------------------------------------------------------------------
# SSA values and time expressions
# --------------------------------------------------------------------------

_value_ids = itertools.count()


class Use(NamedTuple):
    """A single operand slot referencing a value (MLIR's OpOperand)."""

    op: "Operation"
    index: int


class Value:
    """An SSA value.  ``birth`` is the schedule information: for primitive
    values it records when the value becomes valid (paper §4.3: each SSA
    variable of primitive type is defined only at a specific time instant).
    Constants and memrefs have ``birth is None`` (always valid).

    Every value maintains its *use-def chain*: ``_use_ops`` is a multiset of
    the operations currently holding this value as an operand, kept up to
    date by the ``OperandList`` mutation hooks.  Use queries (``uses``,
    ``users``, ``replace_all_uses_with``) are therefore O(#uses) instead of
    O(region) — the asymptotic difference that makes the worklist rewriter
    in ``core.rewrite`` fast."""

    __slots__ = ("id", "type", "name", "defining_op", "birth", "validity_end", "_use_ops")

    def __init__(self, type: Type, name: str = "", defining_op: Optional["Operation"] = None):
        self.id = next(_value_ids)
        self.type = type
        self.name = name or f"v{self.id}"
        self.defining_op = defining_op
        # ``birth``: Optional[Time] — cycle at which the value becomes valid.
        self.birth: Optional[Time] = None
        # validity window length in cycles; None => valid forever after birth
        # (e.g. a sequential loop's induction variable), 1 => single cycle.
        self.validity_end: Optional[int] = 1
        # op -> number of operand slots of that op holding this value
        self._use_ops: dict["Operation", int] = {}

    # -- use-def chain ------------------------------------------------------
    @property
    def uses(self) -> list[Use]:
        """All (op, operand_index) slots currently holding this value."""
        out: list[Use] = []
        for op in self._use_ops:
            for i, o in enumerate(op.operands):
                if o is self:
                    out.append(Use(op, i))
        return out

    def users(self) -> list["Operation"]:
        """Operations using this value (each listed once)."""
        return list(self._use_ops)

    @property
    def num_uses(self) -> int:
        return sum(self._use_ops.values())

    def has_uses(self) -> bool:
        return bool(self._use_ops)

    def replace_all_uses_with(self, new: "Value") -> int:
        """Replace *every* use of this value, anywhere in the IR, with
        ``new``.  O(#uses).  Returns the number of replaced operand slots.

        The use-def bookkeeping is batched: all of one user's slots move in
        a single counter transfer instead of an unregister/register pair per
        slot (the per-slot ``OperandList.__setitem__`` path)."""
        if new is self:
            return 0
        n = 0
        new_uses = new._use_ops
        for op, cnt in list(self._use_ops.items()):
            ol = op.operands
            for i, o in enumerate(ol):
                if o is self:
                    list.__setitem__(ol, i, new)
            if ol._live:
                del self._use_ops[op]
                new_uses[op] = new_uses.get(op, 0) + cnt
            n += cnt
        return n

    def __repr__(self) -> str:
        return f"%{self.name}: {self.type}"

    def __hash__(self) -> int:
        return self.id

    def __eq__(self, other: Any) -> bool:
        return self is other


@dataclass(frozen=True)
class Time:
    """A time expression: ``tv + offset`` where ``tv`` is a time variable
    (an SSA Value of TimeType) and ``offset`` a compile-time constant."""

    tv: Value
    offset: int = 0

    def __post_init__(self):
        assert isinstance(self.tv.type, TimeType), self.tv
        assert self.offset >= 0, f"negative time offset {self.offset}"

    def __add__(self, k: int) -> "Time":
        return Time(self.tv, self.offset + int(k))

    def __str__(self) -> str:
        if self.offset == 0:
            return f"%{self.tv.name}"
        return f"%{self.tv.name} offset {self.offset}"


# --------------------------------------------------------------------------
# Operations and regions
# --------------------------------------------------------------------------


class Region:
    """A lexical scope: a list of operations plus block arguments (e.g. the
    loop induction variable and the iteration time variable)."""

    __slots__ = ("args", "ops", "parent_op")

    def __init__(self, args: Sequence[Value] = ()):  # block args
        self.args: list[Value] = list(args)
        self.ops: list[Operation] = []
        self.parent_op: Optional[Operation] = None

    def add(self, op: "Operation") -> "Operation":
        op.parent_region = self
        self.ops.append(op)
        return op

    def insert(self, index: int, op: "Operation") -> "Operation":
        op.parent_region = self
        self.ops.insert(index, op)
        return op

    def insert_before(self, anchor: "Operation", op: "Operation") -> "Operation":
        return self.insert(self.ops.index(anchor), op)

    def remove(self, op: "Operation") -> None:
        self.ops.remove(op)
        op.parent_region = None

    def walk(self) -> Iterator["Operation"]:
        """Preorder walk (op before its nested regions).  Eager: snapshots
        the op tree, so callers may mutate region op-lists while iterating;
        nested ``yield from`` generator chains were a measurable per-op cost
        in the optimizer hot loop."""
        out: list[Operation] = []
        _collect_ops(self, out)
        return iter(out)


def _collect_ops(region: "Region", out: list) -> None:
    for op in region.ops:
        out.append(op)
        for r in op.regions:
            _collect_ops(r, out)


class OperandList(list):
    """The operand list of one operation.  Every mutation — indexed or sliced
    assignment, append/insert/remove/pop/clear/extend — keeps the operands'
    use-def chains (``Value._use_ops``) consistent, so legacy code that
    mutates ``op.operands`` in place remains correct under the maintained
    invariant."""

    __slots__ = ("owner", "_live")

    def __init__(self, owner: "Operation", values: Sequence[Value] = ()):
        super().__init__(values)
        self.owner = owner
        self._live = True
        for v in values:
            self._register(v)

    # -- chain bookkeeping --------------------------------------------------
    def _register(self, v: Value) -> None:
        if self._live:
            u = v._use_ops
            u[self.owner] = u.get(self.owner, 0) + 1

    def _unregister(self, v: Value) -> None:
        if self._live:
            u = v._use_ops
            k = u.get(self.owner, 0) - 1
            if k <= 0:
                u.pop(self.owner, None)
            else:
                u[self.owner] = k

    def _drop_all(self) -> None:
        """Detach this list from the chains (the op is being erased).  The
        list contents are kept so accessors on dead ops still read, but no
        further mutation touches the chains.  Idempotent."""
        if self._live:
            for v in self:
                self._unregister(v)
            self._live = False

    # -- intercepted mutations ---------------------------------------------
    def __setitem__(self, i, v):
        if isinstance(i, slice):
            for old in self[i]:
                self._unregister(old)
            v = list(v)
            for new in v:
                self._register(new)
        else:
            self._unregister(self[i])
            self._register(v)
        super().__setitem__(i, v)

    def __delitem__(self, i):
        if isinstance(i, slice):
            for old in self[i]:
                self._unregister(old)
        else:
            self._unregister(self[i])
        super().__delitem__(i)

    def append(self, v):
        self._register(v)
        super().append(v)

    def extend(self, vs):
        vs = list(vs)
        for v in vs:
            self._register(v)
        super().extend(vs)

    def __iadd__(self, vs):
        self.extend(vs)
        return self

    def insert(self, i, v):
        self._register(v)
        super().insert(i, v)

    def remove(self, v):
        super().remove(v)
        self._unregister(v)

    def pop(self, i=-1):
        v = super().pop(i)
        self._unregister(v)
        return v

    def clear(self):
        for v in self:
            self._unregister(v)
        super().clear()

    def __reduce_ex__(self, protocol):
        # deepcopy/pickle: rebuild through Operation.__init__'s wrapping is
        # impossible here, so reconstruct the raw state (owner backref is
        # restored by copying the owner op's attribute graph).
        return (_rebuild_operand_list, (self.owner, list(self), self._live))


def _rebuild_operand_list(owner, values, live):
    ol = OperandList.__new__(OperandList)
    list.__init__(ol, values)
    ol.owner = owner
    ol._live = live
    return ol


class Operation:
    """Generic HIR operation.

    ``start``: Optional[Time] — the op's scheduled start (``at %t offset k``).
    ``None`` means *unscheduled*; unscheduled functions are valid input to the
    HLS auto-scheduler (``core.hls``) but are rejected by the strict verifier
    used ahead of Verilog codegen.
    """

    __slots__ = ("opname", "operands", "results", "attrs", "regions", "start", "loc",
                 "parent_region", "_dead")

    def __init__(
        self,
        opname: str,
        operands: Sequence[Value] = (),
        result_types: Sequence[Type] = (),
        attrs: Optional[dict[str, Any]] = None,
        regions: Sequence[Region] = (),
        start: Optional[Time] = None,
        loc: Loc = UNKNOWN_LOC,
        result_names: Sequence[str] = (),
    ):
        self.opname = opname
        self._dead = False
        self.operands: OperandList = OperandList(self, list(operands))
        self.results: list[Value] = []
        for i, rt in enumerate(result_types):
            nm = result_names[i] if i < len(result_names) else ""
            self.results.append(Value(rt, nm, defining_op=self))
        self.attrs: dict[str, Any] = dict(attrs or {})
        self.regions: list[Region] = list(regions)
        for r in self.regions:
            r.parent_op = self
        self.start = start
        self.loc = loc
        self.parent_region: Optional[Region] = None

    # convenience -----------------------------------------------------------
    @property
    def result(self) -> Value:
        assert len(self.results) == 1, f"{self.opname} has {len(self.results)} results"
        return self.results[0]

    def region(self, i: int = 0) -> Region:
        return self.regions[i]

    # -- mutation API (keeps use-def chains consistent) ---------------------
    def set_operand(self, i: int, v: Value) -> None:
        self.operands[i] = v

    @property
    def is_erased(self) -> bool:
        return self._dead

    def drop_all_uses(self) -> None:
        """Unregister every operand use held by this op and (recursively) by
        the ops of its nested regions, and mark them erased.  Called when the
        op is discarded; idempotent."""
        self._dead = True
        self.operands._drop_all()
        for r in self.regions:
            for op in r.ops:
                op.drop_all_uses()

    def erase(self) -> None:
        """Erase this op: drop all operand uses (recursively through nested
        regions) and unlink it from its parent region's op list.  The op's
        results must be dead or already replaced — erasing an op whose
        results still have uses leaves dangling references."""
        self.drop_all_uses()
        if self.parent_region is not None:
            try:
                self.parent_region.ops.remove(self)
            except ValueError:
                pass  # already unlinked (e.g. batch compaction)
            self.parent_region = None

    def __repr__(self) -> str:
        rs = ", ".join(f"%{r.name}" for r in self.results)
        eq = f"{rs} = " if rs else ""
        at = f" at {self.start}" if self.start is not None else ""
        return f"{eq}hir.{self.opname}(...){at}"


# --------------------------------------------------------------------------
# Concrete op constructors.  Each returns the Operation; results carry their
# birth times per the paper's latency model:
#   * combinational arith (add/sub/and/...)       : birth = start + 0
#   * hir.mult (DSP)                              : combinational by default,
#       or pipelined with attrs["stages"]=k        : birth = start + k
#   * hir.mem_read                                : birth = start + latency
#       (0 for registers, 1 for RAMs)
#   * hir.delay %v by k                           : birth = v.birth + k
#   * hir.call                                    : per-result declared delay
# --------------------------------------------------------------------------

ARITH_OPS = {
    # name -> (n_operands, default latency)
    "add": (2, 0),
    "sub": (2, 0),
    "mult": (2, 0),
    "div": (2, 0),
    "and": (2, 0),
    "or": (2, 0),
    "xor": (2, 0),
    "not": (1, 0),
    "shl": (2, 0),
    "shr": (2, 0),
    "cmp_lt": (2, 0),
    "cmp_le": (2, 0),
    "cmp_eq": (2, 0),
    "cmp_ne": (2, 0),
    "cmp_gt": (2, 0),
    "cmp_ge": (2, 0),
    "select": (3, 0),
    "trunc": (1, 0),
    "zext": (1, 0),
    "sext": (1, 0),
}

COMMUTATIVE_OPS = {"add", "mult", "and", "or", "xor", "cmp_eq", "cmp_ne"}


def _arith_result_type(opname: str, operands: Sequence[Value], result_type: Optional[Type]) -> Type:
    if result_type is not None:
        return result_type
    if opname.startswith("cmp_"):
        return IntType(1, signed=False)
    for v in operands:  # first primitive operand wins; consts adapt
        if is_primitive(v.type):
            return v.type
    return operands[0].type


def arith(
    opname: str,
    operands: Sequence[Value],
    start: Optional[Time] = None,
    result_type: Optional[Type] = None,
    stages: int = 0,
    loc: Loc = UNKNOWN_LOC,
) -> Operation:
    assert opname in ARITH_OPS, opname
    nops, _lat = ARITH_OPS[opname]
    assert len(operands) == nops, (opname, len(operands))
    rt = _arith_result_type(opname, operands, result_type)
    op = Operation(opname, operands, [rt], attrs={"stages": stages}, start=start, loc=loc)
    if start is not None:
        op.result.birth = start + stages
    return op


def constant(value: Union[int, float], type: Type = CONST, name: str = "", loc: Loc = UNKNOWN_LOC) -> Operation:
    op = Operation("constant", [], [type], attrs={"value": value}, loc=loc, result_names=[name])
    op.result.birth = None  # constants are always valid
    op.result.validity_end = None
    return op


def alloc(
    memref: MemrefType,
    ports: Sequence[str] = (PORT_R, PORT_W),
    names: Sequence[str] = (),
    loc: Loc = UNKNOWN_LOC,
) -> Operation:
    """Allocate an on-chip tensor; one result memref per requested port
    (paper: each memref pointing to a tensor is a memory port)."""
    rts = [memref.with_port(p) for p in ports]
    op = Operation("alloc", [], rts, attrs={"base": memref, "ports": tuple(ports)}, loc=loc, result_names=names)
    for r in op.results:
        r.birth = None
        r.validity_end = None
    return op


def mem_read(mem: Value, indices: Sequence[Value], start: Time, loc: Loc = UNKNOWN_LOC) -> Operation:
    mt = mem.type
    assert isinstance(mt, MemrefType), mem
    assert mt.port in (PORT_R, PORT_RW), f"mem_read on write-only memref {mem}"
    assert len(indices) == len(mt.shape), (len(indices), mt.shape)
    op = Operation("mem_read", [mem, *indices], [mt.elem], start=start, loc=loc)
    if start is not None:  # unscheduled (erased) reads have no birth yet
        op.result.birth = start + mt.read_latency()
    return op


def mem_write(
    value: Value,
    mem: Value,
    indices: Sequence[Value],
    start: Time,
    pred: Optional[Value] = None,
    loc: Loc = UNKNOWN_LOC,
) -> Operation:
    mt = mem.type
    assert isinstance(mt, MemrefType), mem
    assert mt.port in (PORT_W, PORT_RW), f"mem_write on read-only memref {mem}"
    assert len(indices) == len(mt.shape), (len(indices), mt.shape)
    operands = [value, mem, *indices] + ([pred] if pred is not None else [])
    return Operation("mem_write", operands, [], attrs={"predicated": pred is not None}, start=start, loc=loc)


def mem_write_parts(op: Operation) -> tuple[Value, Value, list[Value], Optional[Value]]:
    """(value, mem, indices, predicate) of a mem_write op."""
    assert op.opname == "mem_write"
    if op.attrs.get("predicated"):
        return op.operands[0], op.operands[1], list(op.operands[2:-1]), op.operands[-1]
    return op.operands[0], op.operands[1], list(op.operands[2:]), None


def mem_read_parts(op: Operation) -> tuple[Value, list[Value]]:
    """(mem, indices) of a mem_read op."""
    assert op.opname == "mem_read"
    return op.operands[0], list(op.operands[1:])


def mem_op_indices(op: Operation) -> list[Value]:
    return mem_read_parts(op)[1] if op.opname == "mem_read" else mem_write_parts(op)[2]


def delay(v: Value, by: int, start: Optional[Time] = None, loc: Loc = UNKNOWN_LOC) -> Operation:
    assert is_primitive(v.type), f"delay of non-primitive {v}"
    assert by >= 0
    op = Operation("delay", [v], [v.type], attrs={"by": int(by)}, start=start, loc=loc)
    if v.birth is not None:
        op.result.birth = v.birth + by
    elif start is not None:
        op.result.birth = start + by
    return op


def time_offset(t: Time, name: str = "", loc: Loc = UNKNOWN_LOC) -> Operation:
    """Materialise a new time variable at ``t`` (used for task-level
    parallelism: several calls scheduled relative to one event)."""
    op = Operation("time", [t.tv], [TIME], attrs={"offset": t.offset}, loc=loc, result_names=[name])
    op.result.birth = None
    op.result.validity_end = None
    return op


class ForOp(Operation):
    """``hir.for %i = lb to ub step s iter_time(%ti = %t offset k) {body}``.

    Results: ``%tf`` — the time at which the *last* iteration's yield fires
    (i.e. loop completion).  Region args: [%i, %ti].
    The loop II is defined by the body's ``hir.yield`` (paper §4.2).
    """

    def __init__(
        self,
        lb: Value,
        ub: Value,
        step: Value,
        start: Time,
        iv_type: Type = i32,
        iter_arg_offset: int = 0,
        unroll: bool = False,
        iv_name: str = "i",
        tv_name: str = "ti",
        loc: Loc = UNKNOWN_LOC,
    ):
        iv = Value(iv_type, iv_name)
        tv = Value(TIME, tv_name)
        tv.birth = None
        tv.validity_end = None
        body = Region([iv, tv])
        super().__init__(
            "unroll_for" if unroll else "for",
            [lb, ub, step],
            [TIME],
            attrs={"iter_arg_offset": int(iter_arg_offset)},
            regions=[body],
            start=start,
            loc=loc,
            result_names=["tf"],
        )
        # induction variable is born at the iteration start; its validity
        # window is [ti, ti+II) — II is fixed later by the verifier from the
        # yield op.  Until then validity_end=None is refined by analysis.
        iv.birth = Time(tv, 0)
        iv.validity_end = None
        self.results[0].birth = None
        self.results[0].validity_end = None

    # -- accessors ---------------------------------------------------------
    @property
    def lb(self) -> Value:
        return self.operands[0]

    @property
    def ub(self) -> Value:
        return self.operands[1]

    @property
    def step(self) -> Value:
        return self.operands[2]

    @property
    def iv(self) -> Value:
        return self.regions[0].args[0]

    @property
    def time_var(self) -> Value:
        return self.regions[0].args[1]

    @property
    def end_time(self) -> Value:
        return self.results[0]

    def yield_op(self) -> Optional[Operation]:
        for op in self.regions[0].ops:
            if op.opname == "yield":
                return op
        return None

    def initiation_interval(self) -> Optional[int]:
        """Constant II if the yield is scheduled on the iteration time var,
        else None (sequential / data-dependent II)."""
        y = self.yield_op()
        if y is None or y.start is None:
            return None
        if y.start.tv is self.time_var:
            return y.start.offset
        return None

    def trip_count(self) -> Optional[int]:
        def cval(v: Value) -> Optional[int]:
            if v.defining_op is not None and v.defining_op.opname == "constant":
                return int(v.defining_op.attrs["value"])
            return None

        lb, ub, st = cval(self.lb), cval(self.ub), cval(self.step)
        if lb is None or ub is None or st is None or st == 0:
            return None
        return max(0, -(-(ub - lb) // st))


def yield_op(start: Time, loc: Loc = UNKNOWN_LOC) -> Operation:
    return Operation("yield", [], [], start=start, loc=loc)


def return_op(values: Sequence[Value] = (), loc: Loc = UNKNOWN_LOC) -> Operation:
    return Operation("return", list(values), [], loc=loc)


class FuncOp(Operation):
    """``hir.func @name at %t (args...) -> (results...)``.

    The function's schedule interface (paper §5.4): every primitive argument
    carries an input delay (cycles after %t at which the caller supplies it)
    and every result a declared output delay.  This is what makes calls to
    external Verilog modules handshake-free.
    """

    def __init__(
        self,
        name: str,
        arg_types: Sequence[Type],
        arg_names: Sequence[str] = (),
        arg_delays: Optional[Sequence[int]] = None,
        result_types: Sequence[Type] = (),
        result_delays: Optional[Sequence[int]] = None,
        loc: Loc = UNKNOWN_LOC,
    ):
        tv = Value(TIME, "t")
        tv.birth = None
        tv.validity_end = None
        args = []
        for i, at in enumerate(arg_types):
            nm = arg_names[i] if i < len(arg_names) else f"arg{i}"
            v = Value(at, nm)
            if is_primitive(at):
                d = (arg_delays or [0] * len(arg_types))[i]
                v.birth = Time(tv, d)
            else:
                v.birth = None
                v.validity_end = None
            args.append(v)
        body = Region([*args, tv])
        super().__init__(
            "func",
            [],
            [],
            attrs={
                "sym_name": name,
                "arg_delays": tuple(arg_delays or [0] * len(arg_types)),
                "result_types": tuple(result_types),
                "result_delays": tuple(result_delays or [0] * len(result_types)),
            },
            regions=[body],
            loc=loc,
        )

    @property
    def name(self) -> str:
        return self.attrs["sym_name"]

    @property
    def args(self) -> list[Value]:
        return self.regions[0].args[:-1]

    @property
    def time_var(self) -> Value:
        return self.regions[0].args[-1]

    @property
    def body(self) -> Region:
        return self.regions[0]


def call(
    callee: Union[str, FuncOp],
    operands: Sequence[Value],
    start: Time,
    result_types: Sequence[Type] = (),
    result_delays: Sequence[int] = (),
    loc: Loc = UNKNOWN_LOC,
) -> Operation:
    name = callee if isinstance(callee, str) else callee.name
    if isinstance(callee, FuncOp):
        result_types = list(callee.attrs["result_types"])
        result_delays = list(callee.attrs["result_delays"])
    op = Operation(
        "call",
        operands,
        result_types,
        attrs={"callee": name, "result_delays": tuple(result_delays)},
        start=start,
        loc=loc,
    )
    if start is not None:  # unscheduled (erased) calls have no birth yet
        for r, d in zip(op.results, result_delays):
            r.birth = start + d
    return op


class Module:
    """Top-level container of HIR functions (an MLIR module)."""

    def __init__(self, name: str = "module"):
        self.name = name
        self.funcs: dict[str, FuncOp] = {}

    def add(self, f: FuncOp) -> FuncOp:
        assert f.name not in self.funcs, f"duplicate func @{f.name}"
        self.funcs[f.name] = f
        return f

    def get(self, name: str) -> FuncOp:
        return self.funcs[name]

    def walk(self) -> Iterator[Operation]:
        for f in self.funcs.values():
            yield f
            yield from f.body.walk()

    def clone(self) -> "Module":
        """A structurally identical deep copy built by rebuilding ops, values
        and use-def chains directly — an order of magnitude faster than
        ``copy.deepcopy`` (which walks every ``_use_ops`` backref and slot
        through the generic memo machinery).  Value names, op order, attrs,
        schedules (``start``/``birth`` remapped onto the cloned time
        variables) and region structure are preserved; the returned module
        shares no ``Operation``/``Value``/``Region`` objects with the
        original, so both sides can be mutated independently."""
        new = Module(self.name)
        for name, f in self.funcs.items():
            new.funcs[name] = clone_func(f)
        return new


def clone_func(f: FuncOp) -> FuncOp:
    """Clone one function (any ``Operation`` subtree rooted at a FuncOp) with
    fresh Values/Operations and rebuilt use-def chains."""
    return _clone_op(f, {})


def _mapped_value(v: Value, vmap: dict) -> Value:
    """The clone of ``v``.  Values defined inside the cloned subtree are
    already in ``vmap``; anything else (e.g. a ``birth`` time variable left
    dangling by inlining, whose defining op is gone) is cloned fresh on
    first sight — the same fresh-disjoint-object semantics ``deepcopy``
    gave such stragglers."""
    nv = vmap.get(v)
    if nv is None:
        nv = Value(v.type, v.name)
        nv.validity_end = v.validity_end
        vmap[v] = nv
        nv.birth = _clone_time(v.birth, vmap)
    return nv


def _clone_time(t: Optional[Time], vmap: dict) -> Optional[Time]:
    if t is None:
        return None
    return Time(_mapped_value(t.tv, vmap), t.offset)


def _clone_op(op: Operation, vmap: dict) -> Operation:
    """Recursive structural clone.  ``vmap`` maps original Values to their
    clones; SSA dominance guarantees every operand / time variable has been
    cloned by the time it is referenced (region args are created in a first
    pass so intra-region-arg references — e.g. a ForOp's iv born on its own
    time variable — resolve)."""
    c = Operation.__new__(type(op))
    c.opname = op.opname
    c._dead = op._dead
    c.attrs = dict(op.attrs)
    c.loc = op.loc
    c.parent_region = None
    c.start = _clone_time(op.start, vmap)
    c.operands = OperandList(c, [_mapped_value(o, vmap) for o in op.operands])
    c.results = []
    for r in op.results:
        nr = Value(r.type, r.name, defining_op=c)
        nr.validity_end = r.validity_end
        vmap[r] = nr
        c.results.append(nr)
    c.regions = []
    for reg in op.regions:
        nreg = Region.__new__(Region)
        nreg.parent_op = c
        nreg.args = []
        nreg.ops = []
        for a in reg.args:
            na = Value(a.type, a.name)
            na.validity_end = a.validity_end
            vmap[a] = na
            nreg.args.append(na)
        for a, na in zip(reg.args, nreg.args):
            na.birth = _clone_time(a.birth, vmap)
        for inner in reg.ops:
            ic = _clone_op(inner, vmap)
            ic.parent_region = nreg
            nreg.ops.append(ic)
        c.regions.append(nreg)
    # result births last: they may (in principle) reference time variables
    # defined inside the op's own regions
    for r, nr in zip(op.results, c.results):
        nr.birth = _clone_time(r.birth, vmap)
    return c


# --------------------------------------------------------------------------
# Misc IR utilities shared by passes
# --------------------------------------------------------------------------


def const_value(v: Value) -> Optional[Union[int, float]]:
    """The compile-time value of ``v`` if it is defined by hir.constant."""
    if v.defining_op is not None and v.defining_op.opname == "constant":
        return v.defining_op.attrs["value"]
    return None


def _replace_all_uses_in_region(region: Region, old: Value, new: Value) -> int:
    """O(region) region-scoped replacement — retained only as the baseline
    the legacy sweep benchmark measures (and it is scope-limited: uses held
    by sibling scopes are silently missed).  New code wants
    ``old.replace_all_uses_with(new)`` (global, O(#uses))."""
    n = 0
    for op in region.walk():
        for i, o in enumerate(op.operands):
            if o is old:
                op.operands[i] = new
                n += 1
    return n
