"""Array-add — the paper's Figure 1 example.  ``build()`` is the corrected
design; ``build_broken()`` reproduces Fig. 1a exactly: with II=1 the write at
``%ti offset 1`` consumes the induction variable one cycle after it has
already been re-generated — the verifier must report the Fig. 1b error."""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def _body(b: Builder, f, n: int, fix: bool):
    A, B, C = f.args
    with b.for_(0, n, 1, at=f.t + 1, iv_type=ir.i8 if n <= 127 else ir.i32, iv_name="i", tv_name="ti") as li:
        b.yield_(at=li.time + 1)  # II = 1 (textual position irrelevant, §4.2)
        a = b.read(A, [li.iv], at=li.time)
        v = b.read(B, [li.iv], at=li.time)
        c = b.add(a, v)  # combinational, inferred at ti+1
        if fix:
            i1 = b.delay(li.iv, 1, at=li.time)
            b.write(c, C, [i1], at=li.time + 1)
        else:
            b.write(c, C, [li.iv], at=li.time + 1)  # Fig. 1 bug: %i stale at ti+1
    b.ret()


def build(n: int = 128):
    b = Builder(ir.Module("array_add"))
    r = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("array_add", [r, r, w], ["A", "B", "C"]) as f:
        _body(b, f, n, fix=True)
    return b.module, "array_add"


def build_broken(n: int = 128):
    b = Builder(ir.Module("array_add_broken"))
    r = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    w = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("array_add", [r, r, w], ["A", "B", "C"]) as f:
        _body(b, f, n, fix=False)
    return b.module, "array_add"


def oracle(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return a + b


def make_inputs(n: int = 128, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**20), 2**20, size=(n,), dtype=np.int64)
    bb = rng.integers(-(2**20), 2**20, size=(n,), dtype=np.int64)
    return [a, bb, np.zeros((n,), dtype=np.int64)]
