"""3x3 2-d convolution with line buffers (paper §8 "Convolution").

Streaming design: one input pixel per cycle, two line buffers (LUTRAM) hold
the previous two rows, a 3x2 register file holds the previous two columns of
the current 3-row window.  Constant weights [[1,2,1],[2,4,2],[1,2,1]] are
multiplications by constants — the strength-reduction pass turns them into
shifts/adds, which is how the paper's conv uses 0 DSPs.

Loop structure avoids conditionals: explicit prologue loops fill the line
buffers (first two rows) and the column registers (first two columns of each
row); the steady-state loop then writes one output per cycle at II=1.
"""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder

WGT = [[1, 2, 1], [2, 4, 2], [1, 2, 1]]


def _tap_row(b: Builder, col_vals, wcol):
    """Sum of one window *column* against one weight column (combinational)."""
    acc = None
    for v, w in zip(col_vals, wcol):
        m = b.mult(v, w)
        acc = m if acc is None else b.add(acc, m)
    return acc


def build(h: int = 12, w: int = 12):
    b = Builder(ir.Module("conv2d"))
    rmem = ir.MemrefType((h, w), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((h - 2, w - 2), ir.i32, ir.PORT_W)
    with b.func("conv2d", [rmem, wmem], ["Img", "Out"]) as f:
        Img, Out = f.args
        lb_t = ir.MemrefType((w,), ir.i32, kind=ir.KIND_LUTRAM)
        L0r, L0w = b.alloc(lb_t, names=["L0r", "L0w"])  # row r-1
        L1r, L1w = b.alloc(lb_t, names=["L1r", "L1w"])  # row r-2
        # previous two window columns for the current 3 rows: 3x2 registers
        p_t = ir.MemrefType((3, 2), ir.i32, packed=[], kind=ir.KIND_REG)
        Pr, Pw = b.alloc(p_t, names=["Pr", "Pw"])

        def shift_and_fill(c_loop, with_output: bool, row_iv=None):
            """Common loop body: read pixel + line buffers, rotate the column
            registers, update line buffers, optionally emit an output."""
            tc = c_loop.time
            c = c_loop.iv
            v = b.read(Img, [row_iv, c] if row_iv is not None else [0, c], at=tc)  # row r
            a = b.read(L1r, [c], at=tc)        # row r-2 value at column c
            bm = b.read(L0r, [c], at=tc)       # row r-1 value
            c1 = b.delay(c, 1, at=tc)
            # rotate rows in the line buffers
            b.write(bm, L1w, [c1], at=tc + 1)
            b.write(v, L0w, [c1], at=tc + 1)
            # rotate the column registers: col0 <- col1, col1 <- fresh column
            col1 = [b.read(Pr, [r, 1], at=tc + 1) for r in range(3)]
            for r in range(3):
                b.write(col1[r], Pw, [r, 0], at=tc + 1)
            for r, val in enumerate([a, bm, v]):
                b.write(val, Pw, [r, 1], at=tc + 1)
            if with_output:
                col0 = [b.read(Pr, [r, 0], at=tc + 1) for r in range(3)]
                s0 = _tap_row(b, col0, [WGT[r][0] for r in range(3)])
                s1 = _tap_row(b, col1, [WGT[r][1] for r in range(3)])
                s2 = _tap_row(b, [a, bm, v], [WGT[r][2] for r in range(3)])
                s = b.add(b.add(s0, s1), s2)     # combinational at tc+1
                sreg = b.delay(s, 1, at=tc + 1)  # register, valid tc+2
                c2 = b.delay(c, 2, at=tc)
                cm2 = b.sub(c2, 2)
                rm2 = b.sub(row_iv, 2)           # row IV: sequential loop, always valid
                b.write(sreg, Out, [rm2, cm2], at=tc + 2)

        # ---- fill the first two rows into the line buffers ----
        with b.for_(0, 2, 1, at=f.t + 1, iv_name="r0", tv_name="tr0") as lr0:
            with b.for_(0, w, 1, at=lr0.time + 1, iv_name="c0", tv_name="tc0") as lc0:
                b.yield_(at=lc0.time + 1)
                v = b.read(Img, [lr0.iv, lc0.iv], at=lc0.time)
                bm = b.read(L0r, [lc0.iv], at=lc0.time)
                c1 = b.delay(lc0.iv, 1, at=lc0.time)
                b.write(bm, L1w, [c1], at=lc0.time + 1)
                b.write(v, L0w, [c1], at=lc0.time + 1)
            b.yield_(at=lc0.end + 1)

        # ---- main rows ----
        with b.for_(2, h, 1, at=lr0.end + 1, iv_name="r", tv_name="tr") as lr:
            # column prologue: fill the first two window columns
            with b.for_(0, 2, 1, at=lr.time + 1, iv_name="cp", tv_name="tcp") as lcp:
                b.yield_(at=lcp.time + 1)
                shift_and_fill(lcp, with_output=False, row_iv=lr.iv)
            # steady state: one output per cycle
            with b.for_(2, w, 1, at=lcp.end + 2, iv_name="c", tv_name="tcs") as lcs:
                b.yield_(at=lcs.time + 1)
                shift_and_fill(lcs, with_output=True, row_iv=lr.iv)
            b.yield_(at=lcs.end + 2)
        b.ret()
    return b.module, "conv2d"


def oracle(img: np.ndarray) -> np.ndarray:
    h, w = img.shape
    out = np.zeros((h - 2, w - 2), dtype=np.int64)
    for r in range(3):
        for c in range(3):
            out += WGT[r][c] * img[r:h - 2 + r, c:w - 2 + c]
    return out


def make_inputs(h: int = 12, w: int = 12, seed: int = 0):
    rng = np.random.default_rng(seed)
    img = rng.integers(-(2**12), 2**12, size=(h, w), dtype=np.int64)
    return [img, np.zeros((h - 2, w - 2), dtype=np.int64)]
