"""Multiply-accumulate — the paper's Figure 2 pipeline-imbalance example.
``build(mult_stages=2)`` is balanced; ``build(mult_stages=3)`` reproduces the
retiming bug: the multiplier gains a pipeline stage but the delayed addend
still arrives after 2 cycles, so the adder's operands mismatch (2 vs 3)."""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def build(mult_stages: int = 2, delay_c: int = 2):
    b = Builder(ir.Module("mac"))
    with b.func(
        "mac",
        [ir.i32, ir.i32, ir.i32],
        ["a", "bb", "c"],
        arg_delays=[0, 0, 0],
        result_types=[ir.i32],
        result_delays=[max(mult_stages, delay_c)],
    ) as f:
        a, bb, c = f.args
        m = b.mult(a, bb, at=f.t, stages=mult_stages)  # valid at t+stages
        c2 = b.delay(c, delay_c, at=f.t)               # valid at t+delay_c
        res = b.add(m, c2)                             # schedule inferred; Fig. 2 check
        b.ret([res])
    return b.module, "mac"


def build_broken():
    return build(mult_stages=3, delay_c=2)


def oracle(a: int, b: int, c: int) -> int:
    return a * b + c
