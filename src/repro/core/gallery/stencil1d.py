"""1-d stencil (paper Listing 2): a 3-tap weighted window over a streaming
array, with a 2-element register window buffer and a fully pipelined (II=1)
loop.  The weighted reduction is an internal HIR function called with a
declared 1-cycle result delay — the schedule lives in the signature (§5.4)."""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder

W0, W1, W2 = 1, 2, 1  # integer weights (FIR-style)


def build(n: int = 64):
    b = Builder(ir.Module("stencil1d"))

    # the stencil compute op: out = w0*v0 + w1*v1 + w2*v2, registered (delay 1)
    with b.func(
        "stencil_op",
        [ir.i32, ir.i32, ir.i32],
        ["v0", "v1", "v2"],
        result_types=[ir.i32],
        result_delays=[1],
    ) as g:
        v0, v1, v2 = g.args
        m0 = b.mult(v0, W0, at=g.t)
        m1 = b.mult(v1, W1, at=g.t)
        m2 = b.mult(v2, W2, at=g.t)
        s = b.add(b.add(m0, m1), m2)
        r = b.delay(s, 1, at=g.t)  # register the combinational chain
        b.ret([r])

    rmem = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((n - 2,), ir.i32, ir.PORT_W)
    with b.func("stencil1d", [rmem, wmem], ["Ai", "Bw"]) as f:
        Ai, Bw = f.args
        # 2-register window: fully distributed (packing=[]) register bank
        win = ir.MemrefType((2,), ir.i32, ir.PORT_RW, packed=[], kind=ir.KIND_REG)
        Wr, Ww = b.alloc(win, names=["W1r", "W1w"])

        # prologue: preload the first two elements
        vA = b.read(Ai, [0], at=f.t)                      # valid t+1
        vA1 = b.delay(vA, 1, at=f.t + 1)                  # valid t+2
        vB = b.read(Ai, [1], at=f.t + 1)                  # valid t+2
        b.write(vA1, Ww, [0], at=f.t + 2)
        b.write(vB, Ww, [1], at=f.t + 2)

        # pipelined main loop, II=1: i in [1, n-1) computes out[i-1]
        with b.for_(1, n - 1, 1, at=f.t + 3, iv_name="i", tv_name="ti") as li:
            b.yield_(at=li.time + 1)
            v0 = b.read(Wr, [0], at=li.time + 1)          # registers: valid ti+1
            v1 = b.read(Wr, [1], at=li.time + 1)
            ip1 = b.add(li.iv, 1)                         # inferred at ti
            v = b.read(Ai, [ip1], at=li.time)             # valid ti+1
            b.write(v1, Ww, [0], at=li.time + 1)
            b.write(v, Ww, [1], at=li.time + 1)
            r = b.call("stencil_op", [v0, v1, v], at=li.time + 1)  # valid ti+2
            i2 = b.delay(li.iv, 2, at=li.time)
            im1 = b.sub(i2, 1)                            # out index i-1, at ti+2
            b.write(r, Bw, [im1], at=li.time + 2)
        b.ret()
    return b.module, "stencil1d"


def oracle(a: np.ndarray) -> np.ndarray:
    return W0 * a[:-2] + W1 * a[1:-1] + W2 * a[2:]


def make_inputs(n: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**18), 2**18, size=(n,), dtype=np.int64)
    return [a, np.zeros((n - 2,), dtype=np.int64)]
