"""GEMM scheduled for cross-instance time-multiplexing (paper §4.4 taken to
module granularity).

Same 16x16 int32 matmul as ``gemm``, but the compute phase trades latency
for resources: the k-loop runs at II=n (one MAC issue per PE every n
cycles) and the PE columns are staggered by one cycle, so PE(i,j) fires its
``mac`` call exactly at cycles ``{COMPUTE + j + n*m + 1}``.  Within one PE
row the n column schedules are pairwise disjoint (distinct residues mod n),
which is precisely what the ``activation-intervals`` analysis proves — so
``rtl-share-instances`` folds each row's n ``mac`` instances onto a single
physical instance behind a time-division operand mux: 256 instances become
16 at n=16 (a 16x reduction, 768 -> 48 DSPs), with zero arbitration logic
because the disjointness is static.

Memory legality is unchanged from ``gemm``: each A bank is read at
pairwise-distinct cycles (the same disjoint schedule), and the B banks keep
the §4.4 same-address broadcast across rows.
"""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder
from .gemm import make_inputs, oracle  # noqa: F401  (same interface/reference)


def build(n: int = 16):
    b = Builder(ir.Module("gemm_shared"))
    rmem = ir.MemrefType((n, n), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((n, n), ir.i32, ir.PORT_W)

    load_inner = n + 2
    LOAD = n * load_inner
    COMPUTE_START = 1 + LOAD + 1
    # k-loop: trip n at II=n, plus the column stagger and the +1 mac cycle
    DRAIN_START = COMPUTE_START + n * n + n + 2

    with b.func(
        "mac",
        [ir.i32, ir.i32, ir.i32],
        ["a", "bb", "c"],
        result_types=[ir.i32],
        result_delays=[0],
    ) as g:
        ga, gb, gc = g.args
        gm = b.mult(ga, gb, at=g.t)
        b.ret([b.add(gm, gc)])

    with b.func("gemm_shared", [rmem, rmem, wmem], ["A", "B", "C"]) as f:
        A, B, C = f.args
        abuf_t = ir.MemrefType((n, n), ir.i32, packed=[1], kind=ir.KIND_LUTRAM)
        Abr, Abw = b.alloc(abuf_t, names=["Abr", "Abw"])
        bbuf_t = ir.MemrefType((n, n), ir.i32, packed=[0], kind=ir.KIND_LUTRAM)
        Bbr, Bbw = b.alloc(bbuf_t, names=["Bbr", "Bbw"])
        acc_t = ir.MemrefType((n, n), ir.i32, packed=[], kind=ir.KIND_REG)
        AccR, AccW = b.alloc(acc_t, names=["AccR", "AccW"])

        # ---- load phases: identical to gemm ----
        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="li", tv_name="tla") as la:
            b.yield_(at=la.time + load_inner)
            with b.for_(0, n, 1, at=la.time, iv_name="lj", tv_name="tja") as lja:
                b.yield_(at=lja.time + 1)
                v = b.read(A, [la.iv, lja.iv], at=lja.time)
                j1 = b.delay(lja.iv, 1, at=lja.time)
                b.write(v, Abw, [la.iv, j1], at=lja.time + 1)

        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="bi", tv_name="tlb") as lb:
            b.yield_(at=lb.time + load_inner)
            with b.for_(0, n, 1, at=lb.time, iv_name="bk", tv_name="tkb") as lkb:
                b.yield_(at=lkb.time + 1)
                v = b.read(B, [lkb.iv, lb.iv], at=lkb.time)
                k1 = b.delay(lkb.iv, 1, at=lkb.time)
                b.write(v, Bbw, [k1, lb.iv], at=lkb.time + 1)

        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="zi", tv_name="tzi") as zi:
            b.yield_(at=zi.time)
            with b.for_(0, n, 1, at=zi.time, unroll=True, iv_name="zj", tv_name="tzj") as zj:
                b.yield_(at=zj.time)
                b.write(0, AccW, [zi.iv, zj.iv], at=zj.time)

        # ---- compute: column-staggered PEs, one MAC issue per n cycles ----
        with b.for_(0, n, 1, at=f.t + COMPUTE_START, unroll=True, iv_name="pi", tv_name="tpi") as pi:
            b.yield_(at=pi.time)
            with b.for_(0, n, 1, at=pi.time, unroll=True, iv_name="pj", tv_name="tpj") as pj:
                b.yield_(at=pj.time + 1)  # column stagger: disjoint residues
                with b.for_(0, n, 1, at=pj.time, iv_name="k", tv_name="tk") as lk:
                    b.yield_(at=lk.time + n)  # II=n: one firing per slot
                    a = b.read(Abr, [pi.iv, lk.iv], at=lk.time)
                    bv = b.read(Bbr, [lk.iv, pj.iv], at=lk.time)
                    old = b.read(AccR, [pi.iv, pj.iv], at=lk.time + 1)
                    s = b.call("mac", [a, bv, old], at=lk.time + 1)
                    b.write(s, AccW, [pi.iv, pj.iv], at=lk.time + 1)

        # ---- drain: identical to gemm ----
        with b.for_(0, n, 1, at=f.t + DRAIN_START, unroll=True, iv_name="di", tv_name="tdi") as di:
            b.yield_(at=di.time + n)
            with b.for_(0, n, 1, at=di.time, unroll=True, iv_name="dj", tv_name="tdj") as dj:
                b.yield_(at=dj.time + 1)
                v = b.read(AccR, [di.iv, dj.iv], at=dj.time)
                b.write(v, C, [di.iv, dj.iv], at=dj.time)
        b.ret()
    return b.module, "gemm_shared"
