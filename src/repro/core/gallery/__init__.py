"""The paper's benchmark kernels (§8, Tables 5–6) written in HIR:

  matrix transpose, 1-d stencil, histogram, GEMM (systolic array),
  2-d convolution, FIFO — plus the paper's two running examples
  (array-add, multiply-accumulate) in correct and deliberately-broken
  versions for the verifier tests (Figs. 1 and 2).

Each module exposes ``build()`` -> (Module, entry_name) and ``oracle(...)``
(NumPy reference).  ``GALLERY`` maps kernel name -> module.
"""

from . import array_add, conv2d, fifo, gemm, histogram, mac, stencil1d, transpose

GALLERY = {
    "transpose": transpose,
    "stencil1d": stencil1d,
    "histogram": histogram,
    "gemm": gemm,
    "conv2d": conv2d,
    "fifo": fifo,
    "array_add": array_add,
    "mac": mac,
}

PAPER_BENCHMARKS = ["transpose", "stencil1d", "histogram", "gemm", "conv2d", "fifo"]
