"""The paper's benchmark kernels (§8, Tables 5–6) written in HIR:

  matrix transpose, 1-d stencil, histogram, GEMM (systolic array),
  2-d convolution, FIFO — plus the paper's two running examples
  (array-add, multiply-accumulate) in correct and deliberately-broken
  versions for the verifier tests (Figs. 1 and 2).

Each module exposes ``build()`` -> (Module, entry_name) and ``oracle(...)``
(NumPy reference).  ``GALLERY`` maps kernel name -> module.

The ``frontend_*`` entries are not hand-written: they are jax.numpy
programs traced into HIR by ``core.frontend`` (matmul, masked fixed-point
softmax row, gated cumulative sum) and registered here so every downstream
harness — differential RTL sim, backend conformance, DSE — exercises the
traced path alongside the hand-scheduled kernels.
"""

from . import (array_add, conv2d, fifo, gemm, gemm_shared, histogram, mac,
               stencil1d, transpose)
from ..frontend.workloads import (frontend_matmul, frontend_scan,
                                  frontend_softmax_row)

GALLERY = {
    "transpose": transpose,
    "stencil1d": stencil1d,
    "histogram": histogram,
    "gemm": gemm,
    "gemm_shared": gemm_shared,
    "conv2d": conv2d,
    "fifo": fifo,
    "array_add": array_add,
    "mac": mac,
    "frontend_matmul": frontend_matmul,
    "frontend_softmax_row": frontend_softmax_row,
    "frontend_scan": frontend_scan,
}

PAPER_BENCHMARKS = ["transpose", "stencil1d", "histogram", "gemm", "conv2d", "fifo"]
