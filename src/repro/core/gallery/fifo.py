"""Synchronous FIFO (paper §8, the Verilog-baseline benchmark).

``fifo_step`` is a one-cycle FIFO tick: predicated (write-enable) push,
show-ahead pop, pointer registers updated every cycle.  The schedule lives in
the function signature (dout has declared delay 1), so the caller composes it
at II=1 with no handshake logic (paper §5.4).  ``fifo_top`` is a driver that
pushes N values then pops them back out to the output interface.
"""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def build(depth: int = 16, n: int = 16):
    assert depth & (depth - 1) == 0, "depth must be a power of two"
    assert n <= depth
    b = Builder(ir.Module("fifo"))

    buf_t = ir.MemrefType((depth,), ir.i32, kind=ir.KIND_LUTRAM)
    st_t = ir.MemrefType((2,), ir.i32, packed=[], kind=ir.KIND_REG)

    with b.func(
        "fifo_step",
        [ir.IntType(1, signed=False), ir.IntType(1, signed=False), ir.i32,
         buf_t.with_port(ir.PORT_R), buf_t.with_port(ir.PORT_W),
         st_t.with_port(ir.PORT_R), st_t.with_port(ir.PORT_W)],
        ["push", "pop", "din", "BufR", "BufW", "SR", "SW"],
        result_types=[ir.i32],
        result_delays=[1],
    ) as g:
        push, pop, din, BufR, BufW, SR, SW = g.args
        wp = b.read(SR, [0], at=g.t)            # registers: same-cycle
        rp = b.read(SR, [1], at=g.t)
        dout = b.read(BufR, [rp], at=g.t)       # show-ahead head, valid t+1
        b.write(din, BufW, [wp], at=g.t, pred=push)
        wp1 = b.add(wp, b.zext(push, ir.i32))
        rp1 = b.add(rp, b.zext(pop, ir.i32))
        b.write(b.and_(wp1, depth - 1), SW, [0], at=g.t)
        b.write(b.and_(rp1, depth - 1), SW, [1], at=g.t)
        b.ret([dout])

    rmem = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((n,), ir.i32, ir.PORT_W)
    with b.func("fifo_top", [rmem, wmem], ["In", "Out"]) as f:
        In, Out = f.args
        BufR, BufW = b.alloc(buf_t, names=["BufR", "BufW"])
        SR, SW = b.alloc(st_t, names=["SR", "SW"])
        b.write(0, SW, [0], at=f.t)
        b.write(0, SW, [1], at=f.t)
        one = b.const(1, ir.IntType(1, signed=False))
        zero = b.const(0, ir.IntType(1, signed=False))
        z32 = b.const(0, ir.i32)

        with b.for_(0, n, 1, at=f.t + 2, iv_name="i", tv_name="ti") as li:
            b.yield_(at=li.time + 1)
            v = b.read(In, [li.iv], at=li.time)
            b.call("fifo_step", [one, zero, v, BufR, BufW, SR, SW], at=li.time + 1)

        with b.for_(0, n, 1, at=li.end + 3, iv_name="j", tv_name="tj") as lj:
            b.yield_(at=lj.time + 1)
            d = b.call("fifo_step", [zero, one, z32, BufR, BufW, SR, SW], at=lj.time)
            j1 = b.delay(lj.iv, 1, at=lj.time)
            b.write(d, Out, [j1], at=lj.time + 1)
        b.ret()
    return b.module, "fifo_top"


def oracle(inp: np.ndarray) -> np.ndarray:
    return inp.copy()


def make_inputs(n: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**20), 2**20, size=(n,), dtype=np.int64)
    return [a, np.zeros((n,), dtype=np.int64)]
