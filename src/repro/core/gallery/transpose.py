"""Matrix transpose (paper Listing 1): sequential outer row loop, pipelined
(II=1) inner column loop; the column index crosses one pipeline stage and is
delayed to stay schedule-valid."""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def build(n: int = 16):
    b = Builder(ir.Module("transpose"))
    rmem = ir.MemrefType((n, n), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((n, n), ir.i32, ir.PORT_W)
    with b.func("transpose", [rmem, wmem], ["Ai", "Co"]) as f:
        Ai, Co = f.args
        with b.for_(0, n, 1, at=f.t + 1, iv_name="i", tv_name="ti") as li:
            with b.for_(0, n, 1, at=li.time + 1, iv_name="j", tv_name="tj") as lj:
                v = b.read(Ai, [li.iv, lj.iv], at=lj.time)           # valid at tj+1
                j1 = b.delay(lj.iv, 1, at=lj.time)                    # j survives II=1
                b.write(v, Co, [j1, li.iv], at=lj.time + 1)
                b.yield_(at=lj.time + 1)                              # II = 1
            b.yield_(at=lj.end + 1)                                   # sequential outer
        b.ret()
    return b.module, "transpose"


def oracle(a: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(a.T)


def make_inputs(n: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**20), 2**20, size=(n, n), dtype=np.int64)
    out = np.zeros((n, n), dtype=np.int64)
    return [a, out]
