"""GEMM on a fully-pipelined 2-D systolic array (paper §7.3, §8).

16x16 x 16x16 int32 matmul:
  * load phase — A is staged into a row-banked local buffer (distributed dim
    0), B into a column-banked buffer (distributed dim 1); bank selection uses
    unroll_for constants (paper Fig. 3 memory banking).
  * compute phase — a 16x16 grid of PEs (nested ``unroll_for``) each runs a
    pipelined II=1 k-loop: every PE row broadcasts A[i,k] (same-address
    parallel reads are legal, §4.4), every PE column broadcasts B[k,j];
    accumulators live in a fully-distributed register bank.
  * drain phase — accumulators stream out through the single C port, one per
    cycle, staggered by unroll_for iteration times.

All phase offsets are compile-time constants, so the entire design is
scheduled on the function's root time variable.
"""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def build(n: int = 16):
    b = Builder(ir.Module("gemm"))
    rmem = ir.MemrefType((n, n), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((n, n), ir.i32, ir.PORT_W)

    load_inner = n + 2          # per-bank load loop latency (II=1 + pipeline drain)
    LOAD = n * load_inner       # staggered across banks (single A/B input port)
    COMPUTE_START = 1 + LOAD + 1
    DRAIN_START = COMPUTE_START + n + 3

    # the PE compute op: one multiply-accumulate step, combinational
    # (result delay 0) — every PE of the systolic grid calls it, so in
    # hierarchical emission the grid is 256 instances of this one module
    with b.func(
        "mac",
        [ir.i32, ir.i32, ir.i32],
        ["a", "bb", "c"],
        result_types=[ir.i32],
        result_delays=[0],
    ) as g:
        ga, gb, gc = g.args
        gm = b.mult(ga, gb, at=g.t)
        b.ret([b.add(gm, gc)])

    with b.func("gemm", [rmem, rmem, wmem], ["A", "B", "C"]) as f:
        A, B, C = f.args
        # row-banked A buffer: dim0 distributed (16 banks), dim1 packed
        abuf_t = ir.MemrefType((n, n), ir.i32, packed=[1], kind=ir.KIND_LUTRAM)
        Abr, Abw = b.alloc(abuf_t, names=["Abr", "Abw"])
        # column-banked B buffer: dim1 distributed, dim0 packed
        bbuf_t = ir.MemrefType((n, n), ir.i32, packed=[0], kind=ir.KIND_LUTRAM)
        Bbr, Bbw = b.alloc(bbuf_t, names=["Bbr", "Bbw"])
        # PE accumulators: fully distributed register bank
        acc_t = ir.MemrefType((n, n), ir.i32, packed=[], kind=ir.KIND_REG)
        AccR, AccW = b.alloc(acc_t, names=["AccR", "AccW"])

        # ---- load A (banks staggered: one element/cycle on the A port) ----
        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="li", tv_name="tla") as la:
            b.yield_(at=la.time + load_inner)  # stagger = inner latency
            with b.for_(0, n, 1, at=la.time, iv_name="lj", tv_name="tja") as lja:
                b.yield_(at=lja.time + 1)
                v = b.read(A, [la.iv, lja.iv], at=lja.time)
                j1 = b.delay(lja.iv, 1, at=lja.time)
                b.write(v, Abw, [la.iv, j1], at=lja.time + 1)

        # ---- load B (parallel with A: separate input port) ----
        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="bi", tv_name="tlb") as lb:
            b.yield_(at=lb.time + load_inner)
            with b.for_(0, n, 1, at=lb.time, iv_name="bk", tv_name="tkb") as lkb:
                b.yield_(at=lkb.time + 1)
                v = b.read(B, [lkb.iv, lb.iv], at=lkb.time)
                k1 = b.delay(lkb.iv, 1, at=lkb.time)
                b.write(v, Bbw, [k1, lb.iv], at=lkb.time + 1)

        # ---- zero the accumulators (all banks in parallel at t+1) ----
        with b.for_(0, n, 1, at=f.t + 1, unroll=True, iv_name="zi", tv_name="tzi") as zi:
            b.yield_(at=zi.time)
            with b.for_(0, n, 1, at=zi.time, unroll=True, iv_name="zj", tv_name="tzj") as zj:
                b.yield_(at=zj.time)
                b.write(0, AccW, [zi.iv, zj.iv], at=zj.time)

        # ---- systolic compute: 16x16 PEs, pipelined k-loop (II=1) ----
        with b.for_(0, n, 1, at=f.t + COMPUTE_START, unroll=True, iv_name="pi", tv_name="tpi") as pi:
            b.yield_(at=pi.time)
            with b.for_(0, n, 1, at=pi.time, unroll=True, iv_name="pj", tv_name="tpj") as pj:
                b.yield_(at=pj.time)
                with b.for_(0, n, 1, at=pj.time, iv_name="k", tv_name="tk") as lk:
                    b.yield_(at=lk.time + 1)
                    a = b.read(Abr, [pi.iv, lk.iv], at=lk.time)      # bank pi, addr k
                    bv = b.read(Bbr, [lk.iv, pj.iv], at=lk.time)     # bank pj, addr k
                    old = b.read(AccR, [pi.iv, pj.iv], at=lk.time + 1)
                    s = b.call("mac", [a, bv, old], at=lk.time + 1)  # comb, at tk+1
                    b.write(s, AccW, [pi.iv, pj.iv], at=lk.time + 1)

        # ---- drain: one result per cycle through the C port ----
        with b.for_(0, n, 1, at=f.t + DRAIN_START, unroll=True, iv_name="di", tv_name="tdi") as di:
            b.yield_(at=di.time + n)  # row stagger
            with b.for_(0, n, 1, at=di.time, unroll=True, iv_name="dj", tv_name="tdj") as dj:
                b.yield_(at=dj.time + 1)  # element stagger
                v = b.read(AccR, [di.iv, dj.iv], at=dj.time)  # registers: same cycle
                b.write(v, C, [di.iv, dj.iv], at=dj.time)
        b.ret()
    return b.module, "gemm"


def oracle(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int64)


def make_inputs(n: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-(2**10), 2**10, size=(n, n), dtype=np.int64)
    bb = rng.integers(-(2**10), 2**10, size=(n, n), dtype=np.int64)
    return [a, bb, np.zeros((n, n), dtype=np.int64)]
