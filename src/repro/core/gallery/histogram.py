"""Histogram (paper §8): data-dependent addressing into a local bin buffer.
The read-modify-write on the bin RAM is a loop-carried dependence through
memory, so the main loop runs at II=2 (read bin at ti+1, write back at ti+2;
the next iteration's read then observes the committed update)."""

from __future__ import annotations

import numpy as np

from .. import ir
from ..builder import Builder


def build(n: int = 64, bins: int = 16):
    b = Builder(ir.Module("histogram"))
    rmem = ir.MemrefType((n,), ir.i32, ir.PORT_R)
    wmem = ir.MemrefType((bins,), ir.i32, ir.PORT_W)
    with b.func("histogram", [rmem, wmem], ["Img", "Out"]) as f:
        Img, Out = f.args
        hist_t = ir.MemrefType((bins,), ir.i32, kind=ir.KIND_BRAM)
        Hr, Hw = b.alloc(hist_t, names=["Hr", "Hw"])

        # clear the bins (II=1)
        with b.for_(0, bins, 1, at=f.t + 1, iv_name="c", tv_name="tc") as lc:
            b.yield_(at=lc.time + 1)
            b.write(0, Hw, [lc.iv], at=lc.time)

        # main loop: II=2 because of the RMW recurrence through the bin RAM
        with b.for_(0, n, 1, at=lc.end + 1, iv_name="i", tv_name="ti") as li:
            b.yield_(at=li.time + 2)
            v = b.read(Img, [li.iv], at=li.time)          # bin index, valid ti+1
            h = b.read(Hr, [v], at=li.time + 1)           # bin value, valid ti+2
            h1 = b.add(h, 1)                              # ti+2
            v1 = b.delay(v, 1, at=li.time + 1)            # bin index again at ti+2
            b.write(h1, Hw, [v1], at=li.time + 2)
        # drain bins to the output interface (II=1)
        with b.for_(0, bins, 1, at=li.end + 2, iv_name="d", tv_name="td") as ld:
            b.yield_(at=ld.time + 1)
            hv = b.read(Hr, [ld.iv], at=ld.time)
            d1 = b.delay(ld.iv, 1, at=ld.time)
            b.write(hv, Out, [d1], at=ld.time + 1)
        b.ret()
    return b.module, "histogram"


def oracle(img: np.ndarray, bins: int = 16) -> np.ndarray:
    return np.bincount(img, minlength=bins).astype(np.int64)


def make_inputs(n: int = 64, bins: int = 16, seed: int = 0):
    rng = np.random.default_rng(seed)
    img = rng.integers(0, bins, size=(n,), dtype=np.int64)
    return [img, np.zeros((bins,), dtype=np.int64)]
