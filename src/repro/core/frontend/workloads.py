"""Traced gallery workloads: real jax.numpy programs compiled to hardware
through the frontend tracer.

Each workload follows the gallery module protocol — ``build(**kw)`` ->
``(Module, entry)``, ``oracle(*inputs)`` (NumPy reference), and
``make_inputs(seed=..., **kw)`` — so the PR 7 differential harness, the
backend conformance suites, and the DSE explorer all pick them up
unchanged.  The JAX source *is* the specification: every oracle below is
the same arithmetic re-written in NumPy int64, and the differential tests
check the traced hardware against it on hundreds of stimulus vectors.

All three kernels are integer/fixed-point (the frontend's dtype policy):

  ``frontend_matmul``       A @ B through ``dot_general`` -> the tiled
                            mac-calling PE nest;
  ``frontend_softmax_row``  a masked fixed-point base-2 softmax row
                            (exact in int32: weights are ``FP >> shift``)
                            -> where/reduce/broadcast nests;
  ``frontend_scan``         a gated cumulative sum -> the sequential
                            register-accumulator recurrence.
"""

from __future__ import annotations

import numpy as np

_FP_BITS = 12          # fixed-point fraction bits of the softmax weights
_NEG_INF = -(1 << 20)  # masked-score sentinel (far below any real score)
_SH_MAX = 24           # clamp on the weight shift (2**-24 underflows to 0)


# --------------------------------------------------------------------------
# frontend_matmul


class frontend_matmul:
    """int32 matmul, traced from ``jnp.matmul`` (tile = accumulator bank)."""

    @staticmethod
    def build(m: int = 4, k: int = 4, n: int = 4, tile: int = 2):
        import jax.numpy as jnp

        from .tracer import trace

        def fn(a, b):
            return jnp.matmul(a, b)

        return trace(fn, [(m, k), (k, n)], name="frontend_matmul",
                     tile=tile, arg_names=["A", "B"])

    @staticmethod
    def oracle(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int64)

    @staticmethod
    def make_inputs(m: int = 4, k: int = 4, n: int = 4, seed: int = 0):
        rng = np.random.default_rng(seed)
        a = rng.integers(-(2 ** 9), 2 ** 9, size=(m, k), dtype=np.int64)
        b = rng.integers(-(2 ** 9), 2 ** 9, size=(k, n), dtype=np.int64)
        return [a, b, np.zeros((m, n), dtype=np.int64)]


# --------------------------------------------------------------------------
# frontend_softmax_row


class frontend_softmax_row:
    """Masked fixed-point softmax over one row of scores.

    Base-2, integer-exact: each weight is ``FP >> min(max - s, 24)`` (a
    power-of-two approximation of ``exp2(s - max)`` in Q12), normalized by
    the masked weight sum.  Masked-out lanes produce exactly 0; an all-
    masked row produces all-zeros (the ``max(total, 1)`` guard).  Every
    intermediate fits comfortably in int32, so the NumPy int64 oracle and
    the int32 hardware agree bit-for-bit.
    """

    @staticmethod
    def build(n: int = 8):
        import jax.numpy as jnp
        from jax import lax

        from .tracer import trace

        fp = 1 << _FP_BITS

        def fn(s, mask):
            sm = jnp.where(mask > 0, s, _NEG_INF)
            m = jnp.max(sm)
            sh = jnp.minimum(m - sm, _SH_MAX)
            w = jnp.where(mask > 0, fp >> sh, 0)
            total = jnp.maximum(jnp.sum(w), 1)
            return lax.div(w * fp, jnp.broadcast_to(total, w.shape))

        return trace(fn, [(n,), (n,)], name="frontend_softmax_row",
                     arg_names=["S", "MASK"])

    @staticmethod
    def oracle(s: np.ndarray, mask: np.ndarray) -> np.ndarray:
        fp = 1 << _FP_BITS
        s = s.astype(np.int64)
        sm = np.where(mask > 0, s, _NEG_INF)
        m = sm.max()
        sh = np.minimum(m - sm, _SH_MAX)
        w = np.where(mask > 0, fp >> sh, 0)
        total = max(int(w.sum()), 1)
        return (w * fp) // total

    @staticmethod
    def make_inputs(n: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        s = rng.integers(-(2 ** 10), 2 ** 10, size=n, dtype=np.int64)
        mask = (rng.random(n) < 0.75).astype(np.int64)
        if seed % 7 == 0:
            mask[:] = 0  # exercise the all-masked row regularly
        return [s, mask, np.zeros(n, dtype=np.int64)]


# --------------------------------------------------------------------------
# frontend_scan


class frontend_scan:
    """Gated running sum: ``cumsum(where(g > 0, x, 0))`` — the associative-
    scan idiom traced into a sequential register recurrence."""

    @staticmethod
    def build(n: int = 8):
        import jax.numpy as jnp

        from .tracer import trace

        def fn(x, g):
            return jnp.cumsum(jnp.where(g > 0, x, 0))

        return trace(fn, [(n,), (n,)], name="frontend_scan",
                     arg_names=["X", "G"])

    @staticmethod
    def oracle(x: np.ndarray, g: np.ndarray) -> np.ndarray:
        return np.cumsum(np.where(g > 0, x.astype(np.int64), 0))

    @staticmethod
    def make_inputs(n: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        x = rng.integers(-(2 ** 10), 2 ** 10, size=n, dtype=np.int64)
        g = (rng.random(n) < 0.5).astype(np.int64)
        return [x, g, np.zeros(n, dtype=np.int64)]


FRONTEND_WORKLOADS = {
    "frontend_matmul": frontend_matmul,
    "frontend_softmax_row": frontend_softmax_row,
    "frontend_scan": frontend_scan,
}
