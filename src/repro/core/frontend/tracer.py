"""JAX -> HIR frontend tracer (the mirror image of ``lower/to_jax.py``).

``trace(fn, in_shapes, name=...)`` abstractly evaluates a restricted
jax/jax.numpy program (via ``jax.make_jaxpr``) and rebuilds it as an
``hir.func``: every jaxpr equation becomes a bounded ``hir.for`` nest over
the HIR memref holding its result —

  * elementwise primitives -> one loop nest per equation (read operands,
    one arith chain, write the destination buffer);
  * ``reduce_sum`` / ``reduce_max`` / ``reduce_min`` -> an init nest plus a
    read-modify-write reduction nest (the histogram idiom);
  * ``cumsum`` -> a sequential recurrence loop through a register
    accumulator (the fifo/mac idiom);
  * ``dot_general`` (2-D matmul) -> a tiled i/jo/k/ji nest calling a shared
    combinational ``mac`` function, with a ``(tile,)`` register accumulator
    bank — the PE-array idiom of the gallery GEMM, with the column tile as
    the frontend's loop-level design knob;
  * ``broadcast_in_dim`` -> a zero-cost index-remapping view.

The tracer emits a *naive* sequential schedule whose only job is to pin the
program order (every op gets a monotone time offset), then hands the design
to the HLS pipeline: ``erase_schedule`` + ``hls_schedule`` produce the real
schedule, so traced designs share the exact verification/codegen path as
the hand-written gallery.

Dtype policy: integer-only (int32 data, bools as 0/1 i32).  Anything float
raises ``FrontendError`` — fixed-point integer kernels are the supported
hardware target (see README "Frontend" for the rationale and the supported
primitive table).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .. import ir
from ..builder import Builder


class FrontendError(NotImplementedError):
    """The traced program falls outside the supported JAX subset."""


class UnsupportedPrimitiveError(FrontendError):
    """A jaxpr equation uses a primitive the frontend cannot lower."""


# --------------------------------------------------------------------------
# traced values


class _Const:
    """Rank-0 integer literal."""

    __slots__ = ("v",)
    shape: tuple = ()

    def __init__(self, v):
        self.v = int(v)


class _Buf:
    """A (possibly index-remapped view of a) memref read port."""

    __slots__ = ("rd", "shape", "index")

    def __init__(self, rd, shape, index=None):
        self.rd = rd
        self.shape = tuple(shape)
        self.index = index if index is not None else (lambda ids: list(ids))


class _Alloc:
    """A local buffer: read + write ports plus the memref index mapping
    (rank-0 values live in a shape-(1,) register)."""

    __slots__ = ("rd", "wr", "shape")

    def __init__(self, rd, wr, shape):
        self.rd = rd
        self.wr = wr
        self.shape = tuple(shape)

    def midx(self, ids):
        return list(ids) if self.shape else [0]

    def view(self) -> _Buf:
        return _Buf(self.rd, self.shape, index=self.midx)


# --------------------------------------------------------------------------
# elementwise primitive table: jax primitive name -> emitter(tr, *scalars)

def _ew(opname: str):
    return lambda tr, *xs: tr.arith(opname, *xs)


def _cmp(kind: str):
    def f(tr, a, b):
        return tr.b.zext(tr.b.cmp(kind, a, b, at=tr.tick()), ir.i32,
                         at=tr.tick())
    return f


def _minmax(kind: str):
    def f(tr, a, b):
        c = tr.b.cmp(kind, a, b, at=tr.tick())
        # explicit i32 result: the default type inference picks the first
        # primitive operand, which here is the 1-bit compare
        return tr.b._arith("select", c, a, b, at=tr.tick(),
                           result_type=ir.i32)
    return f


_ELEMENTWISE: dict[str, Callable] = {
    "add": _ew("add"),
    "sub": _ew("sub"),
    "mul": _ew("mult"),
    "div": _ew("div"),
    "and": _ew("and"),
    "or": _ew("or"),
    "xor": _ew("xor"),
    "shift_left": _ew("shl"),
    "shift_right_arithmetic": _ew("shr"),
    "neg": lambda tr, a: tr.b.sub(0, a, at=tr.tick()),
    "max": _minmax("ge"),
    "min": _minmax("le"),
    "lt": _cmp("lt"),
    "le": _cmp("le"),
    "eq": _cmp("eq"),
    "ne": _cmp("ne"),
    "gt": _cmp("gt"),
    "ge": _cmp("ge"),
    # select_n picks cases[pred]; hir.select picks a when cond != 0
    "select_n": lambda tr, p, c0, c1: tr.b._arith(
        "select", p, c1, c0, at=tr.tick(), result_type=ir.i32),
}

#: primitives that are identity at the integer-only level
_IDENTITY = ("convert_element_type", "stop_gradient", "copy")

_REDUCE_INIT = {"reduce_sum": 0, "reduce_max": -(1 << 30),
                "reduce_min": (1 << 30)}

SUPPORTED_PRIMITIVES = tuple(sorted(
    set(_ELEMENTWISE) | set(_IDENTITY) | set(_REDUCE_INIT)
    | {"broadcast_in_dim", "cumsum", "dot_general", "pjit"}))


def _check_int(aval, what: str) -> None:
    if not (np.issubdtype(aval.dtype, np.integer)
            or np.issubdtype(aval.dtype, np.bool_)):
        raise FrontendError(
            f"frontend is integer-only (int32 / bool): {what} has dtype "
            f"{aval.dtype}; express the kernel in fixed point")


class _Tracer:
    def __init__(self, b: Builder, root_time: ir.Time, tile: int):
        self.b = b
        self.tile = tile
        self.clocks: list[list] = [[root_time, 0]]
        self.n = 0          # unique-name counter (ivs, time vars, buffers)
        self.n_buf = 0

    # -- naive-schedule clock ------------------------------------------------
    def now(self) -> ir.Time:
        base, off = self.clocks[-1]
        return base + off

    def tick(self) -> ir.Time:
        t = self.now()
        self.clocks[-1][1] += 1
        return t

    def arith(self, opname: str, *xs) -> ir.Value:
        return self.b._arith(opname, *xs, at=self.tick())

    # -- loops ---------------------------------------------------------------
    @contextmanager
    def loop(self, n: int, unroll: bool = False):
        at = self.tick()
        k = self.n
        self.n += 1
        with self.b.for_(0, n, 1, at=at, unroll=unroll, iv_name=f"i{k}",
                         tv_name=f"t{k}") as lp:
            self.clocks.append([lp.time, 0])
            try:
                yield lp.iv
            finally:
                _, off = self.clocks.pop()
                self.b.yield_(at=lp.time + max(off, 1))

    def nest(self, shape: Sequence[int], body: Callable[[list], None]) -> None:
        """Run ``body(ids)`` inside a loop nest over ``shape`` (no loops for
        rank-0: the body runs in the current region)."""
        def rec(ids):
            if len(ids) == len(shape):
                body(ids)
                return
            with self.loop(shape[len(ids)]) as iv:
                rec(ids + [iv])
        rec([])

    # -- buffers --------------------------------------------------------------
    def new_buf(self, shape: Sequence[int], tag: str = "b",
                reg: bool = False) -> _Alloc:
        """Local buffer: BRAM for arrays, a fully-distributed register bank
        for rank-0 values and ``reg=True`` (parallel-access accumulators)."""
        shape = tuple(shape)
        k = self.n_buf
        self.n_buf += 1
        if shape and not reg:
            mt = ir.MemrefType(shape, ir.i32)
        else:
            mt = ir.MemrefType(shape or (1,), ir.i32, packed=[],
                               kind=ir.KIND_REG)
        rd, wr = self.b.alloc(mt, names=[f"{tag}{k}r", f"{tag}{k}w"])
        return _Alloc(rd, wr, shape)

    def elem(self, val, ids):
        """One scalar element of a traced value at loop indices ``ids``."""
        if isinstance(val, _Const):
            return val.v
        return self.b.read(val.rd, val.index(ids), at=self.tick())

    # -- jaxpr environment -----------------------------------------------------
    def lift_const(self, c) -> _Const:
        v = np.asarray(c)
        _check_int(v, "constant")
        if v.ndim == 0:
            return _Const(v)
        raise FrontendError(
            "array-valued constants are not supported; pass the array "
            "as a traced input instead")

    def val(self, env: dict, atom):
        from jax import core as jax_core

        if isinstance(atom, jax_core.Literal):
            return self.lift_const(atom.val)
        return env[atom]

    # -- equation handlers ------------------------------------------------------
    def eval_jaxpr(self, jaxpr, env: dict) -> None:
        for eqn in jaxpr.eqns:
            self.eval_eqn(eqn, env)

    def eval_eqn(self, eqn, env: dict) -> None:
        p = eqn.primitive.name
        if p == "pjit" or p == "closed_call":
            inner = eqn.params["jaxpr"]
            sub = {v: self.val(env, a)
                   for v, a in zip(inner.jaxpr.invars, eqn.invars)}
            for cv, c in zip(inner.jaxpr.constvars, inner.consts):
                sub[cv] = self.lift_const(c)
            self.eval_jaxpr(inner.jaxpr, sub)
            for ov, res in zip(eqn.outvars, inner.jaxpr.outvars):
                env[ov] = self.val(sub, res)
            return
        if p in _IDENTITY:
            _check_int(eqn.outvars[0].aval, f"'{p}' result")
            env[eqn.outvars[0]] = self.val(env, eqn.invars[0])
            return
        if p == "broadcast_in_dim":
            self.eval_broadcast(eqn, env)
            return
        if p in _ELEMENTWISE:
            self.eval_elementwise(eqn, env)
            return
        if p in _REDUCE_INIT:
            self.eval_reduce(eqn, env)
            return
        if p == "cumsum":
            self.eval_cumsum(eqn, env)
            return
        if p == "dot_general":
            self.eval_dot_general(eqn, env)
            return
        raise UnsupportedPrimitiveError(
            f"frontend: unsupported JAX primitive '{p}'; supported "
            f"primitives are: {', '.join(SUPPORTED_PRIMITIVES)}")

    def eval_broadcast(self, eqn, env: dict) -> None:
        src = self.val(env, eqn.invars[0])
        oshape = tuple(eqn.params["shape"])
        bdims = tuple(eqn.params["broadcast_dimensions"])
        if isinstance(src, _Const):
            env[eqn.outvars[0]] = src
            return
        sshape = src.shape
        inner = src.index

        def index(ids, _ss=sshape, _bd=bdims, _osh=oshape):
            return inner([ids[d] if _ss[k] == _osh[d] else 0
                          for k, d in enumerate(_bd)])

        env[eqn.outvars[0]] = _Buf(src.rd, oshape, index=index)

    def eval_elementwise(self, eqn, env: dict) -> None:
        out = eqn.outvars[0]
        _check_int(out.aval, f"'{eqn.primitive.name}' result")
        oshape = tuple(out.aval.shape)
        vals = [self.val(env, a) for a in eqn.invars]
        for v in vals:
            if isinstance(v, _Buf) and v.shape not in (oshape, ()):
                raise FrontendError(
                    f"'{eqn.primitive.name}' operand shape {v.shape} does "
                    f"not match result shape {oshape} (missing broadcast?)")
        if eqn.primitive.name == "select_n" and len(vals) != 3:
            raise UnsupportedPrimitiveError(
                "select_n with more than two cases is not supported")
        impl = _ELEMENTWISE[eqn.primitive.name]
        dst = self.new_buf(oshape)

        def body(ids):
            xs = [self.elem(v, ids) for v in vals]
            self.b.write(impl(self, *xs), dst.wr, dst.midx(ids),
                         at=self.tick())

        self.nest(oshape, body)
        env[out] = dst.view()

    def eval_reduce(self, eqn, env: dict) -> None:
        out = eqn.outvars[0]
        _check_int(out.aval, f"'{eqn.primitive.name}' result")
        src = self.val(env, eqn.invars[0])
        axes = set(eqn.params["axes"])
        ishape = tuple(eqn.invars[0].aval.shape)
        oshape = tuple(out.aval.shape)
        dst = self.new_buf(oshape, tag="red")
        init = _REDUCE_INIT[eqn.primitive.name]
        self.nest(oshape, lambda ids: self.b.write(
            init, dst.wr, dst.midx(ids), at=self.tick()))

        def body(ids):
            oids = [iv for d, iv in enumerate(ids) if d not in axes]
            acc = self.b.read(dst.rd, dst.midx(oids), at=self.tick())
            x = self.elem(src, ids)
            if eqn.primitive.name == "reduce_sum":
                r = self.b.add(acc, x, at=self.tick())
            else:
                kind = "ge" if eqn.primitive.name == "reduce_max" else "le"
                c = self.b.cmp(kind, acc, x, at=self.tick())
                r = self.b._arith("select", c, acc, x, at=self.tick(),
                                  result_type=ir.i32)
            self.b.write(r, dst.wr, dst.midx(oids), at=self.tick())

        self.nest(ishape, body)
        env[out] = dst.view()

    def eval_cumsum(self, eqn, env: dict) -> None:
        out = eqn.outvars[0]
        _check_int(out.aval, "'cumsum' result")
        src = self.val(env, eqn.invars[0])
        shape = tuple(eqn.invars[0].aval.shape)
        if len(shape) != 1 or eqn.params.get("reverse"):
            raise UnsupportedPrimitiveError(
                "cumsum is supported on rank-1 arrays, forward only "
                f"(got shape {shape}, reverse={eqn.params.get('reverse')})")
        dst = self.new_buf(shape, tag="scan")
        acc = self.new_buf((), tag="acc")
        self.b.write(0, acc.wr, [0], at=self.tick())

        def body(ids):
            x = self.elem(src, ids)
            a = self.b.read(acc.rd, [0], at=self.tick())
            s = self.b.add(a, x, at=self.tick())
            self.b.write(s, acc.wr, [0], at=self.tick())
            self.b.write(s, dst.wr, dst.midx(ids), at=self.tick())

        self.nest(shape, body)
        env[out] = dst.view()

    def eval_dot_general(self, eqn, env: dict) -> None:
        out = eqn.outvars[0]
        _check_int(out.aval, "'dot_general' result")
        dn = eqn.params["dimension_numbers"]
        a_val = self.val(env, eqn.invars[0])
        b_val = self.val(env, eqn.invars[1])
        ashape = tuple(eqn.invars[0].aval.shape)
        bshape = tuple(eqn.invars[1].aval.shape)
        if (len(ashape), len(bshape)) != (2, 2) or \
                tuple(map(tuple, dn[0])) != ((1,), (0,)) or any(dn[1]):
            raise UnsupportedPrimitiveError(
                "dot_general is supported as plain 2-D matmul "
                f"(contract a.dim1 with b.dim0, no batch dims; got {dn})")
        m, kk = ashape
        n = bshape[1]
        t = self.tile if self.tile and n % self.tile == 0 else 1
        dst = self.new_buf((m, n), tag="mm")
        # per-tile accumulators: a small local RAM cycled read-modify-write
        # (the histogram idiom); A elements are read once per (i, jo, k) and
        # reused across the ji tile — the tile width is the reuse knob
        acc = self.new_buf((t,), tag="acc")

        b = self.b
        with self.loop(m) as i:
            with self.loop(n // t) as jo:
                with self.loop(t) as ji:
                    b.write(0, acc.wr, [ji], at=self.tick())
                with self.loop(kk) as k:
                    a_el = self.elem(a_val, [i, k])
                    with self.loop(t) as ji:
                        col = self.arith(
                            "add", self.arith("mult", jo, t), ji)
                        b_el = self.elem(b_val, [k, col])
                        old = b.read(acc.rd, [ji], at=self.tick())
                        s = b.call("mac", [a_el, b_el, old], at=self.tick())
                        b.write(s, acc.wr, [ji], at=self.tick())
                with self.loop(t) as ji:
                    col = self.arith("add", self.arith("mult", jo, t), ji)
                    v = b.read(acc.rd, [ji], at=self.tick())
                    b.write(v, dst.wr, [i, col], at=self.tick())
        env[out] = dst.view()


def _walk_jaxprs(jaxpr):
    """Yield jaxpr and every sub-jaxpr reachable through eqn params."""
    from jax import core as jax_core

    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            if isinstance(v, jax_core.ClosedJaxpr):
                yield from _walk_jaxprs(v.jaxpr)
            elif isinstance(v, jax_core.Jaxpr):
                yield from _walk_jaxprs(v)


def _uses_prim(jaxpr, name: str) -> bool:
    return any(eqn.primitive.name == name
               for j in _walk_jaxprs(jaxpr) for eqn in j.eqns)


def trace(fn: Callable, in_shapes: Sequence[Sequence[int]], *,
          name: str, tile: int = 2,
          arg_names: Optional[Sequence[str]] = None,
          schedule: bool = True, cache: bool = True,
          scheduler_options: Any = None):
    """Trace ``fn`` over int32 inputs of ``in_shapes`` into a scheduled HIR
    module.  Returns ``(Module, entry_name)`` — the gallery ``build()``
    contract, so traced kernels drop into every downstream harness
    (``run_differential``, ``hls_compile``, ``explore_design``).

    ``tile`` is the loop-level design knob for ``dot_general`` (column-tile
    width / accumulator-bank size; must divide N, else falls back to 1).
    ``schedule=False`` returns the *unscheduled* (erased) design for callers
    that schedule themselves; ``cache`` forwards to the process-wide
    ``ScheduleCache`` keyed by structural fingerprint."""
    import jax

    from ..hls import erase_schedule, hls_schedule

    examples = [np.zeros(tuple(s) or (), np.int32) for s in in_shapes]
    closed = jax.make_jaxpr(fn)(*examples)
    jaxpr = closed.jaxpr
    for v in jaxpr.invars:
        _check_int(v.aval, "input")
    for v in jaxpr.outvars:
        _check_int(v.aval, "output")

    b = Builder(ir.Module(name))
    if _uses_prim(jaxpr, "dot_general"):
        # the shared PE compute op (create it *before* the main func: the
        # builder hoists constants into region_stack[0], which must be the
        # function under construction)
        with b.func("mac", [ir.i32, ir.i32, ir.i32], ["a", "bb", "c"],
                    result_types=[ir.i32], result_delays=[0]) as g:
            ga, gb, gc = g.args
            b.ret([b.add(b.mult(ga, gb, at=g.t), gc)])

    names = list(arg_names or [f"in{i}" for i in range(len(jaxpr.invars))])
    assert len(names) == len(jaxpr.invars), (names, len(jaxpr.invars))
    outs = jaxpr.outvars
    out_names = ["out"] if len(outs) == 1 else [f"out{i}"
                                               for i in range(len(outs))]
    arg_types = [ir.MemrefType(tuple(v.aval.shape) or (1,), ir.i32,
                               ir.PORT_R) for v in jaxpr.invars]
    arg_types += [ir.MemrefType(tuple(v.aval.shape) or (1,), ir.i32,
                                ir.PORT_W) for v in outs]

    with b.func(name, arg_types, names + out_names) as f:
        tr = _Tracer(b, f.t + 1, tile)
        env: dict = {}
        for var, arg in zip(jaxpr.invars, f.args):
            shape = tuple(var.aval.shape)
            env[var] = _Buf(arg, shape,
                            index=None if shape else (lambda ids: [0]))
        for cv, c in zip(jaxpr.constvars, closed.consts):
            env[cv] = tr.lift_const(c)
        tr.eval_jaxpr(jaxpr, env)
        for ov, out_arg in zip(outs, f.args[len(names):]):
            val = tr.val(env, ov)
            oshape = tuple(ov.aval.shape)

            def copy(ids, _v=val, _a=out_arg, _sh=oshape):
                x = tr.elem(_v, ids)
                tr.b.write(x, _a, list(ids) if _sh else [0], at=tr.tick())

            tr.nest(oshape, copy)
        b.ret()

    um = erase_schedule(b.module)
    if schedule:
        hls_schedule(um, options=scheduler_options,
                     cache=True if cache else None)
    return um, name
