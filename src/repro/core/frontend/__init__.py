"""Model-to-accelerator frontend: trace restricted jax/jax.numpy programs
into scheduled HIR designs (see ``tracer`` for the supported subset and
``workloads`` for the traced gallery kernels)."""

from .tracer import (FrontendError, SUPPORTED_PRIMITIVES,  # noqa: F401
                     UnsupportedPrimitiveError, trace)
from .workloads import (FRONTEND_WORKLOADS, frontend_matmul,  # noqa: F401
                        frontend_scan, frontend_softmax_row)
