"""HLS-style automatic scheduler — the in-repo stand-in for the paper's
Vivado HLS comparison point (Tables 5 and 6).

Given *unscheduled* HIR (see ``eraser``), this pipeline performs what a
high-level synthesis compiler performs between its IR and RTL:

  1. dependence analysis — the shared ``core.analysis`` edge builder: SSA
     dataflow edges with operation latencies; memory dependence edges per
     tensor (conservative serialization of scopes that share storage,
     distance-1 carried dependences for data-dependent addresses, none for
     iteration-private affine accesses);
  2. operator chaining under a 200 MHz timing model (combinational delays
     accumulate along same-cycle chains up to the clock budget);
  3. modulo scheduling of innermost loops — search II = 1, 2, ... with the
     shared ``core.schedule`` engine (resource-constrained list scheduling
     over a modulo reservation table, one access per cycle per memref port
     bank); outer loops run sequentially (II = iteration latency),
     Vivado-style.  ``pipeline_loops=False`` disables the modulo search and
     emits a fully sequential schedule — the input the ``pipeline-loop``
     transform pass starts from;
  4. unroll-parallelism legality — an ``unroll_for``'s iterations run fully
     parallel (stagger 0) only if every touched storage is either banked by
     the unroll IV (distributed-dim index, including compile-time-constant
     IVs) or broadcast (address independent of the IV); otherwise iterations
     are staggered by the body span;
  5. SDC-style refinement — difference constraints relaxed to fixpoint
     (Bellman–Ford longest path), re-run after every reservation bump;
  6. pipeline balancing — ``hir.delay`` ops inserted so every operand arrives
     exactly at its consumption cycle (shared ``core.schedule.balance_delays``);
  7. emission — yields/iter offsets written back; the result is ordinary
     scheduled HIR consumed by the standard verifier + Verilog backend.

Steps 1–5 are the *search* that HIR's explicit schedules make unnecessary —
the codegen-time gap measured in the Table 6 benchmark is the cost of this
search (no artificial sleeps)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .. import ir
from ..analysis import MemTouches, build_dependence_edges
from ..ir import ForOp, FuncOp, Module, Operation, Region, Time, Value
from ..schedule import MAX_II, balance_delays, try_modulo_schedule


@dataclass
class HLSResult:
    module: Module
    iis: dict[str, int] = field(default_factory=dict)
    search_iters: int = 0
    sched_ops: int = 0
    delays_inserted: int = 0
    # the PassManager that optimized the scheduled module (hls_compile only);
    # read .stats_dict() for per-pass timing/rewrite statistics
    pass_manager: Optional[object] = None


class HLSScheduler:
    def __init__(self, module: Module, pipeline_loops: bool = True):
        self.module = module
        self.pipeline_loops = pipeline_loops
        self.result = HLSResult(module)
        self.loop_latency: dict[ForOp, int] = {}
        self.touches = MemTouches()

    # ------------------------------------------------------------------
    def run(self) -> HLSResult:
        for f in self.module.funcs.values():
            if f.attrs.get("external"):
                continue
            self._schedule_region(f, f.body, f.time_var, None)
            self.result.delays_inserted += balance_delays(f)
        return self.result

    def _latency(self, op: Operation) -> int:
        if op.opname == "mem_read":
            return op.operands[0].type.read_latency()
        if op.opname == "mem_write":
            return 1
        if op.opname == "call":
            ds = op.attrs.get("result_delays", ())
            return max(ds) if ds else 0
        if isinstance(op, ForOp):
            return self.loop_latency.get(op, 1)
        if op.opname in ir.ARITH_OPS:
            return op.attrs.get("stages", 0)
        return 0

    # -- region scheduling ----------------------------------------------------
    def _schedule_region(self, f: FuncOp, region: Region, root: Value,
                         loop: Optional[ForOp]) -> tuple[int, int]:
        """Returns (span, ii_or_stagger)."""
        # bottom-up: nested loops first
        has_loop_child = False
        for op in region.ops:
            if isinstance(op, ForOp):
                has_loop_child = True
                span_c, ii_c = self._schedule_region(f, op.region(0), op.time_var, op)
                trip = op.trip_count() or 1
                if op.opname == "unroll_for":
                    self.loop_latency[op] = trip * ii_c + (span_c if ii_c == 0 else max(0, span_c - ii_c))
                else:
                    self.loop_latency[op] = trip * ii_c + max(0, span_c - ii_c)

        ops = [o for o in region.ops
               if o.opname not in ("constant", "alloc", "yield", "return", "time")]

        pipeline = (self.pipeline_loops and loop is not None
                    and loop.opname == "for" and not has_loop_child)
        edges = build_dependence_edges(ops, self.touches.of, self._latency,
                                       loop, carried=pipeline)

        ii = 1 if pipeline else 0
        t: dict[Operation, int] = {}
        while True:
            self.result.search_iters += 1
            got = try_modulo_schedule(ops, edges, ii, self._latency, self.touches.of)
            if got is not None:
                t = got
                break
            ii += 1
            if ii > MAX_II:
                raise RuntimeError(f"HLS: no feasible II <= {MAX_II} for loop in @{f.name}")
        self.result.sched_ops += len(t)

        span = max((t[o] + self._latency(o) for o in ops), default=0)

        # write back starts
        for op, cyc in t.items():
            op.start = Time(root, cyc)
            for r in op.results:
                if ir.is_primitive(r.type):
                    r.birth = Time(root, cyc + self._latency(op))

        # yields / II
        if loop is None:
            return span, 0
        y = next((o for o in region.ops if o.opname == "yield"), None)
        if loop.opname == "unroll_for":
            stagger = self._unroll_stagger(loop, ops, span)
            ytime = Time(root, stagger)
            ii_out = stagger
        else:
            ii_final = ii if pipeline else span
            ii_final = max(1, ii_final)
            ytime = Time(root, ii_final)
            ii_out = ii_final
            self.result.iis[loop.iv.name] = ii_final
        if y is None:
            region.add(ir.yield_op(ytime))
        else:
            y.start = ytime
        return span, ii_out

    def _unroll_stagger(self, loop: ForOp, ops: list[Operation], span: int) -> int:
        """Iterations run in parallel only if every storage touch is banked by
        the unroll IV or broadcast (IV-independent address)."""
        for o in ops:
            for tch in self.touches.of(o):
                if loop.iv in tch.banked_by:
                    continue  # distinct banks per iteration
                if loop.iv not in tch.addr_ivs and not tch.is_write and not isinstance(o, ForOp) \
                        and o.opname != "call":
                    continue  # broadcast read: same address every iteration
                if isinstance(o, ForOp):
                    # nested loop: examine its touches recursively (already in
                    # tch via the MemTouches cache); banked check above applies
                    if loop.iv in tch.banked_by:
                        continue
                    if loop.iv not in tch.addr_ivs and not tch.is_write:
                        continue
                return max(1, span)
        return 0


def hls_schedule(module: Module, pipeline_loops: bool = True) -> HLSResult:
    """Schedule an unscheduled module in place.  ``pipeline_loops=False``
    skips the modulo-II search: every loop runs sequentially (II = body
    span), the natural input for the ``pipeline-loop`` transform pass."""
    return HLSScheduler(module, pipeline_loops=pipeline_loops).run()


def hls_compile(module: Module, entry: Optional[str] = None,
                pipeline: Optional[str] = None, backend: str = "verilog"):
    """Full HLS pipeline: schedule + verify + optimize + netlist codegen.
    Returns (HLSResult, {name: VerilogModule}).

    ``pipeline`` is a textual PassManager spec (default: the paper-benchmark
    optimization pipeline); pass ``""`` to skip optimization.  ``backend``
    selects the netlist printer (``"verilog"`` | ``"systemverilog"`` |
    ``"vhdl"`` | ``"circt"``); the resource summaries are backend-invariant.
    The PassManager used is exposed on the returned HLSResult as
    ``result.pass_manager`` for per-pass statistics (and its
    ``.analysis_manager`` for analysis-cache statistics)."""
    from ..codegen import generate_verilog
    from ..passmgr import DEFAULT_PIPELINE_SPEC, AnalysisManager, PassManager
    from ..verifier import verify

    am = AnalysisManager()
    res = hls_schedule(module)
    verify(module, strict_schedule=False, raise_on_error=False, am=am)
    spec = DEFAULT_PIPELINE_SPEC if pipeline is None else pipeline
    pm = None
    if spec:
        pm = PassManager.from_spec(spec, analysis_manager=am)
        pm.run(module)
        res.pass_manager = pm
    vs = generate_verilog(module, entry=entry, am=am, backend=backend)
    return res, vs
