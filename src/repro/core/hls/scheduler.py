"""HLS-style automatic scheduler — the in-repo stand-in for the paper's
Vivado HLS comparison point (Tables 5 and 6).

Given *unscheduled* HIR (see ``eraser``), this pipeline performs what a
high-level synthesis compiler performs between its IR and RTL:

  1. dependence analysis — the shared ``core.analysis`` edge builder: SSA
     dataflow edges with operation latencies; memory dependence edges per
     tensor (conservative serialization of scopes that share storage,
     distance-1 carried dependences for data-dependent addresses, none for
     iteration-private affine accesses);
  2. operator chaining under a 200 MHz timing model (combinational delays
     accumulate along same-cycle chains up to the clock budget; the clock is
     a :class:`SchedulerOptions` knob so the DSE can trade latency for FF);
  3. modulo scheduling of innermost loops with the shared ``core.schedule``
     engine.  The II search starts at the classical lower bound
     MII = max(resMII, recMII) — resMII from the per-bank access counts,
     recMII from the carried dependence cycles — and probes by galloping +
     binary search between the bound and the first feasible II instead of a
     linear scan from 1 (``SchedulerOptions.linear_scan`` restores the
     reference scan; both produce byte-identical schedules).  Outer loops
     run sequentially (II = iteration latency), Vivado-style;
     ``pipeline_loops=False`` disables the modulo search and emits a fully
     sequential schedule — the input the ``pipeline-loop`` transform pass
     starts from;
  4. unroll-parallelism legality — an ``unroll_for``'s iterations run fully
     parallel (stagger 0) only if every touched storage is either banked by
     the unroll IV (distributed-dim index, including compile-time-constant
     IVs) or broadcast (address independent of the IV); otherwise iterations
     are staggered by the body span;
  5. SDC-style refinement — difference constraints relaxed to fixpoint
     (worklist longest-path over the shared ``SearchState``, seeded from the
     II-independent distance-0 fixpoint instead of from zero);
  6. pipeline balancing — ``hir.delay`` ops inserted so every operand arrives
     exactly at its consumption cycle (shared ``core.schedule.balance_delays``);
  7. emission — yields/iter offsets written back; the result is ordinary
     scheduled HIR consumed by the standard verifier + Verilog backend.

Steps 1–5 are the *search* that HIR's explicit schedules make unnecessary —
the codegen-time gap measured in the Table 6 benchmark is the cost of this
search (no artificial sleeps).

``hls_schedule``/``hls_compile`` additionally memoize whole-function search
results keyed by a structural fingerprint of the unscheduled function (see
``core.hls.dse``), with ``AnalysisManager``-style hit/miss counters on the
returned :class:`HLSResult`."""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional

from .. import ir
from ..analysis import (MemTouches, analyze_loops, build_dependence_edges,
                        op_completion_offset)
from ..ir import ForOp, FuncOp, Module, Operation, Region, Time, Value
from ..schedule import (CLOCK_NS, MAX_II, SearchState, balance_delays,
                        recurrence_mii, try_modulo_schedule)


@dataclass(frozen=True)
class SchedulerOptions:
    """Knobs of one scheduling run — also the per-candidate axes the design
    space explorer (``core.hls.dse``) sweeps.

    ``pipeline_loops``   modulo-pipeline innermost loops (False = fully
                         sequential schedule, the ``pipeline-loop`` pass
                         input);
    ``min_ii``           lower bound imposed on every pipelined loop's II on
                         top of the computed MII (throttling a loop trades
                         latency for ports/banking pressure);
    ``clock_ns``         clock budget for operator chaining — a faster clock
                         breaks chains into more pipeline stages (FF) but
                         shrinks the cycle time;
    ``unroll_parallel``  allow stagger-0 unrolled iterations when banking
                         proves them legal (False = always stagger);
    ``linear_scan``      probe II = MII, MII+1, ... linearly instead of
                         galloping + binary search (reference mode; both
                         find the minimal feasible II of the monotone probe
                         and produce byte-identical schedules)."""

    pipeline_loops: bool = True
    min_ii: int = 1
    clock_ns: float = CLOCK_NS
    unroll_parallel: bool = True
    linear_scan: bool = False

    def __post_init__(self):
        if self.clock_ns <= 0:
            raise ValueError(f"clock_ns must be positive, got {self.clock_ns}")
        if self.min_ii < 1:
            raise ValueError(f"min_ii must be >= 1, got {self.min_ii}")

    def key(self) -> tuple:
        """Hashable identity used in search-cache fingerprints."""
        return (self.pipeline_loops, self.min_ii, round(self.clock_ns, 6),
                self.unroll_parallel, self.linear_scan)


@dataclass
class HLSResult:
    module: Module
    iis: dict[str, int] = field(default_factory=dict)
    search_iters: int = 0
    sched_ops: int = 0
    delays_inserted: int = 0
    # MII lower bound and the actual II probe sequence per pipelined loop IV
    miis: dict[str, int] = field(default_factory=dict)
    ii_probes: dict[str, list[int]] = field(default_factory=dict)
    # body span (end cycle) per scheduled function — the entry's span is the
    # design latency in cycles, which the DSE halving rung scores against
    func_spans: dict[str, int] = field(default_factory=dict)
    # search-cache statistics (AnalysisManager-style): functions whose
    # schedule came from the fingerprint cache vs freshly searched
    search_cache_hits: int = 0
    search_cache_misses: int = 0
    # True when the whole compile was served from the compile-level cache
    from_cache: bool = False
    # the PassManager that optimized the scheduled module (hls_compile only);
    # read .stats_dict() for per-pass timing/rewrite statistics
    pass_manager: Optional[object] = None

    def search_cache_stats(self) -> dict:
        return {"hits": self.search_cache_hits,
                "misses": self.search_cache_misses,
                "from_cache": self.from_cache}


class HLSScheduler:
    def __init__(self, module: Module, pipeline_loops: bool = True,
                 options: Optional[SchedulerOptions] = None):
        self.module = module
        self.opts = (options if options is not None
                     else SchedulerOptions(pipeline_loops=pipeline_loops))
        self.result = HLSResult(module)
        self.loop_latency: dict[ForOp, int] = {}
        self.touches = MemTouches()

    @property
    def pipeline_loops(self) -> bool:  # back-compat accessor
        return self.opts.pipeline_loops

    # ------------------------------------------------------------------
    def run(self) -> HLSResult:
        funcs = [f for f in self.module.funcs.values()
                 if not f.attrs.get("external")]
        for f in _callee_first(funcs):
            sync_call_delays(self.module, f)
            self.schedule_func(f)
        return self.result

    def schedule_func(self, f: FuncOp) -> HLSResult:
        """Schedule one function in place (search + pipeline balancing +
        result-delay reconciliation)."""
        span, _ = self._schedule_region(f, f.body, f.time_var, None)
        self.result.func_spans[f.name] = span
        self.result.delays_inserted += balance_delays(f)
        self.result.delays_inserted += reconcile_result_delays(self.module, f)
        return self.result

    def _latency(self, op: Operation) -> int:
        if op.opname == "mem_read":
            return op.operands[0].type.read_latency()
        if op.opname == "mem_write":
            return 1
        if op.opname == "call":
            ds = op.attrs.get("result_delays", ())
            return max(ds) if ds else 0
        if isinstance(op, ForOp):
            return self.loop_latency.get(op, 1)
        if op.opname in ir.ARITH_OPS:
            return op.attrs.get("stages", 0)
        return 0

    # -- II search ------------------------------------------------------
    def _search_ii(self, f: FuncOp, ops, edges, state: SearchState,
                   mii: int) -> tuple[int, dict, list[int]]:
        """Find the minimal feasible II >= mii.  Feasibility of the list-
        scheduling probe is monotone in II on everything we generate (more
        congruence classes and looser carried bounds never hurt), so instead
        of the linear scan we gallop upward from the MII bound (+1, +2, +4,
        ...) to bracket the first feasible II, then binary-search the
        bracket.  ``linear_scan`` keeps the reference scan for A/B tests —
        the probe count changes, the resulting schedule does not."""
        probes: list[int] = []

        def probe(ii: int):
            self.result.search_iters += 1
            probes.append(ii)
            return try_modulo_schedule(ops, edges, ii, self._latency,
                                       self.touches.of, state=state)

        if self.opts.linear_scan:
            ii = mii
            while True:
                got = probe(ii)
                if got is not None:
                    return ii, got, probes
                ii += 1
                if ii > MAX_II:
                    raise RuntimeError(
                        f"HLS: no feasible II <= {MAX_II} for loop in @{f.name}")

        got = probe(mii)
        if got is not None:
            return mii, got, probes
        # gallop: bracket the first feasible II in (last_bad, hi]
        last_bad, step = mii, 1
        while True:
            cand = min(last_bad + step, MAX_II)
            got = probe(cand)
            if got is not None:
                hi, t_hi = cand, got
                break
            last_bad = cand
            if cand >= MAX_II:
                raise RuntimeError(
                    f"HLS: no feasible II <= {MAX_II} for loop in @{f.name}")
            step *= 2
        # binary search the bracket for the minimal feasible II
        while hi - last_bad > 1:
            mid = (hi + last_bad) // 2
            got = probe(mid)
            if got is not None:
                hi, t_hi = mid, got
            else:
                last_bad = mid
        return hi, t_hi, probes

    # -- region scheduling ----------------------------------------------------
    def _schedule_region(self, f: FuncOp, region: Region, root: Value,
                         loop: Optional[ForOp]) -> tuple[int, int]:
        """Returns (span, ii_or_stagger)."""
        # bottom-up: nested loops first
        has_loop_child = False
        for op in region.ops:
            if isinstance(op, ForOp):
                has_loop_child = True
                span_c, ii_c = self._schedule_region(f, op.region(0), op.time_var, op)
                trip = op.trip_count() or 1
                if op.opname == "unroll_for":
                    self.loop_latency[op] = trip * ii_c + (span_c if ii_c == 0 else max(0, span_c - ii_c))
                else:
                    self.loop_latency[op] = trip * ii_c + max(0, span_c - ii_c)

        ops = [o for o in region.ops
               if o.opname not in ("constant", "alloc", "yield", "return", "time")]

        pipeline = (self.opts.pipeline_loops and loop is not None
                    and loop.opname == "for" and not has_loop_child)
        edges = build_dependence_edges(ops, self.touches.of, self._latency,
                                       loop, carried=pipeline)
        state = SearchState(ops, edges, self._latency, self.touches.of,
                            clock_ns=self.opts.clock_ns)

        if pipeline:
            mii = max(1, self.opts.min_ii, state.res_mii,
                      recurrence_mii(ops, edges))
            ii, t, probes = self._search_ii(f, ops, edges, state, mii)
            if loop is not None:
                self.result.miis[loop.iv.name] = mii
                self.result.ii_probes[loop.iv.name] = probes
        else:
            # sequential region: ii = 0 (carried edges inactive); escalate
            # linearly on the (rare) horizon failure, as the seed did
            ii = 0
            while True:
                self.result.search_iters += 1
                t = try_modulo_schedule(ops, edges, ii, self._latency,
                                        self.touches.of, state=state)
                if t is not None:
                    break
                ii += 1
                if ii > MAX_II:
                    raise RuntimeError(
                        f"HLS: no feasible II <= {MAX_II} for loop in @{f.name}")
        self.result.sched_ops += len(t)

        span = max((t[o] + self._latency(o) for o in ops), default=0)

        # write back starts
        for op, cyc in t.items():
            op.start = Time(root, cyc)
            for r in op.results:
                if ir.is_primitive(r.type):
                    r.birth = Time(root, cyc + self._latency(op))

        # yields / II
        if loop is None:
            return span, 0
        y = next((o for o in region.ops if o.opname == "yield"), None)
        if loop.opname == "unroll_for":
            stagger = self._unroll_stagger(loop, ops, span)
            ytime = Time(root, stagger)
            ii_out = stagger
        else:
            ii_final = ii if pipeline else span
            ii_final = max(1, ii_final)
            ytime = Time(root, ii_final)
            ii_out = ii_final
            self.result.iis[loop.iv.name] = ii_final
        if y is None:
            region.add(ir.yield_op(ytime))
        else:
            y.start = ytime
        return span, ii_out

    def _unroll_stagger(self, loop: ForOp, ops: list[Operation], span: int) -> int:
        """Iterations run in parallel only if every storage touch is banked by
        the unroll IV or broadcast (IV-independent address).  Touches of
        nested loops and calls are their bodies' summaries (``MemTouches``),
        so the same two tests decide them — the seed duplicated both tests in
        an unreachable ``isinstance(o, ForOp)`` branch after already
        ``continue``-ing on them."""
        if not self.opts.unroll_parallel:
            return max(1, span)
        for o in ops:
            for tch in self.touches.of(o):
                if loop.iv in tch.banked_by:
                    continue  # distinct banks per iteration
                if loop.iv not in tch.addr_ivs and not tch.is_write:
                    continue  # broadcast read: same address every iteration
                return max(1, span)
        return 0


def _callee_first(funcs: list[FuncOp]) -> list[FuncOp]:
    """Topological order over the intra-module call graph (callees before
    callers), so every caller is scheduled against its callees' *final*
    declared result delays.  Cycles (recursion) fall back to input order."""
    names = {f.name for f in funcs}
    by_name = {f.name: f for f in funcs}
    callees = {
        f.name: sorted({op.attrs["callee"] for op in f.body.walk()
                        if op.opname == "call"
                        and op.attrs.get("callee") in names})
        for f in funcs}
    order: list[FuncOp] = []
    done: set[str] = set()

    def visit(name: str, path: frozenset) -> None:
        if name in done or name in path:
            return
        for c in callees[name]:
            visit(c, path | {name})
        done.add(name)
        order.append(by_name[name])

    for f in funcs:
        visit(f.name, frozenset())
    return order


def sync_call_delays(module: Module, f: FuncOp,
                     only_callee: Optional[str] = None) -> int:
    """Refresh ``call`` ops in ``f`` whose callee's declared ``result_delays``
    changed after the call was built (a reschedule may legitimately bump
    them — see :func:`reconcile_result_delays`).  Scheduled calls also get
    their result birth times moved to the new delays.  Returns the number
    of call sites updated."""
    n = 0
    for op in f.body.walk():
        if op.opname != "call":
            continue
        name = op.attrs.get("callee")
        if only_callee is not None and name != only_callee:
            continue
        callee = module.funcs.get(name)
        if callee is None:
            continue
        ds = tuple(callee.attrs.get("result_delays", ()))
        if ds and tuple(op.attrs.get("result_delays", ())) != ds:
            op.attrs["result_delays"] = ds
            if op.start is not None:
                for r, d in zip(op.results, ds):
                    r.birth = op.start + d
            n += 1
    return n


def reconcile_result_delays(module: Module, f: FuncOp) -> int:
    """Make a freshly scheduled function honour its declared result delays.

    A signature's ``result_delays`` are a hardware interface contract:
    every call site latches each result exactly ``delay`` cycles after
    issuing the call.  The schedule search places the body for latency
    alone, so a returned value can complete *earlier* than declared (the
    emitted design would stream data ahead of the caller's latch — splice
    a trailing ``hir.delay`` holding it to the contract) or *later* (the
    declaration is unachievable at this clock — bump it and refresh every
    call site in the module; callers scheduled afterwards consume the new
    delay).  Returns the number of delays inserted."""
    declared = list(f.attrs.get("result_delays", ()))
    if not declared:
        return 0
    ret = next((op for op in f.body.ops if op.opname == "return"), None)
    if ret is None or not ret.operands:
        return 0
    loops = analyze_loops(f)
    inserted, bumped = 0, False
    splice: list[Operation] = []
    for i, val in enumerate(list(ret.operands)):
        if i >= len(declared):
            break
        dop = val.defining_op
        ach = (None if dop is None
               else op_completion_offset(dop, f.time_var, loops))
        if ach is None:
            continue
        if ach < declared[i]:
            d = ir.delay(val, declared[i] - ach, Time(f.time_var, ach))
            d.parent_region = f.body
            splice.append(d)
            ret.operands[i] = d.result
            inserted += 1
        elif ach > declared[i]:
            declared[i] = ach
            bumped = True
    if splice:
        pos = f.body.ops.index(ret)
        f.body.ops[pos:pos] = splice
    if bumped:
        f.attrs["result_delays"] = tuple(declared)
        for g in module.funcs.values():
            if g is not f and not g.attrs.get("external"):
                sync_call_delays(module, g, only_callee=f.name)
    return inserted


def _cache_enabled() -> bool:
    return os.environ.get("REPRO_HLS_CACHE", "1") != "0"


def hls_schedule(module: Module, pipeline_loops: bool = True,
                 options: Optional[SchedulerOptions] = None,
                 cache=None, max_workers: int = 1) -> HLSResult:
    """Schedule an unscheduled module in place.  ``pipeline_loops=False``
    skips the modulo-II search: every loop runs sequentially (II = body
    span), the natural input for the ``pipeline-loop`` transform pass.

    ``options`` overrides all knobs (see :class:`SchedulerOptions`);
    ``cache`` is a ``core.hls.dse.ScheduleCache`` (or ``True`` for the
    process-wide default) memoizing whole-function searches by structural
    fingerprint — default off, so benchmarks measuring the cold search stay
    honest; ``max_workers > 1`` schedules independent functions in parallel
    on a process pool (degrading gracefully to serial when the pool is
    unavailable or the worker count is 1 — output is deterministic and
    identical either way)."""
    from . import dse

    opts = (options if options is not None
            else SchedulerOptions(pipeline_loops=pipeline_loops))
    result = HLSResult(module)
    cache_obj = None
    if cache is not None and cache is not False and _cache_enabled():
        cache_obj = dse.SCHEDULE_CACHE if cache is True else cache

    funcs = [f for f in module.funcs.values() if not f.attrs.get("external")]
    names = {f.name for f in funcs}
    cross_calls = any(op.attrs.get("callee") in names
                      for f in funcs for op in f.body.walk()
                      if op.opname == "call")

    if max_workers > 1 and len(funcs) > 1 and not cross_calls:
        # flat call graph: no result-delay propagation between these
        # functions, so the fingerprint pass and the process-pool search
        # are both safe to run on the pre-schedule module wholesale
        todo: list[tuple[FuncOp, Optional[str]]] = []
        for f in funcs:
            key = None
            if cache_obj is not None:
                key = dse.fingerprint_func(f, extra=opts.key())
                hit = cache_obj.get(key)
                if hit is not None:
                    dse.apply_cached_schedule(module, f, hit)
                    _merge_func_meta(result, hit.meta)
                    result.search_cache_hits += 1
                    continue
                result.search_cache_misses += 1
            todo.append((f, key))
        scheduled = (dse.schedule_funcs_parallel(
            module, [f.name for f, _ in todo], opts, max_workers)
            if len(todo) > 1 else None)
        if scheduled is not None:
            for (f, key), (text, meta) in zip(todo, scheduled):
                dse.splice_func_text(module, f.name, text)
                _merge_func_meta(result, meta)
                if cache_obj is not None and key is not None:
                    cache_obj.put(key, text, meta)
            return result
        # pool unavailable (or a single miss): fall through serially with
        # the cache lookups above already resolved
        work = todo
    else:
        # serial path: callee-first so each caller is fingerprinted and
        # scheduled only after its callees' declared delays are final
        work = None

    for item in (work if work is not None else _callee_first(funcs)):
        if work is not None:
            f, key = item
        else:
            f = item
            sync_call_delays(module, f)
            key = None
            if cache_obj is not None:
                key = dse.fingerprint_func(f, extra=opts.key())
                hit = cache_obj.get(key)
                if hit is not None:
                    dse.apply_cached_schedule(module, f, hit)
                    _merge_func_meta(result, hit.meta)
                    result.search_cache_hits += 1
                    continue
                result.search_cache_misses += 1
        s = HLSScheduler(module, options=opts)
        s.schedule_func(f)
        meta = _func_meta(s.result)
        _merge_func_meta(result, meta)
        if cache_obj is not None and key is not None:
            from ..printer import print_func
            cache_obj.put(key, print_func(f), meta, f)
    return result


def _func_meta(r: HLSResult) -> dict:
    return {"iis": dict(r.iis), "miis": dict(r.miis),
            "ii_probes": {k: list(v) for k, v in r.ii_probes.items()},
            "search_iters": r.search_iters, "sched_ops": r.sched_ops,
            "delays_inserted": r.delays_inserted,
            "func_spans": dict(r.func_spans)}


def _merge_func_meta(result: HLSResult, meta: dict) -> None:
    result.iis.update(meta["iis"])
    result.miis.update(meta["miis"])
    result.ii_probes.update(meta["ii_probes"])
    result.search_iters += meta["search_iters"]
    result.sched_ops += meta["sched_ops"]
    result.delays_inserted += meta["delays_inserted"]
    # .get: disk-cache entries written by older builds lack func_spans
    result.func_spans.update(meta.get("func_spans", {}))


def hls_compile(module: Module, entry: Optional[str] = None,
                pipeline: Optional[str] = None, backend: str = "verilog",
                pipeline_loops: bool = True,
                options: Optional[SchedulerOptions] = None,
                cache: bool = True, max_workers: int = 1,
                hierarchy: str = "inline"):
    """Full HLS pipeline: schedule + verify + optimize + netlist codegen.
    Returns (HLSResult, {name: VerilogModule}).

    ``pipeline`` is a textual PassManager spec (default: the paper-benchmark
    optimization pipeline); pass ``""`` to skip optimization.  ``backend``
    selects the netlist printer (``"verilog"`` | ``"systemverilog"`` |
    ``"vhdl"`` | ``"circt"``); the resource summaries are backend-invariant.
    ``pipeline_loops=False`` (or a full :class:`SchedulerOptions` via
    ``options``, which takes precedence) reaches the scheduler, so callers
    can drive the sequential-schedule + ``pipeline-loop``-pass path
    end-to-end.  The PassManager used is exposed on the returned HLSResult
    as ``result.pass_manager`` for per-pass statistics (and its
    ``.analysis_manager`` for analysis-cache statistics).

    Repeated compiles of a structurally-identical module are served from the
    process-wide compile cache (scheduled HIR + netlists keyed by module
    fingerprint, ``result.from_cache``); when ``REPRO_HLS_CACHE_DIR`` is
    set, misses also consult a persistent on-disk cache so warm compiles
    survive process restarts (size-capped, see ``dse.DiskCompileCache``).
    Below the whole-module layer, codegen is *per-function incremental*:
    whole-module misses still reuse every untouched function's lowered RTL
    and printed text from ``dse.FUNC_CODEGEN_CACHE``, so editing one
    ``hir.func`` recompiles only that function (PR 8).  Set ``cache=False``
    or ``REPRO_HLS_CACHE=0`` to disable every cache layer.

    ``hierarchy`` selects flattened (``"inline"``) or modular
    (``"modules"``) emission, forwarded to ``generate_verilog``."""
    from ..codegen import generate_verilog
    from ..passmgr import DEFAULT_PIPELINE_SPEC, AnalysisManager, PassManager
    from ..verifier import verify
    from . import dse

    opts = (options if options is not None
            else SchedulerOptions(pipeline_loops=pipeline_loops))
    spec = DEFAULT_PIPELINE_SPEC if pipeline is None else pipeline
    use_cache = cache and _cache_enabled()
    ckey = None
    if use_cache:
        ckey = dse.fingerprint_module(
            module, extra=(entry, spec, backend, opts.key(), hierarchy))
        hit = dse.COMPILE_CACHE.get(ckey)
        if hit is not None:
            dse.replace_module_contents(module, hit.module)
            res = HLSResult(module, from_cache=True,
                            search_cache_hits=len(hit.meta["funcs"]))
            for meta in hit.meta["funcs"]:
                _merge_func_meta(res, meta)
            return res, dict(hit.netlists)
        disk = dse.disk_cache()
        if disk is not None:
            dhit = disk.get(ckey)
            if dhit is not None:
                dmod, dnets, dmeta = dhit
                # promote to the in-memory cache so later compiles in this
                # process skip the disk round trip too
                dse.COMPILE_CACHE.put(ckey, dmod, dnets, dmeta)
                dse.replace_module_contents(module, dmod)
                res = HLSResult(module, from_cache=True,
                                search_cache_hits=len(dmeta["funcs"]))
                for meta in dmeta["funcs"]:
                    _merge_func_meta(res, meta)
                return res, dnets

    am = AnalysisManager()
    res = hls_schedule(module, options=opts,
                       cache=(True if use_cache else None),
                       max_workers=max_workers)
    verify(module, strict_schedule=False, raise_on_error=False, am=am)
    pm = None
    if spec:
        pm = PassManager.from_spec(spec, analysis_manager=am)
        pm.run(module)
        res.pass_manager = pm
    vs = generate_verilog(module, entry=entry, am=am, backend=backend,
                          hierarchy=hierarchy,
                          func_cache=(dse.FUNC_CODEGEN_CACHE if use_cache
                                      else None),
                          cache_key_extra=(spec, opts.key()),
                          max_workers=max_workers)
    if use_cache and ckey is not None:
        meta = {"funcs": [_func_meta(res)]}
        dse.COMPILE_CACHE.put(ckey, module, vs, meta)
        disk = dse.disk_cache()
        if disk is not None:
            disk.put(ckey, module, vs, meta)
    return res, vs
